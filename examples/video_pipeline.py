"""Video-rate line detection: the paper's deployment loop, batched + streamed.

The paper targets ~300 ms/frame at 50 MHz (a frame every 4 m at 50 km/h).
This runs the detector over a drifting synthetic stream through the
batched/streamed fast path — frames are staged into batches, dispatched as
one kernel launch each, and double-buffered so the host decodes batch k+1
while the device computes batch k — and reports frames/s plus the
heterogeneous placement plan the offload planner derives for this
resolution (the paper's core/accelerator split, computed not hand-chosen).

    PYTHONPATH=src python examples/video_pipeline.py --frames 16 --batch 4
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (
    HoughConfig, LineDetector, PipelineConfig, plan_line_detection,
)
from repro.data.images import frame_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=16)
    ap.add_argument("--height", type=int, default=240)
    ap.add_argument("--width", type=int, default=320)
    ap.add_argument("--batch", type=int, default=4,
                    help="frames per device dispatch (1 = unbatched)")
    ap.add_argument("--no-compact", action="store_true",
                    help="disable the edge-compaction Hough fast path")
    args = ap.parse_args()

    print("offload plan (paper §4.4 partition, derived):")
    for p in plan_line_detection(args.height, args.width):
        print(f"  {p.stage:18s} -> {p.unit.upper():4s} ({p.reason})")

    det = LineDetector(PipelineConfig(
        hough=HoughConfig(compact=not args.no_compact)
    ))
    # warmup / compile at the steady-state batch shape
    warm = [
        s.image for s in frame_stream(args.batch, args.height, args.width)
    ]
    jax.block_until_ready(
        det.detect_batch(jnp.asarray(warm, jnp.float32)).lines
    )

    t0 = time.time()
    detected = 0
    stream = (
        s.image
        for s in frame_stream(args.frames, args.height, args.width, seed=2)
    )
    for res in det.detect_stream(stream, batch_size=args.batch):
        detected += int(res.valid.sum())
    dt = time.time() - t0
    print(f"\n{args.frames} frames in {dt:.2f}s -> "
          f"{args.frames/dt:.1f} frames/s "
          f"({1000*dt/args.frames:.1f} ms/frame; paper target ~300 ms); "
          f"batch={args.batch}, compact={not args.no_compact}; "
          f"{detected} line detections")


if __name__ == "__main__":
    main()
