"""Video-rate line detection: the paper's deployment loop, batched + streamed.

The paper targets ~300 ms/frame at 50 MHz (a frame every 4 m at 50 km/h).
This runs the detector over a drifting synthetic stream through the
batched/streamed fast path — frames are staged into batches, dispatched as
one kernel launch each, and double-buffered so the host decodes batch k+1
while the device computes batch k — and reports frames/s plus the
heterogeneous placement plan the offload planner derives for this
resolution (the paper's core/accelerator split, computed not hand-chosen).

``--scenario`` picks any road-scene family from the scenario engine
(``--scenario mixed`` rotates through all of them — a heterogeneous
stream), detection quality is scored live against the planted ground truth,
and ``--auto-max-edges`` lets the edge-density estimator size the Hough
compaction buffer per batch.

``--deadline-ms`` switches the loop from the raw stream to the
deadline-aware ``DetectionService`` (``serve/detection.py``): every frame
becomes a request with that latency budget, the dispatcher schedules
earliest-deadline-first with early batch close, and the run reports the
miss/shed counts next to throughput — the paper's real-time contract made
observable.  ``--render-overlay`` asks for the per-request phase-3 overlay
on the final frame (the paper's elided image-generation phase, on demand).

``--track`` streams a *drive cycle* (``data/scenarios.py`` ego-motion
sequences) through the session-stateful service path instead: every frame
carries one ``session_id``, the per-session ``LaneTracker``
(``core/tracking.py``) smooths the lanes and coasts through dropout
frames, and the final frame is rendered with the smoothed tracks overlaid
— tracked vs per-frame F1 are reported side by side.

    PYTHONPATH=src python examples/video_pipeline.py --frames 16 --batch 4 \
        --scenario mixed --auto-max-edges --deadline-ms 500
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    HoughConfig, LineDetector, PipelineConfig, aggregate_scores,
    peak_segments, plan_line_detection, score_frame, tracks_as_peaks,
)
from repro.core.lines import render_lines
from repro.data import scenario_names, scenario_stream, standard_drive_cycle


def serve_with_tracking(args, cfg: PipelineConfig) -> None:
    """Session-stateful streaming: every frame of a drive cycle rides one
    ``session_id`` through the DetectionService, the per-session
    LaneTracker smooths/coasts the lanes, and the final frame is rendered
    with the SMOOTHED tracks overlaid (the temporal layer made visible)."""
    from repro.serve.detection import DetectionRequest, DetectionService

    family = "converging" if args.scenario == "mixed" else args.scenario
    cyc = standard_drive_cycle(family, args.frames, args.height, args.width,
                               seed=2)
    shape = (args.height, args.width)
    svc = DetectionService(cfg, buckets=(shape,), batch_size=args.batch)
    svc.detect_many([np.zeros(shape, np.float32)] * args.batch)  # warm
    reqs = [DetectionRequest(uid=i, frame=f.scene.image, session_id="cam0")
            for i, f in enumerate(cyc)]
    t0 = time.time()
    for r in reqs:       # drip-feed: one arrival per engine step
        svc.submit(r)
        svc.step()
    svc.run()
    dt = time.time() - t0
    svc.close()
    per = aggregate_scores([
        score_frame(r.result.peaks, r.result.valid,
                    cyc.frames[r.uid].scene.lines_rho_theta)
        for r in reqs
    ])
    trk = aggregate_scores([
        score_frame(*tracks_as_peaks(r.tracks),
                    cyc.frames[r.uid].scene.lines_rho_theta)
        for r in reqs
    ])
    drops = sum(f.dropout for f in cyc)
    print(f"\n{len(reqs)} drive-cycle frames ({family}, {drops} dropout) "
          f"in {dt:.2f}s -> {len(reqs)/dt:.1f} frames/s through the "
          f"session-stateful service")
    print(f"detection quality: per-frame F1={per['f1']:.2f} vs "
          f"tracked F1={trk['f1']:.2f} "
          f"(smoothing + coasting through dropouts)")
    # overlay the final frame with the SMOOTHED track lines, through the
    # same endpoint convention get_lines uses for detections
    tracks = reqs[-1].tracks
    track_peaks, _ = tracks_as_peaks(tracks)
    lines = peak_segments(track_peaks[:, 0], track_peaks[:, 1],
                          half=float(max(shape)))
    rend = np.asarray(render_lines(
        jnp.asarray(cyc.frames[-1].scene.image),
        lines, jnp.ones(len(tracks), bool),
    ))
    print(f"final-frame overlay from {len(tracks)} smoothed tracks: "
          f"shape {rend.shape}, "
          f"{int((rend[..., 0] == 255).sum())} red line pixels")


def serve_with_deadlines(args, cfg: PipelineConfig) -> None:
    """Drive the stream through the deadline-aware DetectionService:
    per-request latency budgets, EDF dispatch with early batch close, and
    explicit miss accounting instead of silent tail latency."""
    from repro.serve.detection import DetectionRequest, DetectionService

    shape = (args.height, args.width)
    svc = DetectionService(cfg, buckets=(shape,), batch_size=args.batch)
    svc.detect_many([np.zeros(shape, np.float32)] * args.batch)  # warm
    if args.render_overlay:
        # warm the render-bound program too, or its compile lands inside
        # the timed loop and masquerades as a deadline miss
        warm = DetectionRequest(uid=-1, frame=np.zeros(shape, np.float32),
                                render_output=True)
        svc.submit(warm)
        svc.run()
    svc.dispatches = svc.completed = 0
    scenes = list(scenario_stream(args.scenario, args.frames,
                                  args.height, args.width, seed=2))
    reqs = [
        DetectionRequest(
            uid=i, frame=s.image, deadline_s=args.deadline_ms / 1e3,
            render_output=args.render_overlay and i == len(scenes) - 1,
        )
        for i, s in enumerate(scenes)
    ]
    t0 = time.time()
    for r in reqs:       # drip-feed: one arrival per engine step
        svc.submit(r)
        svc.step()
    svc.run()
    dt = time.time() - t0
    svc.close()
    answered = [r for r in reqs if r.ok]
    missed = sum(r.missed_deadline for r in reqs)
    lat = sorted(r.latency_s for r in answered)
    p99 = (f"p99 latency {1e3 * lat[int(0.99 * (len(lat) - 1))]:.1f} ms"
           if lat else "no requests answered")
    print(f"\n{len(reqs)} requests in {dt:.2f}s -> "
          f"{len(reqs)/dt:.1f} req/s at deadline {args.deadline_ms:.0f} ms; "
          f"answered {len(answered)}, shed {svc.shed_deadline}, "
          f"rejected {svc.rejected_queue_full}, late {svc.completed_late} "
          f"-> miss rate {missed/len(reqs):.0%}; {p99}")
    if answered:
        agg = aggregate_scores([
            score_frame(r.result.peaks, r.result.valid,
                        scenes[r.uid].lines_rho_theta)
            for r in answered
        ])
        print(f"detection quality (answered requests): "
              f"F1={agg['f1']:.2f} (P={agg['precision']:.2f} "
              f"R={agg['recall']:.2f})")
    if args.render_overlay and reqs[-1].ok:
        rend = np.asarray(reqs[-1].result.rendered)
        print(f"final-frame overlay: shape {rend.shape}, "
              f"{int((rend[..., 0] == 255).sum())} red line pixels "
              f"(per-request render_output)")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=16)
    ap.add_argument("--height", type=int, default=240)
    ap.add_argument("--width", type=int, default=320)
    ap.add_argument("--batch", type=int, default=4,
                    help="frames per device dispatch (1 = unbatched)")
    ap.add_argument("--no-compact", action="store_true",
                    help="disable the edge-compaction Hough fast path")
    ap.add_argument("--scenario", default="converging",
                    choices=sorted(scenario_names()) + ["mixed"],
                    help="road-scene family (mixed = rotate through all)")
    ap.add_argument("--auto-max-edges", action="store_true",
                    help="size the compaction buffer from the edge-density "
                         "estimate (HoughConfig(max_edges='auto'))")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="serve frames through the deadline-aware "
                         "DetectionService with this latency budget per "
                         "request (EDF + early batch close) and report the "
                         "miss rate")
    ap.add_argument("--render-overlay", action="store_true",
                    help="with --deadline-ms: request the rendered line "
                         "overlay for the final frame (per-request "
                         "render_output)")
    ap.add_argument("--track", action="store_true",
                    help="stream a drive cycle through the session-"
                         "stateful service path (session_id + per-session "
                         "LaneTracker) and overlay the smoothed tracks on "
                         "the final frame")
    args = ap.parse_args()
    if args.track and args.deadline_ms is not None:
        ap.error("--track demonstrates the session-stateful path; run it "
                 "without --deadline-ms")
    if args.render_overlay and args.deadline_ms is None:
        ap.error("--render-overlay demonstrates per-request render on the "
                 "service path; it needs --deadline-ms")
    if args.auto_max_edges and args.no_compact:
        ap.error("--auto-max-edges sizes the compaction buffer; "
                 "it needs compaction on (drop --no-compact)")

    print("offload plan (paper §4.4 partition, derived):")
    for p in plan_line_detection(args.height, args.width):
        print(f"  {p.stage:18s} -> {p.unit.upper():4s} ({p.reason})")

    cfg = PipelineConfig(
        hough=HoughConfig(
            compact=not args.no_compact,
            max_edges="auto" if args.auto_max_edges else None,
        )
    )
    if args.track:
        serve_with_tracking(args, cfg)
        return
    if args.deadline_ms is not None:
        serve_with_deadlines(args, cfg)
        return

    det = LineDetector(cfg)
    if args.auto_max_edges:
        from repro.core import max_edge_tiers
        from repro.kernels.ops import default_max_edges
        # No probe/pinning needed: the detector's plan resolves "auto" ON
        # THE DEVICE — each chunk's edge count picks a compaction tier
        # inside the compiled program (core/plan.py), so a mixed stream
        # never re-resolves or recompiles mid-flight.
        tiers = max_edge_tiers(args.height, args.width)
        print(f"device-side autotune tiers: max_edges in {tiers} "
              f"(hand-tuned default "
              f"{default_max_edges(args.height * args.width)})")

    # warmup / compile at the steady-state batch shape
    warm = [
        s.image
        for s in scenario_stream(args.scenario, args.batch,
                                 args.height, args.width)
    ]
    jax.block_until_ready(
        det.detect_batch(jnp.asarray(warm, jnp.float32)).lines
    )

    # Stream frames through; keep only the tiny (K, 2)/(K,) peak fields
    # per frame (not edges/images — memory stays O(frames * K), and the
    # host never syncs inside the timed window).  Scoring runs after.
    truths, peaks, valids = [], [], []

    def frames():
        for s in scenario_stream(args.scenario, args.frames,
                                 args.height, args.width, seed=2):
            truths.append(s.lines_rho_theta)
            yield s.image

    t0 = time.time()
    for res in det.detect_stream(frames(), batch_size=args.batch):
        peaks.append(res.peaks)
        valids.append(res.valid)
    jax.block_until_ready(peaks[-1])
    dt = time.time() - t0
    agg = aggregate_scores([
        score_frame(p, v, t) for p, v, t in zip(peaks, valids, truths)
    ])
    print(f"\n{args.frames} frames in {dt:.2f}s -> "
          f"{args.frames/dt:.1f} frames/s "
          f"({1000*dt/args.frames:.1f} ms/frame; paper target ~300 ms); "
          f"batch={args.batch}, compact={not args.no_compact}, "
          f"scenario={args.scenario}")
    print(f"detection quality vs planted ground truth: "
          f"F1={agg['f1']:.2f} (P={agg['precision']:.2f} "
          f"R={agg['recall']:.2f}), "
          f"rho err {agg['mean_rho_err']:.1f}px, "
          f"theta err {agg['mean_theta_err_deg']:.1f} deg")


if __name__ == "__main__":
    main()
