"""Video-rate line detection: the paper's deployment loop, batched + streamed.

The paper targets ~300 ms/frame at 50 MHz (a frame every 4 m at 50 km/h).
This runs the detector over a drifting synthetic stream through the
batched/streamed fast path — frames are staged into batches, dispatched as
one kernel launch each, and double-buffered so the host decodes batch k+1
while the device computes batch k — and reports frames/s plus the
heterogeneous placement plan the offload planner derives for this
resolution (the paper's core/accelerator split, computed not hand-chosen).

``--scenario`` picks any road-scene family from the scenario engine
(``--scenario mixed`` rotates through all of them — a heterogeneous
stream), detection quality is scored live against the planted ground truth,
and ``--auto-max-edges`` lets the edge-density estimator size the Hough
compaction buffer per batch.

    PYTHONPATH=src python examples/video_pipeline.py --frames 16 --batch 4 \
        --scenario mixed --auto-max-edges
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import (
    HoughConfig, LineDetector, PipelineConfig, aggregate_scores,
    plan_line_detection, score_frame,
)
from repro.data import scenario_names, scenario_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=16)
    ap.add_argument("--height", type=int, default=240)
    ap.add_argument("--width", type=int, default=320)
    ap.add_argument("--batch", type=int, default=4,
                    help="frames per device dispatch (1 = unbatched)")
    ap.add_argument("--no-compact", action="store_true",
                    help="disable the edge-compaction Hough fast path")
    ap.add_argument("--scenario", default="converging",
                    choices=sorted(scenario_names()) + ["mixed"],
                    help="road-scene family (mixed = rotate through all)")
    ap.add_argument("--auto-max-edges", action="store_true",
                    help="size the compaction buffer from the edge-density "
                         "estimate (HoughConfig(max_edges='auto'))")
    args = ap.parse_args()
    if args.auto_max_edges and args.no_compact:
        ap.error("--auto-max-edges sizes the compaction buffer; "
                 "it needs compaction on (drop --no-compact)")

    print("offload plan (paper §4.4 partition, derived):")
    for p in plan_line_detection(args.height, args.width):
        print(f"  {p.stage:18s} -> {p.unit.upper():4s} ({p.reason})")

    det = LineDetector(PipelineConfig(
        hough=HoughConfig(
            compact=not args.no_compact,
            max_edges="auto" if args.auto_max_edges else None,
        )
    ))
    if args.auto_max_edges:
        from repro.core import max_edge_tiers
        from repro.kernels.ops import default_max_edges
        # No probe/pinning needed: the detector's plan resolves "auto" ON
        # THE DEVICE — each chunk's edge count picks a compaction tier
        # inside the compiled program (core/plan.py), so a mixed stream
        # never re-resolves or recompiles mid-flight.
        tiers = max_edge_tiers(args.height, args.width)
        print(f"device-side autotune tiers: max_edges in {tiers} "
              f"(hand-tuned default "
              f"{default_max_edges(args.height * args.width)})")

    # warmup / compile at the steady-state batch shape
    warm = [
        s.image
        for s in scenario_stream(args.scenario, args.batch,
                                 args.height, args.width)
    ]
    jax.block_until_ready(
        det.detect_batch(jnp.asarray(warm, jnp.float32)).lines
    )

    # Stream frames through; keep only the tiny (K, 2)/(K,) peak fields
    # per frame (not edges/images — memory stays O(frames * K), and the
    # host never syncs inside the timed window).  Scoring runs after.
    truths, peaks, valids = [], [], []

    def frames():
        for s in scenario_stream(args.scenario, args.frames,
                                 args.height, args.width, seed=2):
            truths.append(s.lines_rho_theta)
            yield s.image

    t0 = time.time()
    for res in det.detect_stream(frames(), batch_size=args.batch):
        peaks.append(res.peaks)
        valids.append(res.valid)
    jax.block_until_ready(peaks[-1])
    dt = time.time() - t0
    agg = aggregate_scores([
        score_frame(p, v, t) for p, v, t in zip(peaks, valids, truths)
    ])
    print(f"\n{args.frames} frames in {dt:.2f}s -> "
          f"{args.frames/dt:.1f} frames/s "
          f"({1000*dt/args.frames:.1f} ms/frame; paper target ~300 ms); "
          f"batch={args.batch}, compact={not args.no_compact}, "
          f"scenario={args.scenario}")
    print(f"detection quality vs planted ground truth: "
          f"F1={agg['f1']:.2f} (P={agg['precision']:.2f} "
          f"R={agg['recall']:.2f}), "
          f"rho err {agg['mean_rho_err']:.1f}px, "
          f"theta err {agg['mean_theta_err_deg']:.1f} deg")


if __name__ == "__main__":
    main()
