"""Video-rate line detection: the paper's deployment loop with throughput.

The paper targets ~300 ms/frame at 50 MHz (a frame every 4 m at 50 km/h).
This runs the detector over a drifting synthetic stream and reports
frames/s plus the heterogeneous placement plan the offload planner derives
for this resolution (the paper's core/accelerator split, computed not
hand-chosen).

    PYTHONPATH=src python examples/video_pipeline.py --frames 16
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.core import LineDetector, PipelineConfig, plan_line_detection
from repro.data.images import frame_stream


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=16)
    ap.add_argument("--height", type=int, default=240)
    ap.add_argument("--width", type=int, default=320)
    args = ap.parse_args()

    print("offload plan (paper §4.4 partition, derived):")
    for p in plan_line_detection(args.height, args.width):
        print(f"  {p.stage:18s} -> {p.unit.upper():4s} ({p.reason})")

    det = LineDetector(PipelineConfig())
    # warmup / compile
    first = next(frame_stream(1, args.height, args.width))
    jax.block_until_ready(det.detect(jnp.asarray(first.image, jnp.float32)))

    t0 = time.time()
    detected = 0
    for scene in frame_stream(args.frames, args.height, args.width, seed=2):
        res = det.detect(jnp.asarray(scene.image, jnp.float32))
        detected += int(res.valid.sum())
    dt = time.time() - t0
    print(f"\n{args.frames} frames in {dt:.2f}s -> "
          f"{args.frames/dt:.1f} frames/s "
          f"({1000*dt/args.frames:.1f} ms/frame; paper target ~300 ms); "
          f"{detected} line detections")


if __name__ == "__main__":
    main()
