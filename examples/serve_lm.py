"""Continuous-batching serving demo: requests of mixed lengths share slots.

    PYTHONPATH=src python examples/serve_lm.py --arch falcon-mamba-7b
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:])
