"""Quickstart: detect lane lines in a synthetic road frame (the paper's app).

    PYTHONPATH=src python examples/quickstart.py [--out lines.png]
"""

import argparse
import math

import jax.numpy as jnp
import numpy as np

from repro.core import CannyConfig, LineDetector, PipelineConfig
from repro.data.images import synthetic_road


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None, help="write rendered PNG here")
    ap.add_argument("--integer", action="store_true",
                    help="paper §4.4 integer pipeline")
    ap.add_argument("--fused", action="store_true",
                    help="beyond-paper fused 7x7 single-pass masks")
    args = ap.parse_args()

    scene = synthetic_road(240, 320, seed=3)
    det = LineDetector(PipelineConfig(
        canny=CannyConfig(integer=args.integer, fused=args.fused),
        render_output=args.out is not None,
    ))
    res = det.detect(jnp.asarray(
        scene.image, jnp.int32 if args.integer else jnp.float32))

    print("planted lines (rho, theta_deg):")
    for rho, theta in scene.lines_rho_theta:
        print(f"  rho={float(rho):7.1f}  theta={math.degrees(float(theta)):6.1f}")
    print("detected lines:")
    for (rho, theta), ok in zip(np.asarray(res.peaks), np.asarray(res.valid)):
        if ok:
            print(f"  rho={float(rho):7.1f}  theta={math.degrees(float(theta)):6.1f}")

    if args.out:
        from PIL import Image
        Image.fromarray(np.asarray(res.rendered)).save(args.out)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
