"""End-to-end training driver: train an LM on the synthetic token pipeline.

Default trains a ~20M-param yi-family model for 200 steps on CPU (a few
minutes); ``--preset 100m --steps 300`` is the assignment-scale run.  The
loop exercises the full production path: sharded state on the host mesh,
prefetching resumable data, async checkpoints, resume.

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300 \
        --ckpt /tmp/lm_ckpt
    # kill it mid-run, then resume exactly:
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300 \
        --ckpt /tmp/lm_ckpt --resume
"""

import sys

from repro.launch.train import main

if __name__ == "__main__":
    argv = sys.argv[1:]
    if not any(a.startswith("--steps") for a in argv):
        argv += ["--steps", "200"]
    if not any(a.startswith("--preset") for a in argv):
        argv += ["--preset", "smoke"]
    main(argv)
