"""Shared benchmark utilities: warmed, synchronized wall-time measurement."""

from __future__ import annotations

import csv
import os
import time
from typing import Callable

import jax

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")


def timeit_us(fn: Callable, *args, warmup: int = 2, repeats: int = 5,
              **kw) -> float:
    """Mean wall microseconds of fn(*args) with device sync (paper method:
    averaged repeats, explicit completion boundaries)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats * 1e6


def write_csv(name: str, header: list[str], rows: list[list]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name + ".csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def print_table(title: str, header: list[str], rows: list[list]) -> None:
    print(f"\n== {title} ==")
    widths = [
        max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows else len(h)
        for i, h in enumerate(header)
    ]
    print("  ".join(str(h).ljust(w) for h, w in zip(header, widths)))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
