"""Shared benchmark utilities: warmed, synchronized wall-time measurement."""

from __future__ import annotations

import csv
import datetime
import os
import subprocess
import time
from typing import Callable

import jax

OUT_DIR = os.environ.get("REPRO_BENCH_OUT", "experiments/bench")


def timeit_us(fn: Callable, *args, warmup: int = 2, repeats: int = 5,
              min_wall_s: float = 0.0, **kw) -> float:
    """Mean wall microseconds of fn(*args) with device sync (paper method:
    averaged repeats, explicit completion boundaries).

    ``min_wall_s`` keeps repeating past ``repeats`` until that much wall
    time has accumulated — a fast kernel on a noisy host gets enough
    samples that the mean is stable, while a slow one still stops after
    ``repeats`` (comparative gates like fused-vs-staged want equal-noise
    arms, not equal-repeat arms)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    t0 = time.perf_counter()
    n = 0
    out = None
    while n < repeats or (time.perf_counter() - t0) < min_wall_s:
        out = fn(*args, **kw)
        n += 1
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n * 1e6


def run_stamp() -> dict:
    """{"timestamp_utc", "commit"} identifying this benchmark run.

    Every BENCH_*.json carries one so a checked-in result can be traced
    to the commit (and time) that produced it — a number without its
    provenance cannot be re-baselined honestly."""
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__)),
        ).stdout.strip() or None
    except (OSError, subprocess.SubprocessError):
        commit = None
    return {
        "timestamp_utc": datetime.datetime.now(
            datetime.timezone.utc
        ).isoformat(timespec="seconds"),
        "commit": commit,
    }


def stamp_json(payload: dict) -> dict:
    """Return ``payload`` with the run stamp merged under ``"run"``."""
    return {**payload, "run": run_stamp()}


def write_csv(name: str, header: list[str], rows: list[list]) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, name + ".csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    return path


def print_table(title: str, header: list[str], rows: list[list]) -> None:
    print(f"\n== {title} ==")
    widths = [
        max(len(str(h)), *(len(str(r[i])) for r in rows)) if rows else len(h)
        for i, h in enumerate(header)
    ]
    print("  ".join(str(h).ljust(w) for h, w in zip(header, widths)))
    for r in rows:
        print("  ".join(str(c).ljust(w) for c, w in zip(r, widths)))
