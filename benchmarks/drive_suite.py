"""Closed-loop drive benchmark -> ``BENCH_drive.json``.

The trajectory-error counterpart to the F1 suites: every arm drives the
same :class:`repro.data.ClosedLoopCycle` (plant + rigid-warp world
model, drift+gust disturbance, exact analytic truth) and is scored on
**cross-track error in meters**, so a detection failure costs where it
matters — the vehicle's path — not just a scoring-table cell.

Arms, all deterministic (seeded imagery, closed-form disturbance,
virtual clock; a rerun is bit-identical):

  * **blind** — no steering at all (``advance(None)`` every frame); the
    reference drift that any controlled arm must beat by a wide margin.
  * **per_frame** — ``LineDetector`` -> ``LateralController`` straight
    from each frame's raw peaks; dropouts leave only the decayed hold.
  * **tracked** — ``TrackingPipeline`` with the controller hooked in
    (``process(frame, controller=...)``): smoothed tracks steer, and the
    tracker coasts through the mid-transient dropout on predictions.
  * **service** — the session-stateful ``DetectionService`` drives the
    loop through ``submit``/``step``/``drain`` on the virtual clock with
    a real deadline; two overload windows are forced via the grid's
    latency estimator.  With the degradation ladder ON, coasting keeps
    fresh commands flowing (then budget-exhausted refusals hold); with
    the ladder OFF every overload frame is a refusal.  Gate: ladder-on
    strictly beats ladder-off on both max and mean cross-track.

Gates (exit code 1 on any violation; ``benchmarks/run.py --drive``
aggregates them and ``scripts/check_drive.py`` pins the committed
per-family baseline):

  * every tracked arm's max cross-track stays under its family floor;
  * tracked mean cross-track <= per-frame mean on every noisy family
    (the temporal layer must pay in trajectory error exactly where
    per-frame detection degrades);
  * ladder-on < ladder-off on max AND mean cross-track;
  * a repeated tracked run reproduces the identical trajectory.

Usage: PYTHONPATH=src python -m benchmarks.drive_suite [--quick]
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.core import (
    ControlConfig, HoughConfig, LateralController, LineDetector,
    PipelineConfig, TrackingPipeline,
)
from repro.data import NOISY_FAMILIES, standard_closed_loop
from repro.serve.detection import (
    DetectionRequest, DetectionService, RequestStatus, VirtualClock,
)

from .common import print_table

#: Families the committed baseline pins (scripts/check_drive.py): the
#: noisy three — where coasting must pay — plus the clean reference.
GATED_FAMILIES: tuple[str, ...] = NOISY_FAMILIES + ("straight",)

#: Tracked-arm max-cross-track floors, meters.  The lane half-width of
#: the closed-loop world is 0.5 m: a floor below it means the tracked
#: vehicle never leaves its lane.  Committed values sit ~1.5x above the
#: measured maxima (~0.25-0.26 m) so only a real control/perception
#: regression trips them, not float jitter (there is none) or a retuned
#: detector's few-centimeter shift.
MAX_CROSS_TRACK_FLOOR_M: dict[str, float] = {
    "straight": 0.40, "rain": 0.40, "night": 0.40, "glare": 0.40,
}

N_FRAMES = 48           # NOT a --quick knob: the trajectory of a family
                        # is deterministic per cycle, so quick runs must
                        # measure the same number the baseline pins.
DEADLINE_S = 0.08       # service arm per-frame deadline (< frame_dt)
MODEL_COST_S = 0.02     # virtual-clock cost per dispatched batch
OVERLOAD_EST_S = 1.0    # estimator preset that makes dispatch hopeless
#: Two overload windows: one mid-transient (coasting has to carry the
#: recovery) and one in steady state (holding is cheap — the ladder win
#: must come from the hard window, not an easy average).
OVERLOAD_WINDOWS: tuple[range, ...] = (range(8, 14), range(28, 34))


def _cfg() -> PipelineConfig:
    return PipelineConfig(hough=HoughConfig(compact=True, max_edges="auto"))


def _summary(cyc, extra: dict | None = None) -> dict:
    out = {
        "n_frames": cyc.n_frames,
        "max_cross_track_m": cyc.max_cross_track_m,
        "mean_cross_track_m": cyc.mean_cross_track_m,
        "final_cross_track_m": float(abs(cyc.trajectory[-1][1])),
        "trajectory": [
            [int(t), float(e), float(psi), float(k)]
            for t, e, psi, k in cyc.trajectory
        ],
    }
    if extra:
        out.update(extra)
    return out


def drive_blind(family: str, height: int, width: int) -> dict:
    cyc = standard_closed_loop(family, N_FRAMES, height, width, seed=0)
    for _ in range(N_FRAMES):
        cyc.observe()
        cyc.advance(None)
    return _summary(cyc)


def drive_per_frame(family: str, height: int, width: int) -> dict:
    cyc = standard_closed_loop(family, N_FRAMES, height, width, seed=0)
    det = LineDetector(_cfg())
    ctl = LateralController(clock=lambda: float(cyc.t))
    for _ in range(N_FRAMES):
        fr = cyc.observe()
        res = det.detect(np.asarray(fr.scene.image, np.float32))
        cmd = ctl.command(np.asarray(res.peaks), np.asarray(res.valid))
        cyc.advance(cmd.curvature)
    return _summary(cyc, {"fresh_commands": ctl.fresh_commands,
                          "held_commands": ctl.held_commands})


def drive_tracked(family: str, height: int, width: int) -> dict:
    cyc = standard_closed_loop(family, N_FRAMES, height, width, seed=0)
    ctl = LateralController(clock=lambda: float(cyc.t))
    tp = TrackingPipeline(_cfg(), height=height, width=width)
    for _ in range(N_FRAMES):
        fr = cyc.observe()
        tf = tp.process(fr.scene.image, controller=ctl)
        cyc.advance(tf.steering.curvature)
    return _summary(cyc, {"fresh_commands": ctl.fresh_commands,
                          "held_commands": ctl.held_commands})


def drive_service(family: str, height: int, width: int, *,
                  ladder: bool) -> dict:
    """Drive the closed loop through the full serving stack.

    Each frame: advance the virtual clock one frame period, submit the
    rendered frame as a session request with a real deadline, pump the
    service to a terminal state, and feed whatever steering came back —
    fresh fit, coast from predicted tracks, or decayed hold — into the
    plant.  Overload is forced by presetting the grid's measured
    latency estimate inside the windows (the same mechanism the fleet
    suite uses), so both ladder arms see identical offered load.
    """
    clock = VirtualClock()
    svc = DetectionService(
        _cfg(), buckets=((height, width),), batch_size=1, prefetch=False,
        ladder=ladder, steering=ControlConfig(), clock=clock,
    )
    grid = svc.grids[(height, width)]
    cyc = standard_closed_loop(family, N_FRAMES, height, width, seed=0)
    statuses: dict[str, int] = {}
    try:
        for t in range(N_FRAMES):
            clock.advance(cyc.cfg.frame_dt_s)
            overload = any(t in w for w in OVERLOAD_WINDOWS)
            grid.est_s = OVERLOAD_EST_S if overload else MODEL_COST_S
            grid.est_measured = True
            fr = cyc.observe()
            req = DetectionRequest(uid=t, frame=fr.scene.image,
                                   deadline_s=DEADLINE_S,
                                   session_id="ego")
            svc.submit(req)
            svc.step()
            if grid.in_flight is not None:
                clock.advance(MODEL_COST_S)
                svc.drain()
            for _ in range(4):
                if req.is_terminal:
                    break
                svc.step()
                svc.drain()
            assert req.is_terminal, (family, ladder, t, req.status)
            statuses[req.status.name] = statuses.get(req.status.name, 0) + 1
            cmd = req.steering
            cyc.advance(None if cmd is None else cmd.curvature)
    finally:
        svc.close()
    return _summary(cyc, {
        "ladder": ladder,
        "statuses": statuses,
        "overload_frames": sum(len(w) for w in OVERLOAD_WINDOWS),
        "coasts": statuses.get(RequestStatus.DEGRADED_COAST.name, 0),
        "refusals": statuses.get(RequestStatus.DEADLINE_EXCEEDED.name, 0),
    })


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="one noisy family + the clean reference, skip "
                         "the blind arm (cycle length is pinned — quick "
                         "trims arms, never the measurement)")
    ap.add_argument("--height", type=int, default=240)
    ap.add_argument("--width", type=int, default=320)
    ap.add_argument("--out", default="BENCH_drive.json")
    args = ap.parse_args()

    families = (("rain", "straight") if args.quick else GATED_FAMILIES)
    h, w = args.height, args.width

    rows = {}
    for fam in families:
        arms = {
            "per_frame": drive_per_frame(fam, h, w),
            "tracked": drive_tracked(fam, h, w),
        }
        if not args.quick:
            arms["blind"] = drive_blind(fam, h, w)
        rows[fam] = arms

    # determinism: the tracked arm replayed end-to-end must reproduce
    # the identical trajectory — seeded imagery, closed-form
    # disturbance, no wall clock anywhere in the loop
    rerun = drive_tracked(families[0], h, w)
    deterministic = rerun["trajectory"] == rows[families[0]]["tracked"][
        "trajectory"]

    service = {
        "ladder_on": drive_service("straight", h, w, ladder=True),
        "ladder_off": drive_service("straight", h, w, ladder=False),
    }

    print_table(
        f"closed-loop cross-track error, meters ({h}x{w}, "
        f"{N_FRAMES} frames, lane half-width 0.50)",
        ["family", "noisy", "arm", "max", "mean", "final", "fresh",
         "held"],
        [[fam, "*" if fam in NOISY_FAMILIES else "", arm,
          f"{r['max_cross_track_m']:.3f}",
          f"{r['mean_cross_track_m']:.3f}",
          f"{r['final_cross_track_m']:.3f}",
          r.get("fresh_commands", ""), r.get("held_commands", "")]
         for fam in families for arm, r in sorted(rows[fam].items())],
    )
    print_table(
        f"service arm (straight, deadline {DEADLINE_S * 1e3:.0f} ms, "
        f"overload frames "
        f"{sorted(t for wd in OVERLOAD_WINDOWS for t in wd)})",
        ["ladder", "max", "mean", "coasts", "refusals", "statuses"],
        [[name.removeprefix("ladder_"),
          f"{r['max_cross_track_m']:.3f}",
          f"{r['mean_cross_track_m']:.3f}", r["coasts"], r["refusals"],
          json.dumps(r["statuses"], sort_keys=True)]
         for name, r in service.items()],
    )

    gates = {
        "tracked_under_floor": all(
            rows[f]["tracked"]["max_cross_track_m"]
            <= MAX_CROSS_TRACK_FLOOR_M[f]
            for f in families
        ),
        "tracked_le_per_frame_on_noisy": all(
            rows[f]["tracked"]["mean_cross_track_m"]
            <= rows[f]["per_frame"]["mean_cross_track_m"]
            for f in families if f in NOISY_FAMILIES
        ),
        "ladder_on_beats_off": (
            service["ladder_on"]["max_cross_track_m"]
            < service["ladder_off"]["max_cross_track_m"]
            and service["ladder_on"]["mean_cross_track_m"]
            < service["ladder_off"]["mean_cross_track_m"]
        ),
        "deterministic_replay": deterministic,
    }
    if not args.quick:
        # the controlled arms must beat the uncontrolled drift by a wide
        # margin — the loop is genuinely closed, not coasting on a
        # benign world
        gates["controlled_beats_blind"] = all(
            rows[f]["tracked"]["max_cross_track_m"]
            < 0.5 * rows[f]["blind"]["max_cross_track_m"]
            for f in families
        )
    for name, ok in gates.items():
        print(f"gate {name}: {'ok' if ok else 'VIOLATED'}")

    out = {
        "meta": {
            "backend": jax.default_backend(),
            "height": h, "width": w, "n_frames": N_FRAMES,
            "quick": args.quick,
            "deadline_s": DEADLINE_S,
            "overload_windows": [[wd.start, wd.stop]
                                 for wd in OVERLOAD_WINDOWS],
            "floors_m": MAX_CROSS_TRACK_FLOOR_M,
        },
        "families": rows,
        "service": service,
        "gates": gates,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(f"wrote {args.out}")
    if not all(gates.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
