"""Detection quality x throughput across the scenario-engine families.

For every registered road-scene family (``repro/data/scenarios.py``) this
benchmark runs the detector at batch sizes {1, 8} and reports both axes the
ROADMAP cares about:

  * accuracy   — micro-averaged precision/recall/F1 and mean (rho, theta)
    localization error against the family's analytic ground truth
    (``repro/core/metrics.py``), scored over exactly the frames in each
    batch (the contract check uses the 8-seed batch-8 rows);
  * throughput — ms/frame and frames/s for the same batches.

Two detector variants are compared per family:

  * ``hand``  — the PR-1 compacted fast path with the hand-tuned default
    buffer (``max_edges=None`` => H*W/16);
  * ``auto``  — ``HoughConfig(max_edges="auto")``: the device-side autotune
    (``core/plan.py``) picks a compaction tier per batch from the exact
    on-device edge count; the ``buffer`` column reports the host-visible
    estimator tier (``resolve_config``), an upper bound on what runs.

The suite asserts the ROADMAP autotune contract — on every family, ``auto``
matches ``hand`` F1 exactly while allocating a no-larger buffer — and
records both in ``BENCH_scenarios.json``.  A third, score-only family of
rows covers the low-precision gradient tiers (``CannyConfig.grad_dtype``
f16/int8): per-family F1 that ``scripts/check_f1.py`` pins against the
committed baseline and each family's floor.

Usage: PYTHONPATH=src python -m benchmarks.scenario_suite [--quick]
"""

from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.core import (
    CannyConfig, HoughConfig, LineDetector, PipelineConfig, aggregate_scores,
    score_batch,
)
from repro.data import get_family, scenario_batch, scenario_names
from repro.kernels.ops import default_max_edges

from .common import print_table, timeit_us


def _detector(mode: str) -> LineDetector:
    max_edges = "auto" if mode == "auto" else None
    return LineDetector(PipelineConfig(
        hough=HoughConfig(compact=True, max_edges=max_edges)
    ))


def bench_family(name: str, h: int, w: int, *, n_seeds: int, batches,
                 repeats: int) -> list[dict]:
    imgs_np, truths = scenario_batch([name] * n_seeds, h, w, seed=0)
    imgs = jnp.asarray(imgs_np)
    rows = []
    for mode in ("hand", "auto"):
        det = _detector(mode)
        for B in batches:
            # score and time with exactly the configuration this batch
            # size resolves ("auto" sizes its buffer per batch)
            buffer = det.resolve_config(imgs[:B]).hough.max_edges
            if buffer is None:
                buffer = default_max_edges(h * w)
            res = det.detect_batch(imgs[:B])
            agg = aggregate_scores(
                score_batch(res.peaks, res.valid, truths[:B])
            )
            sec = timeit_us(det.detect_batch, imgs[:B], warmup=1,
                            repeats=repeats) / 1e6
            rows.append({
                "scenario": name, "mode": mode, "batch": B,
                "height": h, "width": w,
                "max_edges_buffer": buffer,
                "f1": agg["f1"], "precision": agg["precision"],
                "recall": agg["recall"],
                "mean_rho_err": agg["mean_rho_err"],
                "mean_theta_err_deg": agg["mean_theta_err_deg"],
                "f1_floor": get_family(name).f1_floor,
                "ms_per_frame": sec / B * 1e3,
                "frames_per_s": B / sec,
            })
    return rows


def bench_quantized(name: str, h: int, w: int, *, n_seeds: int
                    ) -> list[dict]:
    """Score-only rows for the low-precision gradient tiers.

    ``CannyConfig.grad_dtype`` drops the gradient accumulation to f16 or
    int8 (per-frame symmetric input quantization) while the threshold
    compare stays f32 — the accelerator's low-precision path.  Accuracy is
    the only axis that can silently move (on this host the low-precision
    ops are emulated, so timing says nothing), so these rows carry F1 per
    family and ``scripts/check_f1.py`` pins them against the committed
    baseline and each family's registered floor.
    """
    imgs_np, truths = scenario_batch([name] * n_seeds, h, w, seed=0)
    imgs = jnp.asarray(imgs_np)
    rows = []
    for grad in ("f16", "int8"):
        det = LineDetector(PipelineConfig(
            canny=CannyConfig(grad_dtype=grad),
            hough=HoughConfig(compact=True, max_edges="auto"),
        ))
        res = det.detect_batch(imgs)
        agg = aggregate_scores(score_batch(res.peaks, res.valid, truths))
        rows.append({
            "scenario": name, "grad_dtype": grad, "batch": n_seeds,
            "height": h, "width": w,
            "f1": agg["f1"], "precision": agg["precision"],
            "recall": agg["recall"],
            "f1_floor": get_family(name).f1_floor,
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timing repeats per family")
    ap.add_argument("--height", type=int, default=240)
    ap.add_argument("--width", type=int, default=320)
    ap.add_argument("--out", default="BENCH_scenarios.json")
    args = ap.parse_args()

    n_seeds = 8  # == max batch: the batch-8 timing cell uses every seed
    repeats = 1 if args.quick else 2
    batches = (1, 8)

    rows, quantized = [], []
    for name in scenario_names():
        rows += bench_family(name, args.height, args.width,
                             n_seeds=n_seeds, batches=batches,
                             repeats=repeats)
        quantized += bench_quantized(name, args.height, args.width,
                                     n_seeds=n_seeds)

    print_table(
        f"scenario suite ({args.height}x{args.width}, {n_seeds} seeds)",
        ["scenario", "mode", "batch", "buffer", "F1", "prec", "recall",
         "rho_err", "th_err", "ms/frame", "frames/s"],
        [[r["scenario"], r["mode"], r["batch"], r["max_edges_buffer"],
          f"{r['f1']:.3f}", f"{r['precision']:.2f}", f"{r['recall']:.2f}",
          f"{r['mean_rho_err']:.2f}", f"{r['mean_theta_err_deg']:.2f}",
          f"{r['ms_per_frame']:.1f}", f"{r['frames_per_s']:.2f}"]
         for r in rows],
    )

    # The ROADMAP autotune contract, checked per family.
    def cell(name, mode):
        return next(r for r in rows
                    if r["scenario"] == name and r["mode"] == mode
                    and r["batch"] == 8)

    autotune = {}
    for name in scenario_names():
        hand, auto = cell(name, "hand"), cell(name, "auto")
        autotune[name] = {
            "f1_hand": hand["f1"], "f1_auto": auto["f1"],
            "buffer_hand": hand["max_edges_buffer"],
            "buffer_auto": auto["max_edges_buffer"],
            "f1_equal": auto["f1"] == hand["f1"],
            "buffer_no_larger": (
                auto["max_edges_buffer"] <= hand["max_edges_buffer"]
            ),
            "above_floor": auto["f1"] >= get_family(name).f1_floor,
        }
    print_table(
        "quantized gradient tiers (batch-8 F1, score only)",
        ["scenario", "grad", "F1", "prec", "recall", "floor"],
        [[r["scenario"], r["grad_dtype"], f"{r['f1']:.3f}",
          f"{r['precision']:.2f}", f"{r['recall']:.2f}",
          f"{r['f1_floor']:.2f}"] for r in quantized],
    )

    ok = all(v["f1_equal"] and v["buffer_no_larger"] and v["above_floor"]
             for v in autotune.values())
    savings = {
        n: 1.0 - v["buffer_auto"] / v["buffer_hand"]
        for n, v in autotune.items()
    }
    print(f"\nautotune contract (F1 equal, buffer no larger, above floor): "
          f"{'PASS' if ok else 'FAIL'}")
    print("auto buffer savings vs hand-tuned: " + ", ".join(
        f"{n}={s:.0%}" for n, s in savings.items()))

    out = {
        "meta": {
            "backend": jax.default_backend(),
            "height": args.height, "width": args.width,
            "n_seeds": n_seeds, "quick": args.quick,
        },
        "rows": rows,
        "quantized": quantized,
        "autotune": autotune,
        "autotune_contract_ok": ok,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(f"wrote {args.out}")
    if not ok:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
