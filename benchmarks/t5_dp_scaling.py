"""Table 5 analogue: data-parallel scaling of an embarrassingly parallel
workload across device counts.

The paper verifies its multicore platforms with a multithreaded array
workload (near-2x on 2 cores).  The framework analogue: the same batched
line-detection step pmapped over 1 / 2 / 4 host devices — each count runs
in a subprocess because jax pins the device count at first init.

Caveat: on a 1-physical-core host the virtual devices time-share, so the
measured "scaling" hovers near 1.0x regardless of device count — the table
then verifies the pmap program's correctness and overhead, not parallel
speedup (which needs as many cores as devices, as in the paper's dual-core
platforms).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

from .common import print_table, write_csv

_SCRIPT = """
import os, time, json
import jax, jax.numpy as jnp
from repro.core import LineDetector, PipelineConfig
from repro.data.images import synthetic_road

n = len(jax.devices())
det = LineDetector(PipelineConfig())
frames = jnp.stack([
    jnp.asarray(synthetic_road(120, 160, seed=i).image, jnp.float32)
    for i in range(n * 4)
]).reshape(n, 4, 120, 160)

step = jax.pmap(jax.vmap(lambda im: det.detect(im).valid))
jax.block_until_ready(step(frames))
t0 = time.perf_counter()
for _ in range(5):
    out = step(frames)
jax.block_until_ready(out)
dt = (time.perf_counter() - t0) / 5
print(json.dumps({"devices": n, "frames_per_s": n * 4 / dt}))
"""


def table5_dp_scaling(device_counts=(1, 2, 4)):
    results = []
    repo_src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    for n in device_counts:
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
        env["PYTHONPATH"] = repo_src
        r = subprocess.run(
            [sys.executable, "-c", textwrap.dedent(_SCRIPT)],
            env=env, capture_output=True, text=True, timeout=900,
        )
        if r.returncode != 0:
            raise RuntimeError(r.stderr[-2000:])
        import json
        line = [l for l in r.stdout.splitlines() if l.startswith("{")][-1]
        results.append(json.loads(line))

    base = results[0]["frames_per_s"]
    header = ["devices", "frames/s", "scaling"]
    rows = [
        [r["devices"], f"{r['frames_per_s']:.1f}",
         f"{r['frames_per_s']/base:.2f}x"]
        for r in results
    ]
    write_csv("t5_dp_scaling", header, rows)
    print_table("Table 5 analogue: DP scaling of parallel workload",
                header, rows)
    return {"scaling_at_max": results[-1]["frames_per_s"] / base}
