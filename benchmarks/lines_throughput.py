"""Batched line-detection throughput: frames/s vs batch size, resolution,
and edge compaction — the perf trajectory of the streaming fast path.

Four measurement families, all on the host's default (xla) kernel path:

  * ``detect_loop``  — the pre-batching baseline: one ``detect`` call per
    frame (batch=1), dense Hough voting.
  * ``detect_batch`` — the fast path: a stack of frames as one jitted
    program, with the edge-compaction pre-pass on and off.
  * per-stage split  — canny / hough / get_lines microseconds per frame at
    batch 1 and 8, so regressions can be pinned to a stage.
  * fused-vs-staged  — the steady-state comparison: a tracker warmed on
    the scene supplies the theta gate and rho corridors, then the gated
    staged plan races its fused twin on the same frames.  This family
    carries a strict gate — the run fails (exit 1) if the fused hot path
    is slower on ANY config — so a regression in the fused kernels can
    never land silently behind a green benchmark.

Emits ``BENCH_lines.json`` in the working directory.

Usage: PYTHONPATH=src python -m benchmarks.lines_throughput [--quick]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HoughConfig, LineDetector, PipelineConfig
from repro.core.tracking import TrackingPipeline
from repro.data.images import synthetic_road

from .common import print_table, stamp_json, timeit_us

# The fused arm's production shape (serve/detection.py defaults): a
# 40-bin theta gate and an 8-slot corridor budget.
FUSED_BAND = 40
FUSED_CORRIDORS = 8


def _frames(n: int, h: int, w: int) -> np.ndarray:
    return np.stack(
        [synthetic_road(h, w, seed=100 + i).image for i in range(n)]
    ).astype(np.float32)


def _time_s(fn, *args, warmup: int = 1, repeats: int = 2) -> float:
    """Mean wall seconds with device sync (paper method)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    out = None
    for _ in range(repeats):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / repeats


def _pipeline(compact: bool) -> PipelineConfig:
    return PipelineConfig(hough=HoughConfig(compact=compact))


def bench_throughput(h: int, w: int, batches, *, quick: bool):
    """frames/s rows for the loop baseline and the batched fast path."""
    rows = []
    imgs = jnp.asarray(_frames(max(batches), h, w))

    det = LineDetector(_pipeline(compact=False))
    n_loop = 1 if quick else 2
    sec = _time_s(
        lambda: [det.detect(f) for f in imgs[:n_loop]][-1],
        warmup=1, repeats=1 if quick else 2,
    ) / n_loop
    rows.append({
        "height": h, "width": w, "mode": "detect_loop", "batch": 1,
        "compact": False, "ms_per_frame": sec * 1e3,
        "frames_per_s": 1.0 / sec,
    })

    for compact in (True, False):
        d = LineDetector(_pipeline(compact))
        for B in batches:
            if quick and not compact and B > 1:
                continue  # dense batched cells dominate quick-run time
            sec = _time_s(
                d.detect_batch, imgs[:B],
                warmup=1, repeats=3 if compact else 1,
            )
            rows.append({
                "height": h, "width": w, "mode": "detect_batch",
                "batch": B, "compact": compact,
                "ms_per_frame": sec / B * 1e3,
                "frames_per_s": B / sec,
            })
    return rows


def bench_stages(h: int, w: int, batches, *, compact: bool):
    """Per-stage microseconds per frame (canny / hough / get_coordinates),
    via the pipeline's own paper-Table-3 stage profiler."""
    rows = []
    det = LineDetector(_pipeline(compact))
    for B in batches:
        imgs = jnp.asarray(_frames(B, h, w))
        prof = det.detect_stage_profiled(imgs, repeats=3)
        us = {name: stat.mean_us for name, stat in prof.phases.items()}
        rows.append({
            "height": h, "width": w, "batch": B, "compact": compact,
            "canny_us_per_frame": us["canny"] / B,
            "hough_us_per_frame": us["hough"] / B,
            "get_lines_us_per_frame": us["get_coordinates"] / B,
        })
    return rows


def bench_fused(h: int, w: int, batches, *, quick: bool):
    """Fused-vs-staged steady state: warmed tracker, strict per-config gate.

    One scene geometry; a ``TrackingPipeline`` replays it 8 frames so the
    tracker confirms and yields a healthy theta gate + rho corridors —
    exactly the state in which ``serve/detection.py`` engages the fused
    plan.  The batch axis models B parallel streams of that scene with
    independent sensor noise (same geometry, so one corridor set covers
    the whole batch, as the service's corridor union does).  Staged and
    fused arms run the same gated plan config and the same inputs; repeats
    are interleaved (staged/fused rounds alternate, best round kept) so
    host noise cannot systematically favor one arm.
    """
    scene = synthetic_road(h, w, seed=100).image.astype(np.float32)
    pipe = TrackingPipeline(
        PipelineConfig(hough=HoughConfig(compact=True, max_edges="auto")),
        height=h, width=w, theta_band=FUSED_BAND,
    )
    for _ in range(8):
        pipe.process(scene)
    bins = pipe.tracker.gate_bins(pipe.n_theta, band=FUSED_BAND)
    cors = pipe.tracker.corridors(FUSED_CORRIDORS)
    if bins is None or cors is None:
        raise RuntimeError(
            "tracker failed to warm on the benchmark scene — the fused "
            "arm needs a healthy gate and corridors"
        )
    bins = jnp.asarray(bins)
    cors = jnp.asarray(cors)
    staged = pipe.gated_plan
    fused = staged.with_fused(FUSED_CORRIDORS)

    rng = np.random.default_rng(7)
    frames = np.stack([
        np.clip(scene + rng.normal(0.0, 6.0, scene.shape), 0, 255)
        for _ in range(max(batches))
    ]).astype(np.float32)
    frames = jnp.asarray(frames)

    rounds = 2 if quick else 3
    min_wall = 0.05 if quick else 0.25
    rows = []
    for B in batches:
        x = frames[:B]
        ts, tf = [], []
        for _ in range(rounds):
            ts.append(timeit_us(staged.run, x, bins, min_wall_s=min_wall))
            tf.append(timeit_us(fused.run, x, bins, cors,
                                min_wall_s=min_wall))
        t_staged, t_fused = min(ts), min(tf)
        rows.append({
            "height": h, "width": w, "batch": B,
            "staged_us_per_frame": t_staged / B,
            "fused_us_per_frame": t_fused / B,
            "fused_speedup": t_staged / t_fused,
            "gate_ok": t_fused <= t_staged,
        })
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer repeats; skip dense batched cells")
    ap.add_argument("--out", default="BENCH_lines.json")
    args = ap.parse_args()

    resolutions = [(120, 160), (240, 320)]
    batches = (1, 4, 8)

    throughput, stages, fused = [], [], []
    for h, w in resolutions:
        throughput += bench_throughput(h, w, batches, quick=args.quick)
        stages += bench_stages(h, w, (1, 8), compact=True)
        if not args.quick:
            stages += bench_stages(h, w, (8,), compact=False)
    for h, w in ((240, 320), (480, 640)):
        fused += bench_fused(h, w, (1, 8), quick=args.quick)

    def fps(mode, B, compact, h, w):
        for r in throughput:
            if (r["mode"], r["batch"], r["compact"],
                    r["height"], r["width"]) == (mode, B, compact, h, w):
                return r["frames_per_s"]
        return None

    base = fps("detect_loop", 1, False, 240, 320)
    fast = fps("detect_batch", 8, True, 240, 320)
    speedup = (fast / base) if (base and fast) else None

    print_table(
        "lines throughput (frames/s)",
        ["HxW", "mode", "batch", "compact", "ms/frame", "frames/s"],
        [[f"{r['height']}x{r['width']}", r["mode"], r["batch"],
          r["compact"], f"{r['ms_per_frame']:.1f}",
          f"{r['frames_per_s']:.2f}"] for r in throughput],
    )
    print_table(
        "per-stage split (us/frame)",
        ["HxW", "batch", "compact", "canny", "hough", "get_lines"],
        [[f"{r['height']}x{r['width']}", r["batch"], r["compact"],
          f"{r['canny_us_per_frame']:.0f}",
          f"{r['hough_us_per_frame']:.0f}",
          f"{r['get_lines_us_per_frame']:.0f}"] for r in stages],
    )
    print_table(
        "fused vs staged (warmed tracker, us/frame)",
        ["HxW", "batch", "staged", "fused", "speedup", "gate"],
        [[f"{r['height']}x{r['width']}", r["batch"],
          f"{r['staged_us_per_frame']:.0f}",
          f"{r['fused_us_per_frame']:.0f}",
          f"{r['fused_speedup']:.2f}x",
          "ok" if r["gate_ok"] else "FAIL"] for r in fused],
    )
    if speedup is not None:
        print(f"\nbatched fast path (batch=8, compact) vs batch=1 detect "
              f"loop @240x320: {speedup:.1f}x frames/s")

    out = {
        "meta": {
            "backend": jax.default_backend(),
            "impl": "xla (host default)",
            "quick": args.quick,
            "fused_band": FUSED_BAND,
            "fused_corridors": FUSED_CORRIDORS,
        },
        "throughput": throughput,
        "stages": stages,
        "fused_vs_staged": fused,
        "speedup_batch8_compact_vs_loop_240x320": speedup,
    }
    with open(args.out, "w") as f:
        json.dump(stamp_json(out), f, indent=2, default=float)
    print(f"wrote {args.out}")
    bad = [r for r in fused if not r["gate_ok"]]
    if bad:
        for r in bad:
            print(f"FUSED GATE FAILED: {r['height']}x{r['width']} "
                  f"batch={r['batch']} fused "
                  f"{r['fused_us_per_frame']:.0f}us > staged "
                  f"{r['staged_us_per_frame']:.0f}us per frame")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
