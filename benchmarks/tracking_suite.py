"""Temporal-tracking benchmark -> ``BENCH_tracking.json``.

Two regimes over the drive cycles of ``data/scenarios.py``:

  * **Quality** — for each family's standard drive cycle (sway + curvature
    ramp + lane change; dropouts and noise bursts on the noisy families),
    per-frame detection F1 vs tracked F1 (``core/tracking.py``:
    ``TrackingPipeline`` — smoothed, coasting through dropouts).  The gate:
    tracked F1 >= per-frame F1 on every noisy family (rain/night/glare) —
    the temporal layer must *pay* for its latency footprint exactly where
    per-frame detection degrades.
  * **Throughput** — steady-state prediction-gated Hough vs the full theta
    sweep at the paper's 240x320, min-wall over repeated passes (the bench
    host is a noisy 2-core box: min-of-repeats, never single-sample, never
    sleep-based).  The gated pipeline sweeps ``theta_band`` of the 180
    theta bins once its tracks confirm; the gate: >= 1.5x frames/s over
    the identical pipeline running full sweeps, tracker overhead included
    on both sides.

Usage: PYTHONPATH=src python -m benchmarks.tracking_suite [--quick]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.core import (
    HoughConfig, LineDetector, PipelineConfig, TrackingPipeline,
    aggregate_scores, score_frame, tracks_as_peaks,
)
from repro.data import (
    NOISY_FAMILIES, make_scenario, scenario_names, standard_drive_cycle,
)

from .common import print_table

#: Families the smoke-gate baseline pins (scripts/check_f1.py): the noisy
#: three — where the temporal win is mandatory — plus a clean reference.
GATED_FAMILIES: tuple[str, ...] = NOISY_FAMILIES + ("straight",)


def _cfg() -> PipelineConfig:
    return PipelineConfig(hough=HoughConfig(compact=True, max_edges="auto"))


def bench_family_quality(family: str, height: int, width: int,
                         n_frames: int) -> dict:
    """Per-frame vs tracked detection quality over one standard cycle."""
    cyc = standard_drive_cycle(family, n_frames, height, width, seed=0)
    det = LineDetector(_cfg())
    tp = TrackingPipeline(_cfg(), height=height, width=width)
    per, trk, drop_fn = [], [], 0
    for f in cyc:
        res = det.detect(np.asarray(f.scene.image, np.float32))
        per.append(score_frame(np.asarray(res.peaks),
                               np.asarray(res.valid),
                               f.scene.lines_rho_theta))
        rep = tp.process(f.scene.image).tracks
        trk.append(score_frame(*tracks_as_peaks(rep),
                               f.scene.lines_rho_theta))
        if f.dropout:
            drop_fn += score_frame(
                *tracks_as_peaks(rep), f.scene.lines_rho_theta,
                tol_rho=8.0, tol_theta_deg=6.0,
            ).fn
    agg_p, agg_t = aggregate_scores(per), aggregate_scores(trk)
    return {
        "family": family,
        "n_frames": n_frames,
        "f1_per_frame": agg_p["f1"],
        "f1_tracked": agg_t["f1"],
        "tracked_ge_per_frame": agg_t["f1"] >= agg_p["f1"],
        "dropout_frames": sum(f.dropout for f in cyc),
        "dropout_fn_tracked_2x_tol": drop_fn,
        "gated_frames": tp.gated_frames,
        "full_frames": tp.full_frames,
        "noisy": family in NOISY_FAMILIES,
    }


def bench_gated_throughput(height: int, width: int, *, n_frames: int,
                           repeats: int, theta_band: int) -> dict:
    """Steady-state gated vs full-sweep frame throughput (min-wall).

    Both sides run the identical ``TrackingPipeline.process`` loop —
    detector dispatch, host sync, tracker update — on the same static
    steady-state frame (locked gate, zero re-acquisition sweeps), so the
    ratio isolates what the theta gate buys, with the tracker's own
    overhead charged against it."""
    scene = make_scenario("straight", height, width, seed=0)
    frame = scene.image

    gated = TrackingPipeline(_cfg(), height=height, width=width,
                             theta_band=theta_band)
    full = TrackingPipeline(_cfg(), height=height, width=width,
                            theta_band=None)
    for tp in (gated, full):        # warm: compile + confirm + engage gate
        for _ in range(4):
            tp.process(frame)

    # Per-frame minima over interleaved samples, not per-pass sums: on the
    # noisy 2-core bench host a pass-level timing soaks up scheduler
    # interference across its whole window, and interleaving gives both
    # sweeps the same noise environment; the per-frame min is the
    # reproducible steady-state capability each is judged by.
    sec_gated = sec_full = np.inf
    n_samples = repeats * n_frames
    for _ in range(n_samples):
        t0 = time.perf_counter()
        gated.process(frame)
        sec_gated = min(sec_gated, time.perf_counter() - t0)
        t0 = time.perf_counter()
        full.process(frame)
        sec_full = min(sec_full, time.perf_counter() - t0)
    assert gated.gated_frames >= n_samples, (
        "gate never engaged in steady state", gated.gated_frames)
    return {
        "height": height, "width": width,
        "n_frames": n_frames, "repeats": repeats,
        "theta_band": theta_band,
        "n_theta_full": _cfg().hough.n_theta,
        "fps_gated": 1.0 / sec_gated,
        "fps_full": 1.0 / sec_full,
        "ms_per_frame_gated": sec_gated * 1e3,
        "ms_per_frame_full": sec_full * 1e3,
        "speedup": sec_full / sec_gated,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="gated families only, shorter cycles, fewer "
                         "timing repeats")
    ap.add_argument("--height", type=int, default=240)
    ap.add_argument("--width", type=int, default=320)
    ap.add_argument("--theta-band", type=int, default=40)
    ap.add_argument("--out", default="BENCH_tracking.json")
    args = ap.parse_args()

    families = GATED_FAMILIES if args.quick else scenario_names()
    # The cycle length is NOT a --quick knob: the tracked F1 of a family
    # is deterministic per (cycle, detector), so quick runs must measure
    # the same number the committed full-run baseline pins
    # (scripts/check_f1.py compares them exactly).  --quick trims the
    # family set and the timing repeats only.
    n_frames = 32
    repeats = 5 if args.quick else 8
    tp_frames = 8 if args.quick else 12

    rows = [
        bench_family_quality(f, args.height, args.width, n_frames)
        for f in families
    ]
    print_table(
        f"drive-cycle quality ({args.height}x{args.width}, "
        f"{n_frames} frames)",
        ["family", "noisy", "F1/frame", "F1 tracked", ">=", "dropouts",
         "drop FN@2x", "gated", "full"],
        [[r["family"], "*" if r["noisy"] else "",
          f"{r['f1_per_frame']:.3f}", f"{r['f1_tracked']:.3f}",
          "ok" if r["tracked_ge_per_frame"] else "WORSE",
          r["dropout_frames"], r["dropout_fn_tracked_2x_tol"],
          r["gated_frames"], r["full_frames"]]
         for r in rows],
    )

    thr = bench_gated_throughput(
        args.height, args.width, n_frames=tp_frames, repeats=repeats,
        theta_band=args.theta_band,
    )
    print_table(
        f"prediction-gated Hough, steady state "
        f"({args.height}x{args.width}, min-wall over {repeats} passes)",
        ["sweep", "theta bins", "ms/frame", "frames/s"],
        [["full", thr["n_theta_full"], f"{thr['ms_per_frame_full']:.1f}",
          f"{thr['fps_full']:.2f}"],
         ["gated", thr["theta_band"], f"{thr['ms_per_frame_gated']:.1f}",
          f"{thr['fps_gated']:.2f}"]],
    )
    print(f"gated speedup: {thr['speedup']:.2f}x (gate: >= 1.5x)")

    noisy_ok = all(r["tracked_ge_per_frame"] for r in rows if r["noisy"])
    speedup_ok = thr["speedup"] >= 1.5
    out = {
        "meta": {
            "backend": jax.default_backend(),
            "height": args.height, "width": args.width,
            "n_frames": n_frames, "quick": args.quick,
        },
        "rows": rows,
        "throughput": thr,
        "tracked_ge_per_frame_on_noisy": noisy_ok,
        "gated_speedup": thr["speedup"],
        "gated_speedup_ok": speedup_ok,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(f"wrote {args.out}")
    if not (noisy_ok and speedup_ok):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
