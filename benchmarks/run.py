"""Run every paper-table benchmark; print tables; write CSVs + JSON.

The summary dict is also written to ``BENCH_paper_tables.json`` so every
bench run is machine-readable (the throughput benchmark writes its own
``BENCH_lines.json`` — see ``benchmarks/lines_throughput.py``).  With
``--scenarios`` the detection-quality suite also runs and emits
``BENCH_scenarios.json`` (see ``benchmarks/scenario_suite.py``).

With ``--service`` the mixed-resolution detection-service benchmark runs
too and emits ``BENCH_service.json`` (see ``benchmarks/service_suite.py``).

With ``--tracking`` the temporal drive-cycle suite runs and emits
``BENCH_tracking.json`` (see ``benchmarks/tracking_suite.py``): tracked vs
per-frame F1 and the prediction-gated Hough steady-state speedup.

With ``--fleet`` the overload + fault-injection suite runs and emits
``BENCH_fleet.json`` (see ``benchmarks/fleet_suite.py``): degradation
ladder on/off at equal offered load, coast-only F1 floors, and the fault
matrix's all-terminal contract.

With ``--mesh`` the sharded-fleet suite runs and emits
``BENCH_mesh.json`` (see ``benchmarks/mesh_suite.py``): the 1 -> 8
replica scaling curve at equal offered load (8-replica throughput must
strictly exceed 1-replica), the session-affinity ablation, the
speculative local/remote offload race — on the rtt_s compat path and
through the seeded lossy ``NetworkModel`` (bit-exact compat, local
guarantee under 5%/leg loss, deterministic replay) — plus the elastic
4 -> 8 scale-up arm and the diurnal arrival ramp.

With ``--drive`` the closed-loop drive suite runs and emits
``BENCH_drive.json`` (see ``benchmarks/drive_suite.py``): cross-track
trajectory error for blind/per-frame/tracked arms per family plus the
service arm under forced overload (ladder on vs off), with per-family
floors, tracked<=per-frame on noisy families, and deterministic replay
as gates (``scripts/check_drive.py`` pins the committed baseline).

Usage: PYTHONPATH=src python -m benchmarks.run [--quick] [--scenarios]
    [--service] [--tracking] [--fleet] [--mesh] [--drive]
"""

from __future__ import annotations

import json
import sys

from .common import stamp_json
from .paper_tables import (
    table1_full_pipeline,
    table2_elided,
    table3_stage_split,
    table6_core_paths,
    table7_projected,
    table7_speedup_matrix,
    table_fused_roofline,
)
from .t5_dp_scaling import table5_dp_scaling


def _stamp_file(path: str) -> None:
    """Merge this run's timestamp/commit into a suite's BENCH_*.json.

    The suites are standalone scripts that predate the stamp; re-writing
    their JSON here (rather than editing every suite) guarantees every
    BENCH file a ``run.py`` invocation produces carries its provenance —
    a checked-in number nobody can date cannot be re-baselined honestly.
    """
    import os
    if not os.path.exists(path):
        return
    with open(path) as f:
        payload = json.load(f)
    with open(path, "w") as f:
        json.dump(stamp_json(payload), f, indent=2, default=float)


def main() -> None:
    quick = "--quick" in sys.argv
    summary = {}

    if "--scenarios" in sys.argv:
        import os

        from . import scenario_suite
        if os.path.exists("BENCH_scenarios.json"):
            os.remove("BENCH_scenarios.json")  # never score a stale run
        saved_argv = sys.argv
        sys.argv = [saved_argv[0]] + (["--quick"] if quick else [])
        try:
            scenario_suite.main()
        except SystemExit:
            # contract violation: the suite writes its JSON before exiting,
            # so record the failure in the summary, finish the paper
            # tables, and re-signal via this process's exit code below.
            pass
        finally:
            sys.argv = saved_argv
        _stamp_file("BENCH_scenarios.json")
        if os.path.exists("BENCH_scenarios.json"):
            with open("BENCH_scenarios.json") as f:
                sc = json.load(f)
            summary["scenario_autotune_contract_ok"] = (
                sc["autotune_contract_ok"]
            )
            summary["scenario_min_f1"] = min(
                r["f1"] for r in sc["rows"] if r["scenario"] != "empty"
            )
        else:  # suite aborted before writing — treat as a failed contract
            summary["scenario_autotune_contract_ok"] = False

    if "--service" in sys.argv:
        from . import service_suite
        saved_argv = sys.argv
        sys.argv = [saved_argv[0]] + (["--quick"] if quick else [])
        import os
        service_ok = True
        try:
            service_suite.main()
        except SystemExit:
            # the suite writes its JSON before exiting (same contract as
            # --scenarios): read the real bars instead of guessing which
            # one failed
            service_ok = False
        finally:
            sys.argv = saved_argv
        _stamp_file("BENCH_service.json")
        if os.path.exists("BENCH_service.json"):
            with open("BENCH_service.json") as f:
                sv = json.load(f)
            summary["service_mixed_ge_batch8"] = sv["mixed_ge_batch8"]
            summary["service_holds_batch8"] = sv["service_holds_batch8"]
            summary["service_speedup_vs_naive"] = sv["speedup_vs_naive"]
            # deadline regime: virtual-clock simulation, so these two are
            # exact (no host-noise tolerance needed)
            summary["service_deadline_slack_zero_miss"] = (
                sv["deadline_slack_zero_miss"]
            )
            summary["service_deadline_edf_le_fifo"] = (
                sv["deadline_edf_le_fifo"]
            )
            summary["service_deadline_miss_rate_tight"] = (
                sv["deadline_tight_edf"]["miss_rate"]
            )
        else:  # suite aborted before writing
            summary["service_mixed_ge_batch8"] = False
            summary["service_holds_batch8"] = False
            summary["service_deadline_slack_zero_miss"] = False
            summary["service_deadline_edf_le_fifo"] = False
            summary["service_deadline_miss_rate_tight"] = None
        summary["service_contract_ok"] = service_ok and (
            summary["service_mixed_ge_batch8"]
            and summary["service_holds_batch8"]
            and summary["service_deadline_slack_zero_miss"]
            and summary["service_deadline_edf_le_fifo"]
        )

    if "--tracking" in sys.argv:
        import os

        from . import tracking_suite
        if os.path.exists("BENCH_tracking.json"):
            os.remove("BENCH_tracking.json")  # never score a stale run
        saved_argv = sys.argv
        sys.argv = [saved_argv[0]] + (["--quick"] if quick else [])
        tracking_ok = True
        try:
            tracking_suite.main()
        except SystemExit:
            # the suite writes its JSON before exiting (same contract as
            # the other suites): read the real bars below
            tracking_ok = False
        finally:
            sys.argv = saved_argv
        _stamp_file("BENCH_tracking.json")
        if os.path.exists("BENCH_tracking.json"):
            with open("BENCH_tracking.json") as f:
                tr = json.load(f)
            summary["tracking_tracked_ge_per_frame"] = (
                tr["tracked_ge_per_frame_on_noisy"]
            )
            summary["tracking_gated_speedup"] = tr["gated_speedup"]
            summary["tracking_gated_speedup_ok"] = tr["gated_speedup_ok"]
        else:  # suite aborted before writing
            summary["tracking_tracked_ge_per_frame"] = False
            summary["tracking_gated_speedup"] = None
            summary["tracking_gated_speedup_ok"] = False
        summary["tracking_contract_ok"] = tracking_ok and (
            summary["tracking_tracked_ge_per_frame"]
            and summary["tracking_gated_speedup_ok"]
        )

    if "--fleet" in sys.argv:
        import os

        from . import fleet_suite
        if os.path.exists("BENCH_fleet.json"):
            os.remove("BENCH_fleet.json")  # never score a stale run
        saved_argv = sys.argv
        sys.argv = [saved_argv[0]] + (["--quick"] if quick else [])
        fleet_ok = True
        try:
            fleet_suite.main()
        except SystemExit:
            # the suite writes its JSON before exiting (same contract as
            # the other suites): read the real gates below
            fleet_ok = False
        finally:
            sys.argv = saved_argv
        _stamp_file("BENCH_fleet.json")
        if os.path.exists("BENCH_fleet.json"):
            with open("BENCH_fleet.json") as f:
                fl = json.load(f)
            summary["fleet_high_pri_miss_improves"] = (
                fl["gates"]["high_pri_miss_improves"]
            )
            summary["fleet_coast_zero_dispatch"] = (
                fl["gates"]["coast_zero_dispatch"]
            )
            summary["fleet_faults_all_terminal"] = (
                fl["gates"]["faults_all_terminal"]
            )
            summary["fleet_tier0_miss_ladder_on"] = (
                fl["overload"]["ladder_on"]["tier0"]["miss_rate"]
            )
            summary["fleet_tier0_miss_ladder_off"] = (
                fl["overload"]["ladder_off"]["tier0"]["miss_rate"]
            )
        else:  # suite aborted before writing
            summary["fleet_high_pri_miss_improves"] = False
            summary["fleet_coast_zero_dispatch"] = False
            summary["fleet_faults_all_terminal"] = False
            summary["fleet_tier0_miss_ladder_on"] = None
            summary["fleet_tier0_miss_ladder_off"] = None
        summary["fleet_contract_ok"] = fleet_ok and (
            summary["fleet_high_pri_miss_improves"]
            and summary["fleet_coast_zero_dispatch"]
            and summary["fleet_faults_all_terminal"]
        )

    if "--mesh" in sys.argv:
        import os

        from . import mesh_suite
        if os.path.exists("BENCH_mesh.json"):
            os.remove("BENCH_mesh.json")  # never score a stale run
        saved_argv = sys.argv
        sys.argv = [saved_argv[0]] + (["--quick"] if quick else [])
        mesh_ok = True
        try:
            mesh_suite.main()
        except SystemExit:
            mesh_ok = False
        finally:
            sys.argv = saved_argv
        _stamp_file("BENCH_mesh.json")
        # every gate the suite publishes, surfaced 1:1 (mesh_<gate>);
        # the contract is their conjunction — a new suite gate tightens
        # the contract here with no further wiring
        mesh_gates = (
            "throughput_scales", "affinity_tier0_no_worse",
            "speculative_local_guarantee", "speculative_upgrade_iff_wins",
            "all_terminal", "network_compat_bitexact",
            "lossy_local_guarantee", "lossy_upgrade_iff_wins",
            "lossy_deterministic", "scaleup_throughput_no_worse",
            "diurnal_all_terminal",
        )
        if os.path.exists("BENCH_mesh.json"):
            with open("BENCH_mesh.json") as f:
                ms = json.load(f)
            for gate in mesh_gates:
                summary[f"mesh_{gate}"] = ms["gates"].get(gate, False)
            summary["mesh_throughput_1"] = (
                ms["scaling"]["1"]["throughput_rps"]
            )
            summary["mesh_throughput_8"] = (
                ms["scaling"]["8"]["throughput_rps"]
            )
            summary["mesh_lossy_timeout_rate"] = (
                ms["network"]["lossy"]["timeout_rate"]
            )
            summary["mesh_scaleup_throughput"] = (
                ms["scale_up"]["elastic_4_to_8"]["throughput_rps"]
            )
        else:  # suite aborted before writing
            for gate in mesh_gates:
                summary[f"mesh_{gate}"] = False
            summary["mesh_throughput_1"] = None
            summary["mesh_throughput_8"] = None
            summary["mesh_lossy_timeout_rate"] = None
            summary["mesh_scaleup_throughput"] = None
        summary["mesh_contract_ok"] = mesh_ok and all(
            summary[f"mesh_{gate}"] for gate in mesh_gates
        )

    if "--drive" in sys.argv:
        import os

        from . import drive_suite
        if os.path.exists("BENCH_drive.json"):
            os.remove("BENCH_drive.json")  # never score a stale run
        saved_argv = sys.argv
        sys.argv = [saved_argv[0]] + (["--quick"] if quick else [])
        drive_ok = True
        try:
            drive_suite.main()
        except SystemExit:
            # the suite writes its JSON before exiting (same contract as
            # the other suites): read the real gates below
            drive_ok = False
        finally:
            sys.argv = saved_argv
        _stamp_file("BENCH_drive.json")
        # every gate the suite publishes, surfaced 1:1 (drive_<gate>);
        # the contract is their conjunction plus the suite's own exit
        drive_gates = (
            "tracked_under_floor", "tracked_le_per_frame_on_noisy",
            "ladder_on_beats_off", "deterministic_replay",
        )
        if os.path.exists("BENCH_drive.json"):
            with open("BENCH_drive.json") as f:
                dr = json.load(f)
            for gate in drive_gates:
                summary[f"drive_{gate}"] = dr["gates"].get(gate, False)
            summary["drive_worst_tracked_max_m"] = max(
                arms["tracked"]["max_cross_track_m"]
                for arms in dr["families"].values()
            )
            summary["drive_ladder_on_mean_m"] = (
                dr["service"]["ladder_on"]["mean_cross_track_m"]
            )
            summary["drive_ladder_off_mean_m"] = (
                dr["service"]["ladder_off"]["mean_cross_track_m"]
            )
        else:  # suite aborted before writing
            for gate in drive_gates:
                summary[f"drive_{gate}"] = False
            summary["drive_worst_tracked_max_m"] = None
            summary["drive_ladder_on_mean_m"] = None
            summary["drive_ladder_off_mean_m"] = None
        summary["drive_contract_ok"] = drive_ok and all(
            summary[f"drive_{gate}"] for gate in drive_gates
        )

    t1 = table1_full_pipeline()
    t2 = table2_elided()
    summary["elision_speedup"] = t1["total_us"] / t2["total_us"]
    summary["render_share"] = t1["render_share"]

    t3 = table3_stage_split()
    summary["canny_share"] = t3["canny_share"]

    if not quick:
        t5 = table5_dp_scaling((1, 2, 4))
        summary["dp_scaling"] = t5["scaling_at_max"]

    t6 = table6_core_paths()
    summary["t6_canny_speedup"] = t6["canny_speedup"]
    summary["t6_hough_speedup"] = t6["hough_speedup"]

    t7 = table7_speedup_matrix()
    summary["best_total_speedup"] = t7["best_total_speedup"]
    t7p = table7_projected()
    summary["projected_total_speedup"] = t7p["projected_total_speedup"]

    tf = table_fused_roofline()
    summary["fused_roofline_stages"] = tf["stages"]
    summary["fused_hot_path_bytes"] = tf["fused_hot_path_bytes"]
    summary["staged_hot_path_bytes"] = tf["staged_hot_path_bytes"]
    summary["fused_traffic_below_staged"] = (
        tf["fused_traffic_below_staged"]
    )

    print("\n== summary (paper claims -> this platform) ==")
    print("  [methodology: the host is a vector CPU with no matrix unit, "
          "so GEMM-offload wins appear in the TPU projection, not the "
          "host wall-clock — the mirror image of the paper's platform]")
    print(f"  image generation share (paper: 76% on 50MHz core): "
          f"{summary['render_share']:.0%} here (vectorized renderer)")
    print(f"  elision win (paper: 4.2x): {summary['elision_speedup']:.2f}x "
          f"here")
    print(f"  canny share of detection (paper: 87.6% scalar): "
          f"{summary['canny_share']:.0%} here (canny already vectorized; "
          f"the scatter-bound Hough dominates a CPU)")
    if "dp_scaling" in summary:
        import os
        cores = os.cpu_count() or 1
        note = (" — NOTE: this host has 1 physical core, so virtual "
                "devices time-share and wall-clock cannot scale; the "
                "table verifies correctness of the pmap program, the "
                "paper's 2x needs 2 real cores" if cores == 1 else "")
        print(f"  DP scaling (paper: ~2x on 2 cores): "
              f"{summary['dp_scaling']:.2f}x on 4 devices{note}")
    print(f"  projected total speedup, VPU-only vs MXU-offload on TPU v5e "
          f"(paper: 3.7x vs Rocket): "
          f"{summary['projected_total_speedup']:.2f}x")
    if "scenario_min_f1" in summary:
        ok = summary["scenario_autotune_contract_ok"]
        print(f"  scenario suite: min family F1 "
              f"{summary['scenario_min_f1']:.2f}, max_edges autotune "
              f"contract {'ok' if ok else 'VIOLATED'}")
    if "service_contract_ok" in summary:
        miss = summary.get("service_deadline_miss_rate_tight")
        miss_txt = (f"tight-EDF miss rate {miss:.0%}"
                    if miss is not None else "deadline regime missing")
        ok = summary["service_contract_ok"]
        print(f"  detection service: deadline regime (virtual clock) "
              f"{miss_txt}, QoS+throughput gates "
              f"{'ok' if ok else 'VIOLATED'}")
    if "tracking_contract_ok" in summary:
        sp = summary.get("tracking_gated_speedup")
        sp_txt = f"{sp:.2f}x" if sp is not None else "missing"
        ok = summary["tracking_contract_ok"]
        print(f"  temporal tracking: gated-Hough steady state {sp_txt} "
              f"(gate >= 1.5x), tracked>=per-frame on noisy cycles "
              f"{'ok' if ok else 'VIOLATED'}")
    if "fleet_contract_ok" in summary:
        on = summary.get("fleet_tier0_miss_ladder_on")
        off = summary.get("fleet_tier0_miss_ladder_off")
        miss_txt = (f"tier-0 miss {on:.1%} (ladder) vs {off:.1%} (off)"
                    if on is not None and off is not None
                    else "overload arms missing")
        ok = summary["fleet_contract_ok"]
        print(f"  fleet overload: {miss_txt}, coast/fault gates "
              f"{'ok' if ok else 'VIOLATED'}")
    if "mesh_contract_ok" in summary:
        t1 = summary.get("mesh_throughput_1")
        t8 = summary.get("mesh_throughput_8")
        thr_txt = (f"throughput {t1:.0f} -> {t8:.0f} rps (1 -> 8 "
                   f"replicas)" if t1 is not None and t8 is not None
                   else "scaling arms missing")
        ok = summary["mesh_contract_ok"]
        print(f"  sharded fleet: {thr_txt}, affinity/offload gates "
              f"{'ok' if ok else 'VIOLATED'}")

    if "drive_contract_ok" in summary:
        worst = summary.get("drive_worst_tracked_max_m")
        on = summary.get("drive_ladder_on_mean_m")
        off = summary.get("drive_ladder_off_mean_m")
        err_txt = (f"worst tracked max {worst:.2f} m, overload mean "
                   f"{on:.2f} m (ladder) vs {off:.2f} m (off)"
                   if worst is not None and on is not None
                   and off is not None else "arms missing")
        ok = summary["drive_contract_ok"]
        print(f"  closed-loop drive: {err_txt}, trajectory gates "
              f"{'ok' if ok else 'VIOLATED'}")

    gap = (summary["staged_hot_path_bytes"]
           / max(summary["fused_hot_path_bytes"], 1.0))
    print(f"  fused hot path HBM traffic: "
          f"{summary['fused_hot_path_bytes']:.2e} B vs staged "
          f"{summary['staged_hot_path_bytes']:.2e} B ({gap:.2f}x less; "
          f"gate {'ok' if summary['fused_traffic_below_staged'] else 'VIOLATED'})")

    path = "BENCH_paper_tables.json"
    with open(path, "w") as f:
        json.dump(stamp_json(summary), f, indent=2, default=float)
    print(f"\nwrote {path}")
    if not (summary.get("scenario_autotune_contract_ok", True)
            and summary.get("service_contract_ok", True)
            and summary.get("tracking_contract_ok", True)
            and summary.get("fleet_contract_ok", True)
            and summary.get("mesh_contract_ok", True)
            and summary.get("drive_contract_ok", True)
            and summary["fused_traffic_below_staged"]):
        raise SystemExit(1)  # CI gates on the exit code, not the JSON


if __name__ == "__main__":
    main()
