"""Fleet-scale overload + fault-injection benchmark -> ``BENCH_fleet.json``.

Everything here runs on a :class:`VirtualClock` with modeled per-dispatch
service times — no wall time anywhere, so every number is a deterministic
function of the trace and the policy, and the 2-core bench host cannot
flake a gate.  Four sections:

  * **overload** — a heavy-tailed (Zipf) session trace drawn from a
    million-session universe, offered at >= 2x modeled capacity across
    three priority tiers (0: safety, 1: interactive, 2: bulk), replayed
    through two arms at *equal offered load*: the degradation ladder ON
    (downshift -> coast -> tiered shed) and OFF (the pre-ladder
    shed-only service).  Reported per tier and arm: offered,
    served_full/downshift/coast, refused, late, miss rate (refused+late
    over offered) and degraded rate.  GATE: the tier-0 miss rate with
    the ladder on must be *strictly lower* than with it off.
  * **coast_quality** — coast-only answers scored against the analytic
    drive-cycle truth: every 4th frame after tracker warm-up is answered
    from ``LaneTracker.predict_tracks(1)`` (the detector never sees it,
    exactly the serving coast rung) and scored against that frame's
    ground truth.  Per-family coast F1 is pinned by
    ``scripts/check_f1.py`` against the committed baseline.
  * **faults** — one service run per injected fault class (stager death,
    dispatch failure, dispatch stall, corrupt frames, clock jump) over a
    mixed traffic slice.  GATE: every submitted request reaches an
    explicit terminal status — ``hung`` must be 0 for every class.
  * **coast probe** — a warmed session driven hopeless on purpose.
    GATE: the coast answers arrive with ZERO detection dispatches.

Usage: PYTHONPATH=src python -m benchmarks.fleet_suite [--quick]
"""

from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import (
    HoughConfig, LineDetector, PipelineConfig, aggregate_scores,
    score_frame, tracks_as_peaks,
)
from repro.core.tracking import LaneTracker, TrackerConfig
from repro.data import NOISY_FAMILIES, make_scenario, standard_drive_cycle
from repro.runtime import ServiceFaultInjector
from repro.serve.detection import (
    DetectionRequest, DetectionService, RequestStatus, VirtualClock,
)

from .common import print_table

#: Families whose coast-only F1 the smoke gate pins (the noisy three,
#: where coasting through dropouts is the point, plus a clean reference).
GATED_FAMILIES: tuple[str, ...] = NOISY_FAMILIES + ("straight",)

BUCKETS = ((96, 128), (120, 160))
#: Modeled per-dispatch service time per bucket (seconds).  Fixed by
#: construction: the overload arms score *policy*, not hardware.
MODEL_COST = {(96, 128): 0.02, (120, 160): 0.05}
BATCH_SIZE = 4
#: Per-tier deadline budgets (seconds of virtual time).
TIER_DEADLINE = {0: 0.10, 1: 0.15, 2: 0.25}
#: Tier mix: 10% safety, 30% interactive, 60% bulk.
TIER_CUM = (0.10, 0.40, 1.00)
#: Session universe for the heavy-tailed trace (fleet scale: the trace
#: *samples* it; nothing iterates it).
SESSION_UNIVERSE = 1_000_000
ZIPF_A = 1.3
#: Inter-arrival gap: mean modeled per-request cost is ~8.75 ms
#: (50/50 bucket mix, batch 4), so 3.5 ms offers ~2.5x capacity.
ARRIVAL_GAP_S = 0.0035
MAX_QUEUE = 12


def _cfg() -> PipelineConfig:
    return PipelineConfig(hough=HoughConfig(compact=True, max_edges="auto"))


# --- trace generator --------------------------------------------------------

def fleet_trace(n: int, *, seed: int = 0) -> list[dict]:
    """``n`` requests of a heavy-tailed fleet trace: session ids drawn
    Zipf(``ZIPF_A``) from a million-session universe (a few hot cameras
    dominate, a long tail appears once), tiers drawn 10/30/60, and each
    session pinned to one resolution bucket and one scene family so its
    frames form a coherent stream the tracker can learn."""
    rng = np.random.default_rng(seed)
    sessions = np.minimum(rng.zipf(ZIPF_A, size=n), SESSION_UNIVERSE)
    u = rng.random(n)
    tiers = np.select([u < TIER_CUM[0], u < TIER_CUM[1]], [0, 1], 2)
    fams = GATED_FAMILIES
    out = []
    for i in range(n):
        sid = int(sessions[i])
        out.append({
            "arrival_s": i * ARRIVAL_GAP_S,
            "session": f"cam{sid}",
            "tier": int(tiers[i]),
            "shape": BUCKETS[sid % len(BUCKETS)],
            "family": fams[sid % len(fams)],
            "seed": sid % 16,
        })
    return out


_FRAME_CACHE: dict[tuple, np.ndarray] = {}


def _trace_frame(item: dict) -> np.ndarray:
    key = (item["family"], item["shape"], item["seed"])
    if key not in _FRAME_CACHE:
        _FRAME_CACHE[key] = make_scenario(
            item["family"], *item["shape"], seed=item["seed"]
        ).image
    return _FRAME_CACHE[key]


# --- overload arms ----------------------------------------------------------

def _drive(svc: DetectionService, clock: VirtualClock,
           reqs: list[DetectionRequest], arrivals: list[float]) -> None:
    """Replay scripted arrivals; each dispatch advances the clock by the
    bucket's modeled cost and drains immediately (the run_deadline_sim
    recipe from ``service_suite.py``: compute is real, time is modeled)."""
    i = 0
    for _ in range(200_000):
        while i < len(reqs) and arrivals[i] <= clock() + 1e-12:
            svc.submit(reqs[i])
            i += 1
        arrived_all = i == len(reqs)
        d0 = svc.dispatches
        svc.step(flush=arrived_all)
        if svc.dispatches > d0:
            shape, _, _ = svc.dispatch_log[-1]
            clock.advance(MODEL_COST[shape])
            svc.drain()
            continue
        if not arrived_all:
            clock.advance(max(arrivals[i] - clock(), 0.0) or 1e-4)
        elif svc.queued or any(g.active for g in svc.grids.values()):
            clock.advance(1e-4)
        else:
            break
    svc.close()


def run_overload_arm(trace: list[dict], *, ladder: bool) -> dict:
    clock = VirtualClock()
    svc = DetectionService(
        _cfg(), buckets=BUCKETS, batch_size=BATCH_SIZE, clock=clock,
        max_queue=MAX_QUEUE, prefetch=False, ladder=ladder,
    )
    for shape, grid in svc.grids.items():
        grid.est_s = MODEL_COST[shape]
        grid.est_measured = True
    reqs = [
        DetectionRequest(
            uid=i, frame=_trace_frame(it), session_id=it["session"],
            priority=it["tier"], deadline_s=TIER_DEADLINE[it["tier"]],
        )
        for i, it in enumerate(trace)
    ]
    _drive(svc, clock, reqs, [it["arrival_s"] for it in trace])

    tiers: dict[str, dict] = {}
    for tier in (0, 1, 2):
        rs = [r for r, it in zip(reqs, trace) if it["tier"] == tier]
        served_full = sum(r.ok for r in rs)
        ds = sum(r.status is RequestStatus.DEGRADED_DOWNSHIFT for r in rs)
        co = sum(r.status is RequestStatus.DEGRADED_COAST for r in rs)
        refused = sum(r.status.refused for r in rs)
        late = sum(
            r.served and r.finished_at > r.deadline_at for r in rs
        )
        n = len(rs)
        tiers[f"tier{tier}"] = {
            "offered": n,
            "served_full": served_full,
            "served_downshift": ds,
            "served_coast": co,
            "refused": refused,
            "late": late,
            "miss_rate": (refused + late) / n if n else 0.0,
            "degraded_rate": (ds + co) / n if n else 0.0,
        }
    tiers["all_terminal"] = all(r.is_terminal for r in reqs)
    tiers["dispatches"] = svc.dispatches
    tiers["evicted"] = svc.evicted
    tiers["downshifted"] = svc.downshifted
    tiers["served_coast"] = svc.served_coast
    tiers["shed_deadline"] = svc.shed_deadline
    return tiers


# --- coast quality ----------------------------------------------------------

def bench_family_coast(family: str, height: int, width: int,
                       n_frames: int) -> dict:
    """Coast-only F1 on one standard drive cycle: every 4th frame after
    warm-up is answered from the tracker's 1-step prediction (the
    detector never sees it — serving-coast semantics), scored against
    that frame's analytic truth."""
    cyc = standard_drive_cycle(family, n_frames, height, width, seed=0)
    det = LineDetector(_cfg())
    tracker = LaneTracker(TrackerConfig())
    warmup = 10
    scores = []
    for i, f in enumerate(cyc):
        if i >= warmup and i % 4 == 0 and tracker.can_coast():
            pred = tracker.predict_tracks(1)
            scores.append(score_frame(
                *tracks_as_peaks(pred), f.scene.lines_rho_theta,
            ))
            continue          # the coasted frame never reaches detection
        res = det.detect(np.asarray(f.scene.image, np.float32))
        tracker.step(np.asarray(res.peaks), np.asarray(res.valid))
    agg = aggregate_scores(scores) if scores else {"f1": 0.0}
    return {
        "family": family,
        "n_frames": n_frames,
        "f1_coast": agg["f1"],
        "n_scored": len(scores),
    }


# --- coast probe (zero-dispatch gate) ---------------------------------------

def run_coast_probe() -> dict:
    """Warm one session, preset a measured estimate, then offer hopeless
    deadlines: the answers must be DEGRADED_COAST with zero dispatches."""
    clock = VirtualClock()
    svc = DetectionService(
        _cfg(), buckets=((96, 128),), batch_size=1, clock=clock,
        prefetch=False,
    )
    frame = make_scenario("straight", 96, 128, seed=0).image
    for i in range(8):
        r = DetectionRequest(uid=100 + i, frame=frame, session_id="cam0")
        svc.submit(r)
        svc.step()
        clock.advance(0.05)
        svc.drain()
        assert r.ok
    grid = svc.grids[(96, 128)]
    grid.est_s, grid.est_measured = 0.05, True
    before = svc.dispatches
    coasts = []
    for i in range(2):
        r = DetectionRequest(uid=i, frame=frame, session_id="cam0",
                             deadline_s=0.02)
        svc.submit(r)
        svc.run()
        coasts.append(r)
    svc.close()
    ok = (all(r.status is RequestStatus.DEGRADED_COAST for r in coasts)
          and svc.dispatches == before)
    return {
        "n_coast": len(coasts),
        "extra_dispatches": svc.dispatches - before,
        "coast_zero_dispatch": bool(ok),
    }


# --- fault matrix -----------------------------------------------------------

def run_fault_matrix() -> dict:
    """One bounded service run per fault class over a mixed traffic
    slice; the contract is that every request ends terminal (no hangs)
    and the service's fault counters saw the injection."""
    classes = {
        "stager_death": ServiceFaultInjector(kill_stager_at=(0, 3)),
        "dispatch_failure": ServiceFaultInjector(fail_dispatch_at=(1,)),
        "dispatch_stall": ServiceFaultInjector(
            stall_dispatch_at=(1,), stall_s=0.5),
        "corrupt_frames": ServiceFaultInjector(corrupt_frame_uids=(2, 5)),
        "clock_jump": ServiceFaultInjector(
            clock_jump_at_step=(3,), clock_jump_s=5.0),
    }
    base = make_scenario("straight", 96, 128, seed=0).image
    rgb = np.repeat(base[..., None], 3, axis=2)
    out = {}
    for name, inj in classes.items():
        clock = VirtualClock()
        svc = DetectionService(
            _cfg(), buckets=((96, 128),), batch_size=2, clock=clock,
            prefetch=True, faults=inj,
        )
        reqs = []
        for i in range(10):
            reqs.append(DetectionRequest(
                uid=i, frame=rgb if i % 2 else base,
                session_id="cam0" if i % 3 == 0 else None,
                deadline_s=2.0 if i % 4 == 0 else None,
            ))
        for r in reqs:
            svc.submit(r)
        svc.run()
        svc.close()
        hung = sum(not r.is_terminal for r in reqs)
        out[name] = {
            "n_requests": len(reqs),
            "all_terminal": hung == 0,
            "hung": hung,
            "served": sum(r.served for r in reqs),
            "refused": sum(r.status.refused for r in reqs),
            "stager_deaths": svc.stager_deaths,
            "dispatch_faults": svc.dispatch_faults,
            "rejected_invalid": svc.rejected_invalid,
            "served_coast": svc.served_coast,
            "completed_late": svc.completed_late,
        }
    return out


# --- main -------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter trace and cycles")
    ap.add_argument("--out", default="BENCH_fleet.json")
    args = ap.parse_args()

    n_trace = 120 if args.quick else 400
    # coast quality runs the same cycle length in quick and full mode, so
    # the committed check_f1 baseline pins one deterministic value
    n_frames = 48

    trace = fleet_trace(n_trace, seed=0)
    arms = {
        "ladder_on": run_overload_arm(trace, ladder=True),
        "ladder_off": run_overload_arm(trace, ladder=False),
    }
    rows = []
    for arm, t in arms.items():
        for tier in ("tier0", "tier1", "tier2"):
            d = t[tier]
            rows.append([
                arm, tier, d["offered"], d["served_full"],
                d["served_downshift"], d["served_coast"], d["refused"],
                d["late"], f"{d['miss_rate']:.3f}",
                f"{d['degraded_rate']:.3f}",
            ])
    print_table(
        f"overload @ ~2.5x capacity ({n_trace} reqs, Zipf sessions, "
        f"virtual clock)",
        ["arm", "tier", "offered", "full", "downshift", "coast",
         "refused", "late", "miss", "degraded"],
        rows,
    )

    coast_rows = [
        bench_family_coast(f, 96, 128, n_frames) for f in GATED_FAMILIES
    ]
    print_table(
        f"coast-only F1 vs drive-cycle truth (96x128, {n_frames} frames)",
        ["family", "scored", "F1 coast"],
        [[r["family"], r["n_scored"], f"{r['f1_coast']:.3f}"]
         for r in coast_rows],
    )

    probe = run_coast_probe()
    faults = run_fault_matrix()
    print_table(
        "fault matrix (every class must end terminal)",
        ["class", "requests", "served", "refused", "hung", "terminal"],
        [[k, v["n_requests"], v["served"], v["refused"], v["hung"],
          "ok" if v["all_terminal"] else "HUNG"]
         for k, v in faults.items()],
    )

    hi_on = arms["ladder_on"]["tier0"]["miss_rate"]
    hi_off = arms["ladder_off"]["tier0"]["miss_rate"]
    gates = {
        "high_pri_miss_improves": hi_on < hi_off,
        "coast_zero_dispatch": probe["coast_zero_dispatch"],
        "faults_all_terminal": all(
            v["all_terminal"] for v in faults.values()
        ) and arms["ladder_on"]["all_terminal"]
        and arms["ladder_off"]["all_terminal"],
    }
    print(f"\n  tier-0 miss rate: ladder on {hi_on:.3f} vs off "
          f"{hi_off:.3f} -> "
          f"{'ok' if gates['high_pri_miss_improves'] else 'VIOLATED'}")
    print(f"  coast zero-dispatch: "
          f"{'ok' if gates['coast_zero_dispatch'] else 'VIOLATED'}")
    print(f"  faults all terminal: "
          f"{'ok' if gates['faults_all_terminal'] else 'VIOLATED'}")

    payload = {
        "meta": {
            "quick": args.quick,
            "n_trace": n_trace,
            "arrival_gap_s": ARRIVAL_GAP_S,
            "model_cost": {f"{k[0]}x{k[1]}": v
                           for k, v in MODEL_COST.items()},
            "tier_deadline_s": TIER_DEADLINE,
            "session_universe": SESSION_UNIVERSE,
            "zipf_a": ZIPF_A,
        },
        "overload": arms,
        "coast_quality": {
            r["family"]: {"f1_coast": r["f1_coast"],
                          "n_scored": r["n_scored"]}
            for r in coast_rows
        },
        "coast_probe": probe,
        "faults": faults,
        "gates": gates,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"\nwrote {args.out}")
    if not all(gates.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
