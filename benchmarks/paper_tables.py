"""One benchmark per paper table (Tables 1, 2, 3, 6, 7).

The paper's numbers are cycles on FireSim'd RISC-V cores; ours are wall
microseconds on this host.  What reproduces is the *structure* the paper's
argument rests on — which phase dominates, which stage benefits from the
matrix unit, which stage is immune — and the speedup methodology (fixed
baseline, per-stage ratios).  Each function returns (header, rows) and
writes a CSV.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    CannyConfig, HoughConfig, LineDetector, LinesConfig, PipelineConfig,
    canny, get_lines, hough_paper_loop, hough_transform,
)
from repro.core.lines import render_lines
from repro.data.images import synthetic_road

from .common import print_table, timeit_us, write_csv

H, W = 240, 320            # paper-scale frame (Fig. 4 is a road photo)


def _frame():
    return jnp.asarray(synthetic_road(H, W, seed=5).image, jnp.float32)


def table1_full_pipeline():
    """T1: phase profile including output-image generation."""
    img_u8 = synthetic_road(H, W, seed=5).image
    det = LineDetector(PipelineConfig(render_output=True))
    load_us = timeit_us(lambda: det.load(jnp.asarray(img_u8)))
    image = det.load(jnp.asarray(img_u8))
    detect_us = timeit_us(lambda: det.detect(image))
    res = det.detect(image)
    render_us = timeit_us(
        lambda: render_lines(image.astype(jnp.uint8), res.lines, res.valid)
    )
    total = load_us + detect_us + render_us
    rows = [
        ["image_load", f"{load_us:.0f}", f"{100*load_us/total:.1f}%"],
        ["line_detection", f"{detect_us:.0f}", f"{100*detect_us/total:.1f}%"],
        ["image_generation", f"{render_us:.0f}",
         f"{100*render_us/total:.1f}%"],
        ["total", f"{total:.0f}", ""],
    ]
    header = ["phase", "time(us)", "% over total"]
    write_csv("t1_full_pipeline", header, rows)
    print_table("Table 1 analogue: full pipeline phases", header, rows)
    return {"render_share": render_us / total, "total_us": total}


def table2_elided():
    """T2: the paper's 4.2x elision — drop image generation."""
    img_u8 = synthetic_road(H, W, seed=5).image
    det = LineDetector(PipelineConfig(render_output=False))
    load_us = timeit_us(lambda: det.load(jnp.asarray(img_u8)))
    image = det.load(jnp.asarray(img_u8))
    detect_us = timeit_us(lambda: det.detect(image))
    total = load_us + detect_us
    rows = [
        ["image_load", f"{load_us:.0f}", f"{100*load_us/total:.1f}%"],
        ["line_detection", f"{detect_us:.0f}", f"{100*detect_us/total:.1f}%"],
        ["total", f"{total:.0f}", ""],
    ]
    header = ["phase", "time(us)", "% over total"]
    write_csv("t2_elided", header, rows)
    print_table("Table 2 analogue: output generation elided", header, rows)
    return {"total_us": total}


def table3_stage_split():
    """T3: Canny vs Hough vs get-coordinates inside line detection."""
    image = _frame()
    ccfg, hcfg, lcfg = CannyConfig(), HoughConfig(), LinesConfig()
    canny_j = jax.jit(lambda im: canny(im, ccfg))
    hough_j = jax.jit(lambda e: hough_transform(e, hcfg))
    lines_j = jax.jit(lambda v: get_lines(v, height=H, width=W, cfg=lcfg))
    edges = canny_j(image)
    votes = hough_j(edges)
    c = timeit_us(canny_j, image)
    h = timeit_us(hough_j, edges)
    g = timeit_us(lines_j, votes)
    total = c + h + g
    rows = [
        ["canny", f"{c:.0f}", f"{100*c/total:.1f}%"],
        ["hough", f"{h:.0f}", f"{100*h/total:.1f}%"],
        ["get_coordinates", f"{g:.0f}", f"{100*g/total:.1f}%"],
        ["total", f"{total:.0f}", ""],
    ]
    header = ["stage", "time(us)", "% over total"]
    write_csv("t3_stage_split", header, rows)
    print_table("Table 3 analogue: line-detection stages", header, rows)
    return {"canny_share": c / total}


def _stage_times(canny_cfg: CannyConfig, hough_fast: bool):
    """(canny_us, hough_us, coords_us) for one execution configuration."""
    image = _frame()
    ccfg, hcfg, lcfg = canny_cfg, HoughConfig(), LinesConfig()
    canny_j = jax.jit(lambda im: canny(im, ccfg))
    edges = canny_j(image)
    if hough_fast:
        hough_j = jax.jit(lambda e: hough_transform(e, hcfg))
    else:
        hough_j = jax.jit(lambda e: hough_paper_loop(e, hcfg))
    votes = hough_j(edges)
    lines_j = jax.jit(lambda v: get_lines(v, height=H, width=W, cfg=lcfg))
    return (
        timeit_us(canny_j, image),
        timeit_us(hough_j, edges, repeats=2),
        timeit_us(lines_j, votes),
    )


def table6_core_paths():
    """T6 analogue: per-stage cost on the two execution paths.

    'rocket' = stencil Canny + paper-loop Hough (the scalar-core program);
    'boom'   = vectorized Canny + GEMM Hough.  The paper's observation —
    Hough's serial data dependencies defeat a better core while Canny gains
    — maps to the loop-form Hough barely moving between paths.
    """
    slow = _stage_times(CannyConfig(impl="stencil"), hough_fast=False)
    fast = _stage_times(CannyConfig(), hough_fast=True)
    header = ["stage", "scalar-path(us)", "vector-path(us)", "speedup"]
    names = ["canny", "hough", "get_coordinates"]
    rows = [
        [n, f"{s:.0f}", f"{f:.0f}", f"{s/f:.2f}x"]
        for n, s, f in zip(names, slow, fast)
    ]
    write_csv("t6_core_paths", header, rows)
    print_table("Table 6 analogue: scalar vs vector execution", header, rows)
    return {"hough_speedup": slow[1] / fast[1],
            "canny_speedup": slow[0] / fast[0]}


def table7_speedup_matrix():
    """T7: speedups vs the fixed baseline (paper: Rocket@50MHz; here the
    stencil-Canny + loop-Hough configuration).

    Configurations mirror the paper's platforms:
      baseline        stencil conv, loop Hough      (Rocket, no accel)
      gemm            conv-as-GEMM offload          (+Gemmini — the paper's
                                                     Workload 3 move)
      gemm+hough      GEMM Hough too                (beyond paper: offload
                                                     the stage the paper
                                                     left on the core)
      +fused          single-pass 7x7 fused masks   (beyond paper)
      +int            integer pipeline (§4.4)
    """
    base = _stage_times(CannyConfig(impl="stencil"), hough_fast=False)
    configs = [
        ("gemm", _stage_times(CannyConfig(), hough_fast=False)),
        ("gemm+hough", _stage_times(CannyConfig(), hough_fast=True)),
        ("gemm+hough+fused", _stage_times(CannyConfig(fused=True),
                                          hough_fast=True)),
        ("gemm+hough+int", _stage_times(CannyConfig(integer=True),
                                        hough_fast=True)),
    ]
    header = ["config", "canny", "hough", "coords", "total"]
    bt = sum(base)
    rows = [["baseline", "1.00x", "1.00x", "1.00x", "1.00x"]]
    best = 1.0
    for name, t in configs:
        total = bt / sum(t)
        best = max(best, total)
        rows.append([
            name,
            f"{base[0]/t[0]:.2f}x", f"{base[1]/t[1]:.2f}x",
            f"{base[2]/t[2]:.2f}x", f"{total:.2f}x",
        ])
    write_csv("t7_speedup_matrix", header, rows)
    print_table(
        "Table 7 analogue: speedups vs baseline (MEASURED on CPU host — "
        "no matrix unit, so the GEMM rewrite loses here; see projection)",
        header, rows,
    )
    return {"best_total_speedup": best}


def table_fused_roofline():
    """Measured roofline gap of the fused hot path vs the staged stages.

    Per stage: HBM bytes + MXU (dot) FLOPs from the compiled HLO
    (``launch.hlo_cost.analyze`` over ``jit(f).lower(x).compile()``), wall
    time measured, achieved GB/s / GFLOP/s against the v5e peaks
    (``launch.roofline.stage_roofline``).  The staged hot path is the sum
    of separately-jitted canny + hough modules — each is its own XLA
    module, so the edge map crosses HBM between them (write + read), which
    is exactly the traffic the fused module deletes.  ``max_edges`` is
    pinned to one tier (no ``lax.switch``) so the HLO byte count is the
    one program that actually runs, not a sum over branches.  The gate:
    fused-module bytes strictly below the staged stages' summed bytes.
    """
    from repro.core.hough import fused_hough
    from repro.launch.hlo_cost import analyze
    from repro.launch.roofline import stage_roofline

    image = _frame()
    max_edges = 2048
    ccfg = CannyConfig()
    hcfg = HoughConfig(compact=True, max_edges=max_edges)

    canny_fn = lambda im: canny(im, ccfg)                  # noqa: E731
    hough_fn = lambda e: hough_transform(e, hcfg)          # noqa: E731
    fused_fn = lambda im: fused_hough(im, ccfg, hcfg)      # noqa: E731
    edges = jax.jit(canny_fn)(image)
    votes = jax.jit(hough_fn)(edges)
    lines_fn = lambda v: get_lines(                        # noqa: E731
        v, height=H, width=W, cfg=LinesConfig()
    )

    cells = []
    for name, fn, arg in [
        ("canny", canny_fn, image),
        ("hough", hough_fn, edges),
        ("get_coordinates", lines_fn, votes),
        ("fused_canny_hough", fused_fn, image),
    ]:
        jitted = jax.jit(fn)
        cost = analyze(jitted.lower(arg).compile().as_text())
        wall_us = timeit_us(jitted, arg, min_wall_s=0.2)
        cells.append(stage_roofline(
            name, bytes=cost.bytes, dot_flops=cost.dot_flops,
            wall_s=wall_us * 1e-6,
        ))

    staged = {c["stage"]: c for c in cells}
    staged_bytes = staged["canny"]["bytes"] + staged["hough"]["bytes"]
    fused_bytes = staged["fused_canny_hough"]["bytes"]
    header = ["stage", "HBM bytes", "dot FLOPs", "wall(us)",
              "achieved GB/s", "% HBM peak", "achieved GFLOP/s",
              "% FLOP peak", "bottleneck"]
    rows = [
        [c["stage"], f"{c['bytes']:.3e}", f"{c['dot_flops']:.3e}",
         f"{c['wall_s']*1e6:.0f}", f"{c['achieved_gbps']:.2f}",
         f"{c['frac_hbm_peak']:.2%}", f"{c['achieved_gflops']:.2f}",
         f"{c['frac_flops_peak']:.2%}", c["bottleneck"]]
        for c in cells
    ]
    rows.append([
        "staged hot path (canny+hough)", f"{staged_bytes:.3e}", "", "", "",
        "", "", "", "",
    ])
    write_csv("t_fused_roofline", header, rows)
    print_table(
        "Fused hot path roofline (achieved vs v5e peak; HLO-derived "
        "bytes/FLOPs, measured walls)", header, rows,
    )
    ok = fused_bytes < staged_bytes
    print(f"  fused-module HBM bytes {fused_bytes:.3e} "
          f"{'<' if ok else '>='} staged canny+hough {staged_bytes:.3e} "
          f"({'ok' if ok else 'VIOLATED'}; the deleted edge-map round "
          f"trip)")
    return {
        "stages": cells,
        "fused_hot_path_bytes": fused_bytes,
        "staged_hot_path_bytes": staged_bytes,
        "fused_traffic_below_staged": ok,
    }


def table7_projected():
    """Table 7 on the *target*: TPU v5e projection via the offload model.

    The host has no systolic array, so measured numbers invert the paper's
    result (conv-as-GEMM loses to fused stencils on a vector CPU — the
    mirror image of the paper's 'stencil loses on a 16x16 array' finding).
    The projection puts every stage on the VPU (the scalar-core baseline,
    paper's Rocket) vs the planner's MXU/VPU placement (paper's
    core+Gemmini), using the §Roofline hardware constants — the same
    methodology the roofline section uses for the LM cells.
    """
    from repro.core.offload import PEAK_FLOPS_VPU, place
    from repro.core.profiling import line_detection_costs

    H, W = 720, 1280          # deployment-resolution frame
    stages = line_detection_costs(H, W)
    rows = []
    total_base = total_acc = 0.0
    for s in stages:
        t_base = max(s.flops / PEAK_FLOPS_VPU, s.bytes_moved / 819e9)
        pl = place(s)
        total_base += t_base
        total_acc += pl.est_time_s
        rows.append([
            s.name, pl.unit.upper(), f"{t_base*1e6:.1f}",
            f"{pl.est_time_s*1e6:.1f}", f"{t_base/pl.est_time_s:.2f}x",
        ])
    rows.append(["total", "", f"{total_base*1e6:.1f}",
                 f"{total_acc*1e6:.1f}", f"{total_base/total_acc:.2f}x"])
    header = ["stage", "unit", "vpu-only(us)", "offloaded(us)", "speedup"]
    write_csv("t7_projected_tpu", header, rows)
    print_table(
        "Table 7 projection on TPU v5e (paper's platform comparison: "
        "scalar-core baseline vs matrix-unit offload)", header, rows,
    )
    return {"projected_total_speedup": total_base / total_acc}
