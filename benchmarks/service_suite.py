"""Mixed-resolution detection-service benchmark -> ``BENCH_service.json``.

Measures the continuous-batching ``DetectionService`` (``serve/detection.py``)
on the traffic shape the ROADMAP north star cares about — a queue of
requests carrying frames of heterogeneous resolutions — against two
references:

  * ``naive``   — the pre-service deployment: a per-frame ``detect`` loop
    at each request's native resolution (no batching, no buckets);
  * ``batch8``  — the PR-1 single-resolution fast path: ``detect_batch``
    over full batches of 8 at the bucket resolution.  The acceptance bar is
    that the *service*, fed single-bucket traffic at ``batch_size=8``,
    sustains at least this throughput — slotting/padding/double-buffering
    must not eat the batching win.

Reported per workload: requests/s, mean ms/request, and p50/p99 request
latency (submit -> result ready).  Latencies are measured under
drip-feed submission (requests arrive while the service runs), so they
reflect queueing + batching delay, not just compute.

Usage: PYTHONPATH=src python -m benchmarks.service_suite [--quick]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HoughConfig, LineDetector, PipelineConfig
from repro.data import make_scenario, scenario_names
from repro.serve.detection import DetectionRequest, DetectionService

from .common import print_table

# The mixed-resolution ladder: requests cycle through these shapes (all
# land in the (120,160) or (240,320) buckets of DEFAULT_BUCKETS).
MIXED_SHAPES = ((120, 160), (240, 320), (96, 128), (240, 320), (180, 240))
BUCKETS = ((120, 160), (240, 320))


def _cfg() -> PipelineConfig:
    return PipelineConfig(
        hough=HoughConfig(compact=True, max_edges="auto")
    )


def make_requests(n: int, shapes) -> list[np.ndarray]:
    fams = scenario_names()
    return [
        make_scenario(
            fams[i % len(fams)], *shapes[i % len(shapes)], seed=i
        ).image
        for i in range(n)
    ]


def percentile(xs: list[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p))


def run_service(frames: list[np.ndarray], *, batch_size: int,
                drip: int) -> dict:
    """Drive a fresh service; drip-feed ``drip`` requests per step so the
    queue behaves like live traffic rather than one pre-loaded burst."""
    svc = DetectionService(_cfg(), buckets=BUCKETS, batch_size=batch_size)
    # warm every bucket's plan outside the timed window (compile cost is
    # a one-time property of the plan, not of the traffic), then zero the
    # counters so the JSON reports the timed workload only
    for shape in BUCKETS:
        svc.detect_many([np.zeros(shape, np.float32)] * batch_size)
    svc.dispatches = svc.completed = 0
    reqs = [DetectionRequest(uid=i, frame=f) for i, f in enumerate(frames)]
    t0 = time.perf_counter()
    pending = list(reqs)
    while pending:  # live traffic: a few arrivals between engine steps
        for r in pending[:drip]:
            svc.submit(r)
        pending = pending[drip:]
        svc.step()
    svc.run()  # traffic over: flush partial grids and drain in-flight
    dt = time.perf_counter() - t0
    assert all(r.done for r in reqs)
    lats = [r.latency_s * 1e3 for r in reqs]
    return {
        "n_requests": len(reqs),
        "wall_s": dt,
        "requests_per_s": len(reqs) / dt,
        "ms_per_request": dt / len(reqs) * 1e3,
        "latency_ms_p50": percentile(lats, 50),
        "latency_ms_p99": percentile(lats, 99),
        "dispatches": svc.dispatches,
    }


def run_naive(frames: list[np.ndarray]) -> dict:
    """The pre-service loop: one unbatched detect per request at native
    resolution (per-resolution plans still cached and warm)."""
    det = LineDetector(_cfg())
    shapes = sorted({f.shape[:2] for f in frames})
    for shape in shapes:  # warm per-shape compiles
        jax.block_until_ready(
            det.detect(jnp.zeros(shape, jnp.float32)).lines
        )
    t0 = time.perf_counter()
    last = None
    for f in frames:
        last = det.detect(jnp.asarray(f, jnp.float32))
    jax.block_until_ready(last.lines)
    dt = time.perf_counter() - t0
    return {
        "n_requests": len(frames),
        "wall_s": dt,
        "requests_per_s": len(frames) / dt,
        "ms_per_request": dt / len(frames) * 1e3,
    }


def run_batch8(shape: tuple[int, int], n: int) -> dict:
    """PR-1 reference: full detect_batch(8) dispatches at one resolution."""
    det = LineDetector(_cfg())
    frames = make_requests(n, (shape,))
    imgs = jnp.asarray(np.stack([f.astype(np.float32) for f in frames]))
    jax.block_until_ready(det.detect_batch(imgs[:8]).lines)  # warm
    t0 = time.perf_counter()
    last = None
    for k in range(0, n, 8):
        last = det.detect_batch(imgs[k:k + 8])
    jax.block_until_ready(last.lines)
    dt = time.perf_counter() - t0
    return {
        "n_requests": n,
        "wall_s": dt,
        "requests_per_s": n / dt,
        "ms_per_request": dt / n * 1e3,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer requests per workload")
    ap.add_argument("--out", default="BENCH_service.json")
    args = ap.parse_args()

    n_mixed = 20 if args.quick else 60
    n_single = 16 if args.quick else 48
    repeats = 2 if args.quick else 3

    # Interleave repeats of every workload and keep each one's best run:
    # min-wall is robust to the CPU contention spikes a shared host shows,
    # and interleaving keeps A/B comparisons honest under drifting load.
    mixed_frames = make_requests(n_mixed, MIXED_SHAPES)
    single_frames = make_requests(n_single, ((240, 320),))
    best: dict[str, dict] = {}
    for _ in range(repeats):
        for key, fn in (
            # 1) mixed-resolution traffic through the service (the new
            #    capability), 2) the naive per-frame loop on the same
            #    traffic, 3) single-bucket service at batch 8, 4) the raw
            #    batch-8 fast path it must sustain
            ("mixed", lambda: run_service(mixed_frames, batch_size=4,
                                          drip=3)),
            ("naive", lambda: run_naive(mixed_frames)),
            ("svc8", lambda: run_service(single_frames, batch_size=8,
                                         drip=8)),
            ("raw8", lambda: run_batch8((240, 320), n_single)),
        ):
            r = fn()
            if key not in best or r["wall_s"] < best[key]["wall_s"]:
                best[key] = r
    mixed, naive, svc8, raw8 = (
        best["mixed"], best["naive"], best["svc8"], best["raw8"]
    )

    rows = [
        ["service mixed (b=4)", mixed["n_requests"],
         f"{mixed['requests_per_s']:.2f}", f"{mixed['ms_per_request']:.1f}",
         f"{mixed['latency_ms_p50']:.1f}", f"{mixed['latency_ms_p99']:.1f}"],
        ["naive loop (mixed)", naive["n_requests"],
         f"{naive['requests_per_s']:.2f}", f"{naive['ms_per_request']:.1f}",
         "-", "-"],
        ["service 240x320 (b=8)", svc8["n_requests"],
         f"{svc8['requests_per_s']:.2f}", f"{svc8['ms_per_request']:.1f}",
         f"{svc8['latency_ms_p50']:.1f}", f"{svc8['latency_ms_p99']:.1f}"],
        ["detect_batch(8) 240x320", raw8["n_requests"],
         f"{raw8['requests_per_s']:.2f}", f"{raw8['ms_per_request']:.1f}",
         "-", "-"],
    ]
    print_table(
        "detection service (mixed-resolution continuous batching)",
        ["workload", "reqs", "req/s", "ms/req", "p50 ms", "p99 ms"],
        rows,
    )

    speedup_vs_naive = mixed["requests_per_s"] / naive["requests_per_s"]
    # Two gates, both required.  mixed_ge_batch8 is the PR acceptance bar
    # (mixed traffic sustains the batch-8 single-res path) but mixed
    # requests are partly cheaper than the 240x320 reference, so the
    # same-cost regression guard is service_holds_batch8: single-bucket
    # service vs the raw batch-8 loop, 5% tolerance for slot/padding
    # overhead.  speedup_vs_naive is recorded, not gated — on CPU-bound
    # hosts batching buys nothing per frame, so the naive loop can win
    # wall-clock there; the service's batching win needs an accelerator.
    mixed_ge_batch8 = (
        mixed["requests_per_s"] >= raw8["requests_per_s"]
    )
    service_holds_batch8 = (
        svc8["requests_per_s"] >= raw8["requests_per_s"] * 0.95
    )
    print(f"\nmixed service vs naive loop: {speedup_vs_naive:.2f}x")
    print(f"mixed service vs batch-8 single-res path: "
          f"{mixed['requests_per_s']:.2f} vs {raw8['requests_per_s']:.2f} "
          f"req/s -> {'OK' if mixed_ge_batch8 else 'FAIL'}")
    print(f"service(b=8) vs raw batch-8 path within bucket: "
          f"{svc8['requests_per_s']:.2f} vs {raw8['requests_per_s']:.2f} "
          f"req/s -> {'OK' if service_holds_batch8 else 'REGRESSION'}")

    out = {
        "meta": {
            "backend": jax.default_backend(),
            "quick": args.quick,
            "buckets": [list(b) for b in BUCKETS],
            "mixed_shapes": [list(s) for s in MIXED_SHAPES],
        },
        "service_mixed": mixed,
        "naive_mixed": naive,
        "service_single_b8": svc8,
        "raw_batch8": raw8,
        "speedup_vs_naive": speedup_vs_naive,
        "mixed_ge_batch8": mixed_ge_batch8,
        "service_holds_batch8": service_holds_batch8,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(f"wrote {args.out}")
    if not (mixed_ge_batch8 and service_holds_batch8):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
