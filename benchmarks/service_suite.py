"""Mixed-resolution detection-service benchmark -> ``BENCH_service.json``.

Measures the continuous-batching ``DetectionService`` (``serve/detection.py``)
on the traffic shape the ROADMAP north star cares about — a queue of
requests carrying frames of heterogeneous resolutions — against two
references:

  * ``naive``   — the pre-service deployment: a per-frame ``detect`` loop
    at each request's native resolution (no batching, no buckets);
  * ``batch8``  — the PR-1 single-resolution fast path: ``detect_batch``
    over full batches of 8 at the bucket resolution.  The acceptance bar is
    that the *service*, fed single-bucket traffic at ``batch_size=8``,
    sustains at least this throughput — slotting/padding/double-buffering
    must not eat the batching win.

Reported per workload: requests/s, mean ms/request, and p50/p99 request
latency (submit -> result ready).  Latencies are measured under
drip-feed submission (requests arrive while the service runs), so they
reflect queueing + batching delay, not just compute.

The **deadline regime** exercises the QoS layer on a ``VirtualClock``:
scripted arrivals, a fixed modeled service time per bucket dispatch, and
deterministic completion stamping make the miss rate and the virtual p99 a
pure function of the scheduling policy — the 2-core bench host's timing
noise cannot flake the gate.  Two gates: slack deadlines must see zero
misses, and EDF scheduling must never miss more than the same traffic
pushed through the deadline-blind throughput scheduler (FIFO reference,
scored post-hoc against the same budgets).

Usage: PYTHONPATH=src python -m benchmarks.service_suite [--quick]
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import HoughConfig, LineDetector, PipelineConfig
from repro.data import make_scenario, scenario_names
from repro.serve.detection import (
    DetectionRequest, DetectionService, VirtualClock,
)

from .common import print_table

# The mixed-resolution ladder: requests cycle through these shapes (all
# land in the (120,160) or (240,320) buckets of DEFAULT_BUCKETS).
MIXED_SHAPES = ((120, 160), (240, 320), (96, 128), (240, 320), (180, 240))
BUCKETS = ((120, 160), (240, 320))

# Modeled per-dispatch service time per bucket (seconds) for the
# virtual-clock deadline simulation.  The values are in the ballpark of
# this host's measured dispatch times but their role is to be *fixed*:
# the miss-rate gate scores the scheduling policy, not the hardware.
MODEL_COST = {(120, 160): 0.02, (240, 320): 0.06}
# Deadline ladder for the tight regime: feasible-only-with-early-close,
# comfortable, and generous budgets interleaved across the shape cycle
# (the floor sits above the largest bucket's modeled dispatch cost, so
# every budget is feasible for a scheduler that closes batches early).
TIGHT_DEADLINES = (0.09, 0.20, 0.50)
SLACK_DEADLINE = 1.0
# Inter-arrival gap: ~55% modeled utilization.  The deadline regime probes
# *scheduling* (does grid-fill waiting bust tight budgets?), not overload —
# under overload no policy can win and throughput batching is optimal.
ARRIVAL_GAP_S = 0.02


def _cfg() -> PipelineConfig:
    return PipelineConfig(
        hough=HoughConfig(compact=True, max_edges="auto")
    )


def make_requests(n: int, shapes) -> list[np.ndarray]:
    fams = scenario_names()
    return [
        make_scenario(
            fams[i % len(fams)], *shapes[i % len(shapes)], seed=i
        ).image
        for i in range(n)
    ]


def percentile(xs: list[float], p: float) -> float:
    return float(np.percentile(np.asarray(xs), p))


def run_service(frames: list[np.ndarray], *, batch_size: int,
                drip: int) -> dict:
    """Drive a fresh service; drip-feed ``drip`` requests per step so the
    queue behaves like live traffic rather than one pre-loaded burst."""
    svc = DetectionService(_cfg(), buckets=BUCKETS, batch_size=batch_size)
    # warm every bucket's plan outside the timed window (compile cost is
    # a one-time property of the plan, not of the traffic), then zero the
    # counters so the JSON reports the timed workload only
    for shape in BUCKETS:
        svc.detect_many([np.zeros(shape, np.float32)] * batch_size)
    svc.dispatches = svc.completed = 0
    reqs = [DetectionRequest(uid=i, frame=f) for i, f in enumerate(frames)]
    t0 = time.perf_counter()
    pending = list(reqs)
    while pending:  # live traffic: a few arrivals between engine steps
        for r in pending[:drip]:
            svc.submit(r)
        pending = pending[drip:]
        svc.step()
    svc.run()  # traffic over: flush partial grids and drain in-flight
    dt = time.perf_counter() - t0
    svc.close()
    assert all(r.done for r in reqs)
    lats = [r.latency_s * 1e3 for r in reqs]
    return {
        "n_requests": len(reqs),
        "wall_s": dt,
        "requests_per_s": len(reqs) / dt,
        "ms_per_request": dt / len(reqs) * 1e3,
        "latency_ms_p50": percentile(lats, 50),
        "latency_ms_p99": percentile(lats, 99),
        "dispatches": svc.dispatches,
    }


def run_deadline_sim(frames: list[np.ndarray], deadlines: list[float], *,
                     batch_size: int, max_queue: int | None,
                     use_deadlines: bool) -> dict:
    """Deterministic deadline-regime simulation on a ``VirtualClock``.

    Requests arrive every ``ARRIVAL_GAP_S`` of virtual time; each dispatch
    advances the clock by the bucket's ``MODEL_COST`` and is drained
    immediately (deterministic completion stamps).  The detection compute
    itself runs for real — only *time* is modeled, so the miss rate and
    virtual latencies depend on nothing but the scheduling policy.

    ``use_deadlines=False`` is the FIFO reference: the same traffic runs
    through the deadline-blind throughput scheduler and is scored post-hoc
    against the same budgets.
    """
    clock = VirtualClock()
    svc = DetectionService(
        _cfg(), buckets=BUCKETS, batch_size=batch_size, clock=clock,
        max_queue=max_queue,   # same backpressure bound for EDF and FIFO
        ladder=False,          # this sim scores pure EDF-vs-FIFO
        # scheduling; the degradation ladder has its own benchmark
        # (fleet_suite.py) with ladder-on/off arms
    )
    for shape, grid in svc.grids.items():
        grid.est_s = MODEL_COST[shape]   # the sim's own cost model
        grid.est_measured = True         # modeled == measured for the sim
    reqs = [
        DetectionRequest(
            uid=i, frame=f,
            deadline_s=deadlines[i % len(deadlines)] if use_deadlines
            else None,
        )
        for i, f in enumerate(frames)
    ]
    i = 0
    for _ in range(100_000):
        while i < len(reqs) and i * ARRIVAL_GAP_S <= clock() + 1e-12:
            svc.submit(reqs[i])
            i += 1
        arrived_all = i == len(reqs)
        d0 = svc.dispatches
        svc.step(flush=arrived_all)
        if svc.dispatches > d0:
            shape, _, _ = svc.dispatch_log[-1]
            clock.advance(MODEL_COST[shape])
            svc.drain()                  # deterministic completion stamp
            continue
        if not arrived_all:
            # idle until the next arrival or the next early-close point,
            # whichever comes first (EDF wakes up to protect deadlines)
            targets = [i * ARRIVAL_GAP_S]
            targets += [
                g.tightest_deadline() - g.est_s
                for g in svc.grids.values() if g.active
            ]
            nxt = min(t for t in targets if np.isfinite(t))
            clock.advance(max(nxt - clock(), 0.0) or 1e-4)
        elif svc.queued or any(g.active for g in svc.grids.values()):
            clock.advance(1e-4)          # drain stragglers
        else:
            break
    svc.close()
    assert all(r.done for r in reqs)
    budgets = [deadlines[i % len(deadlines)] for i in range(len(reqs))]
    missed = [
        (not r.ok) or r.latency_s > b for r, b in zip(reqs, budgets)
    ]
    lats = [r.latency_s * 1e3 for r in reqs if r.ok]
    return {
        "n_requests": len(reqs),
        "policy": "edf" if use_deadlines else "fifo",
        "miss_rate": float(np.mean(missed)),
        "missed": int(np.sum(missed)),
        "shed_deadline": svc.shed_deadline,
        "rejected_queue_full": svc.rejected_queue_full,
        "completed_late": svc.completed_late,
        "latency_ms_p50_virtual": percentile(lats, 50) if lats else 0.0,
        "latency_ms_p99_virtual": percentile(lats, 99) if lats else 0.0,
        "dispatches": svc.dispatches,
    }


def run_naive(frames: list[np.ndarray]) -> dict:
    """The pre-service loop: one unbatched detect per request at native
    resolution (per-resolution plans still cached and warm)."""
    det = LineDetector(_cfg())
    shapes = sorted({f.shape[:2] for f in frames})
    for shape in shapes:  # warm per-shape compiles
        jax.block_until_ready(
            det.detect(jnp.zeros(shape, jnp.float32)).lines
        )
    t0 = time.perf_counter()
    last = None
    for f in frames:
        last = det.detect(jnp.asarray(f, jnp.float32))
    jax.block_until_ready(last.lines)
    dt = time.perf_counter() - t0
    return {
        "n_requests": len(frames),
        "wall_s": dt,
        "requests_per_s": len(frames) / dt,
        "ms_per_request": dt / len(frames) * 1e3,
    }


def run_batch8(shape: tuple[int, int], n: int) -> dict:
    """PR-1 reference: full detect_batch(8) dispatches at one resolution."""
    det = LineDetector(_cfg())
    frames = make_requests(n, (shape,))
    imgs = jnp.asarray(np.stack([f.astype(np.float32) for f in frames]))
    jax.block_until_ready(det.detect_batch(imgs[:8]).lines)  # warm
    t0 = time.perf_counter()
    last = None
    for k in range(0, n, 8):
        last = det.detect_batch(imgs[k:k + 8])
    jax.block_until_ready(last.lines)
    dt = time.perf_counter() - t0
    return {
        "n_requests": n,
        "wall_s": dt,
        "requests_per_s": n / dt,
        "ms_per_request": dt / n * 1e3,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer requests per workload")
    ap.add_argument("--out", default="BENCH_service.json")
    args = ap.parse_args()

    n_mixed = 20 if args.quick else 60
    n_single = 16 if args.quick else 48
    # min-wall over interleaved repeats: this 2-core host shows >2x
    # round-to-round contention noise, so 2 repeats flaked the in-run
    # svc8-vs-raw8 comparison; 3 keeps quick mode honest
    repeats = 3

    # Interleave repeats of every workload and keep each one's best run:
    # min-wall is robust to the CPU contention spikes a shared host shows,
    # and interleaving keeps A/B comparisons honest under drifting load.
    mixed_frames = make_requests(n_mixed, MIXED_SHAPES)
    single_frames = make_requests(n_single, ((240, 320),))
    best: dict[str, dict] = {}
    for _ in range(repeats):
        for key, fn in (
            # 1) mixed-resolution traffic through the service (the new
            #    capability), 2) the naive per-frame loop on the same
            #    traffic, 3) single-bucket service at batch 8, 4) the raw
            #    batch-8 fast path it must sustain
            ("mixed", lambda: run_service(mixed_frames, batch_size=4,
                                          drip=3)),
            ("naive", lambda: run_naive(mixed_frames)),
            ("svc8", lambda: run_service(single_frames, batch_size=8,
                                         drip=8)),
            ("raw8", lambda: run_batch8((240, 320), n_single)),
        ):
            r = fn()
            if key not in best or r["wall_s"] < best[key]["wall_s"]:
                best[key] = r
    mixed, naive, svc8, raw8 = (
        best["mixed"], best["naive"], best["svc8"], best["raw8"]
    )

    # Deadline regime: deterministic virtual-clock simulation — one run
    # each, no repeats (there is no noise to average away).
    n_dl = 24 if args.quick else 48
    dl_frames = make_requests(n_dl, MIXED_SHAPES)
    slack = run_deadline_sim(dl_frames, [SLACK_DEADLINE],
                             batch_size=4, max_queue=None,
                             use_deadlines=True)
    tight_edf = run_deadline_sim(dl_frames, list(TIGHT_DEADLINES),
                                 batch_size=4, max_queue=8,
                                 use_deadlines=True)
    tight_fifo = run_deadline_sim(dl_frames, list(TIGHT_DEADLINES),
                                  batch_size=4, max_queue=8,
                                  use_deadlines=False)

    rows = [
        ["service mixed (b=4)", mixed["n_requests"],
         f"{mixed['requests_per_s']:.2f}", f"{mixed['ms_per_request']:.1f}",
         f"{mixed['latency_ms_p50']:.1f}", f"{mixed['latency_ms_p99']:.1f}"],
        ["naive loop (mixed)", naive["n_requests"],
         f"{naive['requests_per_s']:.2f}", f"{naive['ms_per_request']:.1f}",
         "-", "-"],
        ["service 240x320 (b=8)", svc8["n_requests"],
         f"{svc8['requests_per_s']:.2f}", f"{svc8['ms_per_request']:.1f}",
         f"{svc8['latency_ms_p50']:.1f}", f"{svc8['latency_ms_p99']:.1f}"],
        ["detect_batch(8) 240x320", raw8["n_requests"],
         f"{raw8['requests_per_s']:.2f}", f"{raw8['ms_per_request']:.1f}",
         "-", "-"],
    ]
    print_table(
        "detection service (mixed-resolution continuous batching)",
        ["workload", "reqs", "req/s", "ms/req", "p50 ms", "p99 ms"],
        rows,
    )

    dl_rows = [
        [name, r["n_requests"], f"{r['miss_rate']:.1%}", r["shed_deadline"],
         r["rejected_queue_full"], r["completed_late"],
         f"{r['latency_ms_p50_virtual']:.1f}",
         f"{r['latency_ms_p99_virtual']:.1f}"]
        for name, r in (
            ("slack deadlines (EDF)", slack),
            ("tight deadlines (EDF)", tight_edf),
            ("tight deadlines (FIFO ref)", tight_fifo),
        )
    ]
    print_table(
        "deadline regime (virtual clock, modeled dispatch cost — "
        "deterministic)",
        ["workload", "reqs", "miss", "shed", "rej", "late",
         "p50 ms*", "p99 ms*"],
        dl_rows,
    )

    speedup_vs_naive = mixed["requests_per_s"] / naive["requests_per_s"]
    # Two gates, both required.  mixed_ge_batch8 is the PR acceptance bar
    # (mixed traffic sustains the batch-8 single-res path) but mixed
    # requests are partly cheaper than the 240x320 reference, so the
    # same-cost regression guard is service_holds_batch8: single-bucket
    # service vs the raw batch-8 loop, 5% tolerance for slot/padding
    # overhead.  speedup_vs_naive is recorded, not gated — on CPU-bound
    # hosts batching buys nothing per frame, so the naive loop can win
    # wall-clock there; the service's batching win needs an accelerator.
    mixed_ge_batch8 = (
        mixed["requests_per_s"] >= raw8["requests_per_s"]
    )
    service_holds_batch8 = (
        svc8["requests_per_s"] >= raw8["requests_per_s"] * 0.95
    )
    # Deterministic QoS gates: slack deadlines must see zero misses, and
    # EDF must never miss more than the deadline-blind FIFO reference on
    # the same traffic.  Virtual-clock scheduling cannot flake on a noisy
    # host, so both are hard gates.
    deadline_slack_zero_miss = slack["missed"] == 0
    deadline_edf_le_fifo = tight_edf["miss_rate"] <= tight_fifo["miss_rate"]

    print(f"\nmixed service vs naive loop: {speedup_vs_naive:.2f}x")
    print(f"mixed service vs batch-8 single-res path: "
          f"{mixed['requests_per_s']:.2f} vs {raw8['requests_per_s']:.2f} "
          f"req/s -> {'OK' if mixed_ge_batch8 else 'FAIL'}")
    print(f"service(b=8) vs raw batch-8 path within bucket: "
          f"{svc8['requests_per_s']:.2f} vs {raw8['requests_per_s']:.2f} "
          f"req/s -> {'OK' if service_holds_batch8 else 'REGRESSION'}")
    print(f"slack deadlines: {slack['missed']} misses "
          f"-> {'OK' if deadline_slack_zero_miss else 'FAIL'}")
    print(f"tight deadlines, EDF vs FIFO miss rate: "
          f"{tight_edf['miss_rate']:.1%} vs {tight_fifo['miss_rate']:.1%} "
          f"-> {'OK' if deadline_edf_le_fifo else 'FAIL'}")

    out = {
        "meta": {
            "backend": jax.default_backend(),
            "quick": args.quick,
            "buckets": [list(b) for b in BUCKETS],
            "mixed_shapes": [list(s) for s in MIXED_SHAPES],
            "deadline_model_cost_s": {
                f"{h}x{w}": c for (h, w), c in MODEL_COST.items()
            },
            "tight_deadlines_s": list(TIGHT_DEADLINES),
            "slack_deadline_s": SLACK_DEADLINE,
            "arrival_gap_s": ARRIVAL_GAP_S,
        },
        "service_mixed": mixed,
        "naive_mixed": naive,
        "service_single_b8": svc8,
        "raw_batch8": raw8,
        "deadline_slack": slack,
        "deadline_tight_edf": tight_edf,
        "deadline_tight_fifo": tight_fifo,
        "speedup_vs_naive": speedup_vs_naive,
        "mixed_ge_batch8": mixed_ge_batch8,
        "service_holds_batch8": service_holds_batch8,
        "deadline_slack_zero_miss": deadline_slack_zero_miss,
        "deadline_edf_le_fifo": deadline_edf_le_fifo,
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2, default=float)
    print(f"wrote {args.out}")
    if not (mixed_ge_batch8 and service_holds_batch8
            and deadline_slack_zero_miss and deadline_edf_le_fifo):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
