"""Sharded-fleet scaling + affinity + offload benchmark -> ``BENCH_mesh.json``.

Same discipline as ``fleet_suite.py``: everything runs on ONE shared
:class:`VirtualClock` with modeled per-dispatch service times
(``MODEL_COST``), so every number is a deterministic function of the
trace and the policy — replica parallelism is modeled as overlapping
per-replica busy windows on that clock, which is why the scaling curve
is meaningful on a 1-core bench host (and why it would be meaningless
as wall time there).  Three sections:

  * **scaling** — the fleet_suite Zipf session trace replayed at EQUAL
    offered load through 1, 2, 4, and 8 replicas
    (:class:`ShardedDetectionService`).  One replica is offered ~2.5x
    its modeled capacity (the fleet_suite overload point); each doubling
    adds capacity, so served throughput (served requests per second of
    makespan) must rise.  GATE: throughput at 8 replicas is *strictly*
    above 1 replica.
  * **affinity** — the same trace through a mid-size fleet twice:
    session-affinity routing ON (a session pins to the replica holding
    its tracker) vs OFF (pure load routing — the ablation: trackers
    fragment across replicas, so coast answers and union-gated
    dispatches evaporate).  GATE: tier-0 miss rate with affinity on is
    no worse than off.
  * **offload** — the speculative local/remote race
    (``core.offload.decide_race``; Schafhalter et al., PAPERS.md) on a
    scripted schedule: the low-res local pass lands at a fixed virtual
    time, the full-res remote pass at another, and the modeled network
    (``rtt_s``) decides the winner.  GATES: the local answer meets the
    deadline in EVERY arm (the guarantee the local tier exists for),
    and the remote answer upgrades exactly in the arms where
    ``remote_done + rtt <= deadline`` — including never from a dead
    remote replica.
  * **network** — the same races through the honest
    ``core.network.NetworkModel``.  In uplink-compat mode (free
    uplink, no jitter, no loss) the model must reproduce the ``rtt_s``
    arms bit-exactly (GATE: ``network_compat_bitexact``).  Then a
    seeded lossy matrix — lognormal-jittered legs at 5% per-leg loss,
    plus forced lost-uplink / lost-downlink / stalled-remote arms —
    must keep the local deadline guarantee on EVERY race with nonzero
    ``speculative_timeouts`` (a lost leg resolves by timeout, never a
    hang), upgrade exactly when the delivered answer is in hand by the
    deadline, and replay bit-identically (GATES:
    ``lossy_local_guarantee``, ``lossy_upgrade_iff_wins``,
    ``lossy_deterministic``).
  * **scale_up / diurnal** — the elastic half: ``add_replica`` grows
    the fleet 4 -> 8 a quarter into the trace (GATE: elastic
    throughput >= static 4), and a raised-cosine diurnal arrival ramp
    (1x -> 3x) must leave every request terminal (GATE:
    ``diurnal_all_terminal``).

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for
real replica placement (smoke.sh does; the committed BENCH_mesh.json is
generated that way); without the flag every replica shares the one host
device.  Either configuration is bit-reproducible run to run, but the
two differ in the last ulp of the detector's outputs (the flag splits
the host threadpool, changing XLA reduction order), which can nudge
tracker-fed decisions — compare numbers only within one configuration.

Usage: PYTHONPATH=src python -m benchmarks.mesh_suite [--quick]
"""

from __future__ import annotations

import argparse
import json
import math

import jax

from repro.core.network import NetworkConfig
from repro.core.offload import SpeculativeConfig
from repro.data import make_scenario
from repro.runtime import ServiceFaultInjector
from repro.serve.detection import (
    DetectionRequest, RequestStatus, VirtualClock,
)
from repro.serve.fleet import ShardedDetectionService

from .common import print_table
from .fleet_suite import (
    ARRIVAL_GAP_S, BATCH_SIZE, BUCKETS, MAX_QUEUE, MODEL_COST,
    TIER_DEADLINE, _cfg, _trace_frame, fleet_trace,
)

#: The race's scripted virtual-time schedule (seconds): local low-res
#: answer in hand, remote full-res computed, the caller's deadline.
RACE_LOCAL_DONE = 0.02
RACE_REMOTE_DONE = 0.07
RACE_DEADLINE = 0.10


# --- shared-clock fleet driver ----------------------------------------------

def drive_fleet(svc: ShardedDetectionService, clock: VirtualClock,
                reqs: list[DetectionRequest],
                arrivals: list[float],
                scale_up: tuple[float, int] | None = None) -> float:
    """Replay scripted arrivals through a replica fleet on one clock.

    Each replica owns a busy window: a dispatch at ``t`` occupies it
    until ``t + MODEL_COST[shape]``, and its completion is stepped
    exactly when the window closes — so R replicas overlap R windows on
    the shared clock and the makespan shrinks with R (the quantity the
    scaling gate measures).  Compute is real; time is modeled — the
    ``run_deadline_sim`` recipe, one busy window per replica instead of
    one global one.  ``scale_up=(t_s, n_add)`` grows the fleet by
    ``n_add`` replicas (``add_replica`` — estimator warmed, pinned
    sessions rebalanced) the first time the clock reaches ``t_s``.
    Returns the makespan (virtual seconds).
    """
    busy = {rep.index: clock() for rep in svc.replicas}
    i = 0
    for _ in range(500_000):
        while i < len(reqs) and arrivals[i] <= clock() + 1e-12:
            svc.submit(reqs[i])
            i += 1
        arrived_all = i == len(reqs)
        if scale_up is not None and clock() + 1e-12 >= scale_up[0]:
            for _ in range(scale_up[1]):
                svc.add_replica()
            scale_up = None
        if svc.faults is not None:
            k = svc._steps
            svc._steps += 1
            for victim in svc.faults.replicas_to_kill(k):
                svc.kill_replica(victim)
            for host in svc.faults.hosts_to_kill(k):
                svc.kill_host(host)
        pending = False
        for rep in svc.replicas:
            if not rep.alive:
                continue
            s = rep.service
            if busy.setdefault(rep.index, clock()) <= clock() + 1e-12:
                # the model says the device finished when the busy window
                # closed — which is now.  Block until the async result is
                # wall-ready so step()'s non-blocking reap poll retires it
                # HERE, not a window later: completion stamps (and the
                # late/miss classification built on them) must depend on
                # the modeled schedule, never on compile/exec wall time.
                for g in s.grids.values():
                    if g.in_flight is not None:
                        jax.block_until_ready(g.in_flight[1].lines)
                d0 = s.dispatches
                s.step(flush=arrived_all)
                if s.dispatches > d0:
                    shape, _, _ = s.dispatch_log[-1]
                    busy[rep.index] = clock() + MODEL_COST[shape]
            if (s.queued or any(g.active or g.in_flight is not None
                                for g in s.grids.values())):
                pending = True
        if arrived_all and not pending:
            break
        horizon = [busy[rep.index] for rep in svc.replicas
                   if rep.alive and busy[rep.index] > clock() + 1e-12]
        if not arrived_all:
            horizon.append(arrivals[i])
        if horizon:
            clock.advance(max(min(horizon) - clock(), 0.0) or 1e-4)
        else:
            clock.advance(1e-4)   # free replicas still draining queues
    makespan = clock()
    svc.close()
    return makespan


def _tier_stats(reqs: list[DetectionRequest], trace: list[dict]) -> dict:
    tiers: dict[str, dict] = {}
    for tier in (0, 1, 2):
        rs = [r for r, it in zip(reqs, trace) if it["tier"] == tier]
        refused = sum(r.status.refused for r in rs)
        late = sum(r.served and r.finished_at > r.deadline_at for r in rs)
        n = len(rs)
        tiers[f"tier{tier}"] = {
            "offered": n,
            "served_full": sum(r.ok for r in rs),
            "served_downshift": sum(
                r.status is RequestStatus.DEGRADED_DOWNSHIFT for r in rs),
            "served_coast": sum(
                r.status is RequestStatus.DEGRADED_COAST for r in rs),
            "refused": refused,
            "late": late,
            "miss_rate": (refused + late) / n if n else 0.0,
        }
    return tiers


def run_fleet_arm(trace: list[dict], *, n_replicas: int,
                  affinity: bool = True,
                  faults: ServiceFaultInjector | None = None,
                  scale_up: tuple[float, int] | None = None) -> dict:
    clock = VirtualClock()
    svc = ShardedDetectionService(
        _cfg(), n_replicas=n_replicas, clock=clock, buckets=BUCKETS,
        batch_size=BATCH_SIZE, max_queue=MAX_QUEUE, prefetch=False,
        affinity=affinity, faults=None,
    )
    svc.faults = faults
    for rep in svc.replicas:
        for shape, grid in rep.service.grids.items():
            grid.est_s = MODEL_COST[shape]
            grid.est_measured = True
    reqs = [
        DetectionRequest(
            uid=i, frame=_trace_frame(it), session_id=it["session"],
            priority=it["tier"], deadline_s=TIER_DEADLINE[it["tier"]],
        )
        for i, it in enumerate(trace)
    ]
    makespan = drive_fleet(svc, clock, reqs,
                           [it["arrival_s"] for it in trace],
                           scale_up=scale_up)
    served = sum(r.served for r in reqs)
    out = _tier_stats(reqs, trace)
    out.update({
        "n_replicas": n_replicas,
        "n_replicas_final": len(svc.alive_replicas),
        "affinity": affinity,
        "served": served,
        "offered": len(reqs),
        "makespan_s": makespan,
        "throughput_rps": served / makespan if makespan else 0.0,
        "all_terminal": all(r.is_terminal for r in reqs),
        "dispatches": svc.dispatches,
        "gated_dispatches": svc.gated_dispatches,
        "gated_share": (svc.gated_dispatches / svc.dispatches
                        if svc.dispatches else 0.0),
        "served_coast": sum(rep.service.served_coast
                            for rep in svc.replicas),
        "failed_on_death": svc.failed_on_death,
        "requeued": svc.requeued,
        "scale_up_migrations": svc.scale_up_migrations,
    })
    if scale_up is not None:
        out["scale_up_at_s"] = scale_up[0]
        out["scale_up_added"] = scale_up[1]
    if any("rate" in it for it in trace):
        # diurnal trace: split misses into peak vs trough half-cycles
        cut = (1.0 + max(it["rate"] for it in trace)) / 2.0

        def _miss(rs: list[DetectionRequest]) -> float:
            if not rs:
                return 0.0
            bad = sum(r.status.refused
                      or (r.served and r.finished_at > r.deadline_at)
                      for r in rs)
            return bad / len(rs)

        out["peak_miss"] = _miss(
            [r for r, it in zip(reqs, trace) if it["rate"] >= cut])
        out["trough_miss"] = _miss(
            [r for r, it in zip(reqs, trace) if it["rate"] < cut])
    return out


# --- diurnal load ramps -------------------------------------------------------

def diurnal_trace(n: int, *, seed: int = 0, period_s: float = 0.5,
                  peak: float = 3.0) -> list[dict]:
    """The fleet_suite Zipf trace with a diurnal arrival-rate ramp.

    The instantaneous rate multiplier sweeps ``1 -> peak -> 1`` on a
    raised cosine with period ``period_s`` (virtual seconds), so the
    inter-arrival gap is ``ARRIVAL_GAP_S / rate(t)``: troughs offer the
    fleet its baseline load, peaks offer ``peak`` times it — the shape
    a real fleet sees over a day, compressed onto the virtual clock.
    Each item keeps its ``rate`` so arms can split peak vs trough
    misses.  Deterministic: same (n, seed) -> same trace.
    """
    trace = fleet_trace(n, seed=seed)
    t = 0.0
    for it in trace:
        rate = 1.0 + (peak - 1.0) * 0.5 * (
            1.0 - math.cos(2.0 * math.pi * t / period_s)
        )
        it["arrival_s"] = t
        it["rate"] = rate
        t += ARRIVAL_GAP_S / rate
    return trace


# --- speculative offload race ------------------------------------------------

def run_offload_race(rtt_s: float, *, kill_remote: bool = False,
                     net: NetworkConfig | None = None) -> dict:
    """One scripted local/remote race on the shared clock.

    The local low-res pass is driven to completion at
    ``RACE_LOCAL_DONE``; the remote full-res pass computes at
    ``RACE_REMOTE_DONE``; ``decide_race`` then charges ``rtt_s`` on the
    downlink.  Every quantity below is exact virtual time — reruns are
    bit-identical.  With ``net`` the race runs through the
    ``NetworkModel`` path instead of the ``rtt_s`` compat path; pass
    the **uplink-compat** config (``uplink_fraction=0``, no jitter, no
    loss) and the schedule is unchanged, so the two paths must agree
    field-for-field — the ``network_compat_bitexact`` gate.
    """
    clock = VirtualClock()
    svc = ShardedDetectionService(
        _cfg(), n_replicas=2, clock=clock, buckets=BUCKETS,
        batch_size=1, prefetch=False, remote_replica=1,
        speculative=SpeculativeConfig(rtt_s=rtt_s,
                                      local_shape=BUCKETS[0],
                                      network=net),
    )
    for rep in svc.replicas:
        for shape, grid in rep.service.grids.items():
            grid.est_s = MODEL_COST[shape]
            grid.est_measured = True
    if kill_remote:
        svc.kill_replica(1)
    frame = make_scenario("straight", *BUCKETS[1], seed=0).image
    req = DetectionRequest(uid=0, frame=frame, deadline_s=RACE_DEADLINE)
    ticket = svc.submit_speculative(req)
    local_svc = svc.replicas[0].service
    local_svc.step()                                  # dispatch at t=0
    clock.jump_to(RACE_LOCAL_DONE)
    local_svc.step(flush=True)                        # local in hand
    if not kill_remote:
        remote_svc = svc.replicas[1].service
        remote_svc.step(flush=True)
        clock.jump_to(RACE_REMOTE_DONE)
        remote_svc.step(flush=True)                   # remote computed
    decision = svc.resolve_speculative(ticket)
    assert decision is not None
    expected_upgrade = (not kill_remote
                        and RACE_REMOTE_DONE + rtt_s <= RACE_DEADLINE)
    out = {
        "rtt_s": rtt_s,
        "remote_alive": not kill_remote,
        "local_done_at": decision.local_done_at,
        "remote_ready_at": (None if decision.remote_ready_at == float("inf")
                            else decision.remote_ready_at),
        "deadline_at": decision.deadline_at,
        "winner": decision.winner,
        "upgraded": decision.upgraded,
        "expected_upgrade": expected_upgrade,
        "upgrade_as_expected": decision.upgraded == expected_upgrade,
        "local_met_deadline": decision.local_met_deadline,
        "served_bucket": list(req.bucket),
        "served_in_time": bool(req.served
                               and req.finished_at <= req.deadline_at),
    }
    svc.close()
    return out


def run_network_race(net: NetworkConfig, *, lose_uplink: bool = False,
                     lose_downlink: bool = False,
                     stall_remote: bool = False) -> dict:
    """One seeded race on the honest network (jitter + loss + timeout).

    Event-driven on the shared clock: the local pass lands at
    ``RACE_LOCAL_DONE``; the remote clone is submitted when its sampled
    uplink arrives (never, if lost) and computes ``MODEL_COST`` later;
    the sampled downlink decides when — whether — the upgrade is in
    hand.  ``stall_remote`` models a remote that accepts the request
    but never completes (the dispatch-stall class): the race must then
    resolve by the deadline timeout, not hang.  Everything is a pure
    function of ``net.seed`` and the flags — reruns are bit-identical.
    """
    clock = VirtualClock()
    faults = ServiceFaultInjector(
        lose_uplink_races=(0,) if lose_uplink else (),
        lose_downlink_races=(0,) if lose_downlink else (),
    )
    svc = ShardedDetectionService(
        _cfg(), n_replicas=2, clock=clock, buckets=BUCKETS,
        batch_size=1, prefetch=False, remote_replica=1, faults=faults,
        speculative=SpeculativeConfig(local_shape=BUCKETS[0],
                                      network=net),
    )
    for rep in svc.replicas:
        for shape, grid in rep.service.grids.items():
            grid.est_s = MODEL_COST[shape]
            grid.est_measured = True
    frame = make_scenario("straight", *BUCKETS[1], seed=0).image
    req = DetectionRequest(uid=0, frame=frame, deadline_s=RACE_DEADLINE)
    ticket = svc.submit_speculative(req)
    local_svc = svc.replicas[0].service
    remote_svc = svc.replicas[1].service
    local_svc.step()                                  # dispatch at t=0
    t_up = ticket.remote_submit_at
    remote_done_at = None

    def pump_remote() -> None:
        nonlocal remote_done_at
        svc._pump_speculative()
        if ticket.remote_submitted and not stall_remote:
            remote_svc.step(flush=True)               # dispatch at t_up
            remote_done_at = clock() + MODEL_COST[BUCKETS[1]]

    if math.isfinite(t_up) and t_up <= RACE_LOCAL_DONE:
        clock.jump_to(t_up)
        pump_remote()
    clock.jump_to(RACE_LOCAL_DONE)
    local_svc.step(flush=True)                        # local in hand
    if (remote_done_at is None and not ticket.remote_submitted
            and math.isfinite(t_up)):
        clock.jump_to(t_up)
        pump_remote()
    if remote_done_at is not None:
        clock.jump_to(remote_done_at)
        remote_svc.step(flush=True)                   # remote computed
    decision = svc.resolve_speculative(ticket)
    if decision is None:
        # remote leg dead (lost uplink / stalled service): the timeout
        # resolves the race at the deadline — the unresolvable-race fix
        clock.jump_to(max(clock(), RACE_DEADLINE))
        decision = svc.resolve_speculative(ticket)
    assert decision is not None, "race must always resolve"
    up, down = ticket.uplink, ticket.downlink
    expected_upgrade = bool(
        not stall_remote and not up.lost and not down.lost
        and t_up + MODEL_COST[BUCKETS[1]] + down.delay_s <= RACE_DEADLINE
    )
    out = {
        "seed": net.seed,
        "uplink_lost": up.lost,
        "downlink_lost": down.lost,
        "stalled_remote": stall_remote,
        "uplink_s": up.delay_s,
        "downlink_s": down.delay_s,
        "remote_started_at": (None if not ticket.remote_submitted
                              else t_up),
        "local_done_at": decision.local_done_at,
        "remote_ready_at": (None if decision.remote_ready_at == math.inf
                            else decision.remote_ready_at),
        "deadline_at": decision.deadline_at,
        "winner": decision.winner,
        "upgraded": decision.upgraded,
        "timed_out": decision.timed_out,
        "expected_upgrade": expected_upgrade,
        "upgrade_as_expected": decision.upgraded == expected_upgrade,
        "local_met_deadline": decision.local_met_deadline,
        "served_in_time": bool(req.served
                               and req.finished_at <= req.deadline_at),
    }
    svc.close()
    return out


# --- main -------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter trace, fewer fleet sizes")
    ap.add_argument("--out", default="BENCH_mesh.json")
    args = ap.parse_args()

    n_trace = 120 if args.quick else 400
    sizes = (1, 2, 8) if args.quick else (1, 2, 4, 8)
    trace = fleet_trace(n_trace, seed=0)

    scaling = [run_fleet_arm(trace, n_replicas=r) for r in sizes]
    print_table(
        f"scaling @ equal offered load ({n_trace} reqs, ~2.5x one "
        f"replica's capacity, virtual clock)",
        ["replicas", "served", "makespan_s", "thr_rps", "tier0_miss",
         "coast", "gated_share"],
        [[a["n_replicas"], f"{a['served']}/{a['offered']}",
          f"{a['makespan_s']:.3f}", f"{a['throughput_rps']:.1f}",
          f"{a['tier0']['miss_rate']:.3f}", a["served_coast"],
          f"{a['gated_share']:.2f}"] for a in scaling],
    )

    # 4 replicas in BOTH modes: at 2-3 the quick trace's hot Zipf
    # sessions pin one replica into overload and the ablation inverts —
    # the gate compares like against like only at the full-mode width
    aff_n = 4
    aff_on = run_fleet_arm(trace, n_replicas=aff_n, affinity=True)
    aff_off = run_fleet_arm(trace, n_replicas=aff_n, affinity=False)
    print_table(
        f"session affinity ablation ({aff_n} replicas, same trace)",
        ["affinity", "served", "tier0_miss", "coast", "gated_share"],
        [[name, f"{a['served']}/{a['offered']}",
          f"{a['tier0']['miss_rate']:.3f}", a["served_coast"],
          f"{a['gated_share']:.2f}"]
         for name, a in (("on", aff_on), ("off", aff_off))],
    )

    races = [
        run_offload_race(0.01),                     # network fast: upgrade
        run_offload_race(0.05),                     # rtt blows the budget
        run_offload_race(0.01, kill_remote=True),   # dead remote replica
    ]
    print_table(
        f"speculative offload race (local@{RACE_LOCAL_DONE}s, "
        f"remote@{RACE_REMOTE_DONE}s, deadline {RACE_DEADLINE}s)",
        ["rtt_s", "remote", "winner", "upgraded", "as_expected",
         "local_met_deadline"],
        [[r["rtt_s"], "alive" if r["remote_alive"] else "DEAD",
          r["winner"], r["upgraded"], r["upgrade_as_expected"],
          r["local_met_deadline"]] for r in races],
    )

    # same three races through the NetworkModel in uplink-compat mode
    # (free uplink, whole RTT on the response, no jitter/loss): the two
    # paths must agree field-for-field, bit-exactly
    def _compat_net(rtt: float) -> NetworkConfig:
        return NetworkConfig(seed=0, rtt_median_s=rtt,
                             uplink_fraction=0.0, jitter_sigma=0.0,
                             loss=0.0)

    net_races = [
        run_offload_race(0.01, net=_compat_net(0.01)),
        run_offload_race(0.05, net=_compat_net(0.05)),
        run_offload_race(0.01, kill_remote=True, net=_compat_net(0.01)),
    ]
    compat_fields = ("rtt_s", "remote_alive", "local_done_at",
                     "remote_ready_at", "deadline_at", "winner",
                     "upgraded", "expected_upgrade", "upgrade_as_expected",
                     "local_met_deadline", "served_bucket",
                     "served_in_time")
    network_compat_bitexact = all(
        a[f] == b[f]
        for a, b in zip(races, net_races) for f in compat_fields
    )

    # lossy matrix: seeded jittered races at 5% per-leg loss, plus three
    # forced arms (lost uplink, lost downlink, stalled remote) so the
    # timeout path is exercised regardless of which seeds draw a loss
    lossy_cfg = {"rtt_median_s": 0.03, "uplink_fraction": 0.5,
                 "jitter_sigma": 0.6, "loss": 0.05}
    n_matrix = 12 if args.quick else 40

    def _matrix() -> list[dict]:
        return [run_network_race(NetworkConfig(seed=100 + i, **lossy_cfg))
                for i in range(n_matrix)]

    forced_net = {"rtt_median_s": 0.03, "uplink_fraction": 0.5,
                  "jitter_sigma": 0.0, "loss": 0.0}
    matrix = _matrix()
    forced = [
        run_network_race(NetworkConfig(seed=7, **forced_net),
                         lose_uplink=True),
        run_network_race(NetworkConfig(seed=8, **forced_net),
                         lose_downlink=True),
        run_network_race(NetworkConfig(seed=9, **forced_net),
                         stall_remote=True),
    ]
    lossy = matrix + forced
    n_lossy = len(lossy)
    uplink_lost = sum(r["uplink_lost"] for r in lossy)
    downlink_lost = sum(r["downlink_lost"] for r in lossy)
    timeouts = sum(r["timed_out"] for r in lossy)
    upgrades = sum(r["upgraded"] for r in lossy)
    lossy_deterministic = _matrix() == matrix
    print_table(
        f"lossy-network race matrix ({n_matrix} seeded + 3 forced arms; "
        f"rtt~LN(0.03, 0.6), loss 5%/leg, deadline {RACE_DEADLINE}s)",
        ["races", "loss_rate", "upgrade_rate", "timeout_rate",
         "guarantee", "iff_wins", "deterministic"],
        [[n_lossy,
          f"{(uplink_lost + downlink_lost) / (2 * n_lossy):.3f}",
          f"{upgrades / n_lossy:.3f}", f"{timeouts / n_lossy:.3f}",
          all(r["local_met_deadline"] and r["served_in_time"]
              for r in lossy),
          all(r["upgrade_as_expected"] for r in lossy),
          lossy_deterministic]],
    )

    # elastic scale-up: start at 4 replicas, add 4 more a quarter of the
    # way through, vs the same trace on a static 4.  The trace replays
    # at DOUBLE rate so four replicas are genuinely saturated — added
    # capacity then robustly shortens the makespan; at the base rate 4
    # replicas idle between arrivals and adding more only fragments
    # batches (window-quantization noise, not signal).
    stress = [dict(it, arrival_s=it["arrival_s"] * 0.5) for it in trace]
    static4 = run_fleet_arm(stress, n_replicas=4)
    scale_at = stress[-1]["arrival_s"] * 0.25
    elastic = run_fleet_arm(stress, n_replicas=4,
                            scale_up=(scale_at, 4))
    print_table(
        f"elastic scale-up (4 -> 8 replicas at t={scale_at:.3f}s)",
        ["arm", "replicas", "served", "thr_rps", "tier0_miss",
         "migrations"],
        [["static", 4, f"{static4['served']}/{static4['offered']}",
          f"{static4['throughput_rps']:.1f}",
          f"{static4['tier0']['miss_rate']:.3f}", 0],
         ["elastic", f"4->{elastic['n_replicas_final']}",
          f"{elastic['served']}/{elastic['offered']}",
          f"{elastic['throughput_rps']:.1f}",
          f"{elastic['tier0']['miss_rate']:.3f}",
          elastic["scale_up_migrations"]]],
    )

    # diurnal ramp: raised-cosine arrival rate, baseline -> 3x -> baseline
    dtrace = diurnal_trace(n_trace, seed=0, period_s=0.5, peak=3.0)
    diurnal = run_fleet_arm(dtrace, n_replicas=aff_n)
    print_table(
        f"diurnal ramp ({aff_n} replicas, rate 1x -> 3x raised cosine, "
        f"period 0.5s)",
        ["served", "thr_rps", "peak_miss", "trough_miss", "terminal"],
        [[f"{diurnal['served']}/{diurnal['offered']}",
          f"{diurnal['throughput_rps']:.1f}",
          f"{diurnal['peak_miss']:.3f}", f"{diurnal['trough_miss']:.3f}",
          diurnal["all_terminal"]]],
    )

    thr = {a["n_replicas"]: a["throughput_rps"] for a in scaling}
    gates = {
        "throughput_scales": thr[8] > thr[1],
        "affinity_tier0_no_worse": (
            aff_on["tier0"]["miss_rate"] <= aff_off["tier0"]["miss_rate"]
        ),
        "speculative_local_guarantee": all(
            r["local_met_deadline"] and r["served_in_time"]
            for r in races
        ),
        "speculative_upgrade_iff_wins": all(
            r["upgrade_as_expected"] for r in races
        ),
        "all_terminal": all(a["all_terminal"] for a in scaling)
        and aff_on["all_terminal"] and aff_off["all_terminal"],
        # the honest-network regime (this PR's tentpole)
        "network_compat_bitexact": network_compat_bitexact,
        "lossy_local_guarantee": all(
            r["local_met_deadline"] and r["served_in_time"]
            for r in lossy
        ) and timeouts > 0,
        "lossy_upgrade_iff_wins": all(
            r["upgrade_as_expected"] for r in lossy
        ),
        "lossy_deterministic": lossy_deterministic,
        "scaleup_throughput_no_worse": (
            elastic["throughput_rps"] >= static4["throughput_rps"]
        ),
        "diurnal_all_terminal": diurnal["all_terminal"],
    }
    print(f"\n  throughput: {thr[1]:.1f} rps @1 -> {thr[8]:.1f} rps @8 "
          f"-> {'ok' if gates['throughput_scales'] else 'VIOLATED'}")
    print(f"  affinity tier-0 miss {aff_on['tier0']['miss_rate']:.3f} "
          f"(on) vs {aff_off['tier0']['miss_rate']:.3f} (off) -> "
          f"{'ok' if gates['affinity_tier0_no_worse'] else 'VIOLATED'}")
    print(f"  speculative local guarantee: "
          f"{'ok' if gates['speculative_local_guarantee'] else 'VIOLATED'}")
    print(f"  speculative upgrade iff wins: "
          f"{'ok' if gates['speculative_upgrade_iff_wins'] else 'VIOLATED'}")
    print(f"  all requests terminal: "
          f"{'ok' if gates['all_terminal'] else 'VIOLATED'}")
    print(f"  network compat (sigma=0, loss=0) bit-exact with rtt_s: "
          f"{'ok' if gates['network_compat_bitexact'] else 'VIOLATED'}")
    print(f"  lossy local guarantee ({timeouts} timeouts over {n_lossy} "
          f"races): "
          f"{'ok' if gates['lossy_local_guarantee'] else 'VIOLATED'}")
    print(f"  lossy upgrade iff wins: "
          f"{'ok' if gates['lossy_upgrade_iff_wins'] else 'VIOLATED'}")
    print(f"  lossy matrix deterministic: "
          f"{'ok' if gates['lossy_deterministic'] else 'VIOLATED'}")
    print(f"  scale-up thr {elastic['throughput_rps']:.1f} rps "
          f"(4->8) vs static-4 {static4['throughput_rps']:.1f} -> "
          f"{'ok' if gates['scaleup_throughput_no_worse'] else 'VIOLATED'}")
    print(f"  diurnal ramp all terminal: "
          f"{'ok' if gates['diurnal_all_terminal'] else 'VIOLATED'}")

    payload = {
        "meta": {
            "quick": args.quick,
            "n_trace": n_trace,
            "sizes": list(sizes),
            "affinity_replicas": aff_n,
            "arrival_gap_s": ARRIVAL_GAP_S,
            "model_cost": {f"{k[0]}x{k[1]}": v
                           for k, v in MODEL_COST.items()},
            "tier_deadline_s": TIER_DEADLINE,
            "race": {"local_done_s": RACE_LOCAL_DONE,
                     "remote_done_s": RACE_REMOTE_DONE,
                     "deadline_s": RACE_DEADLINE},
            "lossy": dict(lossy_cfg, n_matrix=n_matrix,
                          deadline_s=RACE_DEADLINE),
            "diurnal": {"period_s": 0.5, "peak": 3.0},
        },
        "scaling": {str(a["n_replicas"]): a for a in scaling},
        "affinity": {"on": aff_on, "off": aff_off},
        "offload": races,
        "network": {
            "compat": net_races,
            "lossy": {
                "races": lossy,
                "n": n_lossy,
                "loss_rate": (uplink_lost + downlink_lost) / (2 * n_lossy),
                "upgrade_rate": upgrades / n_lossy,
                "timeout_rate": timeouts / n_lossy,
                "uplink_lost": uplink_lost,
                "downlink_lost": downlink_lost,
                "timeouts": timeouts,
            },
        },
        "scale_up": {"static_4": static4, "elastic_4_to_8": elastic},
        "diurnal": diurnal,
        "gates": gates,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"\nwrote {args.out}")
    if not all(gates.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
