"""Sharded-fleet scaling + affinity + offload benchmark -> ``BENCH_mesh.json``.

Same discipline as ``fleet_suite.py``: everything runs on ONE shared
:class:`VirtualClock` with modeled per-dispatch service times
(``MODEL_COST``), so every number is a deterministic function of the
trace and the policy — replica parallelism is modeled as overlapping
per-replica busy windows on that clock, which is why the scaling curve
is meaningful on a 1-core bench host (and why it would be meaningless
as wall time there).  Three sections:

  * **scaling** — the fleet_suite Zipf session trace replayed at EQUAL
    offered load through 1, 2, 4, and 8 replicas
    (:class:`ShardedDetectionService`).  One replica is offered ~2.5x
    its modeled capacity (the fleet_suite overload point); each doubling
    adds capacity, so served throughput (served requests per second of
    makespan) must rise.  GATE: throughput at 8 replicas is *strictly*
    above 1 replica.
  * **affinity** — the same trace through a mid-size fleet twice:
    session-affinity routing ON (a session pins to the replica holding
    its tracker) vs OFF (pure load routing — the ablation: trackers
    fragment across replicas, so coast answers and union-gated
    dispatches evaporate).  GATE: tier-0 miss rate with affinity on is
    no worse than off.
  * **offload** — the speculative local/remote race
    (``core.offload.decide_race``; Schafhalter et al., PAPERS.md) on a
    scripted schedule: the low-res local pass lands at a fixed virtual
    time, the full-res remote pass at another, and the modeled network
    (``rtt_s``) decides the winner.  GATES: the local answer meets the
    deadline in EVERY arm (the guarantee the local tier exists for),
    and the remote answer upgrades exactly in the arms where
    ``remote_done + rtt <= deadline`` — including never from a dead
    remote replica.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` for
real replica placement (smoke.sh does; the committed BENCH_mesh.json is
generated that way); without the flag every replica shares the one host
device.  Either configuration is bit-reproducible run to run, but the
two differ in the last ulp of the detector's outputs (the flag splits
the host threadpool, changing XLA reduction order), which can nudge
tracker-fed decisions — compare numbers only within one configuration.

Usage: PYTHONPATH=src python -m benchmarks.mesh_suite [--quick]
"""

from __future__ import annotations

import argparse
import json

from repro.core.offload import SpeculativeConfig
from repro.data import make_scenario
from repro.runtime import ServiceFaultInjector
from repro.serve.detection import (
    DetectionRequest, RequestStatus, VirtualClock,
)
from repro.serve.fleet import ShardedDetectionService

from .common import print_table
from .fleet_suite import (
    ARRIVAL_GAP_S, BATCH_SIZE, BUCKETS, MAX_QUEUE, MODEL_COST,
    TIER_DEADLINE, _cfg, _trace_frame, fleet_trace,
)

#: The race's scripted virtual-time schedule (seconds): local low-res
#: answer in hand, remote full-res computed, the caller's deadline.
RACE_LOCAL_DONE = 0.02
RACE_REMOTE_DONE = 0.07
RACE_DEADLINE = 0.10


# --- shared-clock fleet driver ----------------------------------------------

def drive_fleet(svc: ShardedDetectionService, clock: VirtualClock,
                reqs: list[DetectionRequest],
                arrivals: list[float]) -> float:
    """Replay scripted arrivals through a replica fleet on one clock.

    Each replica owns a busy window: a dispatch at ``t`` occupies it
    until ``t + MODEL_COST[shape]``, and its completion is stepped
    exactly when the window closes — so R replicas overlap R windows on
    the shared clock and the makespan shrinks with R (the quantity the
    scaling gate measures).  Compute is real; time is modeled — the
    ``run_deadline_sim`` recipe, one busy window per replica instead of
    one global one.  Returns the makespan (virtual seconds).
    """
    busy = {rep.index: clock() for rep in svc.replicas}
    i = 0
    for _ in range(500_000):
        while i < len(reqs) and arrivals[i] <= clock() + 1e-12:
            svc.submit(reqs[i])
            i += 1
        arrived_all = i == len(reqs)
        if svc.faults is not None:
            k = svc._steps
            svc._steps += 1
            for victim in svc.faults.replicas_to_kill(k):
                svc.kill_replica(victim)
        pending = False
        for rep in svc.replicas:
            if not rep.alive:
                continue
            s = rep.service
            if busy[rep.index] <= clock() + 1e-12:
                d0 = s.dispatches
                s.step(flush=arrived_all)
                if s.dispatches > d0:
                    shape, _, _ = s.dispatch_log[-1]
                    busy[rep.index] = clock() + MODEL_COST[shape]
            if (s.queued or any(g.active or g.in_flight is not None
                                for g in s.grids.values())):
                pending = True
        if arrived_all and not pending:
            break
        horizon = [busy[rep.index] for rep in svc.replicas
                   if rep.alive and busy[rep.index] > clock() + 1e-12]
        if not arrived_all:
            horizon.append(arrivals[i])
        if horizon:
            clock.advance(max(min(horizon) - clock(), 0.0) or 1e-4)
        else:
            clock.advance(1e-4)   # free replicas still draining queues
    makespan = clock()
    svc.close()
    return makespan


def _tier_stats(reqs: list[DetectionRequest], trace: list[dict]) -> dict:
    tiers: dict[str, dict] = {}
    for tier in (0, 1, 2):
        rs = [r for r, it in zip(reqs, trace) if it["tier"] == tier]
        refused = sum(r.status.refused for r in rs)
        late = sum(r.served and r.finished_at > r.deadline_at for r in rs)
        n = len(rs)
        tiers[f"tier{tier}"] = {
            "offered": n,
            "served_full": sum(r.ok for r in rs),
            "served_downshift": sum(
                r.status is RequestStatus.DEGRADED_DOWNSHIFT for r in rs),
            "served_coast": sum(
                r.status is RequestStatus.DEGRADED_COAST for r in rs),
            "refused": refused,
            "late": late,
            "miss_rate": (refused + late) / n if n else 0.0,
        }
    return tiers


def run_fleet_arm(trace: list[dict], *, n_replicas: int,
                  affinity: bool = True,
                  faults: ServiceFaultInjector | None = None) -> dict:
    clock = VirtualClock()
    svc = ShardedDetectionService(
        _cfg(), n_replicas=n_replicas, clock=clock, buckets=BUCKETS,
        batch_size=BATCH_SIZE, max_queue=MAX_QUEUE, prefetch=False,
        affinity=affinity, faults=None,
    )
    svc.faults = faults
    for rep in svc.replicas:
        for shape, grid in rep.service.grids.items():
            grid.est_s = MODEL_COST[shape]
            grid.est_measured = True
    reqs = [
        DetectionRequest(
            uid=i, frame=_trace_frame(it), session_id=it["session"],
            priority=it["tier"], deadline_s=TIER_DEADLINE[it["tier"]],
        )
        for i, it in enumerate(trace)
    ]
    makespan = drive_fleet(svc, clock, reqs,
                           [it["arrival_s"] for it in trace])
    served = sum(r.served for r in reqs)
    out = _tier_stats(reqs, trace)
    out.update({
        "n_replicas": n_replicas,
        "affinity": affinity,
        "served": served,
        "offered": len(reqs),
        "makespan_s": makespan,
        "throughput_rps": served / makespan if makespan else 0.0,
        "all_terminal": all(r.is_terminal for r in reqs),
        "dispatches": svc.dispatches,
        "gated_dispatches": svc.gated_dispatches,
        "gated_share": (svc.gated_dispatches / svc.dispatches
                        if svc.dispatches else 0.0),
        "served_coast": sum(rep.service.served_coast
                            for rep in svc.replicas),
        "failed_on_death": svc.failed_on_death,
        "requeued": svc.requeued,
    })
    return out


# --- speculative offload race ------------------------------------------------

def run_offload_race(rtt_s: float, *, kill_remote: bool = False) -> dict:
    """One scripted local/remote race on the shared clock.

    The local low-res pass is driven to completion at
    ``RACE_LOCAL_DONE``; the remote full-res pass computes at
    ``RACE_REMOTE_DONE``; ``decide_race`` then charges ``rtt_s`` on the
    downlink.  Every quantity below is exact virtual time — reruns are
    bit-identical.
    """
    clock = VirtualClock()
    svc = ShardedDetectionService(
        _cfg(), n_replicas=2, clock=clock, buckets=BUCKETS,
        batch_size=1, prefetch=False, remote_replica=1,
        speculative=SpeculativeConfig(rtt_s=rtt_s,
                                      local_shape=BUCKETS[0]),
    )
    for rep in svc.replicas:
        for shape, grid in rep.service.grids.items():
            grid.est_s = MODEL_COST[shape]
            grid.est_measured = True
    if kill_remote:
        svc.kill_replica(1)
    frame = make_scenario("straight", *BUCKETS[1], seed=0).image
    req = DetectionRequest(uid=0, frame=frame, deadline_s=RACE_DEADLINE)
    ticket = svc.submit_speculative(req)
    local_svc = svc.replicas[0].service
    local_svc.step()                                  # dispatch at t=0
    clock.jump_to(RACE_LOCAL_DONE)
    local_svc.step(flush=True)                        # local in hand
    if not kill_remote:
        remote_svc = svc.replicas[1].service
        remote_svc.step(flush=True)
        clock.jump_to(RACE_REMOTE_DONE)
        remote_svc.step(flush=True)                   # remote computed
    decision = svc.resolve_speculative(ticket)
    assert decision is not None
    expected_upgrade = (not kill_remote
                        and RACE_REMOTE_DONE + rtt_s <= RACE_DEADLINE)
    out = {
        "rtt_s": rtt_s,
        "remote_alive": not kill_remote,
        "local_done_at": decision.local_done_at,
        "remote_ready_at": (None if decision.remote_ready_at == float("inf")
                            else decision.remote_ready_at),
        "deadline_at": decision.deadline_at,
        "winner": decision.winner,
        "upgraded": decision.upgraded,
        "expected_upgrade": expected_upgrade,
        "upgrade_as_expected": decision.upgraded == expected_upgrade,
        "local_met_deadline": decision.local_met_deadline,
        "served_bucket": list(req.bucket),
        "served_in_time": bool(req.served
                               and req.finished_at <= req.deadline_at),
    }
    svc.close()
    return out


# --- main -------------------------------------------------------------------

def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="shorter trace, fewer fleet sizes")
    ap.add_argument("--out", default="BENCH_mesh.json")
    args = ap.parse_args()

    n_trace = 120 if args.quick else 400
    sizes = (1, 2, 8) if args.quick else (1, 2, 4, 8)
    trace = fleet_trace(n_trace, seed=0)

    scaling = [run_fleet_arm(trace, n_replicas=r) for r in sizes]
    print_table(
        f"scaling @ equal offered load ({n_trace} reqs, ~2.5x one "
        f"replica's capacity, virtual clock)",
        ["replicas", "served", "makespan_s", "thr_rps", "tier0_miss",
         "coast", "gated_share"],
        [[a["n_replicas"], f"{a['served']}/{a['offered']}",
          f"{a['makespan_s']:.3f}", f"{a['throughput_rps']:.1f}",
          f"{a['tier0']['miss_rate']:.3f}", a["served_coast"],
          f"{a['gated_share']:.2f}"] for a in scaling],
    )

    aff_n = 2 if args.quick else 4
    aff_on = run_fleet_arm(trace, n_replicas=aff_n, affinity=True)
    aff_off = run_fleet_arm(trace, n_replicas=aff_n, affinity=False)
    print_table(
        f"session affinity ablation ({aff_n} replicas, same trace)",
        ["affinity", "served", "tier0_miss", "coast", "gated_share"],
        [[name, f"{a['served']}/{a['offered']}",
          f"{a['tier0']['miss_rate']:.3f}", a["served_coast"],
          f"{a['gated_share']:.2f}"]
         for name, a in (("on", aff_on), ("off", aff_off))],
    )

    races = [
        run_offload_race(0.01),                     # network fast: upgrade
        run_offload_race(0.05),                     # rtt blows the budget
        run_offload_race(0.01, kill_remote=True),   # dead remote replica
    ]
    print_table(
        f"speculative offload race (local@{RACE_LOCAL_DONE}s, "
        f"remote@{RACE_REMOTE_DONE}s, deadline {RACE_DEADLINE}s)",
        ["rtt_s", "remote", "winner", "upgraded", "as_expected",
         "local_met_deadline"],
        [[r["rtt_s"], "alive" if r["remote_alive"] else "DEAD",
          r["winner"], r["upgraded"], r["upgrade_as_expected"],
          r["local_met_deadline"]] for r in races],
    )

    thr = {a["n_replicas"]: a["throughput_rps"] for a in scaling}
    gates = {
        "throughput_scales": thr[8] > thr[1],
        "affinity_tier0_no_worse": (
            aff_on["tier0"]["miss_rate"] <= aff_off["tier0"]["miss_rate"]
        ),
        "speculative_local_guarantee": all(
            r["local_met_deadline"] and r["served_in_time"]
            for r in races
        ),
        "speculative_upgrade_iff_wins": all(
            r["upgrade_as_expected"] for r in races
        ),
        "all_terminal": all(a["all_terminal"] for a in scaling)
        and aff_on["all_terminal"] and aff_off["all_terminal"],
    }
    print(f"\n  throughput: {thr[1]:.1f} rps @1 -> {thr[8]:.1f} rps @8 "
          f"-> {'ok' if gates['throughput_scales'] else 'VIOLATED'}")
    print(f"  affinity tier-0 miss {aff_on['tier0']['miss_rate']:.3f} "
          f"(on) vs {aff_off['tier0']['miss_rate']:.3f} (off) -> "
          f"{'ok' if gates['affinity_tier0_no_worse'] else 'VIOLATED'}")
    print(f"  speculative local guarantee: "
          f"{'ok' if gates['speculative_local_guarantee'] else 'VIOLATED'}")
    print(f"  speculative upgrade iff wins: "
          f"{'ok' if gates['speculative_upgrade_iff_wins'] else 'VIOLATED'}")
    print(f"  all requests terminal: "
          f"{'ok' if gates['all_terminal'] else 'VIOLATED'}")

    payload = {
        "meta": {
            "quick": args.quick,
            "n_trace": n_trace,
            "sizes": list(sizes),
            "affinity_replicas": aff_n,
            "arrival_gap_s": ARRIVAL_GAP_S,
            "model_cost": {f"{k[0]}x{k[1]}": v
                           for k, v in MODEL_COST.items()},
            "tier_deadline_s": TIER_DEADLINE,
            "race": {"local_done_s": RACE_LOCAL_DONE,
                     "remote_done_s": RACE_REMOTE_DONE,
                     "deadline_s": RACE_DEADLINE},
        },
        "scaling": {str(a["n_replicas"]): a for a in scaling},
        "affinity": {"on": aff_on, "off": aff_off},
        "offload": races,
        "gates": gates,
    }
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2, default=float)
    print(f"\nwrote {args.out}")
    if not all(gates.values()):
        raise SystemExit(1)


if __name__ == "__main__":
    main()
