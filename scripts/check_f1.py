#!/usr/bin/env python
"""CI detection-quality gate: per-family F1 must not regress.

Two sections, each compared against the committed baseline
``benchmarks/baselines/f1_baseline.json`` and failing CI (nonzero exit) on
any regression, so a perf PR that trades accuracy for speed fails loudly
instead of landing silently:

  * ``scenarios`` — static per-family F1 from ``BENCH_scenarios.json``
    (batch-8 ``auto`` rows, the deployment configuration): F1 >= baseline
    F1 - tolerance and >= the family's registered floor.
  * ``quantized`` — the low-precision gradient tiers (``CannyConfig.
    grad_dtype`` f16/int8), also from ``BENCH_scenarios.json``: per
    (family, tier) F1 >= baseline - tolerance and >= the family's floor,
    so precision cuts keep paying only while they stay accurate.
  * ``drive_cycles`` — the temporal path, from ``BENCH_tracking.json``:
    tracked F1 over each gated family's standard drive cycle >= baseline
    - tolerance, and on the noisy families tracked F1 >= the same run's
    per-frame F1 (the temporal layer must keep paying for itself).
  * ``coast`` — the degradation-ladder floor, from ``BENCH_fleet.json``:
    coast-only F1 (answers from ``LaneTracker.predict_tracks``, the
    detector never sees the frame) on each gated family's drive cycle
    >= baseline - tolerance, so overload answers stay above a committed
    quality floor instead of quietly rotting.

The generators, the detector, and the tracker are deterministic, so a
genuine improvement shows up as an exact F1 increase — record it with
``--update`` (review the diff like any other baseline bump).

Usage:
  PYTHONPATH=src python scripts/check_f1.py [--bench BENCH_scenarios.json]
      [--tracking-bench BENCH_tracking.json]
      [--fleet-bench BENCH_fleet.json]
      [--baseline benchmarks/baselines/f1_baseline.json]
      [--tolerance 0.0] [--update]
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def batch8_auto_f1(bench: dict) -> dict[str, dict]:
    """{family: {"f1": ..., "f1_floor": ...}} from the scenario rows."""
    out = {}
    for r in bench["rows"]:
        if r["mode"] == "auto" and r["batch"] == 8:
            out[r["scenario"]] = {
                "f1": float(r["f1"]), "f1_floor": float(r["f1_floor"]),
            }
    return out


def quantized_f1(bench: dict) -> dict[str, dict]:
    """{"family/grad_dtype": {"f1", "f1_floor"}} from the scenario-suite
    quantized rows (absent in bench files predating the tiers)."""
    out = {}
    for r in bench.get("quantized", []):
        out[f"{r['scenario']}/{r['grad_dtype']}"] = {
            "f1": float(r["f1"]), "f1_floor": float(r["f1_floor"]),
        }
    return out


def drive_cycle_f1(bench: dict) -> dict[str, dict]:
    """{family: {"f1_tracked", "f1_per_frame", "noisy"}} from the
    tracking-suite rows (full and --quick runs both cover the gated
    families the baseline pins)."""
    return {
        r["family"]: {
            "f1_tracked": float(r["f1_tracked"]),
            "f1_per_frame": float(r["f1_per_frame"]),
            "noisy": bool(r["noisy"]),
        }
        for r in bench["rows"]
    }


def coast_f1(bench: dict) -> dict[str, dict]:
    """{family: {"f1_coast", "n_scored"}} from the fleet-suite coast
    section (coast-only answers scored against drive-cycle truth; the
    cycle length is fixed across --quick and full runs, so the value is
    one deterministic number per family)."""
    return {
        name: {"f1_coast": float(v["f1_coast"]),
               "n_scored": int(v["n_scored"])}
        for name, v in bench.get("coast_quality", {}).items()
    }


def _load(path: str, what: str) -> dict | None:
    if not os.path.exists(path):
        print(f"check_f1: {path} not found — run {what} first",
              file=sys.stderr)
        return None
    with open(path) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="BENCH_scenarios.json")
    ap.add_argument("--tracking-bench", default="BENCH_tracking.json")
    ap.add_argument("--fleet-bench", default="BENCH_fleet.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/f1_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.0,
                    help="allowed F1 drop before failing (default: none)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current bench runs")
    args = ap.parse_args()

    sc_bench = _load(args.bench, "`python -m benchmarks.scenario_suite`")
    if sc_bench is None:
        return 2
    current = batch8_auto_f1(sc_bench)
    quantized = quantized_f1(sc_bench)
    tr_bench = _load(args.tracking_bench,
                     "`python -m benchmarks.tracking_suite`")
    if tr_bench is None:
        return 2
    cycles = drive_cycle_f1(tr_bench)
    fl_bench = _load(args.fleet_bench, "`python -m benchmarks.fleet_suite`")
    if fl_bench is None:
        return 2
    coasts = coast_f1(fl_bench)

    if args.update:
        if tr_bench.get("meta", {}).get("quick"):
            print("check_f1: refusing --update from a --quick tracking "
                  "run — it covers only the gated subset and would drop "
                  "the other families' drive-cycle pins; rerun "
                  "`python -m benchmarks.tracking_suite` (full)",
                  file=sys.stderr)
            return 2
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        payload = {
            "scenarios": current,
            "quantized": quantized,
            "drive_cycles": {
                name: {"f1_tracked": v["f1_tracked"]}
                for name, v in sorted(cycles.items())
            },
            "coast": {
                name: {"f1_coast": v["f1_coast"]}
                for name, v in sorted(coasts.items())
            },
        }
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"check_f1: wrote baseline for {len(current)} families + "
              f"{len(quantized)} quantized tiers + {len(cycles)} drive "
              f"cycles + {len(coasts)} coast floors -> {args.baseline}")
        return 0

    baseline = _load(args.baseline, "`scripts/check_f1.py --update`")
    if baseline is None:
        return 2

    failures, new_families = [], []
    for name, base in sorted(baseline["scenarios"].items()):
        if name not in current:
            failures.append(f"{name}: family missing from bench run")
            continue
        cur = current[name]
        if cur["f1"] < base["f1"] - args.tolerance:
            failures.append(
                f"{name}: F1 {cur['f1']:.4f} < baseline {base['f1']:.4f}"
            )
        if cur["f1"] < cur["f1_floor"]:
            failures.append(
                f"{name}: F1 {cur['f1']:.4f} below registered floor "
                f"{cur['f1_floor']:.2f}"
            )
    # quantized tiers: same bench file as scenarios, so a pinned tier
    # missing from the run means the suite stopped emitting it — a
    # vanished gate, not a skippable cell
    checked_quant = 0
    for name, base in sorted(baseline.get("quantized", {}).items()):
        if name not in quantized:
            failures.append(
                f"{name} [quantized]: tier missing from bench run"
            )
            continue
        cur = quantized[name]
        checked_quant += 1
        if cur["f1"] < base["f1"] - args.tolerance:
            failures.append(
                f"{name} [quantized]: F1 {cur['f1']:.4f} < baseline "
                f"{base['f1']:.4f}"
            )
        if cur["f1"] < cur["f1_floor"]:
            failures.append(
                f"{name} [quantized]: F1 {cur['f1']:.4f} below registered "
                f"floor {cur['f1_floor']:.2f}"
            )
    # drive cycles: a --quick run covers only the gated subset, so absent
    # families are skipped there — but a FULL run must cover every pinned
    # family (a silently vanished family is a vanished regression gate)
    tracking_quick = bool(tr_bench.get("meta", {}).get("quick"))
    checked_cycles = 0
    for name, base in sorted(baseline.get("drive_cycles", {}).items()):
        if name not in cycles:
            if not tracking_quick:
                failures.append(
                    f"{name} [cycle]: family missing from full tracking "
                    f"bench run"
                )
            continue
        cur = cycles[name]
        checked_cycles += 1
        if cur["f1_tracked"] < base["f1_tracked"] - args.tolerance:
            failures.append(
                f"{name} [cycle]: tracked F1 {cur['f1_tracked']:.4f} < "
                f"baseline {base['f1_tracked']:.4f}"
            )
        if cur["noisy"] and cur["f1_tracked"] < cur["f1_per_frame"]:
            failures.append(
                f"{name} [cycle]: tracked F1 {cur['f1_tracked']:.4f} "
                f"below per-frame {cur['f1_per_frame']:.4f} on a noisy "
                f"family"
            )
    if checked_cycles == 0:
        failures.append("no drive-cycle family overlaps the baseline — "
                        "tracking bench and baseline disagree on families")
    # coast floors: the fleet suite runs every gated family at the same
    # cycle length in quick and full mode, so absence is always a failure
    checked_coast = 0
    for name, base in sorted(baseline.get("coast", {}).items()):
        if name not in coasts:
            failures.append(
                f"{name} [coast]: family missing from fleet bench run"
            )
            continue
        cur = coasts[name]
        checked_coast += 1
        if cur["f1_coast"] < base["f1_coast"] - args.tolerance:
            failures.append(
                f"{name} [coast]: coast F1 {cur['f1_coast']:.4f} < "
                f"baseline {base['f1_coast']:.4f}"
            )
    new_families = sorted(set(current) - set(baseline["scenarios"]))
    if new_families:
        print(f"check_f1: families without baseline (add with --update): "
              f"{', '.join(new_families)}")

    if failures:
        print("check_f1: FAIL")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"check_f1: OK — {len(baseline['scenarios'])} families, "
          f"{checked_quant} quantized tiers, {checked_cycles} drive "
          f"cycles, and {checked_coast} coast floors at or above baseline"
          + (f" (tolerance {args.tolerance})" if args.tolerance else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
