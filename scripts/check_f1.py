#!/usr/bin/env python
"""CI detection-quality gate: per-family F1 must not regress.

Compares the per-family F1 of a fresh ``BENCH_scenarios.json`` (written by
``benchmarks/scenario_suite.py``) against the committed baseline
``benchmarks/baselines/f1_baseline.json`` and exits nonzero on any
regression, so a perf PR that trades accuracy for speed fails CI instead of
landing silently.  The scenario generators and the detector are
deterministic, so a genuine improvement shows up as an exact F1 increase —
record it with ``--update`` (review the diff like any other baseline bump).

Checked per family (batch-8 ``auto`` rows — the deployment configuration):
  * F1 >= baseline F1 - tolerance (default 0.0: bit-deterministic suite),
  * F1 >= the family's registered floor (double-checks the suite's own bar).

Usage:
  PYTHONPATH=src python scripts/check_f1.py [--bench BENCH_scenarios.json]
      [--baseline benchmarks/baselines/f1_baseline.json]
      [--tolerance 0.0] [--update]
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def batch8_auto_f1(bench: dict) -> dict[str, dict]:
    """{family: {"f1": ..., "f1_floor": ...}} from the suite's rows."""
    out = {}
    for r in bench["rows"]:
        if r["mode"] == "auto" and r["batch"] == 8:
            out[r["scenario"]] = {
                "f1": float(r["f1"]), "f1_floor": float(r["f1_floor"]),
            }
    return out


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="BENCH_scenarios.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/f1_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.0,
                    help="allowed F1 drop before failing (default: none)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current bench run")
    args = ap.parse_args()

    if not os.path.exists(args.bench):
        print(f"check_f1: {args.bench} not found — run "
              f"`python -m benchmarks.scenario_suite` first", file=sys.stderr)
        return 2
    with open(args.bench) as f:
        current = batch8_auto_f1(json.load(f))

    if args.update:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(current, f, indent=2, sort_keys=True)
        print(f"check_f1: wrote baseline for {len(current)} families "
              f"-> {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"check_f1: no baseline at {args.baseline}; create one with "
              f"--update", file=sys.stderr)
        return 2
    with open(args.baseline) as f:
        baseline = json.load(f)

    failures, new_families = [], []
    for name, base in sorted(baseline.items()):
        if name not in current:
            failures.append(f"{name}: family missing from bench run")
            continue
        cur = current[name]
        if cur["f1"] < base["f1"] - args.tolerance:
            failures.append(
                f"{name}: F1 {cur['f1']:.4f} < baseline {base['f1']:.4f}"
            )
        if cur["f1"] < cur["f1_floor"]:
            failures.append(
                f"{name}: F1 {cur['f1']:.4f} below registered floor "
                f"{cur['f1_floor']:.2f}"
            )
    new_families = sorted(set(current) - set(baseline))
    if new_families:
        print(f"check_f1: families without baseline (add with --update): "
              f"{', '.join(new_families)}")

    if failures:
        print("check_f1: FAIL")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"check_f1: OK — {len(baseline)} families at or above baseline"
          + (f" (tolerance {args.tolerance})" if args.tolerance else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
