#!/usr/bin/env bash
# Fast CI smoke: quick paper-table benches + the non-slow test suite +
# the detection-quality regression gate.
# The slow marker (pytest.ini) excludes the multi-device subprocess and
# convergence tests; the full tier-1 sweep is `python -m pytest -q`.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python -m benchmarks.run --quick
# fast scenario subset first: the detection-quality net fails loudly and
# early if a change regresses accuracy on any road-scene family
python -m pytest -q -m "scenarios and not slow" -x
# serving layer next: plan resolution + the continuous-batching detection
# service (pytest.ini marker `serve`)
python -m pytest -q -m "serve and not slow" -x
# deadline/QoS layer: virtual-clock tests, fully deterministic (marker
# `deadline`) — backpressure, EDF + early close, prefetch staging, render
python -m pytest -q -m "deadline and not slow" -x
# temporal layer: drive cycles, LaneTracker lifecycle, prediction-gated
# Hough bit-exactness, tracked-vs-per-frame quality (marker `tracking`)
python -m pytest -q -m "tracking and not slow" -x
# robustness layer: degradation ladder, fault injection, overload
# shedding, coast semantics (marker `fleet`)
python -m pytest -q -m "fleet and not slow" -x
# fused hot path: kernel parity, corridor filtering, exact-count tiering,
# steady-state engagement (marker `fused`)
python -m pytest -q -m "fused and not slow" -x
# perception-to-control layer: bird's-eye geometry, waypoints + pure
# pursuit, closed-loop plant, service steering (marker `drive`)
python -m pytest -q -m "drive and not slow" -x
# sharded-fleet layer: replica routing, session affinity, failover,
# host failure domains, elastic scale-up, speculative offload on the
# seeded lossy NetworkModel (marker `mesh`); the 8-device placement scenario
# itself is `slow` — the device-count flag here covers any test that
# inits jax, and the mesh bench below runs under the same flag
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m pytest -q -m "mesh and not slow" -x
python -m pytest -q -m "not slow and not scenarios and not serve and not deadline and not tracking and not fleet and not mesh and not fused and not drive"
# CI F1 gate: regenerate the scenario + drive-cycle + fleet suites and
# compare per-family (static, tracked, and coast-only) F1 against the
# committed baseline (benchmarks/baselines/f1_baseline.json); the fleet
# suite also self-gates its overload/coast/fault contracts via exit code
python -m benchmarks.scenario_suite --quick
python -m benchmarks.tracking_suite --quick
python -m benchmarks.fleet_suite --quick
# sharded-fleet gates (scaling curve, affinity ablation, offload race
# + network-compat bit-exactness, lossy local guarantee, deterministic
# replay, elastic 4->8 scale-up, diurnal ramp), exit-code gated, on the
# forced 8-device host mesh so replica placement is real
XLA_FLAGS="--xla_force_host_platform_device_count=8" \
    python -m benchmarks.mesh_suite --quick
python scripts/check_f1.py
# closed-loop trajectory gate: the drive suite self-gates (floors,
# tracked<=per-frame on noisy, ladder on<off, deterministic replay) and
# check_drive.py compares cross-track error against the committed
# baseline (benchmarks/baselines/drive_baseline.json)
python -m benchmarks.drive_suite --quick
python scripts/check_drive.py
