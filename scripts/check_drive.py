#!/usr/bin/env python
"""CI trajectory-error gate: closed-loop cross-track must not regress.

The drive-suite counterpart of ``scripts/check_f1.py``: compares
``BENCH_drive.json`` (``python -m benchmarks.drive_suite``) against the
committed baseline ``benchmarks/baselines/drive_baseline.json`` and
fails CI (nonzero exit) on any regression, so a perception or control
change that quietly widens the vehicle's path fails loudly instead of
landing:

  * per family, the tracked arm's max and mean cross-track (meters)
    must stay <= baseline + tolerance, and under the suite's registered
    per-family floor;
  * the service ladder-on arm's max/mean must stay <= baseline +
    tolerance (the overload windows, deadline, and estimator preset are
    pinned by the suite, so this is one deterministic number);
  * every gate the suite publishes must hold in the bench run.

The cycle, detector, tracker, controller, and virtual-clock service are
all deterministic, so a genuine improvement shows up as an exact
decrease — record it with ``--update`` (review the diff like any other
baseline bump).  ``--update`` refuses a ``--quick`` bench run: it
covers only a subset of the pinned families.

Usage:
  PYTHONPATH=src python scripts/check_drive.py [--bench BENCH_drive.json]
      [--baseline benchmarks/baselines/drive_baseline.json]
      [--tolerance 0.0] [--update]
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def tracked_errors(bench: dict) -> dict[str, dict]:
    """{family: {"max_cross_track_m", "mean_cross_track_m"}} for the
    tracked arm — the deployment configuration the baseline pins."""
    return {
        fam: {
            "max_cross_track_m": float(arms["tracked"]["max_cross_track_m"]),
            "mean_cross_track_m": float(
                arms["tracked"]["mean_cross_track_m"]),
        }
        for fam, arms in bench["families"].items()
        if "tracked" in arms
    }


def ladder_on_errors(bench: dict) -> dict:
    on = bench["service"]["ladder_on"]
    return {
        "max_cross_track_m": float(on["max_cross_track_m"]),
        "mean_cross_track_m": float(on["mean_cross_track_m"]),
    }


def _load(path: str, what: str) -> dict | None:
    if not os.path.exists(path):
        print(f"check_drive: {path} not found — run {what} first",
              file=sys.stderr)
        return None
    with open(path) as f:
        return json.load(f)


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default="BENCH_drive.json")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/drive_baseline.json")
    ap.add_argument("--tolerance", type=float, default=0.0,
                    help="allowed cross-track increase in meters before "
                         "failing (default: none)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline from the current bench run")
    args = ap.parse_args()

    bench = _load(args.bench, "`python -m benchmarks.drive_suite`")
    if bench is None:
        return 2
    current = tracked_errors(bench)
    service = ladder_on_errors(bench)
    floors = bench["meta"]["floors_m"]

    if args.update:
        if bench.get("meta", {}).get("quick"):
            print("check_drive: refusing --update from a --quick run — "
                  "it covers only a subset of the pinned families; rerun "
                  "`python -m benchmarks.drive_suite` (full)",
                  file=sys.stderr)
            return 2
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        payload = {
            "tracked": {f: current[f] for f in sorted(current)},
            "service_ladder_on": service,
        }
        with open(args.baseline, "w") as f:
            json.dump(payload, f, indent=2, sort_keys=True)
        print(f"check_drive: wrote baseline for {len(current)} families "
              f"+ the ladder-on service arm -> {args.baseline}")
        return 0

    baseline = _load(args.baseline, "`scripts/check_drive.py --update`")
    if baseline is None:
        return 2

    quick = bool(bench.get("meta", {}).get("quick"))
    failures, checked = [], 0
    for fam, base in sorted(baseline["tracked"].items()):
        if fam not in current:
            if not quick:
                failures.append(
                    f"{fam}: family missing from full drive bench run")
            continue
        cur = current[fam]
        checked += 1
        for key, short in (("max_cross_track_m", "max"),
                           ("mean_cross_track_m", "mean")):
            if cur[key] > base[key] + args.tolerance:
                failures.append(
                    f"{fam}: tracked {short} cross-track {cur[key]:.4f} m "
                    f"> baseline {base[key]:.4f} m")
        floor = floors.get(fam)
        if floor is not None and cur["max_cross_track_m"] > floor:
            failures.append(
                f"{fam}: tracked max cross-track "
                f"{cur['max_cross_track_m']:.4f} m above registered "
                f"floor {floor:.2f} m")
    if checked == 0:
        failures.append("no drive family overlaps the baseline — bench "
                        "and baseline disagree on families")
    base_svc = baseline.get("service_ladder_on")
    if base_svc:
        for key, short in (("max_cross_track_m", "max"),
                           ("mean_cross_track_m", "mean")):
            if service[key] > base_svc[key] + args.tolerance:
                failures.append(
                    f"service ladder-on: {short} cross-track "
                    f"{service[key]:.4f} m > baseline "
                    f"{base_svc[key]:.4f} m")
    for gate, ok in bench.get("gates", {}).items():
        if not ok:
            failures.append(f"suite gate violated in bench run: {gate}")
    new_families = sorted(set(current) - set(baseline["tracked"]))
    if new_families:
        print(f"check_drive: families without baseline (add with "
              f"--update): {', '.join(new_families)}")

    if failures:
        print("check_drive: FAIL")
        for f_ in failures:
            print(f"  {f_}")
        return 1
    print(f"check_drive: OK — {checked} families + the ladder-on service "
          f"arm at or below baseline"
          + (f" (tolerance {args.tolerance})" if args.tolerance else ""))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
