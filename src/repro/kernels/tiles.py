"""Shared tiling helpers for the kernels package.

Every Pallas kernel here tiles its operands the same way — round shapes up
to block multiples, pad, crop on the way out — and until the fused
detection kernel arrived each module kept a private copy of the
arithmetic.  This is the single home: ``conv2d_gemm``, ``hough_vote`` and
``fused_detect`` all import from it, so a retune (e.g. a different lane
multiple for a new dtype) lands in one place.
"""

from __future__ import annotations

import jax.numpy as jnp


def acc_dtype(dtype):
    """Accumulator dtype rule shared by the conv kernels and their oracle.

    Integer inputs accumulate in int32 (the paper's integer pipeline); f16
    inputs accumulate in f16 (the low-precision gradient tier, where the
    whole point is cheap accumulation); everything else in f32.
    """
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.int32
    if dtype == jnp.float16:
        return jnp.float16
    return jnp.float32


def round_up(x: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``x``."""
    return -(-x // m) * m


def cdiv(x: int, m: int) -> int:
    """Ceiling division (grid sizing: ``cdiv(dim, block)`` steps)."""
    return -(-x // m)


def pad_trailing(x, target: int, axis: int = -1):
    """Zero-pad one axis of ``x`` up to ``target`` (no-op when it fits)."""
    n = x.shape[axis]
    if n == target:
        return x
    assert n < target, (x.shape, axis, target)
    pad = [(0, 0)] * x.ndim
    pad[axis % x.ndim] = (0, target - n)
    return jnp.pad(x, pad)
