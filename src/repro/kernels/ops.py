"""Public jit'd wrappers for the kernels package.

Every op dispatches between three implementations:

  * ``pallas``    — compiled Pallas TPU kernel (the deployment path),
  * ``interpret`` — the same kernel body executed in Pallas interpret mode
                    (CPU correctness validation; what the tests use),
  * ``xla``       — the pure-jnp oracle in ``ref.py`` (fast on CPU hosts and
                    the path the dry-run lowers, so roofline FLOP/byte counts
                    come from clean HLO dots rather than interpreter loops).

The default is chosen from the backend at call time and can be forced via
``repro.kernels.ops.set_default_impl(...)`` or ``REPRO_KERNEL_IMPL``.
This mirrors the paper's heterogeneous dispatch: the same call site runs on
the accelerator when one is attached and on the host pipeline otherwise.
"""

from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .conv2d_gemm import conv2d_gemm as _conv_pallas
from .flash_attention import flash_attention as _attn_pallas
from .fused_detect import fused_detect as _fused_pallas
from .hough_vote import compact_edges as _compact_edges
from .hough_vote import hough_vote as _hough_pallas
from .ssd_scan import ssd_scan as _ssd_pallas
from .tiled_matmul import tiled_matmul as _matmul_pallas

_VALID = ("pallas", "interpret", "xla", "stencil")
_default_impl: Optional[str] = None


def set_default_impl(impl: Optional[str]) -> None:
    if impl is not None and impl not in _VALID:
        raise ValueError(f"impl must be one of {_VALID}, got {impl!r}")
    global _default_impl
    _default_impl = impl


def resolve_impl(impl: Optional[str] = None) -> str:
    if impl is not None:
        return impl
    if _default_impl is not None:
        return _default_impl
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "xla"


def tiled_matmul(x, y, *, out_dtype=None, impl=None, **kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.tiled_matmul(x, y, out_dtype=out_dtype)
    return _matmul_pallas(
        x, y, out_dtype=out_dtype, interpret=(impl == "interpret"), **kw
    )


def conv2d_gemm(image, masks, *, out_dtype=None, impl=None, **kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        return ref.conv2d_gemm(image, masks, out_dtype=out_dtype)
    if impl == "stencil":   # paper-baseline scalar path (no GEMM rewrite)
        return ref.conv2d_stencil(image, masks, out_dtype=out_dtype)
    return _conv_pallas(
        image, masks, out_dtype=out_dtype, interpret=(impl == "interpret"),
        **kw,
    )


def default_max_edges(n_pix: int) -> int:
    """Hand-tuned edge-compaction buffer default: 1/16 of the pixel count.

    The single source of truth for the dense-dispatch buffer size — the
    autotune cap (``repro.core.hough.auto_max_edges``) and the benchmarks
    reference it so "auto never allocates a larger buffer" stays true if
    this is ever retuned.
    """
    return max(256, n_pix // 16)


def grad_hits(image, *, stride, thresh, corridors=None, widen=0.0,
              impl=None):
    """Downsampled-gradient hit count (the autotune estimator's reduction).

    Element-wise + reduction (VPU work): every impl routes to the jnp form
    in ``ref.py`` — a Pallas variant would buy nothing, but the dispatch
    seam keeps the estimator swappable like every other op here.
    ``corridors``/``widen`` make the count corridor-aware for the fused
    path's tier selector (see ``ref.grad_hits``).
    """
    del impl  # single implementation; signature matches the package
    return ref.grad_hits(
        image, stride=stride, thresh=thresh, corridors=corridors,
        widen=widen,
    )


def fused_weights(image, corridors=None, *, cfg, edge_threshold, impl=None):
    """Thresholded, corridor-filtered flat edge weights (pre-compaction).

    The fused module's tier selector counts this intermediate *exactly*
    before compaction (``core.hough.fused_hough_tiered`` on a host
    backend) — the buffer size then matches the staged tiered dispatch
    instead of over-provisioning from the pre-Canny estimate.  Pure
    element-wise VPU work, so like ``grad_hits`` every impl routes to the
    jnp form; on the TPU path the weights never leave kernel A's VMEM and
    this seam is not used.
    """
    del impl  # single implementation; signature matches the package
    return ref.fused_weights(
        image, cfg=cfg, edge_threshold=edge_threshold, corridors=corridors
    )


def compact_raster(weights, *, width, max_edges, impl=None):
    """Raster-layout compaction: scatter flat indices, rebuild (x, y, 1).

    ``compact_edges`` with the coordinate rows taken out of the scatter
    payload — valid whenever the caller owns the raster layout (the fused
    hot path).  Bit-identical output to ``compact_edges`` on the same
    weights; see ``ref.compact_raster`` for the layout argument.
    """
    del impl  # single implementation; signature matches the package
    return ref.compact_raster(weights, width=width, max_edges=max_edges)


def fused_detect(image, corridors=None, *, cfg, edge_threshold, max_edges,
                 impl=None):
    """Fused canny -> corridor filter -> compact (hot-path kernel A).

    One dispatch replaces the staged canny + compaction round trips: the
    frame goes in, a compacted ``(max_edges, 3)`` homogeneous edge list
    (plus weights) comes out, and nothing in between touches HBM.  Feed
    the result to ``hough_vote(..., compact=False)`` (kernel B).  The
    oracle is ``ref.fused_detect``; the contract is bit-exact with the
    staged path when ``corridors`` is None / full coverage and the edge
    count fits ``max_edges``.
    """
    impl = resolve_impl(impl)
    if impl in ("xla", "stencil"):
        return ref.fused_detect(
            image, cfg=cfg, edge_threshold=edge_threshold,
            max_edges=max_edges, corridors=corridors,
        )
    return _fused_pallas(
        image, corridors, cfg=cfg, edge_threshold=edge_threshold,
        max_edges=max_edges, interpret=(impl == "interpret"),
    )


def hough_vote(xy, weights, trig, *, n_rho, impl=None, compact=False,
               max_edges=None, theta_bins=None, scatter_back=True, **kw):
    """Hough voting with optional edge compaction and theta gating.

    ``compact=True`` runs the prefix-sum edge-compaction pre-pass first so
    the vote stage iterates at most ``max_edges`` pixels (default: 1/16 of
    the pixel count) instead of the full raster — the streaming fast path
    for sparse edge maps.  Both the compacted and dense variants dispatch to
    the same pallas/interpret/xla backends.

    ``theta_bins`` (a traced int32 vector of theta-bin indices, shared
    across any weight batch) is the prediction-gated fast path: the gated
    trig columns are gathered and the backend votes over only that band.
    With ``scatter_back=True`` the band scatters back into a full-width
    accumulator (zeros outside the gate) so every downstream consumer
    keeps full-sweep indexing; ``scatter_back=False`` returns the raw
    (..., n_rho, band) accumulator for consumers that stay in band space
    (``core.lines.get_lines(theta_bins=...)`` — the whole peak stage then
    scales with the band, not n_theta).  The band *length* is a static
    shape — ``core.hough.HoughConfig.theta_band`` pins it at the plan
    layer — while the bin values stay runtime data, so a tracker can
    slide the gate every frame without recompiling.  With ``theta_bins ==
    arange(n_theta)`` the gather and scatter are both identities and the
    result is bit-exact with the ungated call; the oracle is
    ``ref.hough_vote_gated``.  Duplicate bins are allowed (static
    padding): duplicate columns compute identical values and the scatter
    writes them idempotently.
    """
    impl = resolve_impl(impl)
    if compact:
        if isinstance(max_edges, str):
            raise TypeError(
                "max_edges='auto' is a core-layer knob; resolve it to an "
                "int before kernel dispatch (repro.core.hough."
                "resolve_max_edges / auto_max_edges)."
            )
        if max_edges is None:
            max_edges = default_max_edges(weights.shape[-1])
        xy, weights = _compact_edges(xy, weights, max_edges=max_edges)
    n_theta_full = trig.shape[1]
    if theta_bins is not None:
        trig = jnp.asarray(trig)[:, theta_bins]
    if impl == "xla":
        votes = ref.hough_vote(xy, weights, trig, n_rho=n_rho)
    else:
        votes = _hough_pallas(
            xy, weights, trig, n_rho=n_rho, interpret=(impl == "interpret"),
            **kw,
        )
    if theta_bins is not None and scatter_back:
        votes = (
            jnp.zeros(votes.shape[:-1] + (n_theta_full,), votes.dtype)
            .at[..., theta_bins]
            .set(votes)
        )
    return votes


# Above this kv length the xla path switches from dense scores to the
# blockwise-scan form (identical math, O(L*block) memory) so 32k prefill
# cells lower without materializing L^2 score tensors.
_XLA_DENSE_MAX_KV = 2048


def flash_attention(q, k, v, *, causal=True, window=None, q_offset=0,
                    impl=None, **kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        if k.shape[2] > _XLA_DENSE_MAX_KV:
            return ref.attention_blockwise(
                q, k, v, causal=causal, window=window, q_offset=q_offset
            )
        return ref.attention(
            q, k, v, causal=causal, window=window, q_offset=q_offset
        )
    return _attn_pallas(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        interpret=(impl == "interpret"), **kw,
    )


# Above this sequence length the xla path uses the chunked segment-sum SSD
# (one chunk body in HLO) instead of the L-step sequential oracle.
_XLA_SSD_SEQ_MAX = 64


def ssd_scan(x, dt, A, B, C, *, impl=None, **kw):
    impl = resolve_impl(impl)
    if impl == "xla":
        if x.shape[1] > _XLA_SSD_SEQ_MAX:
            return ref.ssd_scan_chunked(x, dt, A, B, C,
                                        chunk=kw.get("chunk", 128))
        return ref.ssd_scan(x, dt, A, B, C)
    return _ssd_pallas(x, dt, A, B, C, interpret=(impl == "interpret"), **kw)
