"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantics of record: each kernel's tests sweep shapes/dtypes
and ``assert_allclose`` against these functions.  They are also the "xla"
execution path used on hosts without a TPU (this container), where XLA's own
fusions are the fastest option and the HLO they produce is what the dry-run
roofline reads.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .tiles import acc_dtype as _acc_dtype


def tiled_matmul(x: jax.Array, y: jax.Array, *, out_dtype=None) -> jax.Array:
    integer = jnp.issubdtype(x.dtype, jnp.integer)
    acc = jnp.int32 if integer else jnp.float32
    if out_dtype is None:
        out_dtype = jnp.int32 if integer else x.dtype
    return jnp.dot(x, y, preferred_element_type=acc).astype(out_dtype)


def conv2d_gemm(image: jax.Array, masks: jax.Array, *, out_dtype=None
                ) -> jax.Array:
    """Same-padded 2D correlation; (..., H, W) -> (..., n_masks, H, W)."""
    H, W = image.shape[-2:]
    n_masks, kh, kw = masks.shape
    integer = jnp.issubdtype(image.dtype, jnp.integer)
    acc = _acc_dtype(image.dtype)
    if out_dtype is None:
        out_dtype = jnp.int32 if integer else image.dtype
    pad = [(0, 0)] * (image.ndim - 2) + [
        (kh // 2, kh // 2), (kw // 2, kw // 2)
    ]
    padded = jnp.pad(image, pad)
    # im2col in HBM: (..., H, W, kh*kw) patch tensor, then one contraction.
    patches = jnp.stack(
        [
            padded[..., dy : dy + H, dx : dx + W]
            for dy in range(kh)
            for dx in range(kw)
        ],
        axis=-1,
    ).astype(acc)
    flat = masks.reshape(n_masks, kh * kw).astype(acc)
    out = jnp.einsum("...hwk,mk->...mhw", patches, flat)
    return out.astype(out_dtype)


def conv2d_stencil(image: jax.Array, masks: jax.Array, *, out_dtype=None
                   ) -> jax.Array:
    """Scalar-core formulation: per-tap shift-multiply-accumulate, no GEMM.

    This is the paper's *baseline* execution (the stencil as written, before
    the matrix rewrite of Workload 3) — kept as a measurable path so the
    benchmarks can report the GEMM-offload speedup the way Table 7 does.
    """
    H, W = image.shape[-2:]
    n_masks, kh, kw = masks.shape
    integer = jnp.issubdtype(image.dtype, jnp.integer)
    acc = _acc_dtype(image.dtype)
    if out_dtype is None:
        out_dtype = jnp.int32 if integer else image.dtype
    pad = [(0, 0)] * (image.ndim - 2) + [
        (kh // 2, kh // 2), (kw // 2, kw // 2)
    ]
    padded = jnp.pad(image, pad).astype(acc)
    outs = []
    for m in range(n_masks):
        o = jnp.zeros(image.shape, acc)
        for dy in range(kh):
            for dx in range(kw):
                o = o + masks[m, dy, dx].astype(acc) * padded[
                    ..., dy : dy + H, dx : dx + W
                ]
        outs.append(o)
    return jnp.stack(outs, axis=-3).astype(out_dtype)


def grad_hits(image: jax.Array, *, stride: int, thresh: float,
              corridors: jax.Array | None = None, widen: float = 0.0
              ) -> jax.Array:
    """Downsampled finite-difference gradient hit count (per frame).

    The reduction behind the ``max_edges`` autotune estimator
    (``core.canny.estimate_edge_count_device``): subsample by ``stride``,
    take |dx|/|dy| finite differences as a stand-in for Sobel-of-Gaussian,
    and count coarse pixels whose stronger difference clears ``thresh``.
    Returns an int32 count per leading-axis frame ((..., H, W) -> (...)).
    Element-wise + reduction — VPU work, no Pallas variant needed; it lives
    here so the estimator shares the kernel package's dispatch/oracle
    structure and a future fused on-device tuner has one seam to replace.

    When ``corridors`` (C, 4) rho windows are given (see ``corridor_keep``),
    coarse hits outside every corridor are not counted — the fused path's
    tier selector sizes its buffer for the *filtered* edge set.  ``widen``
    inflates each window (in pixels) so a coarse cell whose fine pixels
    straddle a corridor edge still counts; callers pass ~2*stride, the max
    rho drift across a stride-wide cell plus slack, to keep the estimate an
    upper bound.
    """
    img = jnp.asarray(image, jnp.float32)
    sub = img[..., ::stride, ::stride]
    gx = jnp.abs(sub[..., :, 1:] - sub[..., :, :-1])[..., :-1, :]
    gy = jnp.abs(sub[..., 1:, :] - sub[..., :-1, :])[..., :, :-1]
    hit = jnp.maximum(gx, gy) >= thresh
    if corridors is not None:
        Hs, Ws = hit.shape[-2:]
        # Fine-pixel coordinates of each coarse cell's top-left corner.
        yy = jnp.arange(Hs, dtype=jnp.float32)[:, None] * stride
        xx = jnp.arange(Ws, dtype=jnp.float32)[None, :] * stride
        cor = jnp.asarray(corridors, jnp.float32)
        rho = (
            xx[None] * cor[:, 0, None, None]
            + yy[None] * cor[:, 1, None, None]
        )  # (C, Hs, Ws)
        keep = (
            (rho >= (cor[:, 2, None, None] - widen))
            & (rho <= (cor[:, 3, None, None] + widen))
        ).any(axis=0)
        hit = hit & keep
    return hit.sum(axis=(-2, -1), dtype=jnp.int32)


def hough_vote(xy: jax.Array, weights: jax.Array, trig: jax.Array,
               *, n_rho: int) -> jax.Array:
    """Scatter-add vote oracle (the paper's Algorithm 2, vectorized).

    ``weights`` may be batched (N, n_pix) — with ``xy`` either shared
    (n_pix, C) or per-frame (N, n_pix, C) — returning (N, n_rho, n_theta).
    """
    if weights.ndim == 2:
        if xy.ndim == 3:
            return jax.vmap(
                lambda x, w: hough_vote(x, w, trig, n_rho=n_rho)
            )(xy, weights)
        return jax.vmap(
            lambda w: hough_vote(xy, w, trig, n_rho=n_rho)
        )(weights)
    rho = xy.astype(jnp.float32) @ trig.astype(jnp.float32)  # (P, n_theta)
    idx = jnp.floor(rho).astype(jnp.int32)
    n_theta = trig.shape[1]
    votes = jnp.zeros((n_rho, n_theta), jnp.float32)
    inside = (idx >= 0) & (idx < n_rho)
    idx = jnp.clip(idx, 0, n_rho - 1)
    w = jnp.where(inside, weights.astype(jnp.float32)[:, None], 0.0)
    t = jnp.broadcast_to(jnp.arange(n_theta)[None, :], idx.shape)
    return votes.at[idx.ravel(), t.ravel()].add(w.ravel())


def compact_edges(xy: jax.Array, weights: jax.Array, *, max_edges: int):
    """Edge-compaction oracle: stable partition of edge pixels to the front.

    Same contract as ``hough_vote.compact_edges`` (which uses a prefix-sum
    scatter) but formulated as a stable argsort so the two implementations
    are independent: rows past the edge count — and edges beyond
    ``max_edges`` — are zeroed/dropped.
    """
    if weights.ndim == 2:
        if xy.ndim == 3:
            return jax.vmap(
                lambda x, w: compact_edges(x, w, max_edges=max_edges)
            )(xy, weights)
        return jax.vmap(
            lambda w: compact_edges(xy, w, max_edges=max_edges)
        )(weights)
    mask = weights > 0
    order = jnp.argsort(~mask, stable=True)[:max_edges]
    keep = mask[order]
    cxy = jnp.where(keep[:, None], xy[order], jnp.zeros_like(xy[order]))
    cw = jnp.where(keep, weights[order], jnp.zeros_like(weights[order]))
    return cxy, cw


def hough_vote_compact(xy: jax.Array, weights: jax.Array, trig: jax.Array,
                       *, n_rho: int, max_edges: int) -> jax.Array:
    """Compacted-vote oracle: compact edges, then vote over max_edges rows."""
    cxy, cw = compact_edges(xy, weights, max_edges=max_edges)
    return hough_vote(cxy, cw, trig, n_rho=n_rho)


def hough_vote_gated(xy: jax.Array, weights: jax.Array, trig: jax.Array,
                     theta_bins: jax.Array, *, n_rho: int) -> jax.Array:
    """Theta-gated vote oracle: the full sweep with every column outside
    the gate zeroed.

    The semantics of record for ``ops.hough_vote(theta_bins=...)`` — which
    gathers the gated trig columns, votes over the narrow band, and
    scatters back — formulated independently (full vote + mask) so the two
    implementations share no code path.  Duplicate gate bins are
    idempotent in both forms.
    """
    full = hough_vote(xy, weights, trig, n_rho=n_rho)
    mask = (
        jnp.zeros((trig.shape[1],), bool).at[theta_bins].set(True)
    )
    return jnp.where(mask, full, jnp.zeros_like(full))


def corridor_keep(xy: jax.Array, corridors: jax.Array) -> jax.Array:
    """Which pixels fall inside at least one rho corridor.

    ``corridors`` is (C, 4) f32 rows ``[cos(theta_c), sin(theta_c),
    rho_lo, rho_hi]`` — a window around one predicted lane in *signed,
    unshifted* rho (``x*cos + y*sin``, the same convention ``get_lines``
    decodes peaks into, so tracker state plugs in directly).  A pixel
    survives if its rho along any corridor's normal lands in that
    corridor's window; padding rows just repeat a real corridor (the OR is
    idempotent).  ``hough.full_corridors`` builds windows that pass
    everything.

    ``xy`` is (..., P, C>=2) with columns (x, y, ...); returns (..., P) bool.
    """
    xyf = xy[..., :2].astype(jnp.float32)
    cor = jnp.asarray(corridors, jnp.float32)
    rho = xyf @ cor[:, :2].T  # (..., P, C)
    return ((rho >= cor[:, 2]) & (rho <= cor[:, 3])).any(axis=-1)


def fused_weights(image: jax.Array, *, cfg, edge_threshold: float,
                  corridors: jax.Array | None = None) -> jax.Array:
    """Flat edge weights of the fused hot path, pre-compaction.

    Runs the full Canny front end (forced onto the pure-jnp "xla" impl so
    the oracle never recurses into Pallas), weights pixels by the edge
    threshold exactly as the staged ``hough`` stage does, and zeroes the
    weights of pixels outside every corridor.  Returns ``(..., H*W)`` f32 —
    the intermediate the fused module's exact tier selector counts before
    compaction (``core.hough.fused_hough_tiered`` on the xla path).
    """
    import dataclasses

    from repro.core.canny import canny as _canny  # function-level: cycle

    edges = _canny(image, dataclasses.replace(cfg, impl="xla"))
    H, W = edges.shape[-2:]
    flat = edges.reshape(edges.shape[:-2] + (H * W,))
    w = (flat >= edge_threshold).astype(jnp.float32)
    if corridors is not None:
        jj, ii = jnp.meshgrid(jnp.arange(W), jnp.arange(H))
        xy = jnp.stack([jj.ravel(), ii.ravel()], axis=1).astype(jnp.float32)
        w = w * corridor_keep(xy, corridors).astype(jnp.float32)
    return w


def compact_raster(weights: jax.Array, *, width: int, max_edges: int):
    """Raster-layout edge compaction: scatter flat *indices*, not rows.

    The generic ``compact_edges`` moves ``(x, y, 1)`` coordinate rows
    through the scatter because its ``xy`` operand is arbitrary.  The
    fused path owns the raster layout, so the pixel coordinate is a pure
    function of the flat index — compaction only needs to scatter one
    int32 per surviving pixel and reconstruct ``(idx % W, idx // W, 1)``
    from the ``(max_edges,)`` result afterwards.  On a host backend this
    cuts the scatter payload 4x (the dominant compaction cost); on the
    TPU kernel it is the natural VMEM form (kernel A emits an index list).

    Same contract as ``compact_edges``: raster order, rows past the edge
    count zeroed, edges beyond ``max_edges`` dropped — and bit-identical
    output (integer pixel coordinates are exact in f32 either way).
    """
    if weights.ndim == 2:
        return jax.vmap(
            lambda w: compact_raster(w, width=width, max_edges=max_edges)
        )(weights)
    n_pix = weights.shape[-1]
    mask = weights > 0
    pos = jnp.where(mask, jnp.cumsum(mask) - 1, max_edges)
    idx = (
        jnp.zeros((max_edges,), jnp.int32)
        .at[pos]
        .set(jnp.arange(n_pix, dtype=jnp.int32), mode="drop")
    )
    slot = jnp.arange(max_edges) < mask.sum()
    cw = jnp.where(slot, weights[idx], 0.0)
    cxy = jnp.stack(
        [
            (idx % width).astype(jnp.float32),
            (idx // width).astype(jnp.float32),
            jnp.ones((max_edges,), jnp.float32),
        ],
        axis=1,
    )
    return jnp.where(slot[:, None], cxy, 0.0), cw


def fused_detect(image: jax.Array, *, cfg, edge_threshold: float,
                 max_edges: int, corridors: jax.Array | None = None):
    """Fused-hot-path oracle: gradient -> threshold -> corridor filter ->
    compact, in one jnp function.

    Semantics of record for ``kernels.fused_detect`` (the Pallas kernel A):
    ``fused_weights`` produces the thresholded, corridor-filtered weights
    and ``compact_raster`` compacts the survivors in raster order into a
    static ``(max_edges, 3)`` homogeneous ``(x, y, 1)`` buffer (first
    ``max_edges`` kept, trailing edges dropped — the same overflow contract
    as ``compact_edges``).  Kernel B is the existing vote kernel, fed this
    buffer.

    Returns ``(cxy, cw)`` of shape ``(..., max_edges, 3)`` /
    ``(..., max_edges)`` in f32.
    """
    W = image.shape[-1]
    w = fused_weights(
        image, cfg=cfg, edge_threshold=edge_threshold, corridors=corridors
    )
    return compact_raster(w, width=W, max_edges=max_edges)


def attention(q, k, v, *, causal=True, window=None, q_offset=0):
    """Dense softmax attention oracle (GQA via head repeat)."""
    B, Hq, Lq, D = q.shape
    Hkv, Lkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (D ** 0.5)
    q_pos = q_offset + jnp.arange(Lq)[:, None]
    kv_pos = jnp.arange(Lkv)[None, :]
    mask = jnp.ones((Lq, Lkv), bool)
    if causal:
        mask &= q_pos >= kv_pos
    if window is not None:
        mask &= (q_pos - kv_pos) < window
    s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)


import functools as _functools


def _abw_mask(q_pos, kv_pos, Lkv, causal, window):
    mask = kv_pos[None, :] < Lkv
    if causal:
        mask = mask & (q_pos[:, None] >= kv_pos[None, :])
    if window is not None:
        mask = mask & ((q_pos[:, None] - kv_pos[None, :]) < window)
    return mask


def _abw_fwd_impl(q, k, v, causal, window, q_offset, block):
    """Forward online-softmax over kv blocks; returns (out, lse)."""
    B, Hq, Lq, D = q.shape
    Hkv, Lkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    pad = (-Lkv) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_blocks = k.shape[2] // block
    qf = q.astype(jnp.float32)
    q_pos = q_offset + jnp.arange(Lq)

    ks = jnp.moveaxis(k.reshape(B, Hkv, n_blocks, block, D), 2, 0)
    vs = jnp.moveaxis(v.reshape(B, Hkv, n_blocks, block, D), 2, 0)

    def step(carry, inp):
        acc, m, l, j = carry
        kb, vb = inp
        kb = jnp.repeat(kb, rep, axis=1).astype(jnp.float32)
        vb = jnp.repeat(vb, rep, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kb) * scale
        kv_pos = j * block + jnp.arange(block)
        mask = _abw_mask(q_pos, kv_pos, Lkv, causal, window)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1, keepdims=True))
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.where(mask[None, None], jnp.exp(s - m_safe), 0.0)
        corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        l = corr * l + jnp.sum(p, axis=-1, keepdims=True)
        acc = corr * acc + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (acc, m_new, l, j + 1), None

    acc0 = jnp.zeros((B, Hq, Lq, D), jnp.float32)
    m0 = jnp.full((B, Hq, Lq, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Hq, Lq, 1), jnp.float32)
    (acc, m, l, _), _ = jax.lax.scan(
        step, (acc0, m0, l0, jnp.int32(0)), (ks, vs)
    )
    out = (acc / jnp.where(l == 0.0, 1.0, l)).astype(q.dtype)
    # lse = m + log l; empty rows get +inf so exp(s - lse) == 0 in bwd
    lse = jnp.where(
        l == 0.0, jnp.inf, jnp.where(jnp.isinf(m), 0.0, m) + jnp.log(
            jnp.where(l == 0.0, 1.0, l))
    )
    return out, lse


@_functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _attention_blockwise(q, k, v, causal, window, q_offset, block):
    out, _ = _abw_fwd_impl(q, k, v, causal, window, q_offset, block)
    return out


def _abw_fwd(q, k, v, causal, window, q_offset, block):
    out, lse = _abw_fwd_impl(q, k, v, causal, window, q_offset, block)
    return out, (q, k, v, out, lse)


def _abw_bwd(causal, window, q_offset, block, res, do):
    """Flash-style backward: recompute per-block p from (q, k, v, lse);
    O(Lq*D + block^2) live memory — the residuals are the layer I/O only.
    """
    q, k, v, out, lse = res
    B, Hq, Lq, D = q.shape
    Hkv, Lkv = k.shape[1], k.shape[2]
    rep = Hq // Hkv
    scale = 1.0 / (D ** 0.5)
    pad = (-Lkv) % block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    n_blocks = k.shape[2] // block
    qf = q.astype(jnp.float32)
    dof = do.astype(jnp.float32)
    q_pos = q_offset + jnp.arange(Lq)
    delta = jnp.sum(dof * out.astype(jnp.float32), axis=-1, keepdims=True)

    ks = jnp.moveaxis(k.reshape(B, Hkv, n_blocks, block, D), 2, 0)
    vs = jnp.moveaxis(v.reshape(B, Hkv, n_blocks, block, D), 2, 0)

    def step(dq, inp):
        kb, vb, j = inp
        kbr = jnp.repeat(kb, rep, axis=1).astype(jnp.float32)
        vbr = jnp.repeat(vb, rep, axis=1).astype(jnp.float32)
        s = jnp.einsum("bhqd,bhkd->bhqk", qf, kbr) * scale
        kv_pos = j * block + jnp.arange(block)
        mask = _abw_mask(q_pos, kv_pos, Lkv, causal, window)
        s = jnp.where(mask[None, None], s, -jnp.inf)
        p = jnp.exp(s - lse)                       # (B, Hq, Lq, block)
        p = jnp.where(mask[None, None], p, 0.0)
        dv_j = jnp.einsum("bhqk,bhqd->bhkd", p, dof)
        dp = jnp.einsum("bhqd,bhkd->bhqk", dof, vbr)
        ds = p * (dp - delta) * scale
        dq = dq + jnp.einsum("bhqk,bhkd->bhqd", ds, kbr)
        dk_j = jnp.einsum("bhqk,bhqd->bhkd", ds, qf)
        # fold GQA group: sum query heads sharing a kv head
        dv_j = dv_j.reshape(B, Hkv, rep, block, D).sum(axis=2)
        dk_j = dk_j.reshape(B, Hkv, rep, block, D).sum(axis=2)
        return dq, (dk_j, dv_j)

    dq0 = jnp.zeros((B, Hq, Lq, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(
        step, dq0, (ks, vs, jnp.arange(n_blocks))
    )
    dk = jnp.moveaxis(dks, 0, 2).reshape(B, Hkv, n_blocks * block, D)
    dv = jnp.moveaxis(dvs, 0, 2).reshape(B, Hkv, n_blocks * block, D)
    if pad:
        dk = dk[:, :, :Lkv]
        dv = dv[:, :, :Lkv]
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_attention_blockwise.defvjp(_abw_fwd, _abw_bwd)


def attention_blockwise(q, k, v, *, causal=True, window=None, q_offset=0,
                        block=512):
    """Online-softmax attention as a ``lax.scan`` over kv blocks.

    Mathematically identical to ``attention`` but O(Lq * block) peak memory
    instead of O(Lq * Lkv), with a flash-style ``custom_vjp`` backward that
    recomputes block scores from (q, k, v, lse) — the jnp expression of the
    Pallas flash kernel's dataflow, used by the 4k/32k/500k lowering cells
    where a dense (Lq, Lkv) score tensor cannot exist.
    """
    return _attention_blockwise(q, k, v, causal, window, q_offset, block)


def ssd_scan_chunked(x, dt, A, B, C, *, chunk=128):
    """Chunked SSD in jnp — the same segment-sum matmul form as the Pallas
    kernel (``ssd_scan.py``), scanned over chunks.  This is the lowering
    path for train/prefill cells: compact HLO (one chunk body), O(L/Q)
    sequential depth, no (L, N, P) tensor ever materialized.
    """
    x, dt, A, B, C = map(jnp.asarray, (x, dt, A, B, C))
    batch, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Q = min(chunk, L)
    pad = (-L) % Q
    xdt = (x * dt[..., None]).astype(jnp.float32)        # (b, L, H, P)
    ldec = (dt * A[None, None, :]).astype(jnp.float32)   # (b, L, H)
    Bf, Cf = B.astype(jnp.float32), C.astype(jnp.float32)
    if pad:
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0), (0, 0)))
        ldec = jnp.pad(ldec, ((0, 0), (0, pad), (0, 0)))
        Bf = jnp.pad(Bf, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cf = jnp.pad(Cf, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (L + pad) // Q

    def to_chunks(t):
        return jnp.moveaxis(
            t.reshape((batch, nc, Q) + t.shape[2:]), 1, 0
        )

    xs = (to_chunks(xdt), to_chunks(ldec), to_chunks(Bf), to_chunks(Cf))

    def step(h, inp):
        xc, lc, Bc, Cc = inp              # (b,Q,H,P), (b,Q,H), (b,Q,G,N)
        Bh = jnp.repeat(Bc, rep, axis=2)  # (b, Q, H, N)
        Ch = jnp.repeat(Cc, rep, axis=2)
        cum = jnp.cumsum(lc, axis=1)      # (b, Q, H) inclusive
        # intra-chunk: masked decay GEMM
        cb = jnp.einsum("bqhn,bkhn->bhqk", Ch, Bh)
        seg = jnp.exp(cum[:, :, None] - cum[:, None, :])  # (b,Q,Q,H)->perm
        seg = jnp.where(
            jnp.arange(Q)[:, None] >= jnp.arange(Q)[None, :],
            seg.transpose(0, 3, 1, 2), 0.0,
        )                                  # (b, H, Q, Q) lower-tri decay
        y = jnp.einsum("bhqk,bkhp->bqhp", cb * seg, xc)
        # inter-chunk: carried state
        y = y + jnp.einsum("bqhn,bhnp->bqhp", Ch, h) * \
            jnp.exp(cum).transpose(0, 1, 2)[..., None]
        # state update
        wB = Bh * jnp.exp(cum[:, -1:, :] - cum)[..., None]
        h = jnp.exp(cum[:, -1])[..., None, None] * h + jnp.einsum(
            "bqhn,bqhp->bhnp", wB, xc
        )
        return h, y

    h0 = jnp.zeros((batch, H, N, P), jnp.float32)
    hL, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(batch, nc * Q, H, P)[:, :L]
    return y.astype(x.dtype), hL


def ssd_scan(x, dt, A, B, C):
    """Sequential selective-scan oracle: h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    x, dt, A, B, C = map(jnp.asarray, (x, dt, A, B, C))
    batch, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    rep = H // G
    Bh = jnp.repeat(B, rep, axis=2).astype(jnp.float32)  # (batch, L, H, N)
    Ch = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)

    def step(h, t):
        a = jnp.exp(dtf[:, t] * A[None, :])  # (batch, H)
        u = jnp.einsum("bh,bhn,bhp->bhnp", dtf[:, t], Bh[:, t], xf[:, t])
        h = a[..., None, None] * h + u
        y = jnp.einsum("bhn,bhnp->bhp", Ch[:, t], h)
        return h, y

    h0 = jnp.zeros((batch, H, N, P), jnp.float32)
    h_final, ys = jax.lax.scan(step, h0, jnp.arange(L))
    y = ys.transpose(1, 0, 2, 3)  # (batch, L, H, P)
    return y.astype(x.dtype), h_final
