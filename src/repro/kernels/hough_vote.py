"""GEMM-form Hough voting Pallas kernel (paper Algorithm 2, re-architected).

The paper *keeps Hough on the scalar core*: its voting loop carries CPI > 3
serial dependencies (``accumulators[idx]++``) that even the OoO BOOM core
cannot hide, so Gemmini gives it nothing (Table 7: 1.07x-1.16x).

The TPU adaptation dissolves the dependency instead of tolerating it:

  1. ``rho[p, theta] = x_p * cos(theta) + y_p * sin(theta)`` for *all* edge
     pixels and angles at once is a single ``(n_pix, C) @ (C, n_theta)`` GEMM
     — MXU work (this is the paper's own conv->matmul move applied to the
     stage the paper gave up on).
  2. The vote histogram becomes a one-hot contraction: for a rho-bin block
     ``[r0, r0+br)`` and a theta block ``[t0, t0+bt)``,
     ``votes[r, t] = sum_p w_p * [rho_idx[p, t] == r]`` — a masked reduction
     over pixels, accumulated in a VMEM-resident ``(br, bt)`` tile.  No
     serialized read-modify-write anywhere.  Blocking theta keeps the peak
     one-hot intermediate at ``(br, bp, bt)`` instead of the old
     ``(br, bp, n_theta)`` broadcast.

Grid: ``(batch, rho_blocks, theta_blocks, pixel_blocks)`` with pixels
innermost so the vote tile stays output-stationary in scratch (same dataflow
as ``tiled_matmul``).  The leading batch axis lowers a stack of frames as
one kernel; shared pixel coordinates (the uncompacted dense raster) are
broadcast through the index map instead of being materialized per frame.

Edge compaction (the streaming fast path): typically <5% of pixels are edge
pixels, so ``compact_edges`` prefix-sum-scatters the edge coordinates into a
static ``(max_edges, C)`` buffer first and the vote grid iterates compacted
pixels only — the pixel-block axis is bounded by ``max_edges``, not H*W.
The uncompacted dense path stays available (``ops.hough_vote(compact=...)``)
and both are mirrored in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .tiles import round_up as _round_up


def _compact_one(xy: jax.Array, w: jax.Array, max_edges: int):
    """Prefix-sum scatter: edge pixel k lands in compacted row k."""
    mask = w > 0
    pos = jnp.where(mask, jnp.cumsum(mask) - 1, max_edges)
    cxy = (
        jnp.zeros((max_edges, xy.shape[-1]), xy.dtype)
        .at[pos]
        .set(xy, mode="drop")
    )
    cw = jnp.zeros((max_edges,), w.dtype).at[pos].set(w, mode="drop")
    return cxy, cw


@functools.partial(jax.jit, static_argnames=("max_edges",))
def compact_edges(xy: jax.Array, weights: jax.Array, *, max_edges: int):
    """Compact edge pixels (weight > 0) to the front of a static buffer.

    Args:
      xy:      (n_pix, C) coordinates, or (N, n_pix, C) per-frame.
      weights: (n_pix,) or (N, n_pix) vote weights; 0 marks non-edges.
      max_edges: static output length.  Edges beyond it are dropped
        (out-of-bounds scatter, mode="drop") — size it for the workload.

    Returns (cxy, cw) of shape (..., max_edges, C) / (..., max_edges); rows
    past the actual edge count are zero (weight 0 => no vote cast).
    """
    if weights.ndim == 1:
        return _compact_one(xy, weights, max_edges)
    if xy.ndim == 2:  # shared raster coordinates, per-frame weights
        return jax.vmap(lambda w: _compact_one(xy, w, max_edges))(weights)
    return jax.vmap(lambda x, w: _compact_one(x, w, max_edges))(xy, weights)


def _vote_kernel(xy_ref, w_ref, trig_ref, o_ref, acc_ref, *, br):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    bp, C = xy_ref.shape[-2:]
    xy = xy_ref[...].reshape(bp, C)      # (bp, C) pixel coordinates
    w = w_ref[...].reshape(bp, 1)        # (bp, 1) edge weights (0 => skip)
    trig = trig_ref[...]                 # (C, bt) cos/sin(/offset) columns

    # Stage 1: the rho GEMM for this theta block.
    rho = jnp.dot(xy, trig, preferred_element_type=jnp.float32)  # (bp, bt)
    rho_idx = jnp.floor(rho).astype(jnp.int32)  # bin index (pre-offset)

    # Stage 2: one-hot contraction against this rho block.
    r0 = pl.program_id(1) * br
    bins = r0 + jax.lax.broadcasted_iota(jnp.int32, (br, 1, 1), 0)
    onehot = (rho_idx[None, :, :] == bins).astype(jnp.float32)  # (br, bp, bt)
    acc_ref[...] += jnp.sum(onehot * w[None, :, :], axis=1)     # (br, bt)

    @pl.when(pl.program_id(3) == pl.num_programs(3) - 1)
    def _flush():
        o_ref[...] = acc_ref[...][None].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_rho", "br", "bp", "bt", "interpret")
)
def hough_vote(
    xy: jax.Array,
    weights: jax.Array,
    trig: jax.Array,
    *,
    n_rho: int,
    br: int = 128,
    bp: int = 256,
    bt: int = 64,
    interpret: bool = False,
) -> jax.Array:
    """Accumulate Hough votes.

    Args:
      xy:      (n_pix, C) f32 pixel coordinates — C=2 for raw (x, y), or C=3
               homogeneous ``(x, y, 1)`` so the rho offset/resolution folds
               into the GEMM and ``floor(xy @ trig)`` lands in ``[0, n_rho)``.
               May be (N, n_pix, C) for per-frame (e.g. compacted) pixel
               sets; a single (n_pix, C) set is shared across a weight batch.
      weights: (n_pix,) f32 vote weight per pixel (0 for non-edge pixels —
               this is how variable-length edge sets stay statically shaped),
               or (N, n_pix) for a batch of frames lowered as one kernel.
      trig:    (C, n_theta) f32, rows ``cos(theta)`` / ``sin(theta)`` (and
               the offset row for C=3) already divided by the rho resolution.
      n_rho:   number of rho bins.
      br/bp/bt: rho-bin / pixel / theta block sizes.

    Returns: (n_rho, n_theta) f32 vote accumulator (paper's
    ``accumulators``), with a leading N axis when ``weights`` is batched.
    """
    squeeze = weights.ndim == 1
    if squeeze:
        weights = weights[None]
        if xy.ndim == 3:
            xy = xy[0]
    N, n_pix = weights.shape
    shared_xy = xy.ndim == 2
    C = xy.shape[-1]
    assert xy.shape[-2] == n_pix and C == trig.shape[0], (
        xy.shape, weights.shape, trig.shape,
    )
    n_theta = trig.shape[1]

    bp = min(bp, _round_up(n_pix, 8))
    br = min(br, _round_up(n_rho, 8))
    bt = min(bt, n_theta)
    P = _round_up(n_pix, bp)
    N_rho = _round_up(n_rho, br)
    N_theta = _round_up(n_theta, bt)
    if P != n_pix:
        pad = [(0, 0)] * (xy.ndim - 2) + [(0, P - n_pix), (0, 0)]
        xy = jnp.pad(xy, pad)
        weights = jnp.pad(weights, ((0, 0), (0, P - n_pix)))
    trig = jnp.pad(trig, ((0, 0), (0, N_theta - n_theta)))
    w3 = weights[:, :, None].astype(jnp.float32)

    if shared_xy:
        xy_spec = pl.BlockSpec((bp, C), lambda n, r, t, p: (p, 0))
    else:
        xy_spec = pl.BlockSpec((1, bp, C), lambda n, r, t, p: (n, p, 0))

    out = pl.pallas_call(
        functools.partial(_vote_kernel, br=br),
        grid=(N, N_rho // br, N_theta // bt, P // bp),
        in_specs=[
            xy_spec,
            pl.BlockSpec((1, bp, 1), lambda n, r, t, p: (n, p, 0)),
            pl.BlockSpec((C, bt), lambda n, r, t, p: (0, t)),
        ],
        out_specs=pl.BlockSpec(
            (1, br, bt), lambda n, r, t, p: (n, r, t)
        ),
        out_shape=jax.ShapeDtypeStruct((N, N_rho, N_theta), jnp.float32),
        scratch_shapes=[pltpu.VMEM((br, bt), jnp.float32)],
        interpret=interpret,
    )(xy.astype(jnp.float32), w3, trig.astype(jnp.float32))
    out = out[:, :n_rho, :n_theta]
    return out[0] if squeeze else out
