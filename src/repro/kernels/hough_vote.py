"""GEMM-form Hough voting Pallas kernel (paper Algorithm 2, re-architected).

The paper *keeps Hough on the scalar core*: its voting loop carries CPI > 3
serial dependencies (``accumulators[idx]++``) that even the OoO BOOM core
cannot hide, so Gemmini gives it nothing (Table 7: 1.07x-1.16x).

The TPU adaptation dissolves the dependency instead of tolerating it:

  1. ``rho[p, theta] = x_p * cos(theta) + y_p * sin(theta)`` for *all* edge
     pixels and angles at once is a single ``(n_pix, 2) @ (2, n_theta)`` GEMM
     — MXU work (this is the paper's own conv->matmul move applied to the
     stage the paper gave up on).
  2. The vote histogram becomes a one-hot contraction: for a rho-bin block
     ``[r0, r0+br)``, ``votes[r, t] = sum_p w_p * [rho_idx[p, t] == r]`` —
     a masked reduction over pixels, accumulated in a VMEM-resident
     ``(br, n_theta)`` tile.  No serialized read-modify-write anywhere.

Grid: ``(rho_blocks, pixel_blocks)`` with pixels innermost so the vote tile
stays output-stationary in scratch (same dataflow as ``tiled_matmul``).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _vote_kernel(xy_ref, w_ref, trig_ref, o_ref, acc_ref, *, br):
    r_blk = pl.program_id(0)

    @pl.when(pl.program_id(1) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xy = xy_ref[...]          # (bp, 2) pixel coordinates (x, y)
    w = w_ref[...]            # (bp, 1) edge weights (0 => not an edge pixel)
    trig = trig_ref[...]      # (2, n_theta) stacked cos/sin rows

    # Stage 1: the rho GEMM.
    rho = jnp.dot(xy, trig, preferred_element_type=jnp.float32)  # (bp, n_t)
    rho_idx = jnp.floor(rho).astype(jnp.int32)  # bin index (pre-offset)

    # Stage 2: one-hot contraction against this rho block.
    r0 = r_blk * br
    bins = r0 + jax.lax.broadcasted_iota(jnp.int32, (br, 1, 1), 0)
    onehot = (rho_idx[None, :, :] == bins).astype(jnp.float32)  # (br, bp, n_t)
    acc_ref[...] += jnp.sum(onehot * w[None, :, :], axis=1)     # (br, n_t)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("n_rho", "br", "bp", "interpret")
)
def hough_vote(
    xy: jax.Array,
    weights: jax.Array,
    trig: jax.Array,
    *,
    n_rho: int,
    br: int = 128,
    bp: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Accumulate Hough votes.

    Args:
      xy:      (n_pix, C) f32 pixel coordinates — C=2 for raw (x, y), or C=3
               homogeneous ``(x, y, 1)`` so the rho offset/resolution folds
               into the GEMM and ``floor(xy @ trig)`` lands in ``[0, n_rho)``.
      weights: (n_pix,) f32 vote weight per pixel (0 for non-edge pixels —
               this is how variable-length edge sets stay statically shaped).
      trig:    (C, n_theta) f32, rows ``cos(theta)`` / ``sin(theta)`` (and the
               offset row for C=3) already divided by the rho bin resolution.
      n_rho:   number of rho bins.

    Returns: (n_rho, n_theta) f32 vote accumulator (paper's ``accumulators``).
    """
    n_pix, C = xy.shape
    assert C == trig.shape[0], (xy.shape, trig.shape)
    n_theta = trig.shape[1]

    pad_p = (-n_pix) % bp
    if pad_p:
        xy = jnp.pad(xy, ((0, pad_p), (0, 0)))
        weights = jnp.pad(weights, (0, pad_p))
    pad_r = (-n_rho) % br
    N_rho = n_rho + pad_r
    P = xy.shape[0]
    w2d = weights[:, None].astype(jnp.float32)

    out = pl.pallas_call(
        functools.partial(_vote_kernel, br=br),
        grid=(N_rho // br, P // bp),
        in_specs=[
            pl.BlockSpec((bp, C), lambda r, p: (p, 0)),
            pl.BlockSpec((bp, 1), lambda r, p: (p, 0)),
            pl.BlockSpec((C, n_theta), lambda r, p: (0, 0)),
        ],
        out_specs=pl.BlockSpec((br, n_theta), lambda r, p: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((N_rho, n_theta), jnp.float32),
        scratch_shapes=[pltpu.VMEM((br, n_theta), jnp.float32)],
        interpret=interpret,
    )(xy.astype(jnp.float32), w2d, trig.astype(jnp.float32))
    return out[:n_rho]
