"""Pallas TPU kernels for the perf-critical stages, with pure-jnp oracles.

The paper's compute hot-spots (conv-as-GEMM Canny stages, Hough voting) and
the framework's transformer/SSM hot-spots all live here.  See ``ops`` for
the public dispatching API and ``ref`` for the semantics of record.
"""

from . import ops, ref  # noqa: F401
from .ops import (  # noqa: F401
    conv2d_gemm,
    flash_attention,
    hough_vote,
    resolve_impl,
    set_default_impl,
    ssd_scan,
    tiled_matmul,
)
