"""Fused multi-mask conv-as-GEMM Pallas kernel (paper Section 4 / Workload 3).

The paper rewrites the Canny stencils (5x5 Gauss mask, Sobel masks) as matrix
multiplications — a 5x5 mask times a 5x5 per-pixel neighbourhood — and ships
them to Gemmini.  Its reported limitation is that 5x5 operands underfill the
16x16 systolic array.

This kernel is the TPU-native fix: im2col happens *inside* VMEM, batching a
whole (bh, bw) pixel tile into a ``(bh, bw, kh*kw)`` patch tensor that is
multiplied against **all masks at once** — ``(n_masks, kh*kw)`` — in a single
MXU-friendly GEMM.  The patch tensor never touches HBM, and all three Canny
masks (Gauss, Sobel-x, Sobel-y) share one im2col pass.

Streaming layout (the batched fast path):
  * the grid is ``(batch, row_block, col_block)`` — a leading batch axis so a
    stack of frames lowers as **one** kernel launch, and a 2-D spatial tiling
    so per-step VMEM is O(bh * bw), independent of the image size.  This
    removes the old whole-image-VMEM-residency ceiling (a 1080p f32 frame is
    ~8 MB *before* im2col; a (bh, bw) tile is a few hundred KB).
  * overlapping stencil windows cannot be expressed as non-overlapping
    BlockSpec tiles, so the halo is streamed by passing the zero-padded image
    through **nine index-mapped BlockSpecs** — the 3x3 neighbourhood of the
    current tile.  The image is padded by one full block on every side so the
    neighbour index maps stay in range and the boundary halos read zeros
    (same-padding semantics for free).  Pallas's pipeline machinery
    double-buffers each neighbour stream from HBM.
  * output is ``(batch, n_masks, H, W)`` so the lane dimension stays W-major.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .tiles import acc_dtype as _acc_dtype
from .tiles import round_up as _round_up


def _conv_kernel(*refs, bh, bw, kh, kw, acc_dtype):
    # refs: 9 halo-neighbour image blocks (row-major 3x3), masks, output.
    nbr, masks_ref, o_ref = refs[:9], refs[9], refs[10]
    ph, pw = kh // 2, kw // 2
    blocks = [
        [nbr[3 * r + c][...].reshape(bh, bw) for c in range(3)]
        for r in range(3)
    ]

    # Assemble only the (bh + 2*ph, bw + 2*pw) halo slab around the centre
    # tile: ph/pw-wide strips of the neighbours, never the full 3x3 tile.
    def strip(row, rs):
        left, centre, right = row
        parts = ([left[rs, bw - pw :]] if pw else []) + [centre[rs, :]] + (
            [right[rs, : pw]] if pw else []
        )
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts, 1)

    pieces = ([strip(blocks[0], slice(bh - ph, bh))] if ph else []) + [
        strip(blocks[1], slice(None))
    ] + ([strip(blocks[2], slice(0, ph))] if ph else [])
    slab = pieces[0] if len(pieces) == 1 else jnp.concatenate(pieces, 0)
    # On-chip im2col: static shifted windows stacked on a new minor axis.
    patches = jnp.stack(
        [
            slab[dy : dy + bh, dx : dx + bw]
            for dy in range(kh)
            for dx in range(kw)
        ],
        axis=-1,
    )  # (bh, bw, kh*kw)
    masks = masks_ref[...]  # (n_masks, kh*kw)
    # One GEMM for every mask: (M, K) x (bh, bw, K) -> (M, bh, bw).
    out = jax.lax.dot_general(
        masks.astype(acc_dtype),
        patches.astype(acc_dtype),
        dimension_numbers=(((1,), (2,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    o_ref[...] = out[None].astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bh", "bw", "out_dtype", "interpret")
)
def conv2d_gemm(
    image: jax.Array,
    masks: jax.Array,
    *,
    bh: int = 8,
    bw: int = 128,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Same-padded 2D correlation of ``image`` with ``masks`` (n_masks, kh, kw).

    ``image`` may be a single frame ``(H, W)`` -> ``(n_masks, H, W)``, or a
    batch ``(N, H, W)`` -> ``(N, n_masks, H, W)`` lowered as one kernel with
    a leading batch grid axis.

    ``bh``/``bw`` tile the rows/columns; non-multiple shapes are padded up
    and cropped.  Accumulation follows ``tiles.acc_dtype``: int32 for
    integer inputs (the paper's integer pipeline), f16 for f16 inputs (the
    low-precision gradient tier), f32 otherwise.
    """
    squeeze = image.ndim == 2
    if squeeze:
        image = image[None]
    N, H, W = image.shape
    n_masks, kh, kw = masks.shape
    integer = jnp.issubdtype(image.dtype, jnp.integer)
    acc_dtype = _acc_dtype(image.dtype)
    if out_dtype is None:
        out_dtype = jnp.int32 if integer else image.dtype

    ph, pw = kh // 2, kw // 2
    bh = max(bh, ph)
    bw = max(min(bw, _round_up(W, 8)), pw)
    Hb, Wb = _round_up(H, bh), _round_up(W, bw)
    # One extra zero block on every side: boundary tiles read their halo
    # from it, and neighbour index maps (i+di, j+dj) never go out of range.
    padded = jnp.pad(
        image, ((0, 0), (bh, Hb - H + bh), (bw, Wb - W + bw))
    )
    flat_masks = masks.reshape(n_masks, kh * kw)

    nbr_specs = [
        pl.BlockSpec(
            (1, bh, bw),
            (lambda n, i, j, di=di, dj=dj: (n, i + di, j + dj)),
        )
        for di in range(3)
        for dj in range(3)
    ]
    out = pl.pallas_call(
        functools.partial(
            _conv_kernel, bh=bh, bw=bw, kh=kh, kw=kw, acc_dtype=acc_dtype
        ),
        grid=(N, Hb // bh, Wb // bw),
        in_specs=nbr_specs
        + [pl.BlockSpec((n_masks, kh * kw), lambda n, i, j: (0, 0))],
        out_specs=pl.BlockSpec(
            (1, n_masks, bh, bw), lambda n, i, j: (n, 0, i, j)
        ),
        out_shape=jax.ShapeDtypeStruct((N, n_masks, Hb, Wb), out_dtype),
        interpret=interpret,
    )(*([padded] * 9), flat_masks)
    out = out[:, :, :H, :W]
    return out[0] if squeeze else out
