"""Fused multi-mask conv-as-GEMM Pallas kernel (paper Section 4 / Workload 3).

The paper rewrites the Canny stencils (5x5 Gauss mask, Sobel masks) as matrix
multiplications — a 5x5 mask times a 5x5 per-pixel neighbourhood — and ships
them to Gemmini.  Its reported limitation is that 5x5 operands underfill the
16x16 systolic array.

This kernel is the TPU-native fix: im2col happens *inside* VMEM, batching a
whole row-block of pixels into a tall ``(bh*W, kh*kw)`` patch matrix that is
multiplied against **all masks at once** — ``(kh*kw, n_masks)`` — in a single
MXU-friendly GEMM.  The patch matrix never touches HBM, and all three Canny
masks (Gauss, Sobel-x, Sobel-y) share one im2col pass.

Layout notes:
  * the (zero-padded) image is kept fully VMEM-resident (a 720p f32 frame is
    ~3.7 MB, well under the ~16 MB v5e VMEM budget) and the grid walks row
    blocks with dynamic slices — overlapping stencil windows cannot be
    expressed as non-overlapping BlockSpec tiles;
  * output is ``(n_masks, H, W)`` so the lane dimension stays W-major.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(img_ref, masks_ref, o_ref, *, bh, kh, kw, W, acc_dtype):
    i = pl.program_id(0)
    # Slab of rows covering the stencil overlap: (bh + kh - 1, W + kw - 1).
    slab = img_ref[pl.dslice(i * bh, bh + kh - 1), :]
    # On-chip im2col: static shifted windows stacked on a new minor axis.
    patches = jnp.stack(
        [
            jax.lax.dynamic_slice(slab, (dy, dx), (bh, W))
            for dy in range(kh)
            for dx in range(kw)
        ],
        axis=-1,
    )  # (bh, W, kh*kw)
    masks = masks_ref[...]  # (n_masks, kh*kw)
    # One GEMM for every mask: (bh, W, K) x (M, K) -> (M, bh, W).
    out = jax.lax.dot_general(
        masks.astype(acc_dtype),
        patches.astype(acc_dtype),
        dimension_numbers=(((1,), (2,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    o_ref[...] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bh", "out_dtype", "interpret")
)
def conv2d_gemm(
    image: jax.Array,
    masks: jax.Array,
    *,
    bh: int = 8,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """Same-padded 2D correlation of ``image`` (H, W) with ``masks``
    (n_masks, kh, kw).  Returns (n_masks, H, W).

    Integer inputs accumulate in int32 (the paper's integer pipeline);
    float inputs accumulate in f32.
    """
    H, W = image.shape
    n_masks, kh, kw = masks.shape
    integer = jnp.issubdtype(image.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32
    if out_dtype is None:
        out_dtype = jnp.int32 if integer else image.dtype

    pad_h = (-H) % bh
    padded = jnp.pad(
        image, ((kh // 2, kh // 2 + pad_h), (kw // 2, kw // 2))
    )
    Hp = H + pad_h
    flat_masks = masks.reshape(n_masks, kh * kw)

    out = pl.pallas_call(
        functools.partial(
            _conv_kernel, bh=bh, kh=kh, kw=kw, W=W, acc_dtype=acc_dtype
        ),
        grid=(Hp // bh,),
        in_specs=[
            # Whole padded image resident per grid step (see module note).
            pl.BlockSpec(padded.shape, lambda i: (0, 0)),
            pl.BlockSpec(flat_masks.shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n_masks, bh, W), lambda i: (0, i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_masks, Hp, W), out_dtype),
        interpret=interpret,
    )(padded, flat_masks)
    return out[:, :H, :]
