"""Blocked GEMM Pallas kernel — the TPU analogue of Gemmini's ``tiled_matmul_auto``.

The paper offloads matrix multiplication to a 16x16 systolic array with an
explicitly managed scratchpad.  Here the systolic array is the 128x128 MXU and
the scratchpad is VMEM, tiled explicitly through ``BlockSpec``.  Like Gemmini,
the kernel supports a low-precision integer path (int8 inputs, wide int32
accumulator — the paper's float->int rewrite) next to the float path
(bf16/f32 inputs, f32 accumulator).

Grid layout: ``(m_blocks, n_blocks, k_blocks)`` with ``k`` innermost so the
(bm, bn) accumulator tile lives in VMEM scratch across the contraction —
exactly Gemmini's output-stationary dataflow.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# MXU-aligned default tile sizes (multiples of 128 on the minor dims).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, acc_dtype):
    """Output-stationary blocked matmul body."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=acc_dtype
    )

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of ``dim`` that is <= preferred (keeps grids exact)."""
    b = min(dim, preferred)
    while dim % b:
        b -= 1
    return b


@functools.partial(
    jax.jit,
    static_argnames=("bm", "bn", "bk", "out_dtype", "interpret"),
)
def tiled_matmul(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    out_dtype=None,
    interpret: bool = False,
) -> jax.Array:
    """``x @ y`` with explicit VMEM tiling.

    int8 x int8 accumulates in int32 (Gemmini's wide accumulator); everything
    else accumulates in f32.  Shapes need not be tile-aligned — they are
    zero-padded up to the block grid (zeros contribute nothing to the GEMM).
    """
    (m, k), (k2, n) = x.shape, y.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")

    integer = jnp.issubdtype(x.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32
    if out_dtype is None:
        out_dtype = jnp.int32 if integer else x.dtype

    bm = _pick_block(m, bm) if m % bm else min(bm, m)
    bn = _pick_block(n, bn) if n % bn else min(bn, n)
    bk = _pick_block(k, bk) if k % bk else min(bk, k)
    # Fall back to padding when the dims are prime-ish and _pick_block
    # degenerates to tiny tiles.
    pad_m = (-m) % bm
    pad_n = (-n) % bn
    pad_k = (-k) % bk
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        y = jnp.pad(y, ((0, pad_k), (0, pad_n)))
    M, K = x.shape
    N = y.shape[1]

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, acc_dtype=acc_dtype),
        grid=(M // bm, N // bn, K // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(x, y)
    if pad_m or pad_n:
        out = out[:m, :n]
    return out
