"""Blocked (flash) attention Pallas kernel: causal / GQA / sliding-window.

Attention is the paper's thesis at transformer scale: a softmax-weighted
average re-expressed as *blocked GEMMs* (QK^T and PV) with an online-softmax
epilogue, sized so every operand tile lives in VMEM and the MXU runs on
128-aligned dims.  GQA is handled without materializing repeated K/V — the
kv BlockSpec ``index_map`` folds the query-head -> kv-head mapping, the VMEM
analogue of Gemmini reusing one scratchpad operand across many row tiles.

Grid: ``(batch*q_heads, q_blocks, kv_blocks)`` with kv innermost; the
(bq, d) accumulator plus running max/denominator are output-stationary in
scratch.  Fully-masked kv blocks are skipped via ``pl.when`` (the causal /
window block frontier), which is what makes sliding-window attention
O(L*window) rather than O(L^2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(
    q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
    *, scale, causal, window, q_offset, kv_len, bq, bk,
):
    i = pl.program_id(1)  # q block
    j = pl.program_id(2)  # kv block

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # Block-frontier skip: is any (q, kv) pair in this tile unmasked?
    q_lo = q_offset + i * bq
    q_hi = q_lo + bq - 1
    kv_lo = j * bk
    needed = kv_lo < min(kv_len, 1 << 62)
    if causal:
        needed = jnp.logical_and(needed, kv_lo <= q_hi)
    if window is not None:
        kv_hi = kv_lo + bk - 1
        needed = jnp.logical_and(needed, kv_hi > q_lo - window)

    @pl.when(needed)
    def _update():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)  # (bk, d)

        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kv_pos = kv_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kv_pos < kv_len
        if causal:
            mask = jnp.logical_and(mask, q_pos >= kv_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - kv_pos < window)

        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]  # (bq, 1)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        # Explicit mask on p: never rely on exp(-inf - -inf) == 0.
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = corr * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = corr * acc_ref[...] + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _flush():
        denom = l_ref[...]
        denom = jnp.where(denom == 0.0, 1.0, denom)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "q_offset", "bq", "bk", "interpret"
    ),
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_offset: int = 0,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Blocked attention.

    Args:
      q: (B, Hq, Lq, D);  k, v: (B, Hkv, Lkv, D) with Hq % Hkv == 0 (GQA).
      causal: apply causal mask in *global* positions (see q_offset).
      window: sliding-window size (kv_pos within ``window`` of q_pos).
      q_offset: global position of q[...,0,:] — used for decode, where
        Lq << Lkv and queries sit at the end of the kv timeline.
    Returns: (B, Hq, Lq, D) in q.dtype.
    """
    B, Hq, Lq, D = q.shape
    _, Hkv, Lkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    scale = 1.0 / (D ** 0.5)

    bq = min(bq, max(8, Lq))
    bk = min(bk, Lkv)
    pad_q = (-Lq) % bq
    pad_k = (-Lkv) % bk
    qr = q.reshape(B * Hq, Lq, D)
    kr = k.reshape(B * Hkv, Lkv, D)
    vr = v.reshape(B * Hkv, Lkv, D)
    if pad_q:
        qr = jnp.pad(qr, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kr = jnp.pad(kr, ((0, 0), (0, pad_k), (0, 0)))
        vr = jnp.pad(vr, ((0, 0), (0, pad_k), (0, 0)))
    Lqp, Lkp = Lq + pad_q, Lkv + pad_k

    def kv_index(h, i, j):
        return ((h // Hq) * Hkv + (h % Hq) // group, j, 0)

    out = pl.pallas_call(
        functools.partial(
            _attn_kernel,
            scale=scale, causal=causal, window=window,
            q_offset=q_offset, kv_len=Lkv, bq=bq, bk=bk,
        ),
        grid=(B * Hq, Lqp // bq, Lkp // bk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, D), kv_index),
            pl.BlockSpec((1, bk, D), kv_index),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * Hq, Lqp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, D), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
        ],
        interpret=interpret,
    )(qr, kr, vr)
    return out[:, :Lq, :].reshape(B, Hq, Lq, D)
