"""Mamba-2 SSD chunked-scan Pallas kernel.

The flagship application of the paper's insight to sequence models: a linear
recurrence ``h_t = a_t h_{t-1} + dt_t B_t x_t``, ``y_t = C_t . h_t`` is
*rewritten as chunked matmuls* (the State Space Duality form), exactly as the
paper rewrites a stencil as mask x neighbourhood GEMMs:

  * intra-chunk:  ``Y = ((C B^T) * decay_mask) @ (x*dt)``   — two GEMMs
  * inter-chunk:  state carried through the sequential chunk grid axis in a
    VMEM scratch accumulator (the output-stationary dataflow again), applied
    to each chunk with one more GEMM.

Grid ``(batch*heads, n_chunks)``: the TPU grid's minor axis iterates
sequentially per core, so the ``(N, P)`` state scratch is the recurrence
carry.  Group-shared B/C (Mamba-2's G groups, analogous to GQA) are folded
via the BlockSpec ``index_map`` — never materialized per-head.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    xdt_ref, ldec_ref, b_ref, c_ref, y_ref, st_ref, state, *, Q
):
    c_idx = pl.program_id(1)

    @pl.when(c_idx == 0)
    def _init():
        state[...] = jnp.zeros_like(state)

    xb = xdt_ref[0].astype(jnp.float32)   # (Q, P)   x * dt
    lc = ldec_ref[...].astype(jnp.float32)  # (1, Q)  log-decay dt*A  (<= 0)
    Bb = b_ref[0].astype(jnp.float32)     # (Q, N)
    Cb = c_ref[0].astype(jnp.float32)     # (Q, N)

    cum = jnp.cumsum(lc, axis=1)[0]       # (Q,) inclusive log-decay prefix

    # Intra-chunk: masked decay GEMM  ((C B^T) * tril(exp(cum_i - cum_j))).
    cb = jax.lax.dot_general(
        Cb, Bb, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    seg = jnp.where(ii >= jj, jnp.exp(cum[:, None] - cum[None, :]), 0.0)
    y = jnp.dot(cb * seg, xb, preferred_element_type=jnp.float32)

    # Inter-chunk: apply the carried state h0 -> Y += (C @ h0) * exp(cum).
    y += jnp.dot(Cb, state[...], preferred_element_type=jnp.float32) \
        * jnp.exp(cum)[:, None]

    # State update: h_Q = exp(cum_Q) h_0 + sum_j exp(cum_Q - cum_j) B_j (x dt)_j.
    wB = Bb * jnp.exp(cum[-1] - cum)[:, None]        # (Q, N)
    state[...] = jnp.exp(cum[-1]) * state[...] + jax.lax.dot_general(
        wB, xb, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(c_idx == pl.num_programs(1) - 1)
    def _flush():
        st_ref[0] = state[...].astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Chunked selective-state-space scan (Mamba-2 SSD).

    Args:
      x:  (batch, L, H, P) inputs per head.
      dt: (batch, L, H)    positive step sizes (already softplus+bias).
      A:  (H,)             negative per-head decay rates.
      B:  (batch, L, G, N) input projections (G groups, H % G == 0).
      C:  (batch, L, G, N) output projections.
    Returns:
      y:     (batch, L, H, P)
      state: (batch, H, N, P) final SSM state (prefill -> decode handoff).
    """
    batch, L, H, P = x.shape
    G, N = B.shape[2], B.shape[3]
    assert H % G == 0, (H, G)
    hpg = H // G

    Q = min(chunk, L)
    pad = (-L) % Q
    xdt = (x * dt[..., None]).transpose(0, 2, 1, 3).reshape(batch * H, L, P)
    ldec = (dt * A[None, None, :]).transpose(0, 2, 1).reshape(batch * H, L)
    Bm = B.transpose(0, 2, 1, 3).reshape(batch * G, L, N)
    Cm = C.transpose(0, 2, 1, 3).reshape(batch * G, L, N)
    if pad:  # zero x-contribution, zero log-decay => identity steps
        xdt = jnp.pad(xdt, ((0, 0), (0, pad), (0, 0)))
        ldec = jnp.pad(ldec, ((0, 0), (0, pad)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    Lp = L + pad

    def bc_index(h, c):
        return ((h // H) * G + (h % H) // hpg, c, 0)

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q),
        grid=(batch * H, Lp // Q),
        in_specs=[
            pl.BlockSpec((1, Q, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, Q), lambda h, c: (h, c)),
            pl.BlockSpec((1, Q, N), bc_index),
            pl.BlockSpec((1, Q, N), bc_index),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda h, c: (h, c, 0)),
            pl.BlockSpec((1, N, P), lambda h, c: (h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((batch * H, Lp, P), x.dtype),
            jax.ShapeDtypeStruct((batch * H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xdt, ldec, Bm, Cm)

    y = y[:, :L].reshape(batch, H, L, P).transpose(0, 2, 1, 3)
    state = state.reshape(batch, H, N, P)
    return y, state
