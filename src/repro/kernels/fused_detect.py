"""Fused canny -> corridor filter -> compact Pallas kernel (kernel A).

The staged hot path runs three dispatches — gradient/canny, edge
compaction, Hough vote — and each round-trips HBM: the gradient stack and
the edge mask are materialized as full (H, W) arrays between kernels.
This module is the fusion the ROADMAP's "one-kernel hot path" item asks
for, split in two at the compaction boundary (the one place the dataflow
genuinely changes shape):

  * **Kernel A (here):** per frame, compute the whole Canny front end,
    threshold, optionally drop pixels outside the tracker's predicted
    rho corridors, and prefix-sum-compact the survivors — all in VMEM.
    The only HBM traffic is the input frame in and the compacted
    ``(max_edges, 3)`` edge list out; no gradient, magnitude, or edge-mask
    array ever hits HBM.
  * **Kernel B:** the existing ``hough_vote`` kernel, consuming the
    compacted list directly (``compact=False`` — it is already compact).

Grid is ``(batch,)`` with one full frame per step: the target workloads
(240x320 .. 480x640 f32) fit VMEM whole, and whole-frame compaction is
what keeps the fused path **bit-exact** with the staged one — a per-tile
compaction quota would drop different edges on overflow.  The kernel body
is written at the jnp level and calls the *same* Canny math as the staged
path (``core.canny.canny`` with the impl pinned to the pure-jnp oracle, so
the body never nests another pallas_call): identical ops on identical
inputs give the identical edge set, and vote weights are small-integer
sums in f32, so bit-exactness follows structurally.  This lowers today
under ``interpret=True`` (and is validated that way); compiling the body
through Mosaic on a real TPU is the re-scoped hardware item in ROADMAP.md.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _fused_kernel(img_ref, cor_ref, *rest, cfg, edge_threshold,
                  max_edges, use_corridors):
    from repro.core.canny import canny as _canny  # function-level: cycle

    mask_refs, (oxy_ref, ow_ref) = rest[:-2], rest[-2:]
    H, W = img_ref.shape[-2:]
    img = img_ref[...].reshape(H, W)
    # uint8 {0, 255}; cfg.impl pinned to "xla", conv masks fed as operands
    # (a Pallas body may not capture array constants).
    edges = _canny(img, cfg, tuple(m[...] for m in mask_refs))
    flat = edges.reshape(H * W)
    w = (flat >= edge_threshold).astype(jnp.float32)

    # Raster (x, y, 1) coordinates — broadcasted_iota, never 1-D iota.
    ii = jax.lax.broadcasted_iota(jnp.float32, (H, W), 0)
    jj = jax.lax.broadcasted_iota(jnp.float32, (H, W), 1)
    xy = jnp.stack(
        [jj.ravel(), ii.ravel(), jnp.ones(H * W, jnp.float32)], axis=1
    )

    if use_corridors:
        w = w * ref.corridor_keep(xy, cor_ref[...]).astype(jnp.float32)

    # Whole-frame prefix-sum compaction (same math as
    # ``hough_vote._compact_one``): edge k lands in row k, overflow drops.
    mask = w > 0
    pos = jnp.where(mask, jnp.cumsum(mask) - 1, max_edges)
    cxy = (
        jnp.zeros((max_edges, 3), jnp.float32).at[pos].set(xy, mode="drop")
    )
    cw = jnp.zeros((max_edges,), jnp.float32).at[pos].set(w, mode="drop")
    oxy_ref[...] = cxy[None]
    ow_ref[...] = cw[None]


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "edge_threshold", "max_edges", "interpret"),
)
def fused_detect(image: jax.Array, corridors: jax.Array | None = None, *,
                 cfg, edge_threshold: float, max_edges: int,
                 interpret: bool = False):
    """Kernel A: frame(s) -> compacted (and corridor-filtered) edge list.

    Args:
      image:     (H, W) or (N, H, W) frame stack.
      corridors: optional (C, 4) rho windows (``ref.corridor_keep`` rows),
                 shared across the batch; None disables filtering.
      cfg:       ``CannyConfig`` — the impl is pinned to the jnp oracle
                 inside the kernel body regardless of what it says.
      edge_threshold: vote-weight threshold on the canny output (the
                 staged ``HoughConfig.edge_threshold``).
      max_edges: static compacted buffer length.

    Returns ``(cxy, cw)``: (..., max_edges, 3) homogeneous coordinates and
    (..., max_edges) f32 weights, matching ``ref.fused_detect``.
    """
    from repro.core.canny import gradient_masks  # function-level: cycle

    cfg = dataclasses.replace(cfg, impl="xla")
    squeeze = image.ndim == 2
    if squeeze:
        image = image[None]
    N, H, W = image.shape
    use_corridors = corridors is not None
    if corridors is None:
        corridors = jnp.zeros((1, 4), jnp.float32)  # placeholder operand
    cor = jnp.asarray(corridors, jnp.float32)
    C = cor.shape[0]
    masks = tuple(jnp.asarray(m) for m in gradient_masks(cfg))

    oxy, ow = pl.pallas_call(
        functools.partial(
            _fused_kernel, cfg=cfg, edge_threshold=edge_threshold,
            max_edges=max_edges, use_corridors=use_corridors,
        ),
        grid=(N,),
        in_specs=[
            pl.BlockSpec((1, H, W), lambda n: (n, 0, 0)),
            pl.BlockSpec((C, 4), lambda n: (0, 0)),
        ] + [
            pl.BlockSpec(m.shape, (lambda n: (0,) * 3)) for m in masks
        ],
        out_specs=[
            pl.BlockSpec((1, max_edges, 3), lambda n: (n, 0, 0)),
            pl.BlockSpec((1, max_edges), lambda n: (n, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((N, max_edges, 3), jnp.float32),
            jax.ShapeDtypeStruct((N, max_edges), jnp.float32),
        ],
        interpret=interpret,
    )(image, cor, *masks)
    if squeeze:
        return oxy[0], ow[0]
    return oxy, ow
