"""Filesystem checkpoint store.

Layout:
    <dir>/step_<N>/manifest.json     tree structure, shapes, dtypes
    <dir>/step_<N>/<leaf_key>.npy    one array per pytree leaf
    <dir>/step_<N>.tmp/...           staging (atomic rename on completion)

Properties needed at scale, kept here in host-scale form:

  * **atomic** — a checkpoint directory appears only after every leaf is
    durably written (tmp dir + rename), so a crash mid-save can never leave
    a half checkpoint that restore would trust;
  * **async** — ``CheckpointManager.save_async`` snapshots device arrays to
    host memory synchronously (cheap) and does the disk I/O on a background
    thread, overlapping the next training steps (the standard
    checkpoint-stall fix);
  * **elastic restore** — leaves are loaded as host numpy and re-placed via
    ``jax.device_put`` against *whatever shardings the new mesh wants*;
    nothing in the file format knows the mesh, so restoring 16x16 state
    onto 8x16 (or 2x16x16) is just a different placement argument;
  * **retention** — keep the last ``keep`` checkpoints, delete older ones.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy can't serialize the ml_dtypes extension types: store them as a
# same-width integer view and record the logical dtype in the manifest.
_EXOTIC_VIEW = {
    "bfloat16": np.uint16,
    "float8_e4m3fn": np.uint8,
    "float8_e5m2": np.uint8,
}


def _flatten_with_paths(tree: Any):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out


def save(state: Any, directory: str, step: int) -> str:
    """Synchronous atomic save. Returns the final checkpoint path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    leaves = _flatten_with_paths(state)
    manifest = {"step": step, "leaves": []}
    for key, leaf in leaves:
        arr = np.asarray(leaf)
        logical = str(arr.dtype)
        if logical in _EXOTIC_VIEW:
            arr = arr.view(_EXOTIC_VIEW[logical])
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append(
            {"key": key, "file": fname, "shape": list(arr.shape),
             "dtype": logical}
        )
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(
    directory: str,
    target: Any,
    step: Optional[int] = None,
    shardings: Any = None,
) -> Any:
    """Load into the structure of ``target`` (pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedSharding for elastic re-placement on the current mesh."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["leaves"]}

    leaves = _flatten_with_paths(target)
    flat_shard = (
        [s for _, s in _flatten_with_paths(shardings)]
        if shardings is not None else [None] * len(leaves)
    )
    out = []
    for (key, leaf), shard in zip(leaves, flat_shard):
        entry = by_key[key]
        arr = np.load(os.path.join(path, entry["file"]))
        if entry["dtype"] in _EXOTIC_VIEW:
            arr = arr.view(getattr(ml_dtypes, entry["dtype"]))
        expect = tuple(getattr(leaf, "shape", arr.shape))
        if tuple(arr.shape) != expect:
            raise ValueError(
                f"checkpoint leaf {key} shape {arr.shape} != target {expect}"
            )
        if shard is not None:
            out.append(jax.device_put(arr, shard))
        else:
            out.append(jax.device_put(arr))
    treedef = jax.tree_util.tree_structure(target)
    return jax.tree_util.tree_unflatten(treedef, out)


class CheckpointManager:
    """Async save + retention."""

    def __init__(self, directory: str, *, keep: int = 3):
        self.directory = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def save_async(self, state: Any, step: int):
        """Snapshot to host now; write to disk in the background."""
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            try:
                save(host_state, self.directory, step)
                self._gc()
            except BaseException as e:   # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def save_sync(self, state: Any, step: int) -> str:
        self.wait()
        path = save(state, self.directory, step)
        self._gc()
        return path

    def _gc(self):
        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.keep]:
            shutil.rmtree(
                os.path.join(self.directory, f"step_{s:08d}"),
                ignore_errors=True,
            )

    def restore_latest(self, target: Any, shardings: Any = None) -> Any:
        self.wait()
        return restore(self.directory, target, shardings=shardings)
