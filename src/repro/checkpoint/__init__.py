"""Checkpointing: async save, manifest, restore-with-resharding (elastic)."""

from .store import (  # noqa: F401
    CheckpointManager,
    latest_step,
    restore,
    save,
)
