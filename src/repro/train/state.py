"""TrainState: parameters + optimizer moments + step, with sharding specs."""

from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.sharding import AxisRules, DEFAULT_RULES, shardings_for_tree

from .optim import adamw_init


class TrainState(NamedTuple):
    step: jax.Array            # () int32
    params: Any
    opt: Any                   # {"m": ..., "v": ...} like params
    err: Optional[Any] = None  # int8-compression error feedback (or None)


def init_train_state(params: Any, *, compression: bool = False) -> TrainState:
    err = (
        jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        if compression else None
    )
    return TrainState(jnp.zeros((), jnp.int32), params, adamw_init(params),
                      err)


def train_state_specs(model, *, compression: bool = False):
    """(abstract TrainState, axes TrainState-shaped tree) for the dry-run."""
    p_abs = model.abstract_params()
    p_axes = model.param_axes()
    abs_state = TrainState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        params=p_abs,
        opt={"m": p_abs, "v": p_abs},
        err=p_abs if compression else None,
    )
    axes_state = TrainState(
        step=(),
        params=p_axes,
        opt={"m": p_axes, "v": p_axes},
        err=p_axes if compression else None,
    )
    return abs_state, axes_state


def train_state_shardings(model, mesh, rules: AxisRules = DEFAULT_RULES, *,
                          compression: bool = False):
    abs_state, axes_state = train_state_specs(model, compression=compression)
    shardings = shardings_for_tree(axes_state, abs_state, mesh, rules)
    return abs_state, shardings
