"""Training substrate: optimizer, train state, step builders, compression.

The paper's int8 rewrite (Section 4.4) generalizes here to error-feedback
int8 gradient compression for the cross-pod reduction — the one collective
that must traverse the slow inter-pod links every step.
"""

from .optim import AdamWConfig, adamw_init, adamw_update, lr_at  # noqa: F401
from .state import TrainState, train_state_specs  # noqa: F401
from .trainer import make_train_step, make_eval_step  # noqa: F401
from .compression import (  # noqa: F401
    CompressionState,
    compress_decompress,
    compressed_allreduce,
    init_compression,
)
