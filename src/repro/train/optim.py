"""AdamW with warmup+cosine schedule and global-norm clipping.

Self-contained (no optax offline).  Optimizer moments live in a pytree
shaped exactly like the parameters, so the same logical-axes tree shards
them (ZeRO-style: FSDP-sharded moments ride the ``data`` axis for free).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    decay_steps: int = 10_000
    floor_ratio: float = 0.1       # final lr = floor_ratio * peak
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def lr_at(step: jax.Array, cfg: AdamWConfig) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / max(cfg.warmup_steps, 1)
    t = jnp.clip(
        (step - cfg.warmup_steps)
        / max(cfg.decay_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = cfg.peak_lr * (
        cfg.floor_ratio
        + (1 - cfg.floor_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    )
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> Any:
    zeros = lambda p: jnp.zeros_like(p)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree: Any) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def adamw_update(
    grads: Any, opt_state: Any, params: Any, step: jax.Array,
    cfg: AdamWConfig,
) -> tuple[Any, Any, dict]:
    """Returns (new_params, new_opt_state, metrics)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g * scale, grads)

    t = step.astype(jnp.float32) + 1.0
    lr = lr_at(step, cfg)
    c1 = 1.0 - cfg.b1 ** t
    c2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * gf
        v = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        mh = m / c1
        vh = v / c2
        new_p = p - lr * (
            mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p
        )
        return new_p.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    new = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [n[0] for n in new])
    new_state = {
        "m": jax.tree.unflatten(treedef, [n[1] for n in new]),
        "v": jax.tree.unflatten(treedef, [n[2] for n in new]),
    }
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
