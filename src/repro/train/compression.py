"""Error-feedback int8 gradient compression for the cross-pod reduction.

The paper's float->int rewrite (Section 4.4) pays off exactly where
precision is cheap and bandwidth is dear.  In a multi-pod mesh the one
mandatory slow-link collective is the per-step gradient reduction over
``pod``; compressing it to int8 cuts the DCN bytes ~4x.  Error feedback
(Seide et al.; 1-bit SGD lineage) keeps the quantization *residual* locally
and re-injects it next step, so compression error accumulates to O(1)
instead of O(T) and convergence is preserved (unit-tested on a quadratic
and a tiny LM in ``tests/test_train.py``).

Mechanics per tensor:
    y      = grad + err                     (re-inject residual)
    q, s   = int8 quantize(y)               (per-tensor symmetric scale)
    total  = sum over pods of dequant(q, s) (all_gather int8+scale, local sum)
    err'   = y - dequant(q, s)              (what this pod failed to send)

The all_gather moves ``P x (n/4 + 4)`` bytes instead of the ~``2n`` of a
ring all-reduce in f32 — visible in the dry-run HLO as int8 collective
operands (``launch/dryrun.py`` artifacts; ROADMAP.md tracks the
collective-bound follow-ups).
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class CompressionState(NamedTuple):
    err: Any    # pytree of f32 residuals, shaped like grads


def init_compression(grads_like: Any) -> CompressionState:
    return CompressionState(
        jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads_like)
    )


def _quantize(y: jax.Array):
    amax = jnp.max(jnp.abs(y))
    scale = jnp.maximum(amax, 1e-30) / 127.0
    q = jnp.clip(jnp.round(y / scale), -128, 127).astype(jnp.int8)
    return q, scale


def compress_decompress(x: jax.Array, err: jax.Array):
    """Single-tensor round trip (what one pod contributes + new residual)."""
    y = x.astype(jnp.float32) + err
    q, scale = _quantize(y)
    deq = q.astype(jnp.float32) * scale
    return deq, y - deq


def compressed_allreduce(x: jax.Array, err: jax.Array, axis_name: str):
    """Mean over ``axis_name`` of int8-compressed contributions.

    Must run inside ``shard_map`` manual over ``axis_name``.  Returns
    (mean, new_err).
    """
    y = x.astype(jnp.float32) + err
    q, scale = _quantize(y)
    deq_own = q.astype(jnp.float32) * scale
    if hasattr(jax, "shard_map"):
        # int8 payload + f32 scale over the slow link
        qs = jax.lax.all_gather(q, axis_name)      # (P, ...)
        ss = jax.lax.all_gather(scale, axis_name)  # (P,)
        n = qs.shape[0]
        total = jnp.tensordot(
            ss, qs.astype(jnp.float32).reshape(n, -1), axes=1
        ).reshape(x.shape)
    else:
        # Old-jax partial-auto shard_map: every collective except psum
        # trips the SPMD partitioner's IsManualSubgroup checks, so reduce
        # the dequantized contributions directly.  Numerically the same sum
        # of per-pod dequant(q, s) terms — the error-feedback semantics the
        # tests pin down — but the int8 wire format only exists on jax
        # versions whose partitioner can gather it.
        n = jax.lax.psum(1, axis_name)
        total = jax.lax.psum(deq_own, axis_name)
    return total / n, y - deq_own


def compressed_allreduce_tree(grads: Any, state: CompressionState,
                              axis_name: str):
    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(state.err)
    outs = [compressed_allreduce(g, e, axis_name)
            for g, e in zip(flat_g, flat_e)]
    mean = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_err = jax.tree.unflatten(treedef, [o[1] for o in outs])
    return mean, CompressionState(new_err)
