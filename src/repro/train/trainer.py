"""Train-step builders: standard pjit path and pod-compressed path.

``make_train_step(model, opt_cfg)`` returns a pure ``(state, batch) ->
(state, metrics)`` suitable for ``jax.jit`` with NamedSharding in/out specs.

Features:
  * microbatching — gradient accumulation via ``lax.scan`` over microbatch
    slices (sequence-preserving, batch-splitting), keeping activation
    memory at 1/n while the global batch stays the assignment's;
  * remat is a model-config flag (applied inside the layer scan);
  * optional int8 error-feedback compression of the cross-pod gradient
    reduction: the whole grad computation runs inside ``shard_map`` manual
    over ``pod`` (auto/GSPMD over data+model), so XLA never inserts the f32
    pod all-reduce — our int8 all_gather is the only DCN traffic.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as PS

from repro.sharding import shard_map
from repro.train import compression as comp
from repro.train.optim import AdamWConfig, adamw_update
from repro.train.state import TrainState


def _split_microbatches(batch: Any, n: int) -> Any:
    """(B, ...) -> (n, B/n, ...) per leaf."""
    def sp(x):
        B = x.shape[0]
        assert B % n == 0, (B, n)
        return x.reshape((n, B // n) + x.shape[1:])
    return jax.tree.map(sp, batch)


def _mean_grads(loss_fn, params, batch, n_micro: int):
    """Accumulated (loss, metrics, grads) over n_micro microbatches."""
    if n_micro <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params, batch)
        return loss, metrics, grads

    micro = _split_microbatches(batch, n_micro)

    def step(carry, mb):
        acc_loss, acc_metrics, acc_grads = carry
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True
        )(params, mb)
        acc_grads = jax.tree.map(jnp.add, acc_grads, grads)
        acc_metrics = jax.tree.map(jnp.add, acc_metrics, metrics)
        return (acc_loss + loss, acc_metrics, acc_grads), None

    zero_g = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    (loss, metrics, grads), _ = jax.lax.scan(
        step,
        (jnp.float32(0), {"ce": jnp.float32(0), "moe_aux": jnp.float32(0)},
         zero_g),
        micro,
    )
    inv = 1.0 / n_micro
    return (
        loss * inv,
        jax.tree.map(lambda m: m * inv, metrics),
        jax.tree.map(lambda g: g * inv, grads),
    )


def make_train_step(
    model,
    opt_cfg: AdamWConfig,
    *,
    n_micro: int = 1,
) -> Callable[[TrainState, Any], tuple[TrainState, dict]]:
    """Standard pjit train step (gradient sync left to XLA/GSPMD)."""

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def train_step(state: TrainState, batch: Any):
        loss, metrics, grads = _mean_grads(
            loss_fn, state.params, batch, n_micro
        )
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, state.step, opt_cfg
        )
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(state.step + 1, new_params, new_opt, state.err), \
            metrics

    return train_step


def make_train_step_pod_compressed(
    model,
    opt_cfg: AdamWConfig,
    mesh,
    *,
    n_micro: int = 1,
) -> Callable[[TrainState, Any], tuple[TrainState, dict]]:
    """Train step whose cross-pod gradient reduction is int8-compressed.

    shard_map manual over ``pod`` / auto over (data, model): each pod
    computes its local mean gradient under GSPMD, contributes an int8
    payload, and applies the identical update (params stay pod-replicated).
    Requires state.err (init_train_state(compression=True)).
    """
    assert "pod" in mesh.axis_names, mesh.axis_names

    def loss_fn(params, batch):
        loss, metrics = model.loss(params, batch)
        return loss, metrics

    def per_pod(state: TrainState, batch: Any):
        loss, metrics, grads = _mean_grads(
            loss_fn, state.params, batch, n_micro
        )
        grads, new_cstate = comp.compressed_allreduce_tree(
            grads, comp.CompressionState(state.err), "pod"
        )
        loss = jax.lax.pmean(loss, "pod")
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, state.opt, state.params, state.step, opt_cfg
        )
        metrics = {**metrics, **opt_metrics, "loss": loss}
        return TrainState(
            state.step + 1, new_params, new_opt, new_cstate.err
        ), metrics

    # state replicated over pod (params/opt/err identical across pods);
    # batch split over pod on dim 0.  data/model sharding inside is GSPMD.
    state_spec = PS()
    batch_spec = PS("pod")
    metrics_spec = PS()

    return shard_map(
        per_pod,
        mesh=mesh,
        in_specs=(state_spec, batch_spec),
        out_specs=(state_spec, metrics_spec),
        axis_names={"pod"},
        check_vma=False,
    )


def make_eval_step(model) -> Callable[[Any, Any], dict]:
    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch)
        return {**metrics, "loss": loss}
    return eval_step
