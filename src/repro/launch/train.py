"""Training driver: sharded train loop with checkpointing and fault tolerance.

Host-scale entry point (the production mesh is exercised by ``dryrun.py``;
this driver runs real steps on whatever devices exist):

    PYTHONPATH=src python -m repro.launch.train \
        --arch yi-9b --preset smoke --steps 100 --ckpt /tmp/ckpt

Features wired in: logical-axis sharded state on a host mesh, deterministic
resumable data pipeline with prefetch, async checkpoints, restart-on-failure
supervision, optional int8 pod-compressed gradient reduction (multi-pod
meshes), microbatching.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro import sharding
from repro.checkpoint import CheckpointManager, latest_step
from repro.configs import ModelConfig, get, get_smoke
from repro.data import PrefetchLoader, TokenPipelineConfig, TokenStream
from repro.launch.mesh import make_host_mesh
from repro.models import build
from repro.sharding import DEFAULT_RULES, shardings_for_tree
from repro.train import AdamWConfig, make_train_step
from repro.train.state import init_train_state, train_state_shardings
from repro.train.trainer import make_train_step_pod_compressed


def preset_config(arch: str, preset: str) -> ModelConfig:
    if preset == "full":
        return get(arch)
    cfg = get_smoke(arch)
    if preset == "100m":
        # ~100M params in the arch's family shape
        return cfg.replace(
            n_layers=max(4, cfg.n_layers), d_model=512,
            n_heads=8, n_kv_heads=max(1, min(8, cfg.n_kv_heads or 8)),
            d_ff=2048, vocab=8192, remat=False,
        )
    return cfg


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "100m", "full"])
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--compress-pod", action="store_true",
                    help="int8 error-feedback cross-pod grad reduction "
                         "(needs a multi-pod host mesh: >= 8 devices)")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = preset_config(args.arch, args.preset)
    model = build(cfg)
    print(f"arch={args.arch} preset={args.preset} "
          f"params={model.param_count()/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    mesh = make_host_mesh(multi_pod=args.compress_pod)
    opt = AdamWConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      decay_steps=args.steps)

    rng = jax.random.PRNGKey(0)
    abs_state, state_sh = train_state_shardings(model, mesh)
    with sharding.activate(mesh, DEFAULT_RULES):
        state = jax.device_put(
            init_train_state(model.init(rng),
                             compression=args.compress_pod),
            state_sh if not args.compress_pod else None,
        )
        if args.compress_pod:
            step_fn = jax.jit(
                make_train_step_pod_compressed(model, opt, mesh,
                                               n_micro=args.n_micro))
        else:
            step_fn = jax.jit(make_train_step(model, opt,
                                              n_micro=args.n_micro),
                              in_shardings=(state_sh, None))

        mgr = CheckpointManager(args.ckpt) if args.ckpt else None
        start = 0
        if args.resume and args.ckpt and latest_step(args.ckpt) is not None:
            state = mgr.restore_latest(state)
            start = int(jax.device_get(state.step))
            print(f"resumed from step {start}")

        stream = TokenStream(TokenPipelineConfig(
            vocab=cfg.vocab, seq_len=args.seq,
            global_batch=args.global_batch))
        loader = PrefetchLoader(stream, depth=2, start_step=start)
        t0 = time.time()
        tokens_seen = 0
        try:
            for i in range(start, args.steps):
                step_idx, batch = loader.get()
                batch = {k: jnp.asarray(v) for k, v in batch.items()}
                state, metrics = step_fn(state, batch)
                tokens_seen += args.global_batch * args.seq
                if (i + 1) % args.log_every == 0 or i + 1 == args.steps:
                    loss = float(metrics["loss"])
                    tps = tokens_seen / (time.time() - t0)
                    print(f"step {i+1:5d}  loss {loss:7.4f}  "
                          f"lr {float(metrics['lr']):.2e}  "
                          f"grad_norm {float(metrics['grad_norm']):.3f}  "
                          f"{tps:,.0f} tok/s", flush=True)
                if mgr and (i + 1) % args.ckpt_every == 0:
                    mgr.save_async(state, i + 1)
        finally:
            loader.close()
            if mgr:
                mgr.save_sync(state, int(jax.device_get(state.step)))
    return state


if __name__ == "__main__":
    main()
