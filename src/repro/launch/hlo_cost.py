"""Static cost analysis of compiled HLO text, with loop trip-count awareness.

``compiled.cost_analysis()`` counts each ``while`` body ONCE — under
scan-over-layers that undercounts a 24-layer model 24x.  This module parses
``compiled.as_text()`` into computations, finds each loop's trip count from
its condition (the canonical ``compare(induction, constant(N))`` pattern),
and aggregates costs bottom-up with multiplication at loop boundaries:

  * ``dot_flops``  — 2 * numel(result) * K for every dot (the MXU term;
    elementwise flops are excluded deliberately: the roofline compute term
    is systolic-array time, the paper's own accounting),
  * ``bytes``      — operand + result bytes at fusion/op granularity
    (a model of HBM traffic under XLA's fusion boundaries),
  * ``collectives``— per-op result bytes for all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute.

Validated in tests against hand-computed matmul/scan programs.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# one shape token: dtype[dims]{layout}  (layout optional)
_SHAPE_TOK = re.compile(r"(\w+)\[([\d,]*)\](?:\{[^}]*\})?")
# an instruction line:  [ROOT] %name = <shape-or-tuple> opcode(...operands...)
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(\(.*?\)|[^\s]+)\s+"
    r"([\w\-]+)\((.*)$"
)
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(.*->.*\{\s*$")
_OPERAND = re.compile(r"%([\w\.\-]+)")


def _shape_info(shape_str: str):
    """(numel, bytes, dims_list) for possibly-tuple shape strings."""
    total_bytes = 0
    first_dims = None
    first_numel = 0
    for m in _SHAPE_TOK.finditer(shape_str):
        dt, dims_s = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in dims_s.split(",") if d]
        n = 1
        for d in dims:
            n *= d
        total_bytes += n * _DTYPE_BYTES[dt]
        if first_dims is None:
            first_dims, first_numel = dims, n
    return first_numel, total_bytes, (first_dims or [])


@dataclasses.dataclass
class Instr:
    name: str
    shape_str: str
    opcode: str
    rest: str          # text after the opcode's "("
    operands: list


@dataclasses.dataclass
class Computation:
    name: str
    instrs: list
    shapes: dict       # %name -> shape_str


def parse_hlo(text: str) -> dict:
    """name -> Computation for every computation block in the module."""
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1), [], {})
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, shape_str, opcode, rest = m.groups()
        # operands appear before any ", xxx=" attribute — take the call args
        head = rest.split("), ")[0]
        operands = _OPERAND.findall(head)
        ins = Instr(name, shape_str, opcode, rest, operands)
        cur.instrs.append(ins)
        cur.shapes[name] = shape_str
    return comps


def _called(rest: str, key: str) -> Optional[str]:
    m = re.search(key + r"=%?([\w\.\-]+)", rest)
    return m.group(1) if m else None


def _trip_count(while_ins: Instr, comps: dict) -> int:
    """Trip count: XLA's own ``backend_config known_trip_count`` when
    present, else the largest s32 constant compared in the condition
    (canonical loops compare the induction var against the bound)."""
    m = re.search(r'known_trip_count[^0-9]*"?(\d+)"?', while_ins.rest)
    if m:
        return int(m.group(1))
    cond_name = _called(while_ins.rest, "condition")
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    best = 1
    for ins in cond.instrs:
        if ins.opcode == "constant" and ins.shape_str.startswith("s32[]"):
            mm = re.search(r"constant\((-?\d+)\)", "constant(" + ins.rest)
            if mm:
                best = max(best, int(mm.group(1)))
    return best


def _dot_flops(ins: Instr, shapes: dict) -> float:
    numel, _, _ = _shape_info(ins.shape_str)
    k = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.rest)
    if m and ins.operands:
        lhs_shape = shapes.get(ins.operands[0], "")
        _, _, dims = _shape_info(lhs_shape)
        for d in m.group(1).split(","):
            if d and int(d) < len(dims):
                k *= dims[int(d)]
    return 2.0 * numel * k


_SKIP_BYTES = {
    "parameter", "constant", "get-tuple-element", "tuple", "bitcast",
    "bitcast-convert", "after-all", "iota", "partition-id", "replica-id",
}


@dataclasses.dataclass
class Cost:
    dot_flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collectives: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.dot_flops += other.dot_flops * mult
        self.bytes += other.bytes * mult
        self.collective_bytes += other.collective_bytes * mult
        for k, v in other.collectives.items():
            e = self.collectives.setdefault(k, {"bytes": 0.0, "count": 0.0})
            e["bytes"] += v["bytes"] * mult
            e["count"] += v["count"] * mult


_PASS_THROUGH = ("bitcast", "bitcast-convert", "reshape", "copy",
                 "transpose", "convert")
_SLICE_OPS = ("dynamic-slice", "slice", "gather")


def _base_shape(s: str) -> str:
    return re.sub(r"\{[^}]*\}", "", s)


def _effective_param_bytes(called: Computation) -> dict:
    """Per-parameter-index effective read bytes inside a fused computation.

    A parameter consumed ONLY through (dynamic-)slice chains (possibly via
    bitcast/reshape/convert pass-throughs, or as the in-place target of a
    dynamic-update-slice) streams just the sliced/updated region — this is
    how scan bodies touch their per-iteration layer slice of the stacked
    buffer; charging the full stack per iteration would overcount n_layers x.
    """
    idx_to_name = {}
    for i in called.instrs:
        if i.opcode == "parameter":
            m = re.search(r"parameter\((\d+)\)", "parameter(" + i.rest)
            if m:
                idx_to_name[int(m.group(1))] = i.name
    out = {}
    for idx, pname in idx_to_name.items():
        frontier = {pname}
        effective = 0.0
        sliced = True
        seen = set()
        while frontier and sliced:
            nxt = set()
            for ins in called.instrs:
                hits = frontier & set(ins.operands)
                if not hits or ins.name in seen:
                    continue
                seen.add(ins.name)
                if ins.opcode in _SLICE_OPS:
                    effective += _shape_info(ins.shape_str)[1]
                elif ins.opcode == "dynamic-update-slice" and \
                        ins.operands and ins.operands[0] in frontier:
                    # in-place update target: traffic = update region
                    if len(ins.operands) > 1:
                        effective += _shape_info(
                            called.shapes.get(ins.operands[1], "")
                        )[1]
                elif ins.opcode in _PASS_THROUGH:
                    nxt.add(ins.name)
                else:
                    sliced = False
                    break
            frontier = nxt
        if sliced:
            out[idx] = effective
    return out


def _comp_cost(comp: Computation, comps: dict, memo: dict,
               inside_fusion: bool = False) -> Cost:
    if comp.name in memo:
        return memo[comp.name]
    c = Cost()
    for ins in comp.instrs:
        op = ins.opcode
        if op == "while":
            body = _called(ins.rest, "body")
            trips = _trip_count(ins, comps)
            if body in comps:
                c.add(_comp_cost(comps[body], comps, memo), trips)
            continue
        if op in ("call", "conditional", "async-start"):
            for key in ("to_apply", "true_computation", "false_computation",
                        "branch_computations", "called_computation"):
                tgt = _called(ins.rest, key)
                if tgt in comps:
                    c.add(_comp_cost(comps[tgt], comps, memo))
            continue
        if op == "fusion":
            tgt = _called(ins.rest, "calls")
            _, rb, _ = _shape_info(ins.shape_str)
            if tgt in comps:
                called = comps[tgt]
                sub = _comp_cost(called, comps, memo, inside_fusion=True)
                c.dot_flops += sub.dot_flops
                eff = _effective_param_bytes(called)
                ob = 0.0
                for idx, o in enumerate(ins.operands):
                    full = _shape_info(comp.shapes.get(o, ""))[1]
                    ob += min(full, eff.get(idx, full))
                # root DUS updates its aliased operand in place: the write
                # is the update region, not the whole buffer
                if any(i.opcode == "dynamic-update-slice"
                       and _base_shape(i.shape_str)
                       == _base_shape(ins.shape_str)
                       for i in called.instrs):
                    rb = min(rb, ob)
            else:
                ob = sum(
                    _shape_info(comp.shapes.get(o, ""))[1]
                    for o in ins.operands
                )
            c.bytes += rb + ob
            continue
        if op == "dynamic-update-slice":
            # in-place: traffic = update region read+write (+indices)
            ub = (
                _shape_info(comp.shapes.get(ins.operands[1], ""))[1]
                if len(ins.operands) > 1 else 0
            )
            c.bytes += 2 * ub
            continue
        if op in ("dynamic-slice", "slice", "gather"):
            # reads only the sliced/gathered elements
            _, rb, _ = _shape_info(ins.shape_str)
            c.bytes += 2 * rb
            continue
        if op == "scatter":
            ub = (
                _shape_info(comp.shapes.get(ins.operands[2], ""))[1]
                if len(ins.operands) > 2 else 0
            )
            c.bytes += 2 * ub
            continue
        if op == "dot":
            c.dot_flops += _dot_flops(ins, comp.shapes)
        if op.startswith(_COLLECTIVE_OPS) or op in _COLLECTIVE_OPS or any(
            op == x or op == x + "-start" for x in _COLLECTIVE_OPS
        ):
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVE_OPS:
                _, b, _ = _shape_info(ins.shape_str)
                c.collective_bytes += b
                e = c.collectives.setdefault(
                    base, {"bytes": 0.0, "count": 0.0}
                )
                e["bytes"] += b
                e["count"] += 1
        if op.endswith("-done"):
            continue
        if op in _SKIP_BYTES or inside_fusion:
            continue
        _, rb, _ = _shape_info(ins.shape_str)
        ob = sum(
            _shape_info(comp.shapes.get(o, ""))[1] for o in ins.operands
        )
        c.bytes += rb + ob
    memo[comp.name] = c
    return c


def analyze(hlo_text: str, entry_hint: str = "main") -> Cost:
    comps = parse_hlo(hlo_text)
    # entry: the computation named like 'main...' else the largest
    entry = None
    for name in comps:
        if name.startswith(entry_hint):
            entry = name
            break
    if entry is None:
        entry = max(comps, key=lambda n: len(comps[n].instrs))
    return _comp_cost(comps[entry], comps, {})


def _trip_multipliers(comps: dict, entry: str) -> dict:
    """Computation name -> total times executed (loop trips multiplied)."""
    mult = {entry: 1.0}
    order = [entry]
    while order:
        cur = order.pop()
        for ins in comps[cur].instrs:
            if ins.opcode == "while":
                body = _called(ins.rest, "body")
                t = _trip_count(ins, comps)
                if body in comps:
                    mult[body] = mult.get(body, 0.0) + mult[cur] * t
                    order.append(body)
            elif ins.opcode in ("call", "conditional"):
                tgt = _called(ins.rest, "to_apply")
                if tgt in comps:
                    mult[tgt] = mult.get(tgt, 0.0) + mult[cur]
                    order.append(tgt)
    return mult


def top_collectives(hlo_text: str, k: int = 12, entry_hint: str = "main"
                    ) -> list:
    """[(total_bytes, op, name, shape, trips, metadata_op_name)] descending —
    the §Perf profiler: which collective, from which model op, costs most."""
    comps = parse_hlo(hlo_text)
    entry = next((n for n in comps if n.startswith(entry_hint)),
                 max(comps, key=lambda n: len(comps[n].instrs)))
    mult = _trip_multipliers(comps, entry)
    rows = []
    for cname, m in mult.items():
        for ins in comps[cname].instrs:
            base = ins.opcode[:-6] if ins.opcode.endswith("-start") \
                else ins.opcode
            if base not in _COLLECTIVE_OPS:
                continue
            _, b, _ = _shape_info(ins.shape_str)
            meta = re.search(r'op_name="([^"]*)"', ins.rest)
            rows.append((b * m, base, ins.name, ins.shape_str[:60], m,
                         meta.group(1)[-90:] if meta else ""))
    rows.sort(reverse=True)
    return rows[:k]
