import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# (the two lines above must stay first: jax locks device count on first init)
if os.environ.get("REPRO_EXTRA_XLA_FLAGS"):
    os.environ["XLA_FLAGS"] += " " + os.environ["REPRO_EXTRA_XLA_FLAGS"]

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST stay the first statements in this file — jax locks
the device count on first initialization, and the production meshes need
512 placeholder host devices.  Everything else (smoke tests, benches) runs
in separate processes that see 1 device.

Per cell this produces, with zero array allocation:
  * ``compiled.memory_analysis()``  — proof the cell fits per-device HBM,
  * ``compiled.cost_analysis()``    — FLOPs / bytes for the roofline terms,
  * a collective-bytes breakdown parsed from the optimized SPMD HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute operand sizes; cost_analysis does not report these).

Artifacts are JSON files under ``experiments/dryrun/`` consumed by
``launch/roofline.py`` and the ``benchmarks`` tables (ROADMAP.md tracks
the open sweep items).  Already-complete cells are
skipped (incremental reruns), and each cell can run in a fresh subprocess
(``--subprocess``) so one cell's compile-memory spike cannot kill the whole
sweep.
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro import sharding
from repro.configs import ARCHS, SHAPES, get, shapes_for
from repro.launch import hlo_cost
from repro.launch.mesh import make_production_mesh
from repro.models import build
from repro.models.model_zoo import batch_axes, input_specs
from repro.sharding import rules_for_shape, shardings_for_tree
from repro.train import AdamWConfig, make_train_step
from repro.train.state import train_state_shardings


# --- cell construction ----------------------------------------------------------

# Remat-carry budget per device (HBM is 16G).  Larger budget => fewer
# microbatches => fewer per-microbatch FSDP gathers and grad reductions
# (measured on qwen train: n_micro 16 -> 8 halves the collective term);
# smaller budget => deeper models fit.  6 GiB balances the two for this
# matrix — the knob and its measured tradeoff are §Perf material.
CARRY_BUDGET_BYTES = 6 * 2 ** 30


def analytic_bytes_per_device(arch: str, shape_name: str, n_chips: int,
                              weight_bytes: int = 2,
                              model_shards: int = 16) -> float:
    """Closed-form HBM traffic per device for one decode step of this cell.

    Per device: its local weight shard (weights are TP-sharded over
    ``model`` and *replicated* over data under the decode rules, so local
    weights = total/model_shards, read once per token) + its slice of the
    KV/state cache (sharded over all chips) + O(B x D) activations.  This
    is the quantity TPU serving is sized by, and it sidesteps the CPU
    backend's bf16->f32 scatter legalization that inflates the HLO-derived
    byte count on decode cells (see the methodology note in
    ``launch/roofline.py``).  Train/prefill cells use the HLO-derived
    count instead (dots
    dominate and parse faithfully there).
    """
    cfg = get(arch)
    shape = SHAPES[shape_name]
    model = build(cfg)
    if shape.kind != "decode":
        return 0.0
    import math
    ring = shape_name.startswith("long") and cfg.window is not None
    c_abs, _ = model.cache_spec(shape.global_batch, shape.seq_len, ring=ring)
    cache_bytes = sum(
        jnp.dtype(l.dtype).itemsize * math.prod(l.shape)
        for l in jax.tree.leaves(c_abs)
    )
    param_bytes = model.param_count() * weight_bytes / model_shards
    act_bytes = 64 * shape.global_batch * cfg.d_model * 2 / n_chips
    return float(param_bytes + cache_bytes / n_chips + act_bytes)


def default_n_micro(cfg, shape, mesh) -> int:
    """Microbatch count so the per-device remat carry stack fits the budget.

    The dominant training residual is the per-layer input saved by the
    layer scan: layers x (B/dp) x S x D x 2 bytes.  Microbatching divides
    the live batch; the grad accumulator it adds is param-sized (already
    FSDP-sharded).
    """
    if shape.kind != "train":
        return 1
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    b_loc = max(shape.global_batch // dp, 1)
    layers = cfg.n_layers + cfg.encoder_layers
    carry = layers * b_loc * shape.seq_len * cfg.d_model * 2
    n = 1
    while carry / n > CARRY_BUDGET_BYTES and n < b_loc:
        n *= 2
    return n


def build_cell(arch: str, shape_name: str, mesh, *, n_micro: int = 1,
               ce_chunks: int = 8, weight_quant: str = ""):
    """Returns (fn, in_shardings, abstract_args) for one workload cell."""
    cfg = get(arch)
    shape = SHAPES[shape_name]
    model = build(cfg)
    rules = rules_for_shape(shape_name)

    if shape.kind == "train":
        abs_state, state_sh = train_state_shardings(model, mesh, rules)
        inputs = input_specs(cfg, shape)
        in_axes = batch_axes(cfg, "train")
        input_sh = shardings_for_tree(in_axes, inputs, mesh, rules)
        step = make_train_step(model, AdamWConfig(), n_micro=n_micro)
        return step, (state_sh, input_sh), (abs_state, inputs), rules

    # Inference weights are served in bf16 (the deployment dtype): half the
    # weight HBM traffic of the f32 training master copy.
    def _serving_params(abs_tree):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(
                s.shape,
                cfg.cdtype if jnp.issubdtype(s.dtype, jnp.floating)
                else s.dtype,
            ),
            abs_tree,
        )

    if shape.kind == "prefill":
        p_abs = _serving_params(model.abstract_params())
        p_sh = shardings_for_tree(model.param_axes(), p_abs, mesh, rules)
        inputs = input_specs(cfg, shape)
        in_axes = batch_axes(cfg, "prefill")
        input_sh = shardings_for_tree(in_axes, inputs, mesh, rules)
        c_abs, c_axes = model.cache_spec(shape.global_batch, shape.seq_len)
        c_sh = shardings_for_tree(c_axes, c_abs, mesh, rules)

        def prefill_fn(params, batch, cache):
            return model.prefill(params, batch, cache)

        return prefill_fn, (p_sh, input_sh, c_sh), (p_abs, inputs, c_abs), \
            rules

    # decode: one new token against a seq_len-deep cache
    ring = shape_name.startswith("long") and cfg.window is not None
    inputs = input_specs(cfg, shape)
    in_axes = batch_axes(cfg, "decode")
    input_sh = shardings_for_tree(in_axes, inputs, mesh, rules)
    c_abs, c_axes = model.cache_spec(shape.global_batch, shape.seq_len,
                                     ring=ring)
    c_sh = shardings_for_tree(c_axes, c_abs, mesh, rules)

    if weight_quant == "int8":
        # §Perf iteration 3: weight-only int8 serving (paper §4.4) — the
        # dequant (convert+scale) fuses into the consuming GEMMs, so the
        # weight HBM/collective traffic is the int8 payload.
        def q_abs(s):
            if jnp.issubdtype(s.dtype, jnp.floating):
                return jax.ShapeDtypeStruct(s.shape, jnp.int8)
            return s

        def s_abs(s):
            if jnp.issubdtype(s.dtype, jnp.floating):
                scale_shape = s.shape[-1:] if len(s.shape) > 1 else ()
                return jax.ShapeDtypeStruct(scale_shape, jnp.float32)
            return jax.ShapeDtypeStruct((), jnp.float32)

        raw_abs = model.abstract_params()
        p_abs = {"q": jax.tree.map(q_abs, raw_abs),
                 "s": jax.tree.map(s_abs, raw_abs)}
        axes = model.param_axes()
        scale_axes = jax.tree.map(
            lambda a: a[-1:] if len(a) > 1 else (),
            axes, is_leaf=lambda t: isinstance(t, tuple),
        )
        p_sh = {
            "q": shardings_for_tree(axes, p_abs["q"], mesh, rules),
            "s": shardings_for_tree(scale_axes, p_abs["s"], mesh, rules),
        }

        def decode_fn(pq, batch, cache):
            def deq(q, s):
                if jnp.issubdtype(q.dtype, jnp.signedinteger) and \
                        jnp.issubdtype(s.dtype, jnp.floating):
                    return (q.astype(jnp.float32) * s).astype(cfg.cdtype)
                return q
            params = jax.tree.map(deq, pq["q"], pq["s"])
            return model.decode_step(params, batch["token"], cache,
                                     batch["pos"], ring=ring)

        return decode_fn, (p_sh, input_sh, c_sh), (p_abs, inputs, c_abs), \
            rules

    p_abs = _serving_params(model.abstract_params())
    p_sh = shardings_for_tree(model.param_axes(), p_abs, mesh, rules)

    def decode_fn(params, batch, cache):
        return model.decode_step(params, batch["token"], cache,
                                 batch["pos"], ring=ring)

    return decode_fn, (p_sh, input_sh, c_sh), (p_abs, inputs, c_abs), rules


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str = "experiments/dryrun",
             n_micro: Optional[int] = None, verbose: bool = True,
             variant: str = "", **build_kw) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell_id = f"{arch}__{shape_name}__{mesh_name}" + (
        f"__{variant}" if variant else ""
    )
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, cell_id + ".json")

    mesh = make_production_mesh(multi_pod=multi_pod)
    if n_micro is None:
        n_micro = default_n_micro(get(arch), SHAPES[shape_name], mesh)
    t0 = time.time()
    fn, in_sh, abs_args, rules = build_cell(
        arch, shape_name, mesh, n_micro=n_micro, **build_kw
    )
    with sharding.activate(mesh, rules):
        jitted = jax.jit(fn, in_shardings=in_sh)
        lowered = jitted.lower(*abs_args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    # trip-count-aware static analysis (cost_analysis counts loop bodies
    # once; see launch/hlo_cost.py)
    static = hlo_cost.analyze(hlo)

    n_chips = mesh.devices.size
    analytic = analytic_bytes_per_device(
        arch, shape_name, int(n_chips),
        weight_bytes=1 if "int8" in variant else 2,
    )
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "variant": variant,
        "n_chips": int(n_chips),
        "n_micro": int(n_micro),
        "flops_per_device": float(static.dot_flops),
        "bytes_per_device": float(static.bytes),
        "bytes_analytic_per_device": analytic,
        "collectives": {
            **static.collectives, "total_bytes": float(
                static.collective_bytes),
        },
        "xla_cost_analysis": {   # loop bodies counted once — cross-check only
            "flops": float(cost.get("flops", 0.0)),
            "bytes": float(cost.get("bytes accessed", 0.0)),
        },
        "memory": {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        },
        "timings": {"lower_s": t_lower, "compile_s": t_compile},
    }
    with open(out_path, "w") as f:
        json.dump(record, f, indent=1)
    if verbose:
        mb = record["memory"]
        print(
            f"[ok] {cell_id}: flops/dev={record['flops_per_device']:.3e} "
            f"bytes/dev={record['bytes_per_device']:.3e} "
            f"coll/dev={record['collectives']['total_bytes']:.3e}B "
            f"args={mb['argument_bytes']/2**30:.2f}GiB "
            f"temp={mb['temp_bytes']/2**30:.2f}GiB n_micro={n_micro} "
            f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)",
            flush=True,
        )
    return record


def cells(archs=None, shapes=None, meshes=("pod16x16", "pod2x16x16")):
    for arch in (archs or ARCHS):
        cfg = get(arch)
        for shape_name in (shapes or shapes_for(cfg)):
            if shapes is None and shape_name not in shapes_for(cfg):
                continue
            for mesh_name in meshes:
                yield arch, shape_name, mesh_name == "pod2x16x16"


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None, help="single arch id (default all)")
    ap.add_argument("--shape", default=None, help="single shape (default all)")
    ap.add_argument("--mesh", default=None,
                    choices=[None, "pod16x16", "pod2x16x16"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells that already have artifacts")
    ap.add_argument("--subprocess", action="store_true",
                    help="run each cell in a fresh python process")
    args = ap.parse_args(argv)

    archs = [args.arch] if args.arch else None
    shapes = [args.shape] if args.shape else None
    meshes = (args.mesh,) if args.mesh else ("pod16x16", "pod2x16x16")

    failures = []
    for arch, shape_name, multi_pod in cells(archs, shapes, meshes):
        mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
        cell_id = f"{arch}__{shape_name}__{mesh_name}"
        out_path = os.path.join(args.out, cell_id + ".json")
        if os.path.exists(out_path) and not args.force:
            print(f"[skip] {cell_id} (artifact exists)", flush=True)
            continue
        if args.subprocess:
            import subprocess
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape_name, "--mesh", mesh_name,
                "--out", args.out,
            ] + (["--force"] if args.force else [])
            r = subprocess.run(cmd)
            if r.returncode != 0:
                failures.append(cell_id)
            continue
        try:
            run_cell(arch, shape_name, multi_pod=multi_pod, out_dir=args.out)
        except Exception:
            traceback.print_exc()
            failures.append(cell_id)
    if failures:
        print(f"FAILED cells ({len(failures)}): {failures}", flush=True)
        sys.exit(1)
    print("dry-run complete", flush=True)


if __name__ == "__main__":
    main()
