"""Roofline analysis over dry-run artifacts (``launch/dryrun.py``;
methodology summarized in ROADMAP.md and the ``benchmarks`` output).

Per (arch x shape x mesh) cell, from the compiled SPMD program's own
counters (no wall clock exists on this host — TPU v5e is the target):

    compute_s    = HLO_FLOPs_per_device / peak_FLOP/s         (197e12 bf16)
    memory_s     = HLO_bytes_per_device / HBM_bw              (819e9 B/s)
    collective_s = collective_bytes_per_device / link_bw      (50e9 B/s)

cost_analysis() reports the per-device SPMD module, so all three terms are
per-device quantities over per-device rates; the bottleneck is the max term.
MODEL_FLOPS (6*N*D train / 2*N*D inference, N_active for MoE) over HLO
FLOPs measures how much compiled compute is useful — remat recompute,
one-hot dispatch, and padding all show up as ratio < 1.
"""

from __future__ import annotations

import glob
import json
import os
from typing import Any, Optional

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s
LINK_BW = 50e9             # bytes/s/link (ICI)


def stage_roofline(name: str, *, bytes: float, dot_flops: float,
                   wall_s: float) -> dict:
    """Achieved-vs-peak roofline cell for one measured pipeline stage.

    The LM cells above are *bound* rooflines (no wall clock on the dry-run
    host); the detection stack has measured walls, so its table reports the
    achieved side too: ``bytes``/``dot_flops`` come from the compiled HLO
    (``launch.hlo_cost.analyze``), ``wall_s`` from a warmed wall-clock
    measurement, and the cell gives achieved GB/s / GFLOP/s against the
    target chip's peaks.  The bottleneck label is the larger *time* term
    at peak rates (the classic roofline ridge test) — on the CPU host the
    achieved fractions are honest about being far from a TPU's peaks; the
    byte counts themselves are host-independent program facts.
    """
    memory_s = bytes / HBM_BW
    compute_s = dot_flops / PEAK_FLOPS
    return {
        "stage": name,
        "bytes": bytes,
        "dot_flops": dot_flops,
        "wall_s": wall_s,
        "achieved_gbps": bytes / wall_s / 1e9 if wall_s else 0.0,
        "achieved_gflops": dot_flops / wall_s / 1e9 if wall_s else 0.0,
        "frac_hbm_peak": bytes / wall_s / HBM_BW if wall_s else 0.0,
        "frac_flops_peak": dot_flops / wall_s / PEAK_FLOPS
        if wall_s else 0.0,
        "bottleneck": "memory" if memory_s >= compute_s else "compute",
    }


def model_flops_per_device(record: dict) -> float:
    """Useful-model FLOPs per device for this cell."""
    from repro.configs import SHAPES, get
    from repro.models import build

    cfg = get(record["arch"])
    model = build(cfg)
    n_active = model.active_param_count()
    shape = SHAPES[record["shape"]]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        total = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        total = 2.0 * n_active * tokens
    else:  # decode: one token per request
        total = 2.0 * n_active * shape.global_batch
    return total / record["n_chips"]


def roofline_terms(record: dict) -> dict:
    compute_s = record["flops_per_device"] / PEAK_FLOPS
    # decode cells use the analytic byte count (params+cache read once) —
    # the CPU backend's bf16 scatter legalization inflates the HLO-derived
    # number there; train/prefill use the HLO-derived count (dot-dominated,
    # parses faithfully).  Methodology note in the docstring above and
    # in launch/dryrun.py.
    mem_bytes = record.get("bytes_analytic_per_device") or 0.0
    if not mem_bytes:
        mem_bytes = record["bytes_per_device"]
    memory_s = mem_bytes / HBM_BW
    coll_s = record["collectives"]["total_bytes"] / LINK_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": coll_s}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_device(record)
    return {
        **terms,
        "bottleneck": bottleneck.replace("_s", ""),
        "model_flops_per_device": mf,
        "useful_flops_ratio": (
            mf / record["flops_per_device"]
            if record["flops_per_device"] else float("nan")
        ),
        "step_time_lower_bound_s": max(terms.values()),
        # MFU against the bound: useful flops / (chips-seconds at peak)
        "mfu_bound": (
            mf / PEAK_FLOPS / max(max(terms.values()), 1e-30)
        ),
    }


def load_records(art_dir: str = "experiments/dryrun") -> list[dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(art_dir, "*.json"))):
        with open(path) as f:
            out.append(json.load(f))
    return out


def table(records: list[dict], mesh: Optional[str] = "pod16x16") -> str:
    rows = []
    header = (
        "| arch | shape | compute_s | memory_s | collective_s | bottleneck "
        "| MODEL/HLO flops | step bound (s) | MFU bound |"
    )
    sep = "|" + "---|" * 9
    for r in records:
        if mesh and r["mesh"] != mesh:
            continue
        t = roofline_terms(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.3e} "
            f"| {t['memory_s']:.3e} | {t['collective_s']:.3e} "
            f"| **{t['bottleneck']}** | {t['useful_flops_ratio']:.2f} "
            f"| {t['step_time_lower_bound_s']:.3e} | {t['mfu_bound']:.1%} |"
        )
    return "\n".join([header, sep] + rows)


def main(argv=None):
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="pod16x16")
    args = ap.parse_args(argv)
    recs = load_records(args.dir)
    if not recs:
        print("no artifacts found; run repro.launch.dryrun first")
        return
    print(table(recs, args.mesh))


if __name__ == "__main__":
    main()
