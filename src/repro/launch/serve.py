"""Serving driver: continuous-batching engine over synthetic request traffic.

    PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --requests 16
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke
from repro.models import build
from repro.serve import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch)
    model = build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, n_slots=args.slots, max_len=args.max_len)

    rng = np.random.default_rng(0)
    reqs = []
    for uid in range(args.requests):
        n = int(rng.integers(3, 12))
        reqs.append(Request(
            uid=uid, prompt=list(rng.integers(1, cfg.vocab, n)),
            max_new_tokens=args.max_new, temperature=args.temperature,
        ))
    t0 = time.time()
    for r in reqs:
        eng.submit(r)
    eng.run()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in reqs)
    print(f"arch={args.arch} slots={args.slots} requests={args.requests}")
    print(f"generated {toks} tokens in {dt:.2f}s "
          f"({toks/dt:,.1f} tok/s, {eng.steps} engine steps, "
          f"{toks/max(eng.steps,1):.2f} tokens/step batching efficiency)")
    assert all(r.done for r in reqs)


if __name__ == "__main__":
    main()
