"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run must set its host-platform flags
before anything initializes jax).

Mesh shapes (TPU v5e):
  single-pod: (16, 16)      axes ("data", "model")   = 256 chips
  multi-pod:  (2, 16, 16)   axes ("pod", "data", "model") = 512 chips

``data`` is the FSDP/DP axis (fast intra-pod ICI), ``model`` the TP/EP
axis, ``pod`` the slow cross-pod axis carrying only batch DP + the per-step
gradient reduction (optionally int8-compressed, train/compression.py).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_replica_mesh(n: int | None = None):
    """1-D ``("replica",)`` mesh over (up to) ``n`` host devices.

    The detection fleet's mesh: each replica of the sharded
    :class:`~repro.serve.fleet.ShardedDetectionService` pins its plans
    and dispatches to one device along this axis.  Testable on a CPU
    host via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
    (set before jax initializes — the device count is frozen at first
    use, which is why the mesh tests run it in subprocesses).
    """
    devs = jax.devices()
    n = min(n or len(devs), len(devs))
    return jax.make_mesh((n,), ("replica",))


def replica_devices(n: int) -> list:
    """``n`` device handles for ``n`` service replicas, cycling over the
    host's real devices when there are fewer — on a 1-device host every
    replica shares device 0 (the policy layer still shards queues,
    trackers, and plan caches; only the physical placement collapses)."""
    devs = jax.devices()
    return [devs[i % len(devs)] for i in range(n)]


def make_host_mesh(*, multi_pod: bool = False, n: int | None = None):
    """Small mesh over however many (host) devices exist — tests/examples.

    Single-pod: (d, m); multi-pod: (2, d, m) when >= 8 devices.
    """
    n = n or len(jax.devices())
    if multi_pod:
        assert n >= 8 and n % 2 == 0, n
        rest = n // 2
        d = max(s for s in range(1, rest + 1) if rest % s == 0 and s <= rest)
        # squarest (d, m) factorization of rest
        d = max(
            s for s in range(1, int(rest ** 0.5) + 1) if rest % s == 0
        )
        return jax.make_mesh((2, rest // d, d), ("pod", "data", "model"))
    d = max(s for s in range(1, int(n ** 0.5) + 1) if n % s == 0)
    return jax.make_mesh((n // d, d), ("data", "model"))
