"""Deadline-aware continuous-batching detection service.

The LM engine (``serve/engine.py``) serves token traffic with a fixed slot
grid; this module applies the same slot/bucket design to the line-detection
stack — and, because the paper's deployment is an AV control loop where a
*late* detection is a *useless* detection, layers an explicit QoS policy on
top of the PR-3 throughput machinery:

  * **Resolution buckets** — requests carry frames of heterogeneous
    resolutions; each frame pads (tapered edge replication, top-left
    anchored) to the smallest registered bucket that holds it, and results
    crop back bit-exact (``pad_to_bucket`` / ``crop_result``).
  * **Fixed batch slots** — every bucket owns a grid of ``batch_size``
    slots; a dispatch always runs the full grid (empty slots carry zero
    frames the frame-independent kernels ignore), so each bucket compiles
    exactly one program per render binding.
  * **Backpressure** — the admission queue is bounded (``max_queue``):
    submits beyond the bound are *rejected* with
    ``RequestStatus.QUEUE_FULL`` instead of silently stretching the tail,
    and queued requests that are expired — or *hopeless*, their remaining
    budget below a queue-depth-aware completion horizon (everything ahead
    of them in EDF order dispatches first, ``batch_size`` per wave) —
    are *shed* with ``RequestStatus.DEADLINE_EXCEEDED`` before they waste
    a slot.
    Every request terminates with an explicit status; nothing blows up
    latency silently, and doomed work never dominoes feasible work.
  * **QoS scheduling** — requests may carry a ``deadline_s`` budget and a
    ``priority`` tiebreak.  Admission within a bucket is earliest-deadline-
    first; dispatch picks the occupied grid with the tightest deadline and
    *closes a batch early* (dispatches a partial grid) when waiting for
    more traffic would bust that deadline, given a per-bucket service-time
    estimate (EMA of measured dispatch times).  With no deadlines anywhere
    admitted the scheduler falls back to PR-3's full-grid-first round-robin
    throughput mode — same traffic, bit-identical results.
  * **Prefetch staging** — host-side staging (grayscale decode + taper
    pad) runs ahead on a ``PrefetchStager`` worker thread: frame N+1
    stages while the device computes batch N.  The worker touches only
    numpy; the single explicit ``jax.device_put`` per dispatch stays on
    the scheduler thread, so the post-warmup hot loop still runs under
    ``jax.transfer_guard("disallow")``.
  * **Session-stateful streaming** — requests sharing a ``session_id``
    are frames of one camera stream: the service keeps a per-session
    :class:`~repro.core.tracking.LaneTracker`, advances it as each
    frame's result completes (slot order == admission order and one batch
    is in flight per grid, so a session's frames arrive at its tracker in
    stream order), and attaches the smoothed reported tracks to the
    request — temporal continuity across the batching machinery, per
    stream, without giving up cross-stream batching.
  * **Per-request rendering** — ``DetectionRequest(render_output=True)``
    returns the paper's phase-3 overlay for that request only, cropped
    back to the native resolution bit-exact; the grid flips to the plan's
    render binding (``DetectionPlan.with_render``) only when someone in
    the batch asked.
  * **Injectable clock** — every timestamp and every deadline/backpressure
    decision reads ``self.clock()`` (default ``time.perf_counter``).
    Passing a :class:`VirtualClock` makes the whole policy deterministic:
    ``tests/test_service_deadlines.py`` and the deadline regime of
    ``benchmarks/service_suite.py`` drive traffic on virtual time, so no
    assertion ever races the noisy 2-core bench host.

Plans come from ``core/plan.py``: one frozen ``DetectionPlan`` per bucket
(plus its render-bound twin on demand).  ``benchmarks/service_suite.py``
measures throughput/latency and the deadline-regime miss rates and writes
``BENCH_service.json``.
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import math
import time
from collections import deque
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, Iterable, Optional, Sequence

import jax
import numpy as np

from repro.core.plan import (
    DetectionPlan, DetectionResult, PipelineConfig, load_frame,
)
from repro.core.tracking import LaneTracker, Track, TrackerConfig

# Default resolution ladder: QQVGA-ish up to the paper's camera frame.
DEFAULT_BUCKETS: tuple[tuple[int, int], ...] = (
    (120, 160), (240, 320), (480, 640),
)


class RequestStatus(enum.Enum):
    """Terminal disposition of a request (plus the initial PENDING)."""
    PENDING = "pending"
    DONE = "done"                          # result delivered
    QUEUE_FULL = "queue_full"              # rejected at submit (backpressure)
    DEADLINE_EXCEEDED = "deadline_exceeded"  # shed before dispatch


class VirtualClock:
    """Deterministic monotonic clock: advances only when told to.

    Inject as ``DetectionService(..., clock=VirtualClock())`` to make every
    deadline/backpressure/early-close decision — and every latency stamp —
    a pure function of the driven schedule.  The unit for ``advance`` is
    seconds, same as ``time.perf_counter``.
    """

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        assert dt >= 0.0, dt
        self.t += float(dt)
        return self.t


class PrefetchStager:
    """Single worker thread staging host-side work ahead of the device
    (a one-worker ``ThreadPoolExecutor`` under a staging-shaped API).

    ``stage(fn, *args)`` enqueues ``fn(*args)`` and returns a
    ``concurrent.futures.Future``; the service resolves it at admission
    time, by which point the worker has usually finished — frame N+1 pads
    while the device computes batch N.  The worker runs numpy only
    (grayscale decode + taper pad); ``jax.device_put`` stays on the
    scheduler thread so ``transfer_guard("disallow")`` still polices the
    hot loop.  Staging is deterministic, so the threaded stream is
    bit-for-bit the synchronous one (property-tested).
    """

    def __init__(self):
        self._pool = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="detection-prefetch"
        )

    def stage(self, fn, *args) -> Future:
        return self._pool.submit(fn, *args)

    def close(self) -> None:
        self._pool.shutdown(wait=True)


@dataclasses.dataclass
class DetectionRequest:
    """One frame in, one ``DetectionResult`` (or explicit refusal) out."""
    uid: int
    frame: np.ndarray                       # (H, W) or (H, W, 3)
    deadline_s: Optional[float] = None      # latency budget from submit
    priority: int = 0                       # deadline tiebreak: lower first
    render_output: bool = False             # per-request phase-3 overlay
    # Session-stateful streaming: requests sharing a ``session_id`` are
    # frames of one camera stream.  The service keeps a LaneTracker per
    # session, advances it as each frame's result lands, and attaches the
    # smoothed reported tracks to the request (``tracks``).  Frames of a
    # session must be submitted in stream order and share one resolution
    # bucket — within a bucket, completion follows dispatch order (one
    # batch in flight per grid), so the tracker sees the stream in order.
    session_id: Optional[str] = None
    # filled by the service
    result: Optional[DetectionResult] = None
    tracks: Optional[list[Track]] = None    # smoothed tracks (sessions only)
    status: RequestStatus = RequestStatus.PENDING
    bucket: Optional[tuple[int, int]] = None
    done: bool = False                      # terminal (any status)
    submitted_at: float = 0.0
    finished_at: float = 0.0
    deadline_at: Optional[float] = None     # absolute, on the service clock
    _staged: Optional[Future] = dataclasses.field(
        default=None, repr=False, compare=False
    )

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at

    @property
    def ok(self) -> bool:
        return self.status is RequestStatus.DONE

    @property
    def missed_deadline(self) -> bool:
        """Shed, rejected, or completed after its deadline."""
        if self.deadline_at is None:
            return False
        if self.status in (RequestStatus.QUEUE_FULL,
                           RequestStatus.DEADLINE_EXCEEDED):
            return True
        return self.done and self.finished_at > self.deadline_at


class _BucketGrid:
    """Slot grid + staging state for one resolution bucket."""

    def __init__(self, shape: tuple[int, int], batch_size: int,
                 plan: DetectionPlan, est_s: float):
        self.shape = shape
        self.plan = plan
        self.est_s = est_s      # EMA service-time estimate for one dispatch
        self.est_measured = False   # True once a real dispatch fed the EMA
        self.slots: list[Optional[DetectionRequest]] = [None] * batch_size
        self.staged = np.zeros((batch_size, *shape), np.float32)
        # (requests snapshot, async result, dispatch time, warm?) awaiting
        # completion; warm=False marks a compiling dispatch whose wall time
        # must not feed the service-time EMA
        self.in_flight: Optional[
            tuple[list[Optional[DetectionRequest]], DetectionResult,
                  float, bool]
        ] = None

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def tightest_deadline(self) -> float:
        """Earliest deadline among slotted requests (inf if none)."""
        ds = [r.deadline_at for r in self.slots
              if r is not None and r.deadline_at is not None]
        return min(ds) if ds else math.inf


# Pad decay horizon (pixels): the diffused pad reaches the flat fill level
# by this depth regardless of pad size.
_PAD_TAPER = 32


def _diffuse_pad(border: np.ndarray, n: int, fill: np.float32
                 ) -> np.ndarray:
    """Continue a border line outward for ``n`` steps, diffusing as it
    fades: each step blurs the previous line ([1, 2, 1]/4) and decays it
    toward ``fill``.  The blur spreads any stroke crossing the border so
    its transverse contrast collapses within a few steps (no extruded bar
    for Hough to vote up), while the decay's along-step slope stays under
    the Canny low threshold (no edge along the taper itself).

    ``border``: (W,) the outermost content line.  Returns (n, W).
    """
    rows = np.empty((n, border.shape[0]), np.float32)
    prev = border.astype(np.float32)
    for i in range(n):
        blurred = prev.copy()
        blurred[1:-1] = (
            0.25 * prev[:-2] + 0.5 * prev[1:-1] + 0.25 * prev[2:]
        )
        k = max(0.0, 1.0 - (i + 1.0) / _PAD_TAPER)
        prev = fill + (blurred - fill) * np.float32(k)
        rows[i] = prev
    return rows


def pad_to_bucket(frame: np.ndarray, shape: tuple[int, int]) -> np.ndarray:
    """Grayscale-load ``frame`` and pad it (top-left anchored) to the
    bucket shape with a *diffusing* edge continuation: the boundary
    row/column carries on (no synthetic step at the content border) while
    blurring and fading to the frame mean.  Plain replication would
    extrude every stroke touching the border into a long axis-aligned
    bright bar — strong enough to vote up spurious near-vertical/
    horizontal lines and to inflate the peak the relative threshold
    normalizes by.  Diffusion kills the bar's transverse contrast within
    a few pixels and the fade slope stays below the Canny thresholds, so
    the pad region contributes (nearly) no edges at any pad size
    (regression-tested in ``tests/test_detection_service.py``)."""
    img = load_frame(frame)
    H, W = img.shape
    bh, bw = shape
    assert H <= bh and W <= bw, (img.shape, shape)
    if (H, W) == (bh, bw):
        return img
    fill = np.float32(img.mean())
    out = np.empty((bh, bw), np.float32)
    out[:H, :W] = img
    if bh > H:
        out[H:, :W] = _diffuse_pad(img[H - 1, :], bh - H, fill)
    if bw > W:
        # columns diffuse from the full left part (content + row pad), so
        # the corner continues both tapers consistently
        out[:, W:] = _diffuse_pad(out[:, W - 1], bw - W, fill).T
    return out


def crop_result(res: DetectionResult, height: int, width: int
                ) -> DetectionResult:
    """Un-pad one frame's result: (rho, theta) peaks are already in
    original coordinates (top-left anchoring) and ``lines`` endpoints
    parameterize the same infinite lines (out-of-frame endpoints are
    normal — the unbatched detector produces them too); raster fields
    (edges, the rendered overlay) crop to (H, W)."""
    return DetectionResult(
        res.lines, res.valid, res.peaks,
        res.edges[..., :height, :width],
        None if res.rendered is None
        else res.rendered[..., :height, :width, :],
    )


class DetectionService:
    """Request-level line detection with backpressure + QoS over fixed
    per-bucket batch slots.

    ``submit`` enqueues (or rejects) requests; ``step`` sheds expired work,
    admits earliest-deadline-first, dispatches one bucket grid — closing a
    batch early when the tightest admitted deadline can't wait — and
    completes the previously dispatched one (double-buffering); ``run``
    drains everything.  ``detect_many`` is the convenience loop the
    benchmarks use.

    QoS knobs:
      * ``max_queue`` — bound on the admission queue (None = unbounded);
        submits beyond it return ``RequestStatus.QUEUE_FULL``.
      * ``est_dispatch_s`` / ``est_smoothing`` — initial per-bucket
        service-time estimate and its EMA factor; the early-close rule
        dispatches a partial grid when ``deadline - now <= est``.
      * ``clock`` — injectable monotonic clock (see :class:`VirtualClock`).
      * ``prefetch`` — stage frames on a :class:`PrefetchStager` worker
        thread (True, default) or synchronously at admission (False);
        results are bit-identical either way.
    """

    def __init__(self, cfg: PipelineConfig = PipelineConfig(), *,
                 buckets: Sequence[tuple[int, int]] = DEFAULT_BUCKETS,
                 batch_size: int = 4,
                 max_queue: Optional[int] = None,
                 est_dispatch_s: float = 0.05,
                 est_smoothing: float = 0.3,
                 clock: Callable[[], float] = time.perf_counter,
                 prefetch: bool = True,
                 tracker: TrackerConfig = TrackerConfig()):
        self.cfg = cfg
        self.batch_size = batch_size
        self.tracker_cfg = tracker
        self.sessions: dict[str, LaneTracker] = {}
        self.buckets = tuple(sorted(buckets))
        self.max_queue = max_queue
        self.est_smoothing = est_smoothing
        self.clock = clock
        self.prefetch = prefetch
        self.grids = {
            shape: _BucketGrid(
                shape, batch_size,
                DetectionPlan.build(cfg, *shape, batch=batch_size),
                est_dispatch_s,
            )
            for shape in self.buckets
        }
        # EDF admission queues: heap of (deadline, priority, seq, request)
        self.queues: dict[
            tuple[int, int],
            list[tuple[float, int, int, DetectionRequest]],
        ] = {shape: [] for shape in self.buckets}
        self._seq = 0
        self._rr = 0            # round-robin cursor (throughput mode)
        self._warmed: set[tuple[tuple[int, int], bool]] = set()
        self._loader: Optional[PrefetchStager] = None
        self.dispatches = 0
        self.completed = 0
        self.rejected_queue_full = 0
        self.shed_deadline = 0
        self.completed_late = 0
        # (shape, active slots, render) per dispatch — introspection for
        # tests/benchmarks; bounded so a long-running service cannot
        # accrete it without limit
        self.dispatch_log: deque[tuple[tuple[int, int], int, bool]] = (
            deque(maxlen=4096)
        )

    # --- lifecycle ------------------------------------------------------
    def close(self) -> None:
        """Stop the prefetch worker (idempotent)."""
        if self._loader is not None:
            self._loader.close()
            self._loader = None

    # --- sessions -------------------------------------------------------
    def session_tracks(self, session_id: str) -> list[Track]:
        """Current live tracks of a streaming session ([] if unknown)."""
        tracker = self.sessions.get(session_id)
        return tracker.tracks if tracker is not None else []

    def end_session(self, session_id: str) -> None:
        """Drop a session's tracker state (idempotent)."""
        self.sessions.pop(session_id, None)

    def __enter__(self) -> "DetectionService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --- bucketing -----------------------------------------------------
    def bucket_for(self, frame: np.ndarray) -> tuple[int, int]:
        """Smallest registered bucket that holds ``frame``."""
        H, W = frame.shape[:2]
        for bh, bw in self.buckets:
            if H <= bh and W <= bw:
                return (bh, bw)
        raise ValueError(
            f"frame {frame.shape} exceeds every bucket {self.buckets}"
        )

    @property
    def queued(self) -> int:
        return sum(len(q) for q in self.queues.values())

    # --- request lifecycle ---------------------------------------------
    def submit(self, req: DetectionRequest) -> RequestStatus:
        """Enqueue ``req`` — or reject it with ``QUEUE_FULL`` when the
        bounded admission queue is at capacity (backpressure: the caller
        learns *now*, instead of every queued request learning late)."""
        req.bucket = self.bucket_for(req.frame)
        now = self.clock()
        req.submitted_at = now
        if req.deadline_s is not None:
            req.deadline_at = now + req.deadline_s
        if self.max_queue is not None and self.queued >= self.max_queue:
            req.status = RequestStatus.QUEUE_FULL
            req.done = True
            req.finished_at = now
            self.rejected_queue_full += 1
            return req.status
        # Prefetch pays only when staging does real work (luma conversion
        # or taper padding).  A grayscale frame already at bucket shape is
        # a pass-through: shipping it to the worker would add one thread
        # round-trip of pure overhead per request — measurable on a 2-core
        # host where the worker steals cycles from device compute.
        needs_staging = (
            req.frame.ndim == 3 or req.frame.shape[:2] != req.bucket
            or req.frame.dtype != np.float32
        )
        if self.prefetch and needs_staging:
            if self._loader is None:
                self._loader = PrefetchStager()
            req._staged = self._loader.stage(
                pad_to_bucket, req.frame, req.bucket
            )
        self._seq += 1
        key = req.deadline_at if req.deadline_at is not None else math.inf
        heapq.heappush(
            self.queues[req.bucket], (key, req.priority, self._seq, req)
        )
        return RequestStatus.PENDING

    def _shed_expired(self) -> None:
        """Shed queued requests that are expired — or *hopeless*: a queued
        request that cannot finish in time even if everything goes well,
        because running it anyway is the EDF overload pathology (doomed
        work dominoes feasible work into lateness).  Either way the
        explicit ``DEADLINE_EXCEEDED`` is the honest answer the admission
        contract promises — instead of a result that arrives too late to
        steer with.

        Feasibility is *queue-depth-aware*: a request at EDF position k in
        its bucket queues behind ``active`` slotted requests and the k
        tighter-deadline entries kept ahead of it, all of which dispatch
        first, ``batch_size`` per wave — so its completion horizon is
        ``now + waves * est_s`` with ``waves = ahead // batch_size + 1``,
        not the single-dispatch optimism of one ``est_s``.  A deep queue
        therefore sheds a mid-pack budget that a shallow queue would keep
        (covered in ``tests/test_service_deadlines.py``); for the shallow
        case (``ahead < batch_size``) the horizon reduces to exactly the
        old one-dispatch rule.  Shed entries do not count toward ``ahead``
        — shedding frees their wave for the survivors.

        The hopeless test only engages once the grid's estimate is
        *measured* (a real dispatch fed the EMA): shedding against an
        unvalidated prior could latch into refusing an entirely feasible
        workload forever, since the estimate only corrects on completions.
        No-deadline entries sort last in EDF order (``inf`` keys), so they
        never inflate a deadlined request's horizon and are themselves
        never shed.
        """
        now = self.clock()
        for shape, q in self.queues.items():
            grid = self.grids[shape]
            est = grid.est_s if grid.est_measured else 0.0
            if not q:
                continue
            worst_waves = (grid.active + len(q) - 1) // len(grid.slots) + 1
            if q[0][0] > now + worst_waves * est:  # heap min: tightest
                continue
            keep = []
            ahead = grid.active          # slotted work dispatches first
            for entry in sorted(q):      # EDF pop order: (key, prio, seq)
                key, _, _, req = entry
                waves = ahead // len(grid.slots) + 1
                if key <= now or (est > 0.0 and key < now + waves * est):
                    req.status = RequestStatus.DEADLINE_EXCEEDED
                    req.done = True
                    req.finished_at = now
                    req._staged = None
                    self.shed_deadline += 1
                else:
                    keep.append(entry)
                    ahead += 1
            q[:] = keep
            heapq.heapify(q)

    def _admit(self) -> None:
        """Fill free slots earliest-deadline-first within each bucket
        (no-deadline requests order FIFO after all deadlined ones; equal
        deadlines tiebreak on ``priority`` then arrival).  Staged frames
        come from the prefetch worker when enabled — admission only copies
        the finished pad into the slot buffer."""
        for shape in self.buckets:
            grid = self.grids[shape]
            q = self.queues[shape]
            while q:
                slot = grid.free_slot()
                if slot is None:
                    break
                _, _, _, req = heapq.heappop(q)
                # resolve staging BEFORE taking the slot: if the prefetch
                # worker raised, the exception surfaces here with the
                # request un-slotted (still PENDING) — never a DONE result
                # silently computed from the slot's zeroed frame
                if req._staged is not None:
                    staged = req._staged.result()
                    req._staged = None
                else:
                    staged = pad_to_bucket(req.frame, grid.shape)
                grid.slots[slot] = req
                grid.staged[slot] = staged

    def _reap(self) -> None:
        """Retire any in-flight batch whose result is already ready.

        Keeps ``latency_s`` honest (a result is delivered as soon as the
        device finishes, not when its grid next refills) without ever
        blocking — ``is_ready`` is a non-blocking poll.
        """
        for g in self.grids.values():
            if g.in_flight is None:
                continue
            lines = g.in_flight[1].lines
            if getattr(lines, "is_ready", lambda: False)():
                # the device finished some unknown time ago (we only just
                # polled), so dispatch->now includes idle gap, not service
                # time — deliver the results but keep it out of the EMA
                self._complete(g, update_est=False)

    def drain(self) -> None:
        """Block until every in-flight batch has completed and resolved
        back onto its requests (deterministic completion stamping for
        virtual-clock drivers — no ``is_ready`` poll races).

        Like ``_reap``, drain's timing samples are idle-contaminated upper
        bounds, so they can lower the service-time estimate but never
        raise it: one long idle gap must not push the estimate past every
        offered deadline (hopeless-shed livelock).  Only back-to-back
        dispatches — the previous batch still in flight when the next one
        landed — can raise it."""
        for g in self.grids.values():
            self._complete(g, update_est=False)

    def _complete(self, grid: _BucketGrid, *, update_est: bool = True
                  ) -> None:
        """Resolve the grid's in-flight batch back onto its requests.

        The dispatch->completion sample ``dt`` feeds the grid's EMA
        service-time estimate (which drives early close + hopeless shed)
        under an asymmetric rule.  ``update_est=True`` — the dispatch-
        completes-previous path in ``step``, where the previous batch was
        still occupying the device — may move the estimate either way.
        ``update_est=False`` — ``_reap`` and ``drain``, whose samples
        include however long the batch sat finished before anyone asked —
        may only ratchet it *down or hold it* (an idle-contaminated sample
        is an upper bound on the true service time, so a sample at or
        below the estimate is still evidence, while a sample above it must
        never inflate the estimate into shedding feasible work).
        Compiling (cold) dispatches are excluded entirely: one XLA compile
        is seconds on this stack, and a seconds-scale estimate would shed
        every sub-second budget."""
        if grid.in_flight is None:
            return
        reqs, res, t_disp, was_warm = grid.in_flight
        grid.in_flight = None
        jax.block_until_ready(res.lines)
        now = self.clock()
        dt = now - t_disp
        if was_warm and dt > 0.0 and (update_est or dt <= grid.est_s):
            a = self.est_smoothing
            grid.est_s = (1.0 - a) * grid.est_s + a * dt
            grid.est_measured = True
        for i, req in enumerate(reqs):
            if req is None:
                continue
            assert not req.done, f"request {req.uid} answered twice"
            H, W = req.frame.shape[:2]
            want = req.render_output or self.cfg.render_output
            rendered = (
                res.rendered[i]
                if want and res.rendered is not None else None
            )
            req.result = crop_result(
                DetectionResult(
                    res.lines[i], res.valid[i], res.peaks[i], res.edges[i],
                    rendered,
                ),
                H, W,
            )
            if req.session_id is not None:
                tracker = self.sessions.get(req.session_id)
                if tracker is None:
                    tracker = LaneTracker(self.tracker_cfg)
                    self.sessions[req.session_id] = tracker
                # slot order == admission order, and one batch is in
                # flight per grid, so a session's frames advance its
                # tracker in stream order (see DetectionRequest docstring)
                req.tracks = tracker.step(
                    np.asarray(req.result.peaks),
                    np.asarray(req.result.valid),
                )
            req.status = RequestStatus.DONE
            req.done = True
            req.finished_at = now
            if req.deadline_at is not None and now > req.deadline_at:
                self.completed_late += 1
            self.completed += 1

    # --- scheduling -----------------------------------------------------
    def _deadline_mode(self) -> bool:
        """QoS scheduling engages iff any *admitted* request carries a
        deadline; otherwise the service is exactly the PR-3 throughput
        scheduler (full-grid-first round-robin)."""
        return any(
            r is not None and r.deadline_at is not None
            for g in self.grids.values() for r in g.slots
        )

    def _next_grid_throughput(self, flush: bool) -> Optional[_BucketGrid]:
        """Round-robin over buckets: FULL grids first (a dispatch always
        computes ``batch_size`` frames, so partial grids waste slots), then
        — only when flushing — any occupied grid."""
        n = len(self.buckets)
        for want_full in (True, False) if flush else (True,):
            for k in range(n):
                shape = self.buckets[(self._rr + k) % n]
                grid = self.grids[shape]
                if grid.active == len(grid.slots) or (
                    not want_full and grid.active
                ):
                    self._rr = (self._rr + k + 1) % n
                    return grid
        return None

    def _next_grid_deadline(self, flush: bool, now: float
                            ) -> Optional[_BucketGrid]:
        """Earliest-deadline-first over occupied grids.

        A grid dispatches when it is full, when it must close early
        (``tightest deadline - now <= est_s``: one more wait would bust
        it), or when flushing.  A less urgent grid may only jump ahead of
        the tightest waiting one if its own dispatch fits inside that
        grid's slack — EDF with admission control, not strict EDF, so
        throughput traffic still flows around a slack deadline."""
        order = sorted(
            (g for g in self.grids.values() if g.active),
            key=lambda g: (g.tightest_deadline(),
                           self.buckets.index(g.shape)),
        )
        guard: Optional[tuple[float, float]] = None  # (deadline, est) held
        for g in order:
            d = g.tightest_deadline()
            full = g.active == len(g.slots)
            urgent = math.isfinite(d) and (d - now) <= g.est_s
            if full or urgent or flush:
                if guard is not None:
                    gd, gest = guard
                    if gd - now - g.est_s < gest:
                        continue   # would bust the tighter waiting grid
                return g
            if guard is None and math.isfinite(d):
                guard = (d, g.est_s)
        return None

    def step(self, *, flush: bool = False) -> bool:
        """Shed -> admit (EDF) -> dispatch one bucket grid -> free its
        slots for the next admission wave; completion of the *previous*
        dispatch on that grid happens just before the new one lands (one
        batch in flight per bucket).  Without deadlines only full grids
        dispatch unless ``flush``; with deadlines the tightest grid may
        close early.  Returns True if any work remains."""
        self._reap()
        self._shed_expired()
        self._admit()
        if self._deadline_mode():
            grid = self._next_grid_deadline(flush, self.clock())
        else:
            grid = self._next_grid_throughput(flush)
        if grid is None:
            # nothing dispatchable: drain whatever is still in flight
            self.drain()
            return bool(self.queued) or any(
                g.active for g in self.grids.values()
            )
        want_render = any(
            r is not None and r.render_output for r in grid.slots
        )
        plan = grid.plan.with_render(True) if want_render else grid.plan
        reqs = list(grid.slots)
        imgs = jax.device_put(grid.staged)
        warm_key = (grid.shape, plan.cfg.render_output)
        was_warm = warm_key in self._warmed
        if was_warm:
            with jax.transfer_guard("disallow"):
                res = plan.run(imgs)            # async dispatch of batch k
        else:
            # a compile takes seconds: retire the previous batch BEFORE it,
            # so the blocking-path EMA sample below cannot absorb compile
            # time (there is no overlap to preserve during a compile), and
            # est_s cannot inflate into shedding feasible traffic
            self._complete(grid)
            res = plan.run(imgs)                # first call compiles
            self._warmed.add(warm_key)
        # device_put may alias (zero-copy) a numpy buffer on CPU backends:
        # hand the old buffer to the in-flight batch and stage the next
        # wave into a fresh one rather than mutating shared memory.  Only
        # AFTER a successful dispatch — if plan.run raised, the slots still
        # hold their requests and a retry must re-ship the real frames,
        # not a zeroed buffer.
        grid.staged = np.zeros_like(grid.staged)
        # batch k-1 retires while k computes; if the dispatch above raised,
        # it is still in_flight and a later step/run() drains it
        self._complete(grid)
        grid.in_flight = (reqs, res, self.clock(), was_warm)
        self.dispatches += 1
        self.dispatch_log.append((grid.shape, grid.active, want_render))
        grid.slots = [None] * self.batch_size   # slots free immediately
        return True

    def run(self, max_steps: int = 10_000) -> None:
        """Drive until the queues, slots, and in-flight batches drain
        (flushing: partial grids dispatch rather than wait for traffic)."""
        while max_steps > 0:
            busy = self.step(flush=True)
            pending = any(
                g.active or g.in_flight is not None
                for g in self.grids.values()
            )
            if not busy and not pending and not self.queued:
                return
            max_steps -= 1

    # --- convenience ----------------------------------------------------
    def detect_many(self, frames: Iterable[np.ndarray]
                    ) -> list[DetectionRequest]:
        """Submit one request per frame, drain, return in submit order."""
        reqs = [DetectionRequest(uid=i, frame=np.asarray(f))
                for i, f in enumerate(frames)]
        for r in reqs:
            self.submit(r)
        self.run()
        assert all(r.done for r in reqs)
        return reqs
