"""Deadline-aware continuous-batching detection service.

The LM engine (``serve/engine.py``) serves token traffic with a fixed slot
grid; this module applies the same slot/bucket design to the line-detection
stack — and, because the paper's deployment is an AV control loop where a
*late* detection is a *useless* detection, layers an explicit QoS policy on
top of the PR-3 throughput machinery:

  * **Resolution buckets** — requests carry frames of heterogeneous
    resolutions; each frame pads (tapered edge replication, top-left
    anchored) to the smallest registered bucket that holds it, and results
    crop back bit-exact (``pad_to_bucket`` / ``crop_result``).
  * **Fixed batch slots** — every bucket owns a grid of ``batch_size``
    slots; a dispatch always runs the full grid (empty slots carry zero
    frames the frame-independent kernels ignore), so each bucket compiles
    exactly one program per render binding.
  * **Backpressure** — the admission queue is bounded (``max_queue``):
    submits beyond the bound are *rejected* with
    ``RequestStatus.QUEUE_FULL`` instead of silently stretching the tail,
    and queued requests that are expired — or *hopeless*, their remaining
    budget below a queue-depth-aware completion horizon (everything ahead
    of them in EDF order dispatches first, ``batch_size`` per wave) —
    are *shed* with ``RequestStatus.DEADLINE_EXCEEDED`` before they waste
    a slot.
    Every request terminates with an explicit status; nothing blows up
    latency silently, and doomed work never dominoes feasible work.
  * **QoS scheduling** — requests may carry a ``deadline_s`` budget and a
    ``priority`` class.  Admission within a bucket is strict-priority,
    earliest-deadline-first within a class (uniform-priority traffic is
    therefore pure EDF); dispatch ranks occupied grids the same way —
    highest class aboard, then tightest deadline — and
    *closes a batch early* (dispatches a partial grid) when waiting for
    more traffic would bust that deadline, given a per-bucket service-time
    estimate (EMA of measured dispatch times).  With no deadlines anywhere
    admitted the scheduler falls back to PR-3's full-grid-first round-robin
    throughput mode — same traffic, bit-identical results.
  * **Prefetch staging** — host-side staging (grayscale decode + taper
    pad) runs ahead on a ``PrefetchStager`` worker thread: frame N+1
    stages while the device computes batch N.  The worker touches only
    numpy; the single explicit ``jax.device_put`` per dispatch stays on
    the scheduler thread, so the post-warmup hot loop still runs under
    ``jax.transfer_guard("disallow")``.
  * **Session-stateful streaming** — requests sharing a ``session_id``
    are frames of one camera stream: the service keeps a per-session
    :class:`~repro.core.tracking.LaneTracker`, advances it as each
    frame's result completes (slot order == admission order and one batch
    is in flight per grid, so a session's frames arrive at its tracker in
    stream order), and attaches the smoothed reported tracks to the
    request — temporal continuity across the batching machinery, per
    stream, without giving up cross-stream batching.
  * **Per-request rendering** — ``DetectionRequest(render_output=True)``
    returns the paper's phase-3 overlay for that request only, cropped
    back to the native resolution bit-exact; the grid flips to the plan's
    render binding (``DetectionPlan.with_render``) only when someone in
    the batch asked.
  * **Injectable clock** — every timestamp and every deadline/backpressure
    decision reads ``self.clock()`` (default ``time.perf_counter``).
    Passing a :class:`VirtualClock` makes the whole policy deterministic:
    ``tests/test_service_deadlines.py`` and the deadline regime of
    ``benchmarks/service_suite.py`` drive traffic on virtual time, so no
    assertion ever races the noisy 2-core bench host.

  * **Degradation ladder** — under overload the service *downgrades*
    requests instead of shedding them, one rung at a time, driven by a
    :class:`LoadController` that reads queue depth, the per-bucket
    service-time EMA, and deadline slack.  Rung order (a request falls
    only as far as it must, and per-request :class:`DegradationPolicy`
    can forbid each rung):

      1. **Resolution downshift** (``DEGRADED_DOWNSHIFT``) — a hopeless
         request re-stages into a smaller registered bucket (2x mean-pool
         per halving, ``core.plan.downshift_frame``) where its deadline is
         feasible; the low-res result scales back to native coordinates
         in closed form (``upscale_result``), never below the policy's
         ``floor`` resolution.
      2. **Tracking coast** (``DEGRADED_COAST``) — a session request
         answers from its ``LaneTracker``'s k-step prediction
         (``predict_tracks``) with ZERO Hough dispatches; eligibility and
         budget are the tracker's own coast rules, so a session can never
         coast longer than it would survive a real camera blackout.
      3. **Priority-tiered shed** — the last rung: expired/unsalvageable
         work sheds with ``DEADLINE_EXCEEDED``, and a full queue evicts
         the worst strictly-lower-tier entry (largest ``priority`` value)
         before rejecting a higher-tier newcomer.

    Per-session SLO accounting (:class:`SessionSLO`) tracks
    full/downshift/coast/refused/late per stream.
  * **Fault injection** — every ladder rung is exercisable
    deterministically: a ``runtime.faults.ServiceFaultInjector`` can kill
    the prefetch worker mid-stream (the stager surfaces
    ``WorkerFailure`` to callers — never a silent hang — and the service
    restarts it up to ``max_stager_restarts`` before falling back to
    synchronous staging, with per-incarnation ``Heartbeat`` liveness on
    the service clock), fail or stall dispatches (``FAILED`` /
    late-complete with the EMA protected), jump the ``VirtualClock``
    forward (whole EDF waves expire in one step), and NaN-poison frames
    (``INVALID_FRAME``, or a coast answer when the session can back one).
    Every injected fault resolves to an explicit terminal status.

Plans come from ``core/plan.py``: one frozen ``DetectionPlan`` per bucket
(plus its render-bound twin on demand).  ``benchmarks/service_suite.py``
measures throughput/latency and the deadline-regime miss rates and writes
``BENCH_service.json``; ``benchmarks/fleet_suite.py`` runs the
heavy-tailed fleet overload + fault matrix on the virtual clock and
writes ``BENCH_fleet.json``::

    {"meta": {...traffic/model parameters...},
     "overload": {"ladder_on":  {per-tier {offered, served_full,
                                 served_downshift, served_coast, refused,
                                 late, miss_rate, degraded_rate}},
                  "ladder_off": {same tiers, shed-only}},
     "coast_quality": {family: {"f1_coast": ..., "n_scored": ...}},
     "faults": {fault_class: {"all_terminal": bool, "hung": int,
                              counters...}},
     "gates": {"high_pri_miss_improves": bool,
               "coast_zero_dispatch": bool,
               "faults_all_terminal": bool}}
"""

from __future__ import annotations

import dataclasses
import enum
import heapq
import math
import queue
import threading
import time
from collections import deque
from concurrent.futures import Future, InvalidStateError
from typing import Callable, Iterable, NamedTuple, Optional, Sequence, Union

import jax
import numpy as np

from repro.core.plan import (
    DetectionPlan, DetectionResult, PipelineConfig, PlanCache,
    downshift_frame, load_frame,
)
from repro.core.control import (
    ControlConfig, LateralController, SteeringCommand,
)
from repro.core.geometry import CameraConfig, CameraGeometry
from repro.core.tracking import (
    LaneTracker, Track, TrackerConfig, tracks_as_peaks,
)
from repro.runtime.heartbeat import Heartbeat
from repro.runtime.supervisor import WorkerFailure

# Default resolution ladder: QQVGA-ish up to the paper's camera frame.
DEFAULT_BUCKETS: tuple[tuple[int, int], ...] = (
    (120, 160), (240, 320), (480, 640),
)


class RequestStatus(enum.Enum):
    """Terminal disposition of a request (plus the initial PENDING).

    Classification goes through the properties below (and through
    ``DetectionRequest.is_terminal`` / ``.served`` / ``.degraded``), never
    through hand-enumerated status tuples: a new status added here is
    classified in exactly one place instead of silently falling through
    every call site's private list.
    """
    PENDING = "pending"
    DONE = "done"                          # full-fidelity result delivered
    # degradation ladder: served, but not at full fidelity
    DEGRADED_DOWNSHIFT = "degraded_downshift"  # served from a smaller bucket
    DEGRADED_COAST = "degraded_coast"      # served from tracker prediction
    # refusals: explicit terminal answers with no result
    QUEUE_FULL = "queue_full"              # rejected/evicted (backpressure)
    DEADLINE_EXCEEDED = "deadline_exceeded"  # shed before dispatch
    INVALID_FRAME = "invalid_frame"        # NaN/corrupt frame at admission
    FAILED = "failed"                      # dispatch fault (injected/real)

    @property
    def terminal(self) -> bool:
        """The request has its final answer (anything but PENDING)."""
        return self is not RequestStatus.PENDING

    @property
    def served(self) -> bool:
        """An answer was delivered (full fidelity or degraded)."""
        return self in (RequestStatus.DONE,
                        RequestStatus.DEGRADED_DOWNSHIFT,
                        RequestStatus.DEGRADED_COAST)

    @property
    def degraded(self) -> bool:
        return self in (RequestStatus.DEGRADED_DOWNSHIFT,
                        RequestStatus.DEGRADED_COAST)

    @property
    def refused(self) -> bool:
        """Terminal without an answer (shed/rejected/failed/invalid)."""
        return self.terminal and not self.served


class VirtualClock:
    """Deterministic monotonic clock: advances only when told to.

    Inject as ``DetectionService(..., clock=VirtualClock())`` to make every
    deadline/backpressure/early-close decision — and every latency stamp —
    a pure function of the driven schedule.  The unit for ``advance`` is
    seconds, same as ``time.perf_counter``.  Monotonicity is a hard
    contract (the EDF heaps, the EMA, and every ``latency_s`` depend on
    it): backward motion raises instead of corrupting the schedule, which
    is also what makes the fault harness's *forward* clock jumps
    (``ServiceFaultInjector.clock_jump_at_step``) safe to inject —
    a jump is indistinguishable from a long stall, expiring whole EDF
    waves in one step, never un-expiring anything.
    """

    def __init__(self, start: float = 0.0):
        self.t = float(start)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        assert dt >= 0.0, f"clock cannot run backward (dt={dt})"
        self.t += float(dt)
        return self.t

    def jump_to(self, t: float) -> float:
        """Jump to absolute time ``t`` (>= now); backward jumps raise."""
        if t < self.t:
            raise ValueError(
                f"backward clock jump rejected: {t} < {self.t}"
            )
        self.t = float(t)
        return self.t


class PrefetchStager:
    """Single worker thread staging host-side work ahead of the device.

    ``stage(fn, *args)`` enqueues ``fn(*args)`` and returns a
    ``concurrent.futures.Future``; the service resolves it at admission
    time, by which point the worker has usually finished — frame N+1 pads
    while the device computes batch N.  The worker runs numpy only
    (grayscale decode + taper pad); ``jax.device_put`` stays on the
    scheduler thread so ``transfer_guard("disallow")`` still polices the
    hot loop.  Staging is deterministic, so the threaded stream is
    bit-for-bit the synchronous one (property-tested).

    **Worker death is loud.**  A task exception resolves its future and
    the worker lives on (same contract as an executor).  A
    ``WorkerFailure`` — raised by the optional ``fault_hook`` (the fault
    harness's injected thread death) or by the task itself — kills the
    worker: the fatal task's future carries the exception, every queued
    future is failed with it, and subsequent ``stage`` calls raise
    ``WorkerFailure`` immediately.  No caller can ever block on a future
    the dead worker will never run (the submit/death race is closed by
    re-draining after enqueue).  With a ``heartbeat_registry`` the worker
    beats once per task on the injected clock, so a
    ``HeartbeatMonitor`` detects the death deterministically.
    """

    def __init__(self, *, fault_hook: Optional[Callable[[], None]] = None,
                 heartbeat_registry: Optional[dict] = None,
                 clock: Callable[[], float] = time.monotonic,
                 worker_id: str = "detection-prefetch"):
        self.worker_id = worker_id
        self._tasks: "queue.SimpleQueue[Optional[tuple]]" = (
            queue.SimpleQueue()
        )
        self._dead = threading.Event()
        self._fault_hook = fault_hook
        self.heartbeat = (
            Heartbeat(worker_id, heartbeat_registry, clock=clock)
            if heartbeat_registry is not None else None
        )
        self._thread = threading.Thread(
            target=self._worker, name=worker_id, daemon=True
        )
        self._thread.start()

    @property
    def alive(self) -> bool:
        return not self._dead.is_set()

    def stage(self, fn, *args) -> Future:
        """Enqueue ``fn(*args)``; raises ``WorkerFailure`` if the worker
        is dead (an explicit error at the submit site, not a future that
        silently never resolves)."""
        if self._dead.is_set():
            raise WorkerFailure(
                f"prefetch worker {self.worker_id!r} is dead"
            )
        fut: Future = Future()
        self._tasks.put((fut, fn, args))
        if self._dead.is_set():
            # the worker died while we enqueued: its drain may have run
            # before our put landed, so drain again — both drains are
            # idempotent, and the future is guaranteed resolved either way
            self._fail_pending()
        return fut

    def _fail_pending(self) -> None:
        """Fail every queued future with ``WorkerFailure`` (idempotent —
        callable from the dying worker AND from a racing ``stage``)."""
        while True:
            try:
                item = self._tasks.get_nowait()
            except queue.Empty:
                return
            if item is None:
                continue
            fut = item[0]
            try:
                fut.set_exception(
                    WorkerFailure("prefetch worker died before this task")
                )
            except InvalidStateError:
                pass   # the other drainer (or the worker) got there first

    def _worker(self) -> None:
        while True:
            item = self._tasks.get()
            if item is None:
                return                     # orderly close()
            fut, fn, args = item
            if self.heartbeat is not None:
                self.heartbeat.beat()
            if not fut.set_running_or_notify_cancel():
                continue
            try:
                if self._fault_hook is not None:
                    self._fault_hook()     # injected thread death
                fut.set_result(fn(*args))
            except WorkerFailure as e:     # fatal: the thread dies
                self._dead.set()
                try:
                    fut.set_exception(e)
                except InvalidStateError:
                    pass
                self._fail_pending()
                return
            except BaseException as e:     # task error: worker survives
                fut.set_exception(e)

    def close(self) -> None:
        if not self._dead.is_set():
            self._tasks.put(None)
        self._thread.join(timeout=5.0)
        self._dead.set()
        if self.heartbeat is not None:
            self.heartbeat.stop()


@dataclasses.dataclass(frozen=True)
class DegradationPolicy:
    """Per-request contract with the degradation ladder.

    The default allows every rung — the service degrades rather than
    sheds whenever it can.  A safety-critical caller that would rather
    get an explicit refusal than a low-res or predicted answer forbids
    the rungs it cannot act on; ``floor`` bounds how far the resolution
    may fall (the smallest bucket the downshift rung may target).
    """
    allow_downshift: bool = True
    allow_coast: bool = True
    floor: Optional[tuple[int, int]] = None  # min (H, W) bucket allowed


DEFAULT_POLICY = DegradationPolicy()
SHED_ONLY = DegradationPolicy(allow_downshift=False, allow_coast=False)


@dataclasses.dataclass
class SessionSLO:
    """Per-session service-level accounting (one per ``session_id``).

    ``miss_rate`` counts explicit refusals plus late full answers —
    the fraction of the stream's frames the vehicle could not steer by.
    ``degraded_rate`` is the fidelity cost the ladder paid to keep the
    miss rate down; the fleet benchmark reports both per priority tier.
    """
    submitted: int = 0
    served_full: int = 0
    served_downshift: int = 0
    served_coast: int = 0
    refused: int = 0        # shed / rejected / failed / invalid
    late: int = 0           # served, but after the deadline

    @property
    def served(self) -> int:
        return self.served_full + self.served_downshift + self.served_coast

    @property
    def degraded_rate(self) -> float:
        s = self.served
        return (self.served_downshift + self.served_coast) / s if s else 0.0

    @property
    def miss_rate(self) -> float:
        n = self.submitted
        return (self.refused + self.late) / n if n else 0.0


@dataclasses.dataclass
class DetectionRequest:
    """One frame in, one ``DetectionResult`` (or explicit refusal) out."""
    uid: int
    frame: np.ndarray                       # (H, W) or (H, W, 3)
    deadline_s: Optional[float] = None      # latency budget from submit
    priority: int = 0                       # strict class: lower admits first
    render_output: bool = False             # per-request phase-3 overlay
    # Session-stateful streaming: requests sharing a ``session_id`` are
    # frames of one camera stream.  The service keeps a LaneTracker per
    # session, advances it as each frame's result lands, and attaches the
    # smoothed reported tracks to the request (``tracks``).  Frames of a
    # session must be submitted in stream order and share one resolution
    # bucket — within a bucket, completion follows dispatch order (one
    # batch in flight per grid), so the tracker sees the stream in order.
    session_id: Optional[str] = None
    policy: DegradationPolicy = DEFAULT_POLICY
    # filled by the service
    result: Optional[DetectionResult] = None
    tracks: Optional[list[Track]] = None    # smoothed tracks (sessions only)
    steering: Optional[SteeringCommand] = None  # lateral command (sessions
                                                # with steering enabled):
                                                # fresh on served answers,
                                                # a decayed hold on refusals
    status: RequestStatus = RequestStatus.PENDING
    bucket: Optional[tuple[int, int]] = None
    downshift: int = 1                      # resolution divisor served at
    submitted_at: float = 0.0
    finished_at: float = 0.0
    deadline_at: Optional[float] = None     # absolute, on the service clock
    _staged: Optional[Union[Future, np.ndarray]] = dataclasses.field(
        default=None, repr=False, compare=False
    )
    _ds_shape: Optional[tuple[int, int]] = dataclasses.field(
        default=None, repr=False, compare=False
    )   # downshifted content shape inside the target bucket

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at

    @property
    def is_terminal(self) -> bool:
        """The request has its final answer — THE status check every
        other predicate routes through (new statuses classify once, in
        ``RequestStatus``, instead of falling through call-site lists)."""
        return self.status.terminal

    @property
    def done(self) -> bool:
        """Alias of ``is_terminal`` (pre-ladder name, kept for callers)."""
        return self.is_terminal

    @property
    def ok(self) -> bool:
        """Full-fidelity result delivered (degraded answers are *served*
        but not ``ok`` — callers gate fidelity-sensitive paths on this)."""
        return self.status is RequestStatus.DONE

    @property
    def served(self) -> bool:
        """An answer usable for steering was delivered (full or degraded:
        a downshifted result or a coast prediction)."""
        return self.status.served

    @property
    def degraded(self) -> bool:
        return self.status.degraded

    @property
    def missed_deadline(self) -> bool:
        """Refused (shed/rejected/failed/invalid), or served late."""
        if self.deadline_at is None:
            return False
        if self.status.refused:
            return True
        return self.is_terminal and self.finished_at > self.deadline_at


class _BucketGrid:
    """Slot grid + staging state for one resolution bucket."""

    def __init__(self, shape: tuple[int, int], batch_size: int,
                 plan: DetectionPlan, est_s: float):
        self.shape = shape
        self.plan = plan
        self.est_s = est_s      # EMA service-time estimate for one dispatch
        self.est_measured = False   # True once a real dispatch fed the EMA
        self.slots: list[Optional[DetectionRequest]] = [None] * batch_size
        self.staged = np.zeros((batch_size, *shape), np.float32)
        # (requests snapshot, async result, dispatch time, warm?, stall_s)
        # awaiting completion; warm=False marks a compiling dispatch whose
        # wall time must not feed the service-time EMA; stall_s > 0 marks
        # an injected dispatch stall (completion lands late, EMA untouched)
        self.in_flight: Optional[
            tuple[list[Optional[DetectionRequest]], DetectionResult,
                  float, bool, float]
        ] = None

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def tightest_deadline(self) -> float:
        """Earliest deadline among slotted requests (inf if none)."""
        ds = [r.deadline_at for r in self.slots
              if r is not None and r.deadline_at is not None]
        return min(ds) if ds else math.inf


# Pad decay horizon (pixels): the diffused pad reaches the flat fill level
# by this depth regardless of pad size.
_PAD_TAPER = 32


def _diffuse_pad(border: np.ndarray, n: int, fill: np.float32
                 ) -> np.ndarray:
    """Continue a border line outward for ``n`` steps, diffusing as it
    fades: each step blurs the previous line ([1, 2, 1]/4) and decays it
    toward ``fill``.  The blur spreads any stroke crossing the border so
    its transverse contrast collapses within a few steps (no extruded bar
    for Hough to vote up), while the decay's along-step slope stays under
    the Canny low threshold (no edge along the taper itself).

    ``border``: (W,) the outermost content line.  Returns (n, W).
    """
    rows = np.empty((n, border.shape[0]), np.float32)
    prev = border.astype(np.float32)
    for i in range(n):
        blurred = prev.copy()
        blurred[1:-1] = (
            0.25 * prev[:-2] + 0.5 * prev[1:-1] + 0.25 * prev[2:]
        )
        k = max(0.0, 1.0 - (i + 1.0) / _PAD_TAPER)
        prev = fill + (blurred - fill) * np.float32(k)
        rows[i] = prev
    return rows


def pad_to_bucket(frame: np.ndarray, shape: tuple[int, int]) -> np.ndarray:
    """Grayscale-load ``frame`` and pad it (top-left anchored) to the
    bucket shape with a *diffusing* edge continuation: the boundary
    row/column carries on (no synthetic step at the content border) while
    blurring and fading to the frame mean.  Plain replication would
    extrude every stroke touching the border into a long axis-aligned
    bright bar — strong enough to vote up spurious near-vertical/
    horizontal lines and to inflate the peak the relative threshold
    normalizes by.  Diffusion kills the bar's transverse contrast within
    a few pixels and the fade slope stays below the Canny thresholds, so
    the pad region contributes (nearly) no edges at any pad size
    (regression-tested in ``tests/test_detection_service.py``)."""
    img = load_frame(frame)
    H, W = img.shape
    bh, bw = shape
    assert H <= bh and W <= bw, (img.shape, shape)
    if (H, W) == (bh, bw):
        return img
    fill = np.float32(img.mean())
    out = np.empty((bh, bw), np.float32)
    out[:H, :W] = img
    if bh > H:
        out[H:, :W] = _diffuse_pad(img[H - 1, :], bh - H, fill)
    if bw > W:
        # columns diffuse from the full left part (content + row pad), so
        # the corner continues both tapers consistently
        out[:, W:] = _diffuse_pad(out[:, W - 1], bw - W, fill).T
    return out


def crop_result(res: DetectionResult, height: int, width: int
                ) -> DetectionResult:
    """Un-pad one frame's result: (rho, theta) peaks are already in
    original coordinates (top-left anchoring) and ``lines`` endpoints
    parameterize the same infinite lines (out-of-frame endpoints are
    normal — the unbatched detector produces them too); raster fields
    (edges, the rendered overlay) crop to (H, W)."""
    return DetectionResult(
        res.lines, res.valid, res.peaks,
        res.edges[..., :height, :width],
        None if res.rendered is None
        else res.rendered[..., :height, :width, :],
    )


def upscale_result(res: DetectionResult, factor: int,
                   height: int, width: int) -> DetectionResult:
    """Map a downshifted frame's (already cropped) result back to native
    coordinates.

    The 2x mean-pool chain maps native pixel centers ``x`` to downshifted
    centers ``(x - c) / factor`` with ``c = (factor - 1) / 2`` (the
    pool's phase offset), so the inverse is exact on line parameters:
    endpoints scale as ``p_native = factor * p + c`` and a (rho, theta)
    peak — theta is scale-invariant — as
    ``rho_native = factor * rho + c * (cos theta + sin theta)``.
    Raster fields (edges, the overlay) nearest-neighbour upsample and
    crop to the native (H, W): blocky, but honest about the fidelity the
    answer was computed at — this is a *degraded* response, flagged
    ``DEGRADED_DOWNSHIFT``, not a reconstruction.
    """
    c = (factor - 1) / 2.0
    peaks = np.array(res.peaks, np.float32).reshape(-1, 2).copy()
    th = peaks[:, 1]
    peaks[:, 0] = factor * peaks[:, 0] + c * (np.cos(th) + np.sin(th))
    lines = factor * np.array(res.lines, np.float32) + c
    valid = np.asarray(res.valid)
    edges = np.asarray(res.edges)
    edges = edges.repeat(factor, axis=-2).repeat(factor, axis=-1)
    edges = edges[..., :height, :width]
    rendered = None
    if res.rendered is not None:
        rendered = np.asarray(res.rendered)
        rendered = rendered.repeat(factor, axis=-3).repeat(factor, axis=-2)
        rendered = rendered[..., :height, :width, :]
    return DetectionResult(lines, valid, peaks, edges, rendered)


def _nan_poison(frame: np.ndarray) -> np.ndarray:
    """Corrupt a frame the way a DMA tear or truncated capture does:
    load it to the service's canonical f32 grayscale and stamp a NaN
    block over the top-left tile.  Used by the fault injector at submit
    so the admission finiteness check (not downstream kernel math) is
    what fields the corruption."""
    img = np.array(load_frame(frame), np.float32, copy=True)
    img[:8, :8] = np.nan
    return img


class BucketLoad(NamedTuple):
    """One bucket's load snapshot (see :class:`LoadController`)."""
    shape: tuple[int, int]
    queued: int                 # EDF queue depth
    active: int                 # occupied slots
    est_s: float                # service-time EMA (one dispatch)
    est_measured: bool          # a real warm dispatch grounded the EMA
    horizon_s: float            # time to drain slotted + queued work
    tightest_slack_s: float     # min(deadline - now) over queued+slotted

    @property
    def overloaded(self) -> bool:
        """The tightest deadline cannot survive the drain horizon."""
        return (math.isfinite(self.tightest_slack_s)
                and self.horizon_s > self.tightest_slack_s)


class LoadController:
    """The ladder's sensor + decision helper: reads queue depth, the
    per-bucket service-time EMA, and deadline slack; answers "can this
    deadline still be met here?" and "which smaller bucket should this
    request fall to?".

    Feasibility is the same queue-depth-aware horizon the shed rule uses
    (``waves * est_s`` with ``waves = ahead // batch_size + 1``), and it
    only *engages* once the bucket's estimate is measured — the ladder
    inherits the shed rule's no-latch discipline: an unvalidated prior
    must not downshift (or refuse) an entirely feasible workload.
    """

    def __init__(self, service: "DetectionService"):
        self._svc = service

    def est_s(self, shape: tuple[int, int]) -> float:
        """The bucket's EMA, or 0.0 while unmeasured (optimism by
        design: see the no-latch note in the class docstring)."""
        g = self._svc.grids[shape]
        return g.est_s if g.est_measured else 0.0

    def waves(self, shape: tuple[int, int], ahead: int) -> int:
        return ahead // len(self._svc.grids[shape].slots) + 1

    def horizon_s(self, shape: tuple[int, int], ahead: int) -> float:
        """Completion horizon for a request queued behind ``ahead``
        entries in ``shape``'s bucket."""
        return self.waves(shape, ahead) * self.est_s(shape)

    def feasible(self, shape: tuple[int, int],
                 deadline_at: Optional[float], now: float,
                 ahead: int) -> bool:
        """Can a request with this absolute deadline still make it?"""
        if deadline_at is None:
            return True
        est = self.est_s(shape)
        if est <= 0.0:              # unmeasured: only expiry is certain
            return deadline_at > now
        return deadline_at >= now + self.horizon_s(shape, ahead)

    def load(self, shape: tuple[int, int], now: float) -> BucketLoad:
        """Introspection snapshot of one bucket (benchmarks/operators)."""
        svc = self._svc
        g = svc.grids[shape]
        q = svc.queues[shape]
        slacks = [k - now for (_, k, _, _) in q if math.isfinite(k)]
        slacks += [
            r.deadline_at - now for r in g.slots
            if r is not None and r.deadline_at is not None
        ]
        return BucketLoad(
            shape, len(q), g.active, g.est_s, g.est_measured,
            self.horizon_s(shape, g.active + len(q)),
            min(slacks) if slacks else math.inf,
        )

    def downshift_target(self, req: DetectionRequest, now: float
                         ) -> Optional[tuple[int, int]]:
        """Largest registered bucket below the request's current one, at
        or above its policy ``floor``, where its deadline is feasible
        given that bucket's current depth — or None (rung exhausted)."""
        svc = self._svc
        idx = svc.buckets.index(req.bucket)
        floor = req.policy.floor
        for target in reversed(svc.buckets[:idx]):
            if floor is not None and (target[0] < floor[0]
                                      or target[1] < floor[1]):
                continue
            ahead = svc.grids[target].active + len(svc.queues[target])
            if self.feasible(target, req.deadline_at, now, ahead):
                return target
        return None


class DetectionService:
    """Request-level line detection with backpressure + QoS over fixed
    per-bucket batch slots.

    ``submit`` enqueues (or rejects) requests; ``step`` sheds expired work,
    admits earliest-deadline-first, dispatches one bucket grid — closing a
    batch early when the tightest admitted deadline can't wait — and
    completes the previously dispatched one (double-buffering); ``run``
    drains everything.  ``detect_many`` is the convenience loop the
    benchmarks use.

    QoS knobs:
      * ``max_queue`` — bound on the admission queue (None = unbounded);
        submits beyond it return ``RequestStatus.QUEUE_FULL`` (with the
        ladder on, a strictly-lower-tier queued request is evicted first).
      * ``est_dispatch_s`` / ``est_smoothing`` — initial per-bucket
        service-time estimate and its EMA factor; the early-close rule
        dispatches a partial grid when ``deadline - now <= est``.
      * ``clock`` — injectable monotonic clock (see :class:`VirtualClock`).
      * ``prefetch`` — stage frames on a :class:`PrefetchStager` worker
        thread (True, default) or synchronously at admission (False);
        results are bit-identical either way.

    Robustness knobs (the degradation ladder + fault harness):
      * ``ladder`` — enable the degradation ladder (default True; False
        is the pre-ladder shed-only service, the fleet benchmark's
        baseline arm).
      * ``validate_frames`` — finiteness-check staged frames at admission
        (a NaN frame would silently poison its whole batch's reduction
        stages); invalid frames coast if their session can back it, else
        refuse with ``INVALID_FRAME``.
      * ``faults`` — a ``runtime.faults.ServiceFaultInjector`` wired into
        the stager / dispatch / clock / frame paths (None in production).
      * ``max_stager_restarts`` — supervision budget for prefetch-worker
        deaths: each death restarts a fresh worker (new ``Heartbeat``
        incarnation in ``self.heartbeats``) until the budget is spent,
        then staging falls back to synchronous (prefetch off) — degraded
        throughput, never a wrong answer.
    """

    def __init__(self, cfg: PipelineConfig = PipelineConfig(), *,
                 buckets: Sequence[tuple[int, int]] = DEFAULT_BUCKETS,
                 batch_size: int = 4,
                 max_queue: Optional[int] = None,
                 est_dispatch_s: float = 0.05,
                 est_smoothing: float = 0.3,
                 clock: Callable[[], float] = time.perf_counter,
                 prefetch: bool = True,
                 tracker: TrackerConfig = TrackerConfig(),
                 ladder: bool = True,
                 validate_frames: bool = True,
                 faults: Optional[object] = None,
                 max_stager_restarts: int = 3,
                 gate_band: Optional[int] = 40,
                 fused_corridors: Optional[int] = None,
                 steering: Optional[ControlConfig] = None,
                 camera: Optional[CameraConfig] = None,
                 device: Optional[object] = None):
        if cfg.hough.theta_band is not None:
            raise ValueError(
                "pass the gate width via gate_band=, not through the "
                "config: the service derives gated plans itself"
            )
        if cfg.hough.corridors is not None or cfg.fused:
            raise ValueError(
                "pass the corridor count via fused_corridors=, not "
                "through the config: the service derives fused plans "
                "itself"
            )
        if fused_corridors is not None:
            if gate_band is None:
                raise ValueError(
                    "fused_corridors requires gate_band: the fused plan "
                    "is the gated plan's twin"
                )
            if not cfg.hough.compact:
                raise ValueError(
                    "fused_corridors requires hough.compact=True: the "
                    "fused kernel's output IS the compacted edge list"
                )
        self.cfg = cfg
        self.batch_size = batch_size
        self.tracker_cfg = tracker
        self.sessions: dict[str, LaneTracker] = {}
        # Steering surface: with a ControlConfig, every *session* request
        # leaves the service carrying a SteeringCommand — a fresh
        # pure-pursuit command on served answers (full, downshifted, or
        # coast), a decayed hold on refusals — so a vehicle consuming
        # the stream always has a lateral command, degradation included.
        # One LateralController per session, on the service clock; the
        # camera model is one fixed rig rescaled to each session's
        # native resolution (CameraConfig.for_image).
        self.steering_cfg = steering
        self.camera_cfg = camera if camera is not None else CameraConfig()
        self.controllers: dict[str, LateralController] = {}
        self.buckets = tuple(sorted(buckets))
        self.max_queue = max_queue
        self.est_smoothing = est_smoothing
        self.clock = clock
        self.prefetch = prefetch
        self.ladder = ladder
        self.validate_frames = validate_frames
        self.faults = faults
        self.max_stager_restarts = max_stager_restarts
        self.gate_band = gate_band
        self.fused_corridors = fused_corridors
        self.device = device
        self.load_controller = LoadController(self)
        # one PlanCache per service: a sharded fleet builds one service
        # per replica, so plans (and the per-dispatch device_put) pin to
        # that replica's device
        self.plans = PlanCache(cfg, device=device)
        self.grids = {
            shape: _BucketGrid(
                shape, batch_size,
                self.plans.plan_for(*shape, batch=batch_size),
                est_dispatch_s,
            )
            for shape in self.buckets
        }
        # Admission queues: heap of (priority, deadline, seq, request) —
        # strict priority classes, earliest-deadline-first within a class
        # (all-equal-priority traffic is therefore pure EDF, the pre-tier
        # behavior; a safety tier is never queued behind bulk work)
        self.queues: dict[
            tuple[int, int],
            list[tuple[int, float, int, DetectionRequest]],
        ] = {shape: [] for shape in self.buckets}
        self._seq = 0
        self._rr = 0            # round-robin cursor (throughput mode)
        self._steps = 0
        # (shape, render, theta_band, fused) plan bindings already compiled
        self._warmed: set[
            tuple[tuple[int, int], bool, Optional[int], bool]
        ] = set()
        self._loader: Optional[PrefetchStager] = None
        self.heartbeats: dict[str, float] = {}   # stager liveness registry
        self.slo: dict[str, SessionSLO] = {}     # per-session accounting
        self._session_coasts: dict[str, int] = {}  # consecutive coasts
        self.dispatches = 0
        self.completed = 0
        self.rejected_queue_full = 0
        self.shed_deadline = 0
        self.completed_late = 0
        # ladder + fault counters
        self.downshifted = 0          # requests moved to a smaller bucket
        self.pre_downshifted = 0      # ...of which at admission time
        self.served_downshift = 0     # completed at reduced resolution
        self.served_coast = 0         # answered from tracker prediction
        self.gated_dispatches = 0     # dispatches under a union theta gate
        self.fused_dispatches = 0     # ...of which ran the fused hot path
        self.evicted = 0              # lower-tier evictions (in rejected_*)
        self.rejected_invalid = 0     # NaN/corrupt frames refused
        self.dispatch_faults = 0      # requests failed by dispatch faults
        self.stager_deaths = 0        # prefetch-worker deaths observed
        # (shape, active slots, render) per dispatch — introspection for
        # tests/benchmarks; bounded so a long-running service cannot
        # accrete it without limit
        self.dispatch_log: deque[tuple[tuple[int, int], int, bool]] = (
            deque(maxlen=4096)
        )

    # --- lifecycle ------------------------------------------------------
    def close(self) -> None:
        """Stop the prefetch worker (idempotent)."""
        if self._loader is not None:
            self._loader.close()
            self._loader = None

    # --- sessions -------------------------------------------------------
    def session_tracks(self, session_id: str) -> list[Track]:
        """Current live tracks of a streaming session ([] if unknown)."""
        tracker = self.sessions.get(session_id)
        return tracker.tracks if tracker is not None else []

    def end_session(self, session_id: str) -> None:
        """Drop a session's tracker state (idempotent; SLO stats are kept
        — accounting outlives the stream it measured)."""
        self.sessions.pop(session_id, None)
        self._session_coasts.pop(session_id, None)
        self.controllers.pop(session_id, None)

    def _controller(self, req: DetectionRequest
                    ) -> Optional[LateralController]:
        """The per-session lateral controller (None unless steering is
        enabled and the request belongs to a session)."""
        if self.steering_cfg is None or req.session_id is None:
            return None
        ctl = self.controllers.get(req.session_id)
        if ctl is None:
            H, W = req.frame.shape[:2]
            ctl = LateralController(
                CameraGeometry(self.camera_cfg.for_image(H, W)),
                self.steering_cfg, clock=self.clock,
            )
            self.controllers[req.session_id] = ctl
        return ctl

    def session_slo(self, session_id: str) -> SessionSLO:
        """The session's SLO accounting (zeros if never seen)."""
        return self.slo.get(session_id, SessionSLO())

    def _slo(self, session_id: str) -> SessionSLO:
        s = self.slo.get(session_id)
        if s is None:
            s = self.slo[session_id] = SessionSLO()
        return s

    @property
    def stager_alive(self) -> bool:
        """Is the current prefetch worker live (True when prefetch is
        synchronous — there is no worker to die)."""
        return self._loader is None or self._loader.alive

    def __enter__(self) -> "DetectionService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # --- bucketing -----------------------------------------------------
    def bucket_for(self, frame: np.ndarray) -> tuple[int, int]:
        """Smallest registered bucket that holds ``frame``."""
        H, W = frame.shape[:2]
        for bh, bw in self.buckets:
            if H <= bh and W <= bw:
                return (bh, bw)
        raise ValueError(
            f"frame {frame.shape} exceeds every bucket {self.buckets}"
        )

    @property
    def queued(self) -> int:
        return sum(len(q) for q in self.queues.values())

    # --- request lifecycle ---------------------------------------------
    def submit(self, req: DetectionRequest, *,
               force_bucket: Optional[tuple[int, int]] = None
               ) -> RequestStatus:
        """Enqueue ``req`` — or reject it with ``QUEUE_FULL`` when the
        bounded admission queue is at capacity (backpressure: the caller
        learns *now*, instead of every queued request learning late).
        With the ladder on, a full queue first tries to evict the worst
        strictly-lower-tier queued request (priority-tiered shedding:
        tier-0 traffic displaces tier-2, never a peer).

        ``force_bucket`` downshifts the request into that (smaller,
        registered) bucket unconditionally at admission — the
        speculative-offload local tier (``serve/fleet.py``), whose
        low-res pass is a downshift *by design*, not a reaction to
        load."""
        req.bucket = self.bucket_for(req.frame)
        now = self.clock()
        req.submitted_at = now
        if req.deadline_s is not None:
            req.deadline_at = now + req.deadline_s
        if req.session_id is not None:
            self._slo(req.session_id).submitted += 1
        if self.faults is not None and self.faults.corrupts(req.uid):
            req.frame = _nan_poison(req.frame)
        if self.max_queue is not None and self.queued >= self.max_queue:
            if not (self.ladder and self._evict_for(req, now)):
                # before refusing outright, a session newcomer may still
                # be answered from its tracker — a degraded answer under
                # backpressure beats an explicit refusal (same rung
                # order the queue police applies)
                if self.ladder and self._try_coast(req, now):
                    return req.status
                self._refuse(req, RequestStatus.QUEUE_FULL, now)
                self.rejected_queue_full += 1
                return req.status
        if force_bucket is not None and force_bucket != req.bucket:
            assert force_bucket in self.buckets, (force_bucket,
                                                  self.buckets)
            self._downshift_into(req, force_bucket)
        # Pre-downshift at admission: when the bucket's measured backlog
        # already makes this deadline infeasible, rung 1 engages NOW —
        # queueing at the native bucket first would burn the little slack
        # the request has left before the queue police notices it is
        # hopeless (one whole scheduler step later, after which even the
        # smaller bucket may no longer save it).
        if (self.ladder and req.deadline_at is not None
                and req.policy.allow_downshift):
            grid = self.grids[req.bucket]
            ahead = grid.active + len(self.queues[req.bucket])
            if not self.load_controller.feasible(
                    req.bucket, req.deadline_at, now, ahead):
                target = self.load_controller.downshift_target(req, now)
                if target is not None and self._downshift_into(req, target):
                    self.pre_downshifted += 1
        # Prefetch pays only when staging does real work (luma conversion
        # or taper padding).  A grayscale frame already at bucket shape is
        # a pass-through: shipping it to the worker would add one thread
        # round-trip of pure overhead per request — measurable on a 2-core
        # host where the worker steals cycles from device compute.
        needs_staging = (
            req.frame.ndim == 3 or req.frame.shape[:2] != req.bucket
            or req.frame.dtype != np.float32
        )
        if self.prefetch and needs_staging and req._staged is None:
            self._stage_supervised(req)
        self._seq += 1
        key = req.deadline_at if req.deadline_at is not None else math.inf
        heapq.heappush(
            self.queues[req.bucket], (req.priority, key, self._seq, req)
        )
        return RequestStatus.PENDING

    # --- refusals + SLO --------------------------------------------------
    def _refuse(self, req: DetectionRequest, status: RequestStatus,
                now: float) -> None:
        """Terminate ``req`` without an answer (explicit refusal)."""
        req.status = status
        req.finished_at = now
        req._staged = None
        if req.session_id is not None:
            self._slo(req.session_id).refused += 1
            ctl = self._controller(req)
            if ctl is not None:
                # no answer still needs a lateral command: the vehicle
                # holds the last one, decayed toward straight
                req.steering = ctl.hold()

    def _evict_for(self, req: DetectionRequest, now: float) -> bool:
        """Priority-tiered backpressure: free one queue slot for ``req``
        by shedding the worst queued request of a STRICTLY lower tier
        (larger ``priority`` value; ties broken latest-deadline, then
        latest-arrival).  Equal-tier traffic is never displaced — within
        a tier the original reject-the-newcomer contract stands, so a
        tier cannot starve itself by churning."""
        worst_rank: Optional[tuple[int, float, int]] = None
        worst: Optional[tuple[tuple[int, int], tuple]] = None
        for shape, q in self.queues.items():
            for entry in q:
                prio, key, seq, _ = entry
                if prio <= req.priority:
                    continue
                rank = (prio, key, seq)
                if worst_rank is None or rank > worst_rank:
                    worst_rank, worst = rank, (shape, entry)
        if worst is None:
            return False
        shape, entry = worst
        q = self.queues[shape]
        q.remove(entry)
        heapq.heapify(q)
        victim = entry[3]
        # the victim leaves the queue either way; a session victim whose
        # tracker can back a coast gets a degraded answer instead of a
        # refusal (rung 2 before rung 3, same as the queue police)
        if not self._try_coast(victim, now):
            self._refuse(victim, RequestStatus.QUEUE_FULL, now)
            self.rejected_queue_full += 1   # still a backpressure refusal
        self.evicted += 1
        return True

    # --- prefetch supervision -------------------------------------------
    def _make_stager(self) -> PrefetchStager:
        hook = (self.faults.check_stage
                if self.faults is not None else None)
        return PrefetchStager(
            fault_hook=hook, heartbeat_registry=self.heartbeats,
            clock=self.clock,
            worker_id=f"detection-prefetch-{self.stager_deaths}",
        )

    def _note_stager_death(self) -> None:
        """Account a dead prefetch worker and decide restart vs fallback:
        within the ``max_stager_restarts`` budget the next staging call
        starts a fresh worker (a new heartbeat incarnation); past it,
        prefetch turns off and staging runs synchronously at admission —
        results are bit-identical either way, only overlap is lost.

        One real death can surface more than once (the fatal task's
        future AND every queued future carry ``WorkerFailure``), so the
        death is only charged while the dead worker is still the current
        one — a stale failure from an already-replaced worker is not a
        second death."""
        if self._loader is None or self._loader.alive:
            return
        self.stager_deaths += 1
        self._loader = None
        if self.stager_deaths > self.max_stager_restarts:
            self.prefetch = False

    def _stage_supervised(self, req: DetectionRequest) -> None:
        """Stage on the prefetch worker; on ``WorkerFailure`` (the death
        the stager surfaces *explicitly* at the submit site) restart once
        within budget, else leave ``req`` unstaged — admission stages it
        synchronously.  Either way the request is answered; a dead thread
        costs overlap, never correctness."""
        for _ in range(2):
            if not self.prefetch:
                return
            if self._loader is None:
                self._loader = self._make_stager()
            try:
                req._staged = self._loader.stage(
                    pad_to_bucket, req.frame, req.bucket
                )
                return
            except WorkerFailure:
                self._note_stager_death()

    def _shed_or_degrade(self) -> None:
        """Police every queue: expired or *hopeless* entries leave it —
        but with the ladder on, a hopeless (not yet expired) request is
        walked DOWN the degradation ladder before the shed rung fires:

          1. downshift into a smaller bucket where its deadline is
             feasible (policy + ``LoadController.downshift_target``),
          2. else answer from the session tracker's coast prediction,
          3. else shed with the explicit ``DEADLINE_EXCEEDED`` the
             admission contract promises.

        Hopeless means: cannot finish in time even if everything goes
        well — running it anyway is the EDF overload pathology (doomed
        work dominoes feasible work into lateness).  An already *expired*
        entry goes straight to the shed rung: any answer, degraded or
        not, would land after the deadline it exists to meet.

        Feasibility is *queue-depth-aware*: a request at EDF position k in
        its bucket queues behind ``active`` slotted requests and the k
        tighter-deadline entries kept ahead of it, all of which dispatch
        first, ``batch_size`` per wave — so its completion horizon is
        ``now + waves * est_s`` with ``waves = ahead // batch_size + 1``,
        not the single-dispatch optimism of one ``est_s``.  A deep queue
        therefore sheds a mid-pack budget that a shallow queue would keep
        (covered in ``tests/test_service_deadlines.py``); for the shallow
        case (``ahead < batch_size``) the horizon reduces to exactly the
        old one-dispatch rule.  Entries that shed OR degrade out of the
        queue do not count toward ``ahead`` — leaving frees their wave
        for the survivors.

        The hopeless test only engages once the grid's estimate is
        *measured* (a real dispatch fed the EMA): acting on an
        unvalidated prior could latch into degrading/refusing an entirely
        feasible workload forever, since the estimate only corrects on
        completions.  Pop order is the admission order — priority class
        first, EDF within a class — so ``ahead`` counts exactly what
        really dispatches first, including no-deadline entries of a
        higher class; no-deadline entries themselves (``inf`` keys) are
        never shed.

        Buckets are policed largest-first: a request downshifted out of a
        large bucket lands in a smaller queue that is policed later in
        the SAME pass, so a downshift that turns out hopeless at the
        target too (the target saturated this step) still coasts or
        sheds this step — it cannot hide for a step in a doomed queue.
        """
        now = self.clock()
        for shape in reversed(self.buckets):
            q = self.queues[shape]
            if not q:
                continue
            grid = self.grids[shape]
            est = grid.est_s if grid.est_measured else 0.0
            worst_waves = (grid.active + len(q) - 1) // len(grid.slots) + 1
            tightest = min(e[1] for e in q)
            if tightest > now + worst_waves * est:
                continue
            keep = []
            ahead = grid.active          # slotted work dispatches first
            for entry in sorted(q):      # pop order: (prio, key, seq)
                _, key, _, req = entry
                waves = ahead // len(grid.slots) + 1
                doomed = (key <= now
                          or (est > 0.0 and key < now + waves * est))
                if not doomed:
                    keep.append(entry)
                    ahead += 1
                    continue
                expired = key <= now
                if not expired and self._try_downshift(req, now):
                    continue
                if not expired and self._try_coast(req, now):
                    continue
                self._refuse(req, RequestStatus.DEADLINE_EXCEEDED, now)
                self.shed_deadline += 1
            q[:] = keep
            heapq.heapify(q)

    # --- the ladder rungs -----------------------------------------------
    def _downshift_into(self, req: DetectionRequest,
                        target: tuple[int, int]) -> bool:
        """Re-stage ``req`` for the smaller ``target`` bucket (shared by
        the queue-police rung and the admission-time pre-downshift; the
        caller enqueues).  The frame mean-pools by 2x per halving
        (host-side, ``core.plan.downshift_frame``) and the result scales
        back to native coordinates at completion (``upscale_result``).
        Staging is synchronous, now: the downshift exists to make an
        imminent deadline, so the pooled pad must be slot-ready the
        moment the target grid admits (host work, same cost class as the
        synchronous staging path)."""
        img, factor = downshift_frame(req.frame, target)
        if factor <= req.downshift:
            return False   # no actual resolution drop: nothing gained
        req._staged = pad_to_bucket(img, target)
        req._ds_shape = img.shape
        req.downshift = factor
        req.bucket = target
        self.downshifted += 1
        return True

    def _try_downshift(self, req: DetectionRequest, now: float) -> bool:
        """Rung 1: re-stage ``req`` into a smaller bucket where its
        deadline is feasible — a lower-fidelity answer in time beats a
        perfect answer late."""
        if not self.ladder or not req.policy.allow_downshift:
            return False
        target = self.load_controller.downshift_target(req, now)
        if target is None:
            return False
        if not self._downshift_into(req, target):
            return False
        self._seq += 1
        key = req.deadline_at if req.deadline_at is not None else math.inf
        heapq.heappush(
            self.queues[target], (req.priority, key, self._seq, req)
        )
        return True

    def _try_coast(self, req: DetectionRequest, now: float) -> bool:
        """Rung 2: answer a session request from its tracker's k-step
        coast prediction — ZERO detection dispatches, the near-free local
        answer that always meets the deadline.  Eligibility and budget
        are the tracker's own coast rules (``LaneTracker.predict_tracks``
        with ``steps`` = consecutive coasts served + 1): a session that
        coasted its way past ``max_misses`` gets no further coasts until
        a real frame completes and re-grounds the tracker, exactly like a
        camera blackout of the same length."""
        if not self.ladder or not req.policy.allow_coast:
            return False
        if req.session_id is None:
            return False
        tracker = self.sessions.get(req.session_id)
        if tracker is None:
            return False
        steps = self._session_coasts.get(req.session_id, 0) + 1
        tracks = tracker.predict_tracks(steps)
        if not tracks:
            return False
        req.tracks = tracks
        req.status = RequestStatus.DEGRADED_COAST
        req.finished_at = now
        req._staged = None
        ctl = self._controller(req)
        if ctl is not None:
            # a coast answer still steers: the command comes from the
            # tracker's predicted lanes, exactly like a served frame
            req.steering = ctl.command(*tracks_as_peaks(tracks))
        self._session_coasts[req.session_id] = steps
        self.served_coast += 1
        self._slo(req.session_id).served_coast += 1
        return True

    def _resolve_staged(self, req: DetectionRequest,
                        shape: tuple[int, int]) -> np.ndarray:
        """Produce the slot-ready padded frame for ``req``.

        Downshifted requests carry their pooled pad as a plain array
        (staged synchronously by the ladder).  Prefetched requests carry
        a ``Future``; if the worker died mid-task the ``WorkerFailure``
        surfaces here — the service notes the death (restart budget) and
        falls back to staging synchronously, so an injected stager death
        degrades prefetch, never correctness."""
        staged = req._staged
        req._staged = None
        if isinstance(staged, np.ndarray):
            return staged
        if staged is not None:            # a prefetch Future
            try:
                return staged.result()
            except WorkerFailure:
                self._note_stager_death()
        return pad_to_bucket(req.frame, shape)

    def _admit(self) -> None:
        """Fill free slots in strict priority classes within each bucket,
        earliest-deadline-first within a class (no-deadline requests
        order FIFO after their class's deadlined ones).  Staged frames
        come from the prefetch worker when enabled — admission only copies
        the finished pad into the slot buffer.

        Admission is also the frame-validity gate: a non-finite pad (NaN
        Inf — sensor corruption, injected or real) must never reach the
        device, where it would poison the whole batch's reduction math.
        A corrupt session frame falls to the coast rung (the tracker's
        prediction is exactly the right answer to one bad capture);
        otherwise the request refuses with ``INVALID_FRAME``.  Either
        way the slot stays free for the next queue entry."""
        for shape in self.buckets:
            grid = self.grids[shape]
            q = self.queues[shape]
            while q:
                slot = grid.free_slot()
                if slot is None:
                    break
                _, _, _, req = heapq.heappop(q)
                # resolve staging BEFORE taking the slot: if the prefetch
                # worker raised, the exception surfaces here with the
                # request un-slotted (still PENDING) — never a DONE result
                # silently computed from the slot's zeroed frame
                staged = self._resolve_staged(req, grid.shape)
                if self.validate_frames and not np.isfinite(staged).all():
                    if not self._try_coast(req, self.clock()):
                        self._refuse(req, RequestStatus.INVALID_FRAME,
                                     self.clock())
                        self.rejected_invalid += 1
                    continue
                grid.slots[slot] = req
                grid.staged[slot] = staged

    def _reap(self) -> None:
        """Retire any in-flight batch whose result is already ready.

        Keeps ``latency_s`` honest (a result is delivered as soon as the
        device finishes, not when its grid next refills) without ever
        blocking — ``is_ready`` is a non-blocking poll.
        """
        for g in self.grids.values():
            if g.in_flight is None:
                continue
            lines = g.in_flight[1].lines
            if getattr(lines, "is_ready", lambda: False)():
                # the device finished some unknown time ago (we only just
                # polled), so dispatch->now includes idle gap, not service
                # time — deliver the results but keep it out of the EMA
                self._complete(g, update_est=False)

    def drain(self) -> None:
        """Block until every in-flight batch has completed and resolved
        back onto its requests (deterministic completion stamping for
        virtual-clock drivers — no ``is_ready`` poll races).

        Like ``_reap``, drain's timing samples are idle-contaminated upper
        bounds, so they can lower the service-time estimate but never
        raise it: one long idle gap must not push the estimate past every
        offered deadline (hopeless-shed livelock).  Only back-to-back
        dispatches — the previous batch still in flight when the next one
        landed — can raise it."""
        for g in self.grids.values():
            self._complete(g, update_est=False)

    def _complete(self, grid: _BucketGrid, *, update_est: bool = True
                  ) -> None:
        """Resolve the grid's in-flight batch back onto its requests.

        The dispatch->completion sample ``dt`` feeds the grid's EMA
        service-time estimate (which drives early close + hopeless shed)
        under an asymmetric rule.  ``update_est=True`` — the dispatch-
        completes-previous path in ``step``, where the previous batch was
        still occupying the device — may move the estimate either way.
        ``update_est=False`` — ``_reap`` and ``drain``, whose samples
        include however long the batch sat finished before anyone asked —
        may only ratchet it *down or hold it* (an idle-contaminated sample
        is an upper bound on the true service time, so a sample at or
        below the estimate is still evidence, while a sample above it must
        never inflate the estimate into shedding feasible work).
        Compiling (cold) dispatches are excluded entirely: one XLA compile
        is seconds on this stack, and a seconds-scale estimate would shed
        every sub-second budget."""
        if grid.in_flight is None:
            return
        reqs, res, t_disp, was_warm, stall_s = grid.in_flight
        grid.in_flight = None
        jax.block_until_ready(res.lines)
        if stall_s > 0.0 and hasattr(self.clock, "advance"):
            # an injected dispatch stall: the device "took" stall_s extra
            # seconds — model it on the virtual clock so the batch lands
            # late, but keep the sample out of the EMA (a one-off stall is
            # not evidence about steady-state service time)
            self.clock.advance(stall_s)
            was_warm = False
        now = self.clock()
        dt = now - t_disp
        if was_warm and dt > 0.0 and (update_est or dt <= grid.est_s):
            a = self.est_smoothing
            grid.est_s = (1.0 - a) * grid.est_s + a * dt
            grid.est_measured = True
        for i, req in enumerate(reqs):
            if req is None:
                continue
            assert not req.is_terminal, f"request {req.uid} answered twice"
            H, W = req.frame.shape[:2]
            want = req.render_output or self.cfg.render_output
            rendered = (
                res.rendered[i]
                if want and res.rendered is not None else None
            )
            per = DetectionResult(
                res.lines[i], res.valid[i], res.peaks[i], res.edges[i],
                rendered,
            )
            if req.downshift > 1:
                # the batch ran at the downshifted bucket: crop to the
                # pooled content shape, then map back to native coords
                dh, dw = req._ds_shape
                req.result = upscale_result(
                    crop_result(per, dh, dw), req.downshift, H, W,
                )
                req.status = RequestStatus.DEGRADED_DOWNSHIFT
                self.served_downshift += 1
            else:
                req.result = crop_result(per, H, W)
                req.status = RequestStatus.DONE
            if req.session_id is not None:
                tracker = self.sessions.get(req.session_id)
                if tracker is None:
                    tracker = LaneTracker(self.tracker_cfg)
                    self.sessions[req.session_id] = tracker
                # slot order == admission order, and one batch is in
                # flight per grid, so a session's frames advance its
                # tracker in stream order (see DetectionRequest docstring).
                # scale= widens the rho association gate for downshifted
                # frames: the upscaled coarse detections must re-ground
                # the existing tracks, not birth quantized twins —
                # tracker state persists across resolution downshifts
                req.tracks = tracker.step(
                    np.asarray(req.result.peaks),
                    np.asarray(req.result.valid),
                    scale=req.downshift,
                )
                ctl = self._controller(req)
                if ctl is not None:
                    # steer from the smoothed tracks when the tracker
                    # reports any, from the frame's raw detections
                    # otherwise (session warmup) — the same fallback as
                    # TrackedFrame.control_peaks
                    if req.tracks:
                        req.steering = ctl.command(
                            *tracks_as_peaks(req.tracks)
                        )
                    else:
                        req.steering = ctl.command(
                            np.asarray(req.result.peaks),
                            np.asarray(req.result.valid),
                        )
                # a real frame re-grounds the tracker: the coast budget
                # resets (see _try_coast)
                self._session_coasts.pop(req.session_id, None)
                slo = self._slo(req.session_id)
                if req.downshift > 1:
                    slo.served_downshift += 1
                else:
                    slo.served_full += 1
            req.finished_at = now
            if req.deadline_at is not None and now > req.deadline_at:
                self.completed_late += 1
                if req.session_id is not None:
                    self._slo(req.session_id).late += 1
            self.completed += 1

    # --- union theta gate -----------------------------------------------
    def _union_gate(self, grid: _BucketGrid) -> Optional[np.ndarray]:
        """Union theta-band gate for one dispatched grid, or None (full
        sweep).

        The single-session ``TrackingPipeline`` realizes the 1.59x
        prediction-gated speedup; batching frames whose gates differ
        needs the *union* of the member sessions' bands.  Gating engages
        only when EVERY occupied slot is covered — each request belongs
        to a session whose tracker is healthy (``gate_bins`` non-None:
        confirmed tracks, none coasting, no open rescan window) — and
        the union fits the static ``gate_band`` budget; otherwise the
        grid full-sweeps, so gating is never a correctness dependence
        (same fallback contract as the pipeline path).  At full
        coverage the gated result is bit-exact with the full sweep
        (tested): theta is scale-invariant, so downshifted members gate
        identically.
        """
        if self.gate_band is None:
            return None
        n_theta = self.cfg.hough.n_theta
        bins: set[int] = set()
        for req in grid.slots:
            if req is None:
                continue
            if req.session_id is None:
                return None
            tracker = self.sessions.get(req.session_id)
            if tracker is None:
                return None
            b = tracker.gate_bins(n_theta)
            if b is None:
                return None
            bins.update(int(x) for x in b)
        if not bins or len(bins) > self.gate_band:
            return None           # empty grid or band-budget overflow
        out = sorted(bins)
        out += [out[0]] * (self.gate_band - len(out))
        return np.asarray(out, np.int32)

    # --- union rho corridors (fused hot path) ---------------------------
    def _union_corridors(self, grid: _BucketGrid) -> Optional[np.ndarray]:
        """Union rho-corridor set for one dispatched grid, or None (stay
        on the staged path).

        The corridor twin of :meth:`_union_gate`, with one extra
        admission rule: corridors are rho windows in *native* pixel
        coordinates, so every occupied slot must be serving at native
        resolution (``req.downshift == 1``) — a downshifted member's rho
        scale differs and its session's windows would filter the wrong
        pixels.  Beyond that, same contract: every slot needs a session
        whose tracker yields healthy (unpadded) corridors, the union must
        fit the static ``fused_corridors`` budget, and any failure means
        the grid runs the staged (gated or full-sweep) path — the fused
        dispatch is a perf hook, never a correctness dependence.
        """
        if self.fused_corridors is None:
            return None
        rows: list[np.ndarray] = []
        for req in grid.slots:
            if req is None:
                continue
            if req.session_id is None or req.downshift != 1:
                return None
            tracker = self.sessions.get(req.session_id)
            if tracker is None:
                return None
            c = tracker.corridors()
            if c is None:
                return None
            rows.append(c)
        if not rows:
            return None
        out = np.concatenate(rows, axis=0)
        if out.shape[0] > self.fused_corridors:
            return None           # corridor-budget overflow
        pad = np.tile(out[:1], (self.fused_corridors - out.shape[0], 1))
        return np.concatenate([out, pad], axis=0).astype(np.float32)

    # --- scheduling -----------------------------------------------------
    def _deadline_mode(self) -> bool:
        """QoS scheduling engages iff any *admitted* request carries a
        deadline; otherwise the service is exactly the PR-3 throughput
        scheduler (full-grid-first round-robin)."""
        return any(
            r is not None and r.deadline_at is not None
            for g in self.grids.values() for r in g.slots
        )

    def _next_grid_throughput(self, flush: bool) -> Optional[_BucketGrid]:
        """Round-robin over buckets: FULL grids first (a dispatch always
        computes ``batch_size`` frames, so partial grids waste slots), then
        — only when flushing — any occupied grid."""
        n = len(self.buckets)
        for want_full in (True, False) if flush else (True,):
            for k in range(n):
                shape = self.buckets[(self._rr + k) % n]
                grid = self.grids[shape]
                if grid.active == len(grid.slots) or (
                    not want_full and grid.active
                ):
                    self._rr = (self._rr + k + 1) % n
                    return grid
        return None

    def _next_grid_deadline(self, flush: bool, now: float
                            ) -> Optional[_BucketGrid]:
        """Priority-major, earliest-deadline-first over occupied grids.

        Grids rank by the highest priority class aboard, then tightest
        deadline (uniform-priority traffic is therefore pure EDF over
        grids, the pre-tier behavior bit-exact).  When total queued work
        exceeds the slack — someone must be late — this is what makes
        the lateness land on the lowest class instead of whichever
        bucket sorted first.  A grid dispatches when it is full, when it
        must close early (``tightest deadline - now <= est_s``: one more
        wait would bust it), or when flushing.  A lower-ranked grid may
        only jump ahead of the first waiting one if its own dispatch
        fits inside that grid's slack — EDF with admission control, not
        strict EDF, so throughput traffic still flows around a slack
        deadline."""
        order = sorted(
            (g for g in self.grids.values() if g.active),
            key=lambda g: (
                min(r.priority for r in g.slots if r is not None),
                g.tightest_deadline(),
                self.buckets.index(g.shape),
            ),
        )
        guard: Optional[tuple[float, float]] = None  # (deadline, est) held
        for g in order:
            d = g.tightest_deadline()
            full = g.active == len(g.slots)
            urgent = math.isfinite(d) and (d - now) <= g.est_s
            if full or urgent or flush:
                if guard is not None:
                    gd, gest = guard
                    if gd - now - g.est_s < gest:
                        continue   # would bust the tighter waiting grid
                return g
            if guard is None and math.isfinite(d):
                guard = (d, g.est_s)
        return None

    def step(self, *, flush: bool = False) -> bool:
        """Shed/degrade -> admit (EDF) -> dispatch one bucket grid ->
        free its slots for the next admission wave; completion of the
        *previous* dispatch on that grid happens just before the new one
        lands (one batch in flight per bucket).  Without deadlines only
        full grids dispatch unless ``flush``; with deadlines the tightest
        grid may close early.  Returns True if any work remains."""
        k_step = self._steps
        self._steps += 1
        if self.faults is not None and hasattr(self.clock, "advance"):
            jump = self.faults.clock_jump_for_step(k_step)
            if jump > 0.0:
                # an injected clock jump: time lurches forward before the
                # scheduler looks at anything — every queued deadline the
                # jump crossed expires in this one step's shed pass
                self.clock.advance(jump)
        self._reap()
        self._shed_or_degrade()
        self._admit()
        if self._deadline_mode():
            grid = self._next_grid_deadline(flush, self.clock())
        else:
            grid = self._next_grid_throughput(flush)
        if grid is None:
            # nothing dispatchable: drain whatever is still in flight
            self.drain()
            return bool(self.queued) or any(
                g.active for g in self.grids.values()
            )
        want_render = any(
            r is not None and r.render_output for r in grid.slots
        )
        plan = grid.plan.with_render(True) if want_render else grid.plan
        theta_bins = self._union_gate(grid)
        corridors = None
        if theta_bins is not None:
            plan = plan.with_theta_band(self.gate_band)
            # fused only under an engaged theta gate: both gates read the
            # same tracker health, so a corridor-eligible grid is already
            # gated — the fused plan is the gated plan's twin
            corridors = self._union_corridors(grid)
            if corridors is not None:
                plan = plan.with_fused(self.fused_corridors)
        reqs = list(grid.slots)
        if self.faults is not None and self.faults.fails_dispatch(
                self.dispatches):
            # injected dispatch failure: the batch never reaches the
            # device.  Retire the grid's previous batch first (its result
            # is real), then fail THIS batch's requests explicitly —
            # FAILED, never a hang, never a silent retry-with-zeros.  The
            # failed dispatch gets no log entry and does not advance the
            # dispatch counter: it never happened, device-wise.
            self._complete(grid)
            now = self.clock()
            for req in reqs:
                if req is not None:
                    self._refuse(req, RequestStatus.FAILED, now)
            self.dispatch_faults += 1
            grid.slots = [None] * self.batch_size
            grid.staged = np.zeros_like(grid.staged)
            return True
        imgs = self.plans.put(grid.staged)
        warm_key = (grid.shape, plan.cfg.render_output,
                    plan.cfg.hough.theta_band, plan.cfg.fused)
        was_warm = warm_key in self._warmed
        if was_warm:
            with jax.transfer_guard("disallow"):
                # async dispatch, batch k
                res = plan.run(imgs, theta_bins, corridors)
        else:
            # a compile takes seconds: retire the previous batch BEFORE it,
            # so the blocking-path EMA sample below cannot absorb compile
            # time (there is no overlap to preserve during a compile), and
            # est_s cannot inflate into shedding feasible traffic
            self._complete(grid)
            res = plan.run(imgs, theta_bins, corridors)  # compiles
            self._warmed.add(warm_key)
        if theta_bins is not None:
            self.gated_dispatches += 1
        if corridors is not None:
            self.fused_dispatches += 1
        # device_put may alias (zero-copy) a numpy buffer on CPU backends:
        # hand the old buffer to the in-flight batch and stage the next
        # wave into a fresh one rather than mutating shared memory.  Only
        # AFTER a successful dispatch — if plan.run raised, the slots still
        # hold their requests and a retry must re-ship the real frames,
        # not a zeroed buffer.
        grid.staged = np.zeros_like(grid.staged)
        # batch k-1 retires while k computes; if the dispatch above raised,
        # it is still in_flight and a later step/run() drains it
        self._complete(grid)
        stall = (self.faults.stall_for_dispatch(self.dispatches)
                 if self.faults is not None else 0.0)
        grid.in_flight = (reqs, res, self.clock(), was_warm, stall)
        self.dispatches += 1
        self.dispatch_log.append((grid.shape, grid.active, want_render))
        grid.slots = [None] * self.batch_size   # slots free immediately
        return True

    def run(self, max_steps: int = 10_000) -> None:
        """Drive until the queues, slots, and in-flight batches drain
        (flushing: partial grids dispatch rather than wait for traffic)."""
        while max_steps > 0:
            busy = self.step(flush=True)
            pending = any(
                g.active or g.in_flight is not None
                for g in self.grids.values()
            )
            if not busy and not pending and not self.queued:
                return
            max_steps -= 1

    # --- convenience ----------------------------------------------------
    def detect_many(self, frames: Iterable[np.ndarray]
                    ) -> list[DetectionRequest]:
        """Submit one request per frame, drain, return in submit order."""
        reqs = [DetectionRequest(uid=i, frame=np.asarray(f))
                for i, f in enumerate(frames)]
        for r in reqs:
            self.submit(r)
        self.run()
        assert all(r.done for r in reqs)
        return reqs
