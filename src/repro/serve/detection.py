"""Continuous-batching detection service: mixed-resolution request traffic.

The LM engine (``serve/engine.py``) serves token traffic with a fixed slot
grid; this module applies the same slot/bucket design to the line-detection
stack so heavy mixed-resolution camera traffic (the ROADMAP north star)
rides the batched plan path instead of a per-frame loop:

  * **Resolution buckets** — requests carry frames of heterogeneous
    resolutions; each frame pads (tapered edge replication, top-left
    anchored) to the smallest registered bucket that holds it.  Top-left
    anchoring keeps the original pixel coordinates, so detected
    (rho, theta) peaks need no remapping; line endpoints parameterize the
    infinite line in those same coordinates (they can lie outside any
    frame, padded or native — clip when rasterizing, as ``render_lines``
    does).
  * **Fixed batch slots** — every bucket owns a grid of ``batch_size``
    slots.  Admission fills free slots from the queue; a dispatch always
    runs the full grid (empty slots carry zero frames that the
    frame-independent kernels ignore), so each bucket compiles exactly one
    program — the same static-shapes-for-lock-step trade the LM engine
    makes.
  * **Double-buffered drain** — while the device computes bucket batch k,
    the host stages batch k+1 (admission, padding, one explicit
    ``device_put``).  Completion splits the batched result back to the
    requests, crops per-frame fields to the original resolution, and frees
    the slots for immediate readmission — requests from different arrival
    times coexist in one grid, which is what "continuous batching" means.

Plans come from ``core/plan.py``: one frozen ``DetectionPlan`` per bucket,
resolved once (device-side ``max_edges`` autotune included).
``benchmarks/service_suite.py`` measures throughput/latency against the
naive per-frame loop and writes ``BENCH_service.json``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable, Optional, Sequence

import jax
import numpy as np

from repro.core.plan import (
    DetectionPlan, DetectionResult, PipelineConfig, load_frame,
)

# Default resolution ladder: QQVGA-ish up to the paper's camera frame.
DEFAULT_BUCKETS: tuple[tuple[int, int], ...] = (
    (120, 160), (240, 320), (480, 640),
)


@dataclasses.dataclass
class DetectionRequest:
    """One frame in, one ``DetectionResult`` out."""
    uid: int
    frame: np.ndarray                       # (H, W) or (H, W, 3)
    # filled by the service
    result: Optional[DetectionResult] = None
    bucket: Optional[tuple[int, int]] = None
    done: bool = False
    submitted_at: float = 0.0
    finished_at: float = 0.0

    @property
    def latency_s(self) -> float:
        return self.finished_at - self.submitted_at


class _BucketGrid:
    """Slot grid + staging state for one resolution bucket."""

    def __init__(self, shape: tuple[int, int], batch_size: int,
                 plan: DetectionPlan):
        self.shape = shape
        self.plan = plan
        self.slots: list[Optional[DetectionRequest]] = [None] * batch_size
        self.staged = np.zeros((batch_size, *shape), np.float32)
        # (requests snapshot, async result) awaiting completion
        self.in_flight: Optional[
            tuple[list[Optional[DetectionRequest]], DetectionResult]
        ] = None

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)

    def free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None


# Pad decay horizon (pixels): the diffused pad reaches the flat fill level
# by this depth regardless of pad size.
_PAD_TAPER = 32


def _diffuse_pad(border: np.ndarray, n: int, fill: np.float32
                 ) -> np.ndarray:
    """Continue a border line outward for ``n`` steps, diffusing as it
    fades: each step blurs the previous line ([1, 2, 1]/4) and decays it
    toward ``fill``.  The blur spreads any stroke crossing the border so
    its transverse contrast collapses within a few steps (no extruded bar
    for Hough to vote up), while the decay's along-step slope stays under
    the Canny low threshold (no edge along the taper itself).

    ``border``: (W,) the outermost content line.  Returns (n, W).
    """
    rows = np.empty((n, border.shape[0]), np.float32)
    prev = border.astype(np.float32)
    for i in range(n):
        blurred = prev.copy()
        blurred[1:-1] = (
            0.25 * prev[:-2] + 0.5 * prev[1:-1] + 0.25 * prev[2:]
        )
        k = max(0.0, 1.0 - (i + 1.0) / _PAD_TAPER)
        prev = fill + (blurred - fill) * np.float32(k)
        rows[i] = prev
    return rows


def pad_to_bucket(frame: np.ndarray, shape: tuple[int, int]) -> np.ndarray:
    """Grayscale-load ``frame`` and pad it (top-left anchored) to the
    bucket shape with a *diffusing* edge continuation: the boundary
    row/column carries on (no synthetic step at the content border) while
    blurring and fading to the frame mean.  Plain replication would
    extrude every stroke touching the border into a long axis-aligned
    bright bar — strong enough to vote up spurious near-vertical/
    horizontal lines and to inflate the peak the relative threshold
    normalizes by.  Diffusion kills the bar's transverse contrast within
    a few pixels and the fade slope stays below the Canny thresholds, so
    the pad region contributes (nearly) no edges at any pad size
    (regression-tested in ``tests/test_detection_service.py``)."""
    img = load_frame(frame)
    H, W = img.shape
    bh, bw = shape
    assert H <= bh and W <= bw, (img.shape, shape)
    if (H, W) == (bh, bw):
        return img
    fill = np.float32(img.mean())
    out = np.empty((bh, bw), np.float32)
    out[:H, :W] = img
    if bh > H:
        out[H:, :W] = _diffuse_pad(img[H - 1, :], bh - H, fill)
    if bw > W:
        # columns diffuse from the full left part (content + row pad), so
        # the corner continues both tapers consistently
        out[:, W:] = _diffuse_pad(out[:, W - 1], bw - W, fill).T
    return out


def crop_result(res: DetectionResult, height: int, width: int
                ) -> DetectionResult:
    """Un-pad one frame's result: (rho, theta) peaks are already in
    original coordinates (top-left anchoring) and ``lines`` endpoints
    parameterize the same infinite lines (out-of-frame endpoints are
    normal — the unbatched detector produces them too); raster fields
    crop to (H, W)."""
    return DetectionResult(
        res.lines, res.valid, res.peaks,
        res.edges[..., :height, :width],
        None if res.rendered is None
        else res.rendered[..., :height, :width, :],
    )


class DetectionService:
    """Request-level line detection over fixed per-bucket batch slots.

    ``submit`` enqueues requests; ``step`` admits, dispatches one bucket
    grid, and completes the previously dispatched one (double-buffering);
    ``run`` drains everything.  ``detect_many`` is the convenience loop the
    benchmarks use.
    """

    def __init__(self, cfg: PipelineConfig = PipelineConfig(), *,
                 buckets: Sequence[tuple[int, int]] = DEFAULT_BUCKETS,
                 batch_size: int = 4):
        self.cfg = cfg
        self.batch_size = batch_size
        self.buckets = tuple(sorted(buckets))
        self.grids = {
            shape: _BucketGrid(
                shape, batch_size,
                DetectionPlan.build(cfg, *shape, batch=batch_size),
            )
            for shape in self.buckets
        }
        self.queue: deque[DetectionRequest] = deque()
        self._rr = 0            # round-robin cursor over buckets
        self._warmed: set[tuple[int, int]] = set()
        self.dispatches = 0
        self.completed = 0

    # --- bucketing -----------------------------------------------------
    def bucket_for(self, frame: np.ndarray) -> tuple[int, int]:
        """Smallest registered bucket that holds ``frame``."""
        H, W = frame.shape[:2]
        for bh, bw in self.buckets:
            if H <= bh and W <= bw:
                return (bh, bw)
        raise ValueError(
            f"frame {frame.shape} exceeds every bucket {self.buckets}"
        )

    # --- request lifecycle ---------------------------------------------
    def submit(self, req: DetectionRequest) -> None:
        req.bucket = self.bucket_for(req.frame)
        req.submitted_at = time.perf_counter()
        self.queue.append(req)

    def _admit(self) -> None:
        """Fill free slots in arrival order; skip over requests whose
        bucket grid is full (they keep their queue position)."""
        blocked: list[DetectionRequest] = []
        while self.queue:
            req = self.queue.popleft()
            grid = self.grids[req.bucket]
            slot = grid.free_slot()
            if slot is None:
                blocked.append(req)
                if all(g.free_slot() is None for g in self.grids.values()):
                    break
                continue
            grid.slots[slot] = req
            grid.staged[slot] = pad_to_bucket(req.frame, grid.shape)
        self.queue.extendleft(reversed(blocked))

    def _reap(self) -> None:
        """Retire any in-flight batch whose result is already ready.

        Keeps ``latency_s`` honest (a result is delivered as soon as the
        device finishes, not when its grid next refills) without ever
        blocking — ``is_ready`` is a non-blocking poll.
        """
        for g in self.grids.values():
            if g.in_flight is None:
                continue
            lines = g.in_flight[1].lines
            if getattr(lines, "is_ready", lambda: False)():
                self._complete(g)

    def _complete(self, grid: _BucketGrid) -> None:
        """Resolve the grid's in-flight batch back onto its requests."""
        if grid.in_flight is None:
            return
        reqs, res = grid.in_flight
        grid.in_flight = None
        jax.block_until_ready(res.lines)
        now = time.perf_counter()
        for i, req in enumerate(reqs):
            if req is None:
                continue
            H, W = req.frame.shape[:2]
            req.result = crop_result(
                DetectionResult(
                    res.lines[i], res.valid[i], res.peaks[i], res.edges[i],
                    None if res.rendered is None else res.rendered[i],
                ),
                H, W,
            )
            req.done = True
            req.finished_at = now
            self.completed += 1

    def _next_grid(self, flush: bool) -> Optional[_BucketGrid]:
        """Round-robin over buckets: FULL grids first (a dispatch always
        computes ``batch_size`` frames, so partial grids waste slots), then
        — only when flushing — any occupied grid."""
        n = len(self.buckets)
        for want_full in (True, False) if flush else (True,):
            for k in range(n):
                shape = self.buckets[(self._rr + k) % n]
                grid = self.grids[shape]
                if grid.active == len(grid.slots) or (
                    not want_full and grid.active
                ):
                    self._rr = (self._rr + k + 1) % n
                    return grid
        return None

    def step(self, *, flush: bool = False) -> bool:
        """Admit -> dispatch one bucket grid -> free its slots for the next
        admission wave; completion of the *previous* dispatch on that grid
        happens just before the new one lands (one batch in flight per
        bucket).  Only full grids dispatch unless ``flush`` — partial
        batches are for draining, not steady state.  Returns True if any
        work remains."""
        self._reap()
        self._admit()
        grid = self._next_grid(flush)
        if grid is None:
            # nothing dispatchable: drain whatever is still in flight
            for g in self.grids.values():
                self._complete(g)
            return bool(self.queue) or any(
                g.active for g in self.grids.values()
            )
        reqs = list(grid.slots)
        imgs = jax.device_put(grid.staged)
        # device_put may alias (zero-copy) a numpy buffer on CPU backends:
        # hand the old buffer to the in-flight batch and stage the next
        # wave into a fresh one rather than mutating shared memory.
        grid.staged = np.zeros_like(grid.staged)
        if grid.shape in self._warmed:
            with jax.transfer_guard("disallow"):
                res = grid.plan.run(imgs)       # async dispatch of batch k
        else:
            res = grid.plan.run(imgs)           # first call compiles
            self._warmed.add(grid.shape)
        # batch k-1 retires while k computes; if the dispatch above raised,
        # it is still in_flight and a later step/run() drains it
        self._complete(grid)
        grid.in_flight = (reqs, res)
        self.dispatches += 1
        grid.slots = [None] * self.batch_size   # slots free immediately
        return True

    def run(self, max_steps: int = 10_000) -> None:
        """Drive until the queue, slots, and in-flight batches drain
        (flushing: partial grids dispatch rather than wait for traffic)."""
        while max_steps > 0:
            busy = self.step(flush=True)
            pending = any(
                g.active or g.in_flight is not None
                for g in self.grids.values()
            )
            if not busy and not pending and not self.queue:
                return
            max_steps -= 1

    # --- convenience ----------------------------------------------------
    def detect_many(self, frames: Iterable[np.ndarray]
                    ) -> list[DetectionRequest]:
        """Submit one request per frame, drain, return in submit order."""
        reqs = [DetectionRequest(uid=i, frame=np.asarray(f))
                for i, f in enumerate(frames)]
        for r in reqs:
            self.submit(r)
        self.run()
        assert all(r.done for r in reqs)
        return reqs
