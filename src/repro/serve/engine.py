"""Continuous-batching serving engine.

Slot model: a fixed grid of ``n_slots`` request slots shares one batched
cache pytree.  Admission runs a single-request prefill (bucketed lengths so
the jit cache stays warm) and scatters the resulting cache slice into the
grid; decode advances *all* active slots with one jitted step per token
(inactive slots compute garbage that is masked out — static shapes are the
price of lock-step batching, the standard trade).  Freed slots readmit from
the queue immediately: requests at different depths coexist, which is what
"continuous batching" means.
"""

from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .sampling import sample


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    # filled by the engine
    output: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


def _bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class Engine:
    def __init__(self, model, params, *, n_slots: int = 4,
                 max_len: int = 256, ring: bool = False,
                 prefill_buckets: tuple[int, ...] = (16, 32, 64, 128),
                 seed: int = 0):
        self.model = model
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.ring = ring
        self.buckets = prefill_buckets
        self.cache = model.init_cache(n_slots, max_len, ring=ring)
        self.slots: list[Optional[Request]] = [None] * n_slots
        self.pos = np.zeros(n_slots, np.int32)       # next position to write
        self.last_token = np.zeros(n_slots, np.int32)
        self.queue: deque[Request] = deque()
        self.rng = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos, ring=ring)
        )
        self._prefill = jax.jit(
            lambda p, batch, c, positions: model.prefill(
                p, batch, c, positions=positions
            )
        )
        self.steps = 0

    # --- request lifecycle -------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _free_slot(self) -> Optional[int]:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def _admit(self) -> None:
        while self.queue:
            slot = self._free_slot()
            if slot is None:
                return
            req = self.queue.popleft()
            self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request) -> None:
        """Admit one request: prefill its first n-1 tokens, then schedule the
        n-th through the shared decode step.

        Bucketed prefill pads with zeros; causal masking guarantees the pad
        region ([n-1, L)) is never attended before decode overwrites it slot
        by slot.  SSM/hybrid caches carry *recurrent* state that pads would
        corrupt, so those families prefill at exact length (one compile per
        distinct prompt length — the lock-step grid still amortizes decode).
        """
        n = len(req.prompt)
        exact = self.model.cfg.family in ("ssm", "hybrid")
        if n > 1:
            L = (n - 1) if exact else _bucket(n - 1, self.buckets)
            toks = np.zeros((1, L), np.int32)
            toks[0, : n - 1] = req.prompt[: n - 1]
            one_cache = self.model.init_cache(1, self.max_len, ring=self.ring)
            positions = jnp.arange(L, dtype=jnp.int32)[None]
            _, one_cache = self._prefill(
                self.params, {"tokens": jnp.asarray(toks)}, one_cache,
                positions,
            )
            self.cache = jax.tree.map(
                lambda big, one: jax.lax.dynamic_update_index_in_dim(
                    big, one[:, 0], slot, 1
                ),
                self.cache, one_cache,
            )
        self.slots[slot] = req
        self.pos[slot] = n - 1           # next decode consumes prompt[n-1]
        self.last_token[slot] = req.prompt[n - 1]

    # --- decode ---------------------------------------------------------
    def step(self) -> None:
        """Admit pending requests, then advance every active slot one token."""
        self._admit()
        if not any(s is not None for s in self.slots):
            return
        toks = jnp.asarray(self.last_token)
        pos = jnp.asarray(self.pos)
        logits, self.cache = self._decode(self.params, toks, self.cache, pos)
        self.rng, r = jax.random.split(self.rng)
        temps = [s.temperature if s else 0.0 for s in self.slots]
        # one sample call per distinct temperature (usually 1)
        nxt = np.asarray(sample(r, logits, temperature=temps[0]))
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            self.pos[i] += 1
            tok = int(nxt[i])
            self.last_token[i] = tok
            req.output.append(tok)
            hit_eos = req.eos_id is not None and tok == req.eos_id
            if len(req.output) >= req.max_new_tokens or hit_eos or \
                    int(self.pos[i]) >= self.max_len - 1:
                req.done = True
                self.slots[i] = None
        self.steps += 1

    def run(self, max_steps: int = 10_000) -> None:
        """Drive until queue and slots drain."""
        while (self.queue or any(self.slots)) and max_steps > 0:
            self.step()
            max_steps -= 1

    @property
    def active(self) -> int:
        return sum(s is not None for s in self.slots)
