"""Sharded detection fleet: multi-replica dispatch with session affinity.

The paper's premise is that one general-purpose core cannot meet AV
real-time requirements alone; ``DetectionService`` scaled the stack to
one device, this module scales it past one.  A
:class:`ShardedDetectionService` fronts N :class:`DetectionService`
replicas, each pinned to its own jax device (``launch.mesh`` — on this
host an 8-device CPU mesh via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) with its own
:class:`~repro.core.plan.PlanCache`, admission queues, service-time
EMAs, and session trackers:

  * **Replica-aware routing** — a sessionless request routes to the
    replica with the shortest projected completion horizon for its
    bucket (per-replica queue depth x per-replica per-bucket EMA — the
    same ``LoadController`` arithmetic each replica's admission police
    uses, so the router and the ladder agree about what "busy" means),
    ties broken by total queue depth then index.
  * **Session affinity** — sessions carry tracker state (PR 5): a
    session request pins to the replica holding its tracker, because a
    tracker split across replicas is two half-blind trackers (each sees
    every other frame, coasts constantly, and births twin tracks).
    ``affinity=False`` disables pinning (the benchmark's ablation arm);
    ``migrate_session`` moves the tracker + SLO + coast budget to
    another replica explicitly — affinity is a routing *invariant*, not
    a cage.
  * **Replica death + failover** — ``runtime.faults`` schedules
    ``kill_replica_at`` (step, replica) pairs: the dead replica's
    in-flight and slotted work fails explicitly (``FAILED`` — the
    batch died with the device), its queue re-routes to survivors with
    original deadlines preserved, and its session pins drop (the
    tracker died with it; the next frame re-pins wherever routing
    lands and rebuilds — the warm-start coast rule shortens the blind
    window).  Nothing hangs; every request still terminates.
  * **Speculative local/remote offload** (Schafhalter et al.,
    PAPERS.md; policy in ``core.offload``) — ``submit_speculative``
    races a fast low-res *local* pass (forced downshift on the local
    replica: the deadline guarantee) against a full-res *remote* pass
    on a designated replica behind a modeled network
    (``SpeculativeConfig.rtt_s`` charged on the response); the remote
    answer upgrades the local one iff it is in hand by the deadline.
    On the shared :class:`VirtualClock` the race is a pure function of
    the schedule — deterministic to test, like every other policy here.

``benchmarks/mesh_suite.py`` drives the scaling curve (1 -> 8 replicas
at equal offered load), the affinity ablation, and the offload race and
writes ``BENCH_mesh.json``.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.offload import RaceDecision, SpeculativeConfig, decide_race
from repro.core.plan import PipelineConfig
from repro.core.tracking import Track
from repro.launch.mesh import replica_devices
from repro.serve.detection import (
    SHED_ONLY, DegradationPolicy, DetectionRequest, DetectionService,
    RequestStatus, SessionSLO,
)


@dataclasses.dataclass
class _Replica:
    index: int
    service: DetectionService
    alive: bool = True


@dataclasses.dataclass
class SpeculativeTicket:
    """One speculative race in flight: the caller's request plus its two
    racing clones (resolved by ``resolve_speculative`` / ``run``)."""
    request: DetectionRequest
    local: DetectionRequest
    remote: DetectionRequest
    decision: Optional[RaceDecision] = None

    @property
    def resolved(self) -> bool:
        return self.decision is not None


class ShardedDetectionService:
    """N ``DetectionService`` replicas behind one routing front.

    Every replica keeps the full single-device contract (bounded
    admission, priority-major/EDF, degradation ladder, fault injection,
    session streaming); this class only decides *which* replica each
    request reaches — and proves the decisions (affinity, failover, the
    speculative race) deterministically on the shared clock.

    ``devices`` defaults to ``launch.mesh.replica_devices(n_replicas)``:
    one device per replica when the host has them (the
    ``--xla_force_host_platform_device_count`` mesh), cycling otherwise.
    ``faults`` here is the *router's* injector (``kill_replica_at``);
    per-replica service faults belong to the replicas' own injectors.
    """

    def __init__(self, cfg: PipelineConfig = PipelineConfig(), *,
                 n_replicas: int = 2,
                 devices: Optional[Sequence] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 affinity: bool = True,
                 speculative: Optional[SpeculativeConfig] = None,
                 remote_replica: Optional[int] = None,
                 faults: Optional[object] = None,
                 **svc_kw):
        assert n_replicas >= 1
        if devices is None:
            devices = replica_devices(n_replicas)
        assert len(devices) == n_replicas, (len(devices), n_replicas)
        self.cfg = cfg
        self.clock = clock
        self.affinity = affinity
        self.speculative = speculative
        self.remote_replica = (
            remote_replica if remote_replica is not None else n_replicas - 1
        )
        self.faults = faults
        self.replicas = [
            _Replica(i, DetectionService(
                cfg, clock=clock, device=devices[i], **svc_kw,
            ))
            for i in range(n_replicas)
        ]
        self._session_replica: dict[str, int] = {}
        self._tickets: list[SpeculativeTicket] = []
        self._steps = 0
        # routing + failover + race counters
        self.routed = 0
        self.session_migrations = 0    # saturated pins moved explicitly
        self.session_failovers = 0     # pins dropped by a replica death
        self.requeued = 0              # queued work re-routed off a corpse
        self.failed_on_death = 0       # in-flight/slotted work that died
        self.speculative_races = 0
        self.speculative_upgrades = 0

    # --- introspection --------------------------------------------------
    @property
    def alive_replicas(self) -> list[_Replica]:
        return [r for r in self.replicas if r.alive]

    @property
    def dispatches(self) -> int:
        return sum(r.service.dispatches for r in self.replicas)

    @property
    def gated_dispatches(self) -> int:
        return sum(r.service.gated_dispatches for r in self.replicas)

    def session_location(self, session_id: str) -> Optional[int]:
        """Replica index the session is pinned to (None if unpinned)."""
        return self._session_replica.get(session_id)

    def session_tracks(self, session_id: str) -> list[Track]:
        i = self._session_replica.get(session_id)
        if i is not None:
            return self.replicas[i].service.session_tracks(session_id)
        for r in self.replicas:
            ts = r.service.session_tracks(session_id)
            if ts:
                return ts
        return []

    def session_slo(self, session_id: str) -> SessionSLO:
        """Aggregated SLO across every replica the session touched
        (affinity keeps that to one; the ablation arm and failover
        don't)."""
        total = SessionSLO()
        for r in self.replicas:
            s = r.service.slo.get(session_id)
            if s is None:
                continue
            for f in dataclasses.fields(SessionSLO):
                setattr(total, f.name,
                        getattr(total, f.name) + getattr(s, f.name))
        return total

    # --- routing --------------------------------------------------------
    def _route_cost(self, rep: _Replica, shape: tuple[int, int]
                    ) -> tuple[float, int, int]:
        svc = rep.service
        grid = svc.grids[shape]
        ahead = grid.active + len(svc.queues[shape])
        horizon = svc.load_controller.horizon_s(shape, ahead)
        return (horizon, svc.queued, rep.index)

    def _route(self, req: DetectionRequest) -> int:
        """Pick a replica: affinity pin first, else the shortest
        projected completion horizon for the request's bucket."""
        alive = self.alive_replicas
        if not alive:
            raise RuntimeError("no live replicas")
        sid = req.session_id
        if sid is not None and self.affinity:
            pinned = self._session_replica.get(sid)
            if pinned is not None:
                if self.replicas[pinned].alive:
                    target = self._maybe_migrate(req, pinned)
                    return pinned if target is None else target
                # the pinned replica died: the tracker is gone, so the
                # stream re-pins wherever routing sends it (explicitly
                # accounted — a failover, not silent drift)
                del self._session_replica[sid]
                self.session_failovers += 1
        shape = alive[0].service.bucket_for(req.frame)
        best = min(alive, key=lambda r: self._route_cost(r, shape))
        if sid is not None and self.affinity:
            self._session_replica[sid] = best.index
        return best.index

    def _maybe_migrate(self, req: DetectionRequest,
                       pinned: int) -> Optional[int]:
        """Explicit migration escape hatch for a saturated pin.

        Affinity is an invariant about *where the tracker lives*, not a
        cage: when the pinned replica's measured backlog makes this
        request's deadline infeasible and another replica could still
        meet it, the SESSION moves there — tracker, SLO, coast budget —
        via :meth:`migrate_session`, so the stream stays whole on the
        new replica instead of missing deadlines on the old one.
        Returns the new replica index, or None (keep the pin).
        """
        if req.deadline_s is None:
            return None
        svc = self.replicas[pinned].service
        shape = svc.bucket_for(req.frame)
        now = self.clock()
        deadline_at = now + req.deadline_s
        grid = svc.grids[shape]
        ahead = grid.active + len(svc.queues[shape])
        if svc.load_controller.feasible(shape, deadline_at, now, ahead):
            return None
        best = min(self.alive_replicas,
                   key=lambda r: self._route_cost(r, shape))
        if best.index == pinned:
            return None
        b = best.service
        b_ahead = (b.grids[shape].active + len(b.queues[shape]))
        if not b.load_controller.feasible(shape, deadline_at, now,
                                          b_ahead):
            return None             # nowhere better: the ladder's problem
        self.migrate_session(req.session_id, best.index)
        self.session_migrations += 1
        return best.index

    def submit(self, req: DetectionRequest) -> RequestStatus:
        status = self.replicas[self._route(req)].service.submit(req)
        self.routed += 1
        return status

    def migrate_session(self, session_id: str, to_replica: int) -> bool:
        """Explicitly move a session's tracker + SLO + coast budget to
        ``to_replica`` (the sanctioned way to rebalance a pinned stream;
        returns False if the session has no state anywhere or the target
        is dead).  The tracker object moves — stream continuity (track
        ids, hit counts, the warm-start grounding) survives the hop."""
        if not self.replicas[to_replica].alive:
            return False
        src = self._session_replica.get(session_id)
        if src is None:
            src = next(
                (r.index for r in self.replicas
                 if session_id in r.service.sessions), None,
            )
        if src is None:
            return False
        if src != to_replica:
            s_svc = self.replicas[src].service
            d_svc = self.replicas[to_replica].service
            tracker = s_svc.sessions.pop(session_id, None)
            if tracker is not None:
                d_svc.sessions[session_id] = tracker
            slo = s_svc.slo.pop(session_id, None)
            if slo is not None:
                # merge, not overwrite: the target may have history from
                # a pre-affinity or failover era
                d = d_svc._slo(session_id)
                for f in dataclasses.fields(SessionSLO):
                    setattr(d, f.name,
                            getattr(d, f.name) + getattr(slo, f.name))
            coasts = s_svc._session_coasts.pop(session_id, None)
            if coasts is not None:
                d_svc._session_coasts[session_id] = coasts
        self._session_replica[session_id] = to_replica
        return True

    # --- replica death + failover ---------------------------------------
    def kill_replica(self, index: int) -> None:
        """Kill one replica: in-flight and slotted work dies with the
        device (``FAILED``), queued work re-routes to survivors with its
        original deadlines, session pins drop (trackers are gone)."""
        rep = self.replicas[index]
        if not rep.alive:
            return
        rep.alive = False
        svc = rep.service
        now = svc.clock()
        victims: list[DetectionRequest] = []
        for g in svc.grids.values():
            if g.in_flight is not None:
                victims += [r for r in g.in_flight[0] if r is not None]
                g.in_flight = None
            victims += [r for r in g.slots if r is not None]
            g.slots = [None] * len(g.slots)
            g.staged = np.zeros_like(g.staged)
        for r in victims:
            if not r.is_terminal:
                svc._refuse(r, RequestStatus.FAILED, now)
                self.failed_on_death += 1
        requeue: list[DetectionRequest] = []
        for q in svc.queues.values():
            requeue += [entry[3] for entry in q]
            q.clear()
        svc.close()
        survivors = {
            s: r for s, r in self._session_replica.items() if r != index
        }
        self.session_failovers += (
            len(self._session_replica) - len(survivors)
        )
        self._session_replica = survivors
        # re-route in arrival order (the seq was part of the heap key)
        for req in sorted(requeue, key=lambda r: r.submitted_at):
            self._resubmit(req)

    def _resubmit(self, req: DetectionRequest) -> None:
        """Re-route one queued request off a dead replica, preserving
        its original submit stamp and ABSOLUTE deadline (the failover
        must not hand it a fresh budget)."""
        sub, dl = req.submitted_at, req.deadline_at
        req._staged = None
        req._ds_shape = None
        req.downshift = 1
        req.bucket = None
        try:
            target = self._route(req)
        except RuntimeError:
            req.status = RequestStatus.FAILED
            req.finished_at = sub
            return
        svc = self.replicas[target].service
        svc.submit(req)
        req.submitted_at, req.deadline_at = sub, dl
        if req.session_id is not None:
            # submit() charged the stream a second arrival; the frame
            # was offered once — undo the double count
            svc._slo(req.session_id).submitted -= 1
        self.requeued += 1

    # --- speculative offload (local/remote race) ------------------------
    def submit_speculative(self, req: DetectionRequest
                           ) -> SpeculativeTicket:
        """Race a low-res local pass against a full-res remote pass.

        The *local* clone force-downshifts into
        ``SpeculativeConfig.local_shape`` (default: the smallest
        registered bucket) on the best non-remote replica — small enough
        that its answer always lands inside the deadline (the
        guarantee).  The *remote* clone runs full-res, shed-only (a
        degraded remote answer is pointless: the local tier already
        covers degraded) on the designated remote replica; the modeled
        network charges ``rtt_s`` on its response.  ``run`` (or an
        explicit ``resolve_speculative``) applies
        :func:`repro.core.offload.decide_race` and stamps the winner
        onto ``req``.  Clones are sessionless by construction — a
        tracker must see ONE stream, not a race's two interleaved
        copies.
        """
        if self.speculative is None:
            raise ValueError("no SpeculativeConfig on this service")
        spec = self.speculative
        alive = self.alive_replicas
        if not alive:
            raise RuntimeError("no live replicas")
        remote_rep = self.replicas[self.remote_replica]
        locals_ = [r for r in alive if r.index != self.remote_replica]
        local_rep = locals_[0] if locals_ else alive[0]
        if len(locals_) > 1:
            shape = local_rep.service.bucket_for(req.frame)
            local_rep = min(
                locals_, key=lambda r: self._route_cost(r, shape),
            )
        buckets = local_rep.service.buckets
        local_shape = spec.local_shape or buckets[0]
        local = DetectionRequest(
            uid=req.uid, frame=req.frame, deadline_s=req.deadline_s,
            priority=req.priority, render_output=req.render_output,
            policy=DegradationPolicy(allow_coast=False),
        )
        remote = DetectionRequest(
            uid=req.uid, frame=req.frame, deadline_s=req.deadline_s,
            priority=req.priority, render_output=req.render_output,
            policy=SHED_ONLY,
        )
        local_rep.service.submit(local, force_bucket=local_shape)
        if remote_rep.alive:
            remote_rep.service.submit(remote)
        else:
            remote.status = RequestStatus.FAILED
            remote.finished_at = self.clock()
        ticket = SpeculativeTicket(req, local, remote)
        self._tickets.append(ticket)
        self.speculative_races += 1
        return ticket

    def resolve_speculative(self, ticket: SpeculativeTicket
                            ) -> Optional[RaceDecision]:
        """Apply the race policy once both clones are terminal; stamps
        the winning answer onto the caller's request.  Returns None
        while either side is still pending."""
        if ticket.resolved:
            return ticket.decision
        local, remote, req = ticket.local, ticket.remote, ticket.request
        if not (local.is_terminal and remote.is_terminal):
            return None
        decision = decide_race(
            local.finished_at,
            remote.finished_at if remote.ok else None,
            local.deadline_at,
            rtt_s=self.speculative.rtt_s,
        )
        win = remote if decision.upgraded else local
        req.result = win.result
        req.status = win.status
        req.bucket = win.bucket
        req.downshift = win.downshift
        req.submitted_at = local.submitted_at
        req.deadline_at = local.deadline_at
        req.finished_at = (
            decision.remote_ready_at if decision.upgraded
            else local.finished_at
        )
        if decision.upgraded:
            self.speculative_upgrades += 1
        ticket.decision = decision
        return decision

    # --- scheduling -----------------------------------------------------
    def step(self, *, flush: bool = False) -> bool:
        """One router step: injected replica deaths fire first, then
        every live replica takes one scheduler step.  Returns True while
        any replica still has work."""
        k = self._steps
        self._steps += 1
        if self.faults is not None:
            for victim in self.faults.replicas_to_kill(k):
                self.kill_replica(victim)
        busy = False
        for rep in self.replicas:
            if rep.alive:
                busy = rep.service.step(flush=flush) or busy
        return busy

    def run(self, max_steps: int = 10_000) -> None:
        """Drive every replica until the fleet drains, then resolve any
        open speculative tickets."""
        while max_steps > 0:
            busy = self.step(flush=True)
            pending = any(
                g.active or g.in_flight is not None
                for rep in self.alive_replicas
                for g in rep.service.grids.values()
            )
            queued = any(r.service.queued for r in self.alive_replicas)
            if not busy and not pending and not queued:
                break
            max_steps -= 1
        for t in self._tickets:
            self.resolve_speculative(t)

    def close(self) -> None:
        for rep in self.replicas:
            rep.service.close()

    def __enter__(self) -> "ShardedDetectionService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
