"""Sharded detection fleet: multi-replica dispatch with session affinity.

The paper's premise is that one general-purpose core cannot meet AV
real-time requirements alone; ``DetectionService`` scaled the stack to
one device, this module scales it past one.  A
:class:`ShardedDetectionService` fronts N :class:`DetectionService`
replicas, each pinned to its own jax device (``launch.mesh`` — on this
host an 8-device CPU mesh via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``) with its own
:class:`~repro.core.plan.PlanCache`, admission queues, service-time
EMAs, and session trackers:

  * **Replica-aware routing** — a sessionless request routes to the
    replica with the shortest projected completion horizon for its
    bucket (per-replica queue depth x per-replica per-bucket EMA — the
    same ``LoadController`` arithmetic each replica's admission police
    uses, so the router and the ladder agree about what "busy" means),
    ties broken by total queue depth then index.
  * **Session affinity** — sessions carry tracker state (PR 5): a
    session request pins to the replica holding its tracker, because a
    tracker split across replicas is two half-blind trackers (each sees
    every other frame, coasts constantly, and births twin tracks).
    ``affinity=False`` disables pinning (the benchmark's ablation arm);
    ``migrate_session`` moves the tracker + SLO + coast budget to
    another replica explicitly — affinity is a routing *invariant*, not
    a cage.
  * **Replica + host death, failover** — ``runtime.faults`` schedules
    ``kill_replica_at`` (step, replica) pairs: the dead replica's
    in-flight and slotted work fails explicitly (``FAILED`` — the
    batch died with the device), its queue re-routes to survivors with
    original deadlines preserved, and its session pins drop (the
    tracker died with it; the next frame re-pins wherever routing
    lands and rebuilds — the warm-start coast rule shortens the blind
    window).  Replicas group into *host* failure domains
    (``hosts=``); ``kill_host`` / ``kill_host_at`` kill a whole group
    at once, marked dead before any teardown so no victim's backlog
    lands on a dying same-host sibling.  Nothing hangs; every request
    still terminates.
  * **Elastic scale-up** — ``add_replica`` grows the fleet at runtime:
    the newcomer joins the host mesh with a warmed service-time
    estimator and pinned sessions above the post-growth fair share
    migrate onto it via ``migrate_session`` (the scale-up dual of the
    death path; one tracker per session throughout).
  * **Speculative local/remote offload** (Schafhalter et al.,
    PAPERS.md; policy in ``core.offload``, link model in
    ``core.network``) — ``submit_speculative`` races a fast low-res
    *local* pass (forced downshift, preferring a different host than
    the remote: the deadline guarantee) against a full-res *remote*
    pass on a designated replica.  With
    ``SpeculativeConfig.network`` the link is honest: a seeded
    lognormal *uplink* delays the remote's start (lost uplink — the
    remote never runs), a seeded *downlink* delays the response (lost
    downlink — no upgrade), and a race whose remote is still pending
    at the deadline resolves to the local answer with
    ``timed_out=True``.  Without it, the PR-7 compat path charges
    ``rtt_s`` once on the response.  On the shared
    :class:`VirtualClock` the race is a pure function of
    (schedule, seed) — deterministic to test, like every policy here.

``benchmarks/mesh_suite.py`` drives the scaling curve (1 -> 8 replicas
at equal offered load), the affinity ablation, and the offload race and
writes ``BENCH_mesh.json``.
"""

from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.network import Delivery, NetworkModel, force_lost
from repro.core.offload import RaceDecision, SpeculativeConfig, decide_race
from repro.core.plan import PipelineConfig
from repro.core.tracking import Track
from repro.launch.mesh import replica_devices
from repro.serve.detection import (
    SHED_ONLY, DegradationPolicy, DetectionRequest, DetectionService,
    RequestStatus, SessionSLO,
)


@dataclasses.dataclass
class _Replica:
    index: int
    service: DetectionService
    alive: bool = True
    host: int = 0               # failure domain (host death kills the group)


@dataclasses.dataclass
class SpeculativeTicket:
    """One speculative race in flight: the caller's request plus its two
    racing clones (resolved by ``resolve_speculative`` / ``run``).

    Under the honest network (``SpeculativeConfig.network``) both legs
    are sampled at race creation — ``uplink``/``downlink`` — so the
    race's fate is fixed at submit regardless of when it resolves.  The
    remote clone is *not* submitted until the uplink lands
    (``remote_submit_at``, ``inf`` for a lost uplink — the remote pass
    then never runs and the race resolves by timeout)."""
    request: DetectionRequest
    local: DetectionRequest
    remote: DetectionRequest
    decision: Optional[RaceDecision] = None
    uplink: Optional[Delivery] = None
    downlink: Optional[Delivery] = None
    remote_submit_at: Optional[float] = None
    remote_submitted: bool = True   # compat path submits immediately
    created_at: float = 0.0
    race_idx: int = 0

    @property
    def resolved(self) -> bool:
        return self.decision is not None


class ShardedDetectionService:
    """N ``DetectionService`` replicas behind one routing front.

    Every replica keeps the full single-device contract (bounded
    admission, priority-major/EDF, degradation ladder, fault injection,
    session streaming); this class only decides *which* replica each
    request reaches — and proves the decisions (affinity, failover, the
    speculative race) deterministically on the shared clock.

    ``devices`` defaults to ``launch.mesh.replica_devices(n_replicas)``:
    one device per replica when the host has them (the
    ``--xla_force_host_platform_device_count`` mesh), cycling otherwise.
    ``faults`` here is the *router's* injector (``kill_replica_at``);
    per-replica service faults belong to the replicas' own injectors.
    """

    def __init__(self, cfg: PipelineConfig = PipelineConfig(), *,
                 n_replicas: int = 2,
                 devices: Optional[Sequence] = None,
                 clock: Callable[[], float] = time.perf_counter,
                 affinity: bool = True,
                 speculative: Optional[SpeculativeConfig] = None,
                 remote_replica: Optional[int] = None,
                 hosts: Optional[Sequence[int]] = None,
                 faults: Optional[object] = None,
                 **svc_kw):
        assert n_replicas >= 1
        if devices is None:
            devices = replica_devices(n_replicas)
        assert len(devices) == n_replicas, (len(devices), n_replicas)
        if hosts is None:
            # default: every replica its own failure domain (the PR-7
            # semantics — replica death IS host death)
            hosts = tuple(range(n_replicas))
        assert len(hosts) == n_replicas, (len(hosts), n_replicas)
        self.cfg = cfg
        self.clock = clock
        self.affinity = affinity
        self.speculative = speculative
        self.remote_replica = (
            remote_replica if remote_replica is not None else n_replicas - 1
        )
        self.faults = faults
        self._svc_kw = dict(svc_kw)
        self.network = (
            NetworkModel(speculative.network)
            if speculative is not None and speculative.network is not None
            else None
        )
        self.replicas = [
            _Replica(i, DetectionService(
                cfg, clock=clock, device=devices[i], **svc_kw,
            ), host=hosts[i])
            for i in range(n_replicas)
        ]
        self._session_replica: dict[str, int] = {}
        self._tickets: list[SpeculativeTicket] = []
        self._steps = 0
        # routing + failover + race counters
        self.routed = 0
        self.session_migrations = 0    # saturated pins moved explicitly
        self.session_failovers = 0     # pins dropped by a replica death
        self.requeued = 0              # queued work re-routed off a corpse
        self.failed_on_death = 0       # in-flight/slotted work that died
        self.speculative_races = 0
        self.speculative_upgrades = 0
        self.speculative_timeouts = 0  # races resolved with remote pending
        self.uplink_lost_total = 0
        self.downlink_lost_total = 0
        self.scale_up_migrations = 0   # sessions rebalanced by add_replica
        self.host_kills = 0

    # --- introspection --------------------------------------------------
    @property
    def alive_replicas(self) -> list[_Replica]:
        return [r for r in self.replicas if r.alive]

    @property
    def dispatches(self) -> int:
        return sum(r.service.dispatches for r in self.replicas)

    @property
    def gated_dispatches(self) -> int:
        return sum(r.service.gated_dispatches for r in self.replicas)

    def session_location(self, session_id: str) -> Optional[int]:
        """Replica index the session is pinned to (None if unpinned)."""
        return self._session_replica.get(session_id)

    def session_tracks(self, session_id: str) -> list[Track]:
        i = self._session_replica.get(session_id)
        if i is not None:
            return self.replicas[i].service.session_tracks(session_id)
        for r in self.replicas:
            ts = r.service.session_tracks(session_id)
            if ts:
                return ts
        return []

    def session_slo(self, session_id: str) -> SessionSLO:
        """Aggregated SLO across every replica the session touched
        (affinity keeps that to one; the ablation arm and failover
        don't)."""
        total = SessionSLO()
        for r in self.replicas:
            s = r.service.slo.get(session_id)
            if s is None:
                continue
            for f in dataclasses.fields(SessionSLO):
                setattr(total, f.name,
                        getattr(total, f.name) + getattr(s, f.name))
        return total

    # --- routing --------------------------------------------------------
    def _route_cost(self, rep: _Replica, shape: tuple[int, int]
                    ) -> tuple[float, int, int]:
        svc = rep.service
        grid = svc.grids[shape]
        ahead = grid.active + len(svc.queues[shape])
        horizon = svc.load_controller.horizon_s(shape, ahead)
        return (horizon, svc.queued, rep.index)

    @staticmethod
    def _busy_extra_s(rep: _Replica, shape: tuple[int, int]) -> float:
        """Seconds the device is still occupied by a batch already in
        flight — the wave arithmetic counts queued + slotted work but
        forgets the batch computing right now, which delays everything
        behind it by up to one service time."""
        svc = rep.service
        grid = svc.grids[shape]
        if grid.in_flight is None:
            return 0.0
        return svc.load_controller.est_s(shape)

    def _route(self, req: DetectionRequest) -> int:
        """Pick a replica: affinity pin first, else the shortest
        projected completion horizon for the request's bucket."""
        alive = self.alive_replicas
        if not alive:
            raise RuntimeError("no live replicas")
        sid = req.session_id
        if sid is not None and self.affinity:
            pinned = self._session_replica.get(sid)
            if pinned is not None:
                if self.replicas[pinned].alive:
                    target = self._maybe_migrate(req, pinned)
                    return pinned if target is None else target
                # the pinned replica died: the tracker is gone, so the
                # stream re-pins wherever routing sends it (explicitly
                # accounted — a failover, not silent drift)
                del self._session_replica[sid]
                self.session_failovers += 1
        shape = alive[0].service.bucket_for(req.frame)
        best = min(alive, key=lambda r: self._route_cost(r, shape))
        if sid is not None and self.affinity:
            self._session_replica[sid] = best.index
        return best.index

    def _maybe_migrate(self, req: DetectionRequest,
                       pinned: int) -> Optional[int]:
        """Explicit migration escape hatch for a saturated pin.

        Affinity is an invariant about *where the tracker lives*, not a
        cage: when the pinned replica's measured backlog makes this
        request's deadline infeasible and another replica could still
        meet it, the SESSION moves there — tracker, SLO, coast budget —
        via :meth:`migrate_session`, so the stream stays whole on the
        new replica instead of missing deadlines on the old one.
        Returns the new replica index, or None (keep the pin).
        """
        if req.deadline_s is None:
            return None
        svc = self.replicas[pinned].service
        shape = svc.bucket_for(req.frame)
        now = self.clock()
        deadline_at = now + req.deadline_s
        grid = svc.grids[shape]
        ahead = grid.active + len(svc.queues[shape])
        # the in-flight batch holds the device for up to one more
        # service time before anything queued can start: charge it
        # against the deadline on both sides of the comparison
        if svc.load_controller.feasible(
                shape,
                deadline_at - self._busy_extra_s(self.replicas[pinned],
                                                 shape),
                now, ahead):
            return None
        best = min(self.alive_replicas,
                   key=lambda r: self._route_cost(r, shape))
        if best.index == pinned:
            return None
        b = best.service
        b_ahead = (b.grids[shape].active + len(b.queues[shape]))
        if not b.load_controller.feasible(
                shape,
                deadline_at - self._busy_extra_s(best, shape),
                now, b_ahead):
            return None             # nowhere better: the ladder's problem
        self.migrate_session(req.session_id, best.index)
        self.session_migrations += 1
        return best.index

    def submit(self, req: DetectionRequest) -> RequestStatus:
        status = self.replicas[self._route(req)].service.submit(req)
        self.routed += 1
        return status

    def migrate_session(self, session_id: str, to_replica: int) -> bool:
        """Explicitly move a session's tracker + SLO + coast budget to
        ``to_replica`` (the sanctioned way to rebalance a pinned stream;
        returns False if the session has no state anywhere or the target
        is dead).  The tracker object moves — stream continuity (track
        ids, hit counts, the warm-start grounding) survives the hop."""
        if not self.replicas[to_replica].alive:
            return False
        src = self._session_replica.get(session_id)
        if src is None:
            src = next(
                (r.index for r in self.replicas
                 if session_id in r.service.sessions), None,
            )
        if src is None:
            return False
        if src != to_replica:
            s_svc = self.replicas[src].service
            d_svc = self.replicas[to_replica].service
            tracker = s_svc.sessions.pop(session_id, None)
            if tracker is not None:
                d_svc.sessions[session_id] = tracker
            slo = s_svc.slo.pop(session_id, None)
            if slo is not None:
                # merge, not overwrite: the target may have history from
                # a pre-affinity or failover era
                d = d_svc._slo(session_id)
                for f in dataclasses.fields(SessionSLO):
                    setattr(d, f.name,
                            getattr(d, f.name) + getattr(slo, f.name))
            coasts = s_svc._session_coasts.pop(session_id, None)
            if coasts is not None:
                d_svc._session_coasts[session_id] = coasts
        self._session_replica[session_id] = to_replica
        return True

    # --- replica/host death + failover ----------------------------------
    def kill_replica(self, index: int) -> None:
        """Kill one replica: in-flight and slotted work dies with the
        device (``FAILED``), queued work re-routes to survivors with its
        original deadlines, session pins drop (trackers are gone)."""
        self._kill_replicas((index,))

    def kill_host(self, host: int) -> None:
        """Kill a whole failure domain: every live replica with this
        ``host`` id dies at once.  The group is marked dead *before* any
        teardown, so no victim's queue can re-route onto a dying sibling
        on the same host — survivors on other hosts absorb the re-routed
        work with its original deadlines."""
        victims = tuple(
            r.index for r in self.replicas if r.alive and r.host == host
        )
        if not victims:
            return
        self.host_kills += 1
        self._kill_replicas(victims)

    def _kill_replicas(self, indices: Sequence[int]) -> None:
        """Shared death path: mark every victim dead FIRST (so
        ``_resubmit`` routing only sees true survivors), then tear each
        down, then re-route the merged queue backlog in arrival order."""
        dead: list[_Replica] = []
        for i in indices:
            rep = self.replicas[i]
            if rep.alive:
                rep.alive = False
                dead.append(rep)
        if not dead:
            return
        requeue: list[DetectionRequest] = []
        for rep in dead:
            requeue += self._teardown_replica(rep)
        gone = {rep.index for rep in dead}
        survivors = {
            s: r for s, r in self._session_replica.items() if r not in gone
        }
        self.session_failovers += (
            len(self._session_replica) - len(survivors)
        )
        self._session_replica = survivors
        # re-route in arrival order (the seq was part of the heap key)
        for req in sorted(requeue, key=lambda r: r.submitted_at):
            self._resubmit(req)

    def _teardown_replica(self, rep: _Replica) -> list[DetectionRequest]:
        """Fail a dead replica's in-flight/slotted work and return its
        queued backlog for re-routing (caller owns the resubmit)."""
        svc = rep.service
        now = svc.clock()
        victims: list[DetectionRequest] = []
        for g in svc.grids.values():
            if g.in_flight is not None:
                victims += [r for r in g.in_flight[0] if r is not None]
                g.in_flight = None
            victims += [r for r in g.slots if r is not None]
            g.slots = [None] * len(g.slots)
            g.staged = np.zeros_like(g.staged)
        for r in victims:
            if not r.is_terminal:
                svc._refuse(r, RequestStatus.FAILED, now)
                self.failed_on_death += 1
        requeue: list[DetectionRequest] = []
        for q in svc.queues.values():
            requeue += [entry[3] for entry in q]
            q.clear()
        svc.close()
        return requeue

    # --- elastic scale-up ------------------------------------------------
    def add_replica(self, *, device=None, host: Optional[int] = None
                    ) -> int:
        """Grow the fleet by one replica and rebalance pinned sessions
        onto it (the scale-up dual of ``kill_replica`` — until now only
        death was handled).

        The newcomer gets the next device from the host mesh and its own
        fresh failure domain by default.  Its per-bucket service-time
        estimator is warmed from a live veteran — routing is
        horizon-based, and a cold EMA would make the newcomer look
        infinitely fast and swallow the whole fleet's traffic.  Pinned
        sessions above the post-growth fair share migrate over via
        :meth:`migrate_session` (tracker + SLO + coast budget move
        atomically, counted in ``scale_up_migrations``), so the
        one-tracker-per-session invariant survives the rebalance.
        Returns the new replica's index."""
        n_new = len(self.replicas) + 1
        if device is None:
            device = replica_devices(n_new)[n_new - 1]
        if host is None:
            host = max(r.host for r in self.replicas) + 1
        svc = DetectionService(
            self.cfg, clock=self.clock, device=device, **self._svc_kw,
        )
        rep = _Replica(len(self.replicas), svc, host=host)
        donor = next((r for r in self.replicas if r.alive), None)
        if donor is not None:
            for shape, g in svc.grids.items():
                dg = donor.service.grids.get(shape)
                if dg is not None:
                    g.est_s = dg.est_s
                    g.est_measured = dg.est_measured
        self.replicas.append(rep)
        self._rebalance_onto(rep)
        return rep.index

    def _rebalance_onto(self, rep: _Replica) -> None:
        """Drain pins above the post-growth fair share into replicas
        below it, the newcomer first (deterministic: donors, sessions,
        and receivers all visit in sorted order)."""
        if not self.affinity or not self._session_replica:
            return
        alive = self.alive_replicas
        fair = math.ceil(len(self._session_replica) / len(alive))
        counts = {r.index: 0 for r in alive}
        by_rep: dict[int, list[str]] = {}
        for sid in sorted(self._session_replica):
            idx = self._session_replica[sid]
            by_rep.setdefault(idx, []).append(sid)
            counts[idx] = counts.get(idx, 0) + 1
        for idx in sorted(by_rep):
            sids = by_rep[idx]
            k = 0
            while counts[idx] > fair and k < len(sids):
                sid = sids[k]
                k += 1
                recv = min(
                    (r for r in alive if counts[r.index] < fair),
                    key=lambda r: (r.index != rep.index,
                                   counts[r.index], r.index),
                    default=None,
                )
                if recv is None:
                    return
                if self.migrate_session(sid, recv.index):
                    counts[idx] -= 1
                    counts[recv.index] += 1
                    self.scale_up_migrations += 1

    def _resubmit(self, req: DetectionRequest) -> None:
        """Re-route one queued request off a dead replica, preserving
        its original submit stamp and ABSOLUTE deadline (the failover
        must not hand it a fresh budget)."""
        sub, dl = req.submitted_at, req.deadline_at
        req._staged = None
        req._ds_shape = None
        req.downshift = 1
        req.bucket = None
        try:
            target = self._route(req)
        except RuntimeError:
            req.status = RequestStatus.FAILED
            req.finished_at = sub
            return
        svc = self.replicas[target].service
        svc.submit(req)
        req.submitted_at, req.deadline_at = sub, dl
        if req.session_id is not None:
            # submit() charged the stream a second arrival; the frame
            # was offered once — undo the double count
            svc._slo(req.session_id).submitted -= 1
        self.requeued += 1

    # --- speculative offload (local/remote race) ------------------------
    def submit_speculative(self, req: DetectionRequest
                           ) -> SpeculativeTicket:
        """Race a low-res local pass against a full-res remote pass.

        The *local* clone force-downshifts into
        ``SpeculativeConfig.local_shape`` (default: the smallest
        registered bucket) on the best non-remote replica — small enough
        that its answer always lands inside the deadline (the
        guarantee), preferring a replica on a *different host* than the
        remote so one host death cannot take both racers.  The *remote*
        clone runs full-res, shed-only (a degraded remote answer is
        pointless: the local tier already covers degraded) on the
        designated remote replica.

        With ``SpeculativeConfig.network`` set both legs are sampled
        here: the remote clone is submitted only when the uplink *lands*
        (a lost uplink means it never runs — the sender cannot observe
        the loss, so the race resolves through the deadline timeout),
        and the sampled downlink is charged on the response.  Without a
        network config (the PR-7 compat path) the remote is submitted
        immediately and ``rtt_s`` is charged once on the response.
        ``run`` (or an explicit ``resolve_speculative``) applies
        :func:`repro.core.offload.decide_race` and stamps the winner
        onto ``req``.  Clones are sessionless by construction — a
        tracker must see ONE stream, not a race's two interleaved
        copies.
        """
        if self.speculative is None:
            raise ValueError("no SpeculativeConfig on this service")
        spec = self.speculative
        alive = self.alive_replicas
        if not alive:
            raise RuntimeError("no live replicas")
        remote_rep = self.replicas[self.remote_replica]
        locals_ = [r for r in alive if r.index != self.remote_replica]
        cross_host = [r for r in locals_ if r.host != remote_rep.host]
        if cross_host:
            locals_ = cross_host
        local_rep = locals_[0] if locals_ else alive[0]
        if len(locals_) > 1:
            shape = local_rep.service.bucket_for(req.frame)
            local_rep = min(
                locals_, key=lambda r: self._route_cost(r, shape),
            )
        buckets = local_rep.service.buckets
        local_shape = spec.local_shape or buckets[0]
        local = DetectionRequest(
            uid=req.uid, frame=req.frame, deadline_s=req.deadline_s,
            priority=req.priority, render_output=req.render_output,
            policy=DegradationPolicy(allow_coast=False),
        )
        remote = DetectionRequest(
            uid=req.uid, frame=req.frame, deadline_s=req.deadline_s,
            priority=req.priority, render_output=req.render_output,
            policy=SHED_ONLY,
        )
        now = self.clock()
        race_idx = self.speculative_races
        ticket = SpeculativeTicket(req, local, remote,
                                   created_at=now, race_idx=race_idx)
        local_rep.service.submit(local, force_bucket=local_shape)
        if self.network is None:
            # PR-7 compat: free uplink, remote starts immediately
            if remote_rep.alive:
                remote_rep.service.submit(remote)
            else:
                remote.status = RequestStatus.FAILED
                remote.finished_at = now
        else:
            up, down = self.network.uplink(), self.network.downlink()
            if self.faults is not None:
                if getattr(self.faults, "loses_uplink",
                           lambda i: False)(race_idx):
                    up = force_lost(up)
                if getattr(self.faults, "loses_downlink",
                           lambda i: False)(race_idx):
                    down = force_lost(down)
            self.uplink_lost_total += up.lost
            self.downlink_lost_total += down.lost
            ticket.uplink, ticket.downlink = up, down
            ticket.remote_submit_at = up.arrives_at(now)
            ticket.remote_submitted = False
            if ticket.remote_submit_at <= now:
                self._submit_remote(ticket)
        self._tickets.append(ticket)
        self.speculative_races += 1
        return ticket

    def _submit_remote(self, ticket: SpeculativeTicket) -> None:
        """The uplink landed: submit the remote clone (or fail it if the
        remote replica died while the request was in flight).  The clone
        keeps the race's ORIGINAL absolute deadline — the uplink delay
        must not hand the remote pass a fresh budget."""
        ticket.remote_submitted = True
        rep = self.replicas[self.remote_replica]
        if not rep.alive:
            ticket.remote.status = RequestStatus.FAILED
            ticket.remote.finished_at = self.clock()
            return
        rep.service.submit(ticket.remote)
        if ticket.local.deadline_at is not None:
            ticket.remote.deadline_at = ticket.local.deadline_at

    def _pump_speculative(self) -> None:
        """Submit every deferred remote clone whose uplink has landed
        (no-op on the compat path — remotes submit at race creation)."""
        if self.network is None:
            return
        now = self.clock()
        for t in self._tickets:
            if (not t.resolved and not t.remote_submitted
                    and t.remote_submit_at is not None
                    and t.remote_submit_at <= now):
                self._submit_remote(t)

    def _race_timeout_at(self, ticket: SpeculativeTicket
                         ) -> Optional[float]:
        """When this race gives up on a still-pending remote: the
        request's own absolute deadline (past it the remote cannot win
        anyway), else ``created_at + race_timeout_s`` for deadline-less
        races, else None (no timeout configured)."""
        if ticket.local.deadline_at is not None:
            return ticket.local.deadline_at
        if self.speculative.race_timeout_s is not None:
            return ticket.created_at + self.speculative.race_timeout_s
        return None

    def resolve_speculative(self, ticket: SpeculativeTicket
                            ) -> Optional[RaceDecision]:
        """Apply the race policy and stamp the winning answer onto the
        caller's request.  Resolves when both clones are terminal — or,
        with the remote still pending (never submitted, lost response,
        stalled dispatch), once the race's timeout passes: the local
        answer then wins with ``timed_out=True`` (the unresolvable-race
        fix — a dead network must never leave the caller without the
        answer the local tier guaranteed).  Returns None while the race
        is genuinely still open."""
        if ticket.resolved:
            return ticket.decision
        self._pump_speculative()
        local, remote, req = ticket.local, ticket.remote, ticket.request
        if not local.is_terminal:
            return None
        remote_pending = not (ticket.remote_submitted
                              and remote.is_terminal)
        if remote_pending:
            timeout_at = self._race_timeout_at(ticket)
            if timeout_at is None or self.clock() < timeout_at:
                return None
            decision = decide_race(
                local.finished_at, None, local.deadline_at,
                rtt_s=self.speculative.rtt_s, timed_out=True,
            )
            self.speculative_timeouts += 1
        else:
            downlink_s = None
            if ticket.downlink is not None:
                downlink_s = (math.inf if ticket.downlink.lost
                              else ticket.downlink.delay_s)
            decision = decide_race(
                local.finished_at,
                remote.finished_at if remote.ok else None,
                local.deadline_at,
                rtt_s=self.speculative.rtt_s,
                downlink_s=downlink_s,
            )
        win = remote if decision.upgraded else local
        req.result = win.result
        req.status = win.status
        req.bucket = win.bucket
        req.downshift = win.downshift
        req.submitted_at = local.submitted_at
        req.deadline_at = local.deadline_at
        req.finished_at = (
            decision.remote_ready_at if decision.upgraded
            else local.finished_at
        )
        if decision.upgraded:
            self.speculative_upgrades += 1
        ticket.decision = decision
        return decision

    # --- scheduling -----------------------------------------------------
    def step(self, *, flush: bool = False) -> bool:
        """One router step: injected replica/host deaths fire first,
        then deferred speculative remotes whose uplink has landed are
        submitted, then every live replica takes one scheduler step.
        Returns True while any replica still has work."""
        k = self._steps
        self._steps += 1
        if self.faults is not None:
            for victim in self.faults.replicas_to_kill(k):
                self.kill_replica(victim)
            hosts = getattr(self.faults, "hosts_to_kill", None)
            if hosts is not None:
                for host in hosts(k):
                    self.kill_host(host)
        self._pump_speculative()
        busy = False
        for rep in self.replicas:
            if rep.alive:
                busy = rep.service.step(flush=flush) or busy
        return busy

    def _drain(self, max_steps: int) -> None:
        while max_steps > 0:
            busy = self.step(flush=True)
            pending = any(
                g.active or g.in_flight is not None
                for rep in self.alive_replicas
                for g in rep.service.grids.values()
            )
            queued = any(r.service.queued for r in self.alive_replicas)
            if not busy and not pending and not queued:
                break
            max_steps -= 1

    def run(self, max_steps: int = 10_000) -> None:
        """Drive every replica until the fleet drains, then resolve the
        speculative tickets.  A ticket that cannot resolve yet because
        its clock hasn't reached a known event — a deferred remote's
        uplink arrival, a race's timeout — advances a jumpable clock
        (``VirtualClock.jump_to``) to the next such event and re-drains,
        so every race with a timeout resolves; only a deadline-less race
        with no ``race_timeout_s`` and a dead remote leg stays open
        (there is nothing to wait for — the config opted out)."""
        guard = 4 * len(self._tickets) + 4
        while True:
            self._drain(max_steps)
            for t in self._tickets:
                self.resolve_speculative(t)
            open_ = [t for t in self._tickets if not t.resolved]
            jump = getattr(self.clock, "jump_to", None)
            if not open_ or jump is None or guard <= 0:
                break
            now = self.clock()
            events = []
            for t in open_:
                if (not t.remote_submitted
                        and t.remote_submit_at is not None
                        and math.isfinite(t.remote_submit_at)):
                    events.append(t.remote_submit_at)
                timeout_at = self._race_timeout_at(t)
                if timeout_at is not None and math.isfinite(timeout_at):
                    events.append(timeout_at)
            events = [e for e in events if e > now]
            if not events:
                break
            jump(min(events))
            guard -= 1

    def close(self) -> None:
        for rep in self.replicas:
            rep.service.close()

    def __enter__(self) -> "ShardedDetectionService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
