"""Serving substrate: continuous batching for tokens AND frames.

``Engine`` implements continuous batching over a fixed slot grid for LM
traffic: requests are admitted into free slots (prefill), all active slots
decode in lock-step (one jitted ``decode_step`` for the whole grid), and
finished requests free their slots immediately.  Caches are linear, ring
(SWA long-context), or SSM-state depending on the architecture — the engine
is cache-layout agnostic because the model owns its cache pytree.

``DetectionService`` applies the same slot/bucket design to the paper's
line-detection stack (``serve/detection.py``): mixed-resolution frame
requests pad to resolution buckets, fill fixed batch slots, and drain
double-buffered through resolve-once ``DetectionPlan``s (``core/plan.py``).
"""

from .detection import (  # noqa: F401
    DetectionRequest,
    DetectionService,
    crop_result,
    pad_to_bucket,
)
from .engine import Engine, Request  # noqa: F401
from .sampling import sample  # noqa: F401
