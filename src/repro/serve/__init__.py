"""Serving substrate: continuous batching for tokens AND frames.

``Engine`` implements continuous batching over a fixed slot grid for LM
traffic: requests are admitted into free slots (prefill), all active slots
decode in lock-step (one jitted ``decode_step`` for the whole grid), and
finished requests free their slots immediately.  Caches are linear, ring
(SWA long-context), or SSM-state depending on the architecture — the engine
is cache-layout agnostic because the model owns its cache pytree.

``DetectionService`` applies the same slot/bucket design to the paper's
line-detection stack (``serve/detection.py``) and adds the QoS layer an AV
control loop needs: mixed-resolution frame requests pad to resolution
buckets and fill fixed batch slots; a bounded admission queue applies
backpressure (``RequestStatus.QUEUE_FULL`` / ``DEADLINE_EXCEEDED`` instead
of silent tail latency); requests with ``deadline_s`` schedule earliest-
deadline-first with early batch close, falling back to full-grid-first
throughput mode when no deadlines are set; host staging runs ahead on a
``PrefetchStager`` worker thread; and every timing decision reads an
injectable clock (``VirtualClock`` makes the whole policy deterministic
under test).  Results drain double-buffered through resolve-once
``DetectionPlan``s (``core/plan.py``), cropped back bit-exact — including
the per-request ``render_output`` overlay.

Under overload the service walks a *degradation ladder* instead of
shedding outright (resolution downshift -> tracker-coast answers ->
priority-tiered shed; see the ``serve/detection.py`` docstring), driven
by a ``LoadController`` and per-request ``DegradationPolicy``, with
per-session ``SessionSLO`` accounting; a deterministic
``runtime.faults.ServiceFaultInjector`` exercises the failure paths.
"""

from .detection import (  # noqa: F401
    DEFAULT_POLICY,
    SHED_ONLY,
    BucketLoad,
    DegradationPolicy,
    DetectionRequest,
    DetectionService,
    LoadController,
    PrefetchStager,
    RequestStatus,
    SessionSLO,
    VirtualClock,
    crop_result,
    pad_to_bucket,
    upscale_result,
)
from .engine import Engine, Request  # noqa: F401
from .sampling import sample  # noqa: F401
