"""Serving substrate: batched prefill/decode engine with slot scheduling.

``Engine`` implements continuous batching over a fixed slot grid: requests
are admitted into free slots (prefill), all active slots decode in lock-step
(one jitted ``decode_step`` for the whole grid), and finished requests free
their slots immediately.  Caches are linear, ring (SWA long-context), or
SSM-state depending on the architecture — the engine is cache-layout
agnostic because the model owns its cache pytree.
"""

from .engine import Engine, Request  # noqa: F401
from .sampling import sample  # noqa: F401
