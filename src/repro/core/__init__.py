"""The paper's primary contribution, as composable JAX modules.

Line detection for autonomous vehicles: Canny (conv-as-GEMM, MXU) ->
Hough transform (GEMM + histogram voting) -> get-lines-coordinates, with
the paper's float->int rewrite, phase profiling, and heterogeneous
placement planning as first-class features.
"""

from .canny import (  # noqa: F401
    GAUSS_5x5, SOBEL_X, SOBEL_Y, CannyConfig, canny, estimate_edge_count,
    estimate_edge_count_device,
)
from .hough import (  # noqa: F401
    HoughConfig, auto_max_edges, hough_paper_loop, hough_transform,
    hough_transform_tiered, max_edge_tiers, resolve_max_edges, rho_bins,
)
from .lines import (  # noqa: F401
    LinesConfig, get_lines, peak_segments, render_lines,
)
from .plan import (  # noqa: F401
    DetectionPlan, PlanCache, batch_bucket, load_frame, resolve_static,
)
from .metrics import (  # noqa: F401
    DetectionScore, aggregate_scores, match_peaks, score_batch, score_frame,
)
from .geometry import (  # noqa: F401
    DEFAULT_CAMERA, CameraConfig, CameraGeometry, canonical_rho_theta,
)
from .control import (  # noqa: F401
    ControlConfig, LateralController, SteeringCommand, Waypoints,
    extract_waypoints, ground_boundaries,
)
from .network import (  # noqa: F401
    Delivery, NetworkConfig, NetworkModel, expected_rtt_s, force_lost,
)
from .offload import Placement, place, plan, plan_line_detection  # noqa: F401
from .tracking import (  # noqa: F401
    LaneTracker, Track, TrackedFrame, TrackerConfig, TrackingPipeline,
    merge_peaks, signed_residual, tracks_as_peaks, wrap_canonical,
)
from .pipeline import DetectionResult, LineDetector, PipelineConfig  # noqa: F401
from .profiling import PhaseProfiler, StageCost, line_detection_costs  # noqa: F401
from .quantize import Quantized, dequantize, quantize, quantized_matmul  # noqa: F401
