"""Float -> integer rewrites (paper Section 4.4).

The paper replaces the pipeline's floats with integers "without any loss in
accuracy", matching Gemmini's int8 array + wide accumulator.  The same
machinery serves four places in this framework:

  * the low-precision gradient tier of the detection stack
    (``CannyConfig(grad_dtype="int8")`` -> :func:`quantize_frames`, the
    per-frame entry point the ``DetectionPlan`` pipeline lowers through),
  * the integer Canny/Hough path (``CannyConfig(integer=True)``),
  * int8 GEMM operands for ``tiled_matmul`` (MXU int8 path),
  * int8 error-feedback gradient compression (``train/compression.py``) for
    the slow cross-pod reductions.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Quantized(NamedTuple):
    values: jax.Array   # int8 (or int16/int32 for wider modes)
    scale: jax.Array    # f32 scalar (per-tensor) or vector (per-axis)


def quantize(x: jax.Array, *, bits: int = 8, axis=None) -> Quantized:
    """Symmetric linear quantization. axis=None => per-tensor scale."""
    qmax = 2 ** (bits - 1) - 1
    amax = (
        jnp.max(jnp.abs(x))
        if axis is None
        else jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    )
    scale = jnp.maximum(amax, 1e-12) / qmax
    dtype = {8: jnp.int8, 16: jnp.int16, 32: jnp.int32}[bits]
    q = jnp.clip(jnp.round(x / scale), -qmax - 1, qmax).astype(dtype)
    return Quantized(q, scale.astype(jnp.float32))


def dequantize(q: Quantized) -> jax.Array:
    return q.values.astype(jnp.float32) * q.scale


def quantize_frames(images: jax.Array, *, bits: int = 8) -> Quantized:
    """Per-frame symmetric quantization of an ``(..., H, W)`` frame stack.

    The detection-stack entry point (this module predates ``DetectionPlan``
    and used to offer only per-tensor scales): one scale per frame
    (``axis=(-2, -1)``, keepdims so it broadcasts straight back over the
    frame), so a dark frame batched with a bright one keeps its own dynamic
    range instead of inheriting the batch max.  Traced-safe — the plan
    pipeline calls it under jit.
    """
    return quantize(jnp.asarray(images, jnp.float32), bits=bits,
                    axis=(-2, -1))


def quantize_weights_int8(params, *, compute_dtype=jnp.bfloat16):
    """Weight-only int8 quantization of a parameter pytree (serving).

    The paper's float->int rewrite applied to inference weight traffic:
    every floating leaf becomes (int8 values, per-output-channel f32 scale);
    ``dequantize_weights`` restores compute-dtype weights on the fly (the
    convert+scale fuses into the consuming GEMM on TPU, so HBM reads are
    the int8 bytes).  Integer leaves pass through untouched.
    Returns ({"q": int8 tree, "s": scale tree}, dequant_fn).
    """
    def q_leaf(p):
        if not jnp.issubdtype(p.dtype, jnp.floating):
            return p, jnp.ones((), jnp.float32)
        axis = tuple(range(p.ndim - 1)) if p.ndim > 1 else None
        qq = quantize(p.astype(jnp.float32), axis=axis)
        return qq.values, qq.scale

    flat, treedef = jax.tree.flatten(params)
    qs = [q_leaf(p) for p in flat]
    q_tree = jax.tree.unflatten(treedef, [q for q, _ in qs])
    s_tree = jax.tree.unflatten(treedef, [s for _, s in qs])

    def dequant(qtree, stree):
        def d_leaf(q, s):
            if not jnp.issubdtype(q.dtype, jnp.signedinteger):
                return q
            return (q.astype(jnp.float32) * s).astype(compute_dtype)
        return jax.tree.map(d_leaf, qtree, stree)

    return {"q": q_tree, "s": s_tree}, dequant


def quantized_matmul(x: jax.Array, y: jax.Array, *, impl=None) -> jax.Array:
    """f32 matmul computed through the int8 MXU path (Gemmini-style):
    quantize both operands per-tensor, int8 GEMM with int32 accumulation,
    rescale.  Accuracy is the paper's claim; tests bound the error."""
    from repro.kernels import ops

    qx, qy = quantize(x), quantize(y)
    acc = ops.tiled_matmul(qx.values, qy.values, impl=impl)
    return acc.astype(jnp.float32) * (qx.scale * qy.scale)
