"""Hough transform (paper Section 4.2 / Algorithm 2) in GEMM + histogram form.

The paper keeps this stage on the scalar core: its voting loop is a chain of
data-dependent read-modify-writes (CPI > 3 on both Rocket and BOOM, Table 6)
and Gemmini buys it nothing (Table 7).  The TPU adaptation dissolves the
dependency — see ``kernels/hough_vote.py``.  This module provides:

  * ``hough_transform``   — the accelerated path: homogeneous-coordinate rho
    GEMM + blockwise one-hot vote accumulation.
  * ``hough_paper_loop``  — a faithful scalar-form reference implementing
    Algorithm 2's per-pixel/per-theta loop nest (``lax`` loops, one pixel at
    a time).  This is the measured "no-accelerator baseline" in the
    benchmarks, the analogue of the paper's Rocket/BOOM-only runs.

``hough_transform`` accepts batches (N, H, W) — one batched vote kernel —
and ``HoughConfig(compact=True, max_edges=...)`` enables the edge-compaction
pre-pass (vote over <=max_edges compacted edge pixels instead of H*W; exact
same accumulator as long as the buffer isn't exceeded).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class HoughConfig:
    n_theta: int = 180          # 1-degree bins, theta in [0, 180)
    rho_res: float = 1.0        # rho bin width (pixels)
    edge_threshold: float = 250.0  # paper: image[i*width+j] >= 250
    impl: str | None = None
    # Edge-compaction fast path: prefix-sum-scatter the (typically <5%)
    # edge pixels into a static buffer so the vote stage iterates
    # ``max_edges`` pixels instead of H*W.  ``max_edges=None`` defers to
    # the dispatch default in ``kernels.ops.hough_vote`` (~H*W/16); edges
    # beyond the buffer are dropped, so leave compaction off when exact
    # parity on pathologically dense edge maps matters.
    compact: bool = False
    max_edges: int | None = None


def rho_bins(height: int, width: int, cfg: HoughConfig) -> int:
    diag = math.hypot(height, width)
    return int(2.0 * diag / cfg.rho_res) + 1


@functools.partial(
    jax.jit, static_argnames=("cfg",)
)
def hough_transform(edges: jax.Array, cfg: HoughConfig = HoughConfig()
                    ) -> jax.Array:
    """Vote accumulator (..., n_rho, n_theta) from an edge map (..., H, W).

    rho = j*cos(theta) + i*sin(theta)  (paper's convention: x=col, y=row),
    shifted by +rho_max and binned at cfg.rho_res.  The shift and the
    resolution are folded into a homogeneous third coordinate so the whole
    stage is literally one GEMM + histogram.  A batch of edge maps
    (N, H, W) shares one raster coordinate table and lowers as one batched
    vote; ``cfg.compact`` routes through the edge-compaction pre-pass.
    """
    H, W = edges.shape[-2:]
    n_rho = rho_bins(H, W, cfg)
    diag = math.hypot(H, W)

    theta = np.arange(cfg.n_theta, dtype=np.float32) * (
        math.pi / cfg.n_theta
    )
    trig = np.stack(
        [
            np.cos(theta) / cfg.rho_res,
            np.sin(theta) / cfg.rho_res,
            np.full_like(theta, diag / cfg.rho_res),
        ]
    ).astype(np.float32)

    jj, ii = jnp.meshgrid(jnp.arange(W), jnp.arange(H))
    xy = jnp.stack(
        [jj.ravel(), ii.ravel(), jnp.ones(H * W, jnp.int32)], axis=1
    ).astype(jnp.float32)
    flat = edges.reshape(edges.shape[:-2] + (H * W,))
    weights = (flat >= cfg.edge_threshold).astype(jnp.float32)

    return ops.hough_vote(
        xy, weights, jnp.asarray(trig), n_rho=n_rho, impl=cfg.impl,
        compact=cfg.compact, max_edges=cfg.max_edges,
    )


def hough_paper_loop(edges: jax.Array, cfg: HoughConfig = HoughConfig()
                     ) -> jax.Array:
    """Paper Algorithm 2, faithfully serial: for each edge point, for each
    theta, ``accumulators[(rho + c_rho)*n_theta + theta]++``.

    Implemented as a ``lax.fori_loop`` over pixels with a vectorized inner
    theta sweep — the closest a data-parallel host gets to the scalar-core
    loop while staying jittable.  Used as the measured baseline for the
    Table 7 speedup analogue.
    """
    H, W = edges.shape
    n_rho = rho_bins(H, W, cfg)
    diag = math.hypot(H, W)
    theta = jnp.arange(cfg.n_theta, dtype=jnp.float32) * (
        math.pi / cfg.n_theta
    )
    cos_t, sin_t = jnp.cos(theta), jnp.sin(theta)
    flat = edges.ravel().astype(jnp.float32)

    def body(p, acc):
        i = p // W
        j = p % W
        rho = j * cos_t + i * sin_t + diag
        idx = jnp.floor(rho / cfg.rho_res).astype(jnp.int32)
        w = jnp.where(flat[p] >= cfg.edge_threshold, 1.0, 0.0)
        return acc.at[idx, jnp.arange(cfg.n_theta)].add(w)

    acc0 = jnp.zeros((n_rho, cfg.n_theta), jnp.float32)
    return jax.lax.fori_loop(0, H * W, body, acc0)
