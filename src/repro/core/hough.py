"""Hough transform (paper Section 4.2 / Algorithm 2) in GEMM + histogram form.

The paper keeps this stage on the scalar core: its voting loop is a chain of
data-dependent read-modify-writes (CPI > 3 on both Rocket and BOOM, Table 6)
and Gemmini buys it nothing (Table 7).  The TPU adaptation dissolves the
dependency — see ``kernels/hough_vote.py``.  This module provides:

  * ``hough_transform``   — the accelerated path: homogeneous-coordinate rho
    GEMM + blockwise one-hot vote accumulation.
  * ``hough_paper_loop``  — a faithful scalar-form reference implementing
    Algorithm 2's per-pixel/per-theta loop nest (``lax`` loops, one pixel at
    a time).  This is the measured "no-accelerator baseline" in the
    benchmarks, the analogue of the paper's Rocket/BOOM-only runs.

``hough_transform`` accepts batches (N, H, W) — one batched vote kernel —
and ``HoughConfig(compact=True, max_edges=...)`` enables the edge-compaction
pre-pass (vote over <=max_edges compacted edge pixels instead of H*W; exact
same accumulator as long as the buffer isn't exceeded).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class HoughConfig:
    n_theta: int = 180          # 1-degree bins, theta in [0, 180)
    rho_res: float = 1.0        # rho bin width (pixels)
    edge_threshold: float = 250.0  # paper: image[i*width+j] >= 250
    impl: str | None = None
    # Edge-compaction fast path: prefix-sum-scatter the (typically <5%)
    # edge pixels into a static buffer so the vote stage iterates
    # ``max_edges`` pixels instead of H*W.  ``max_edges=None`` defers to
    # the dispatch default in ``kernels.ops.hough_vote`` (~H*W/16); edges
    # beyond the buffer are dropped, so leave compaction off when exact
    # parity on pathologically dense edge maps matters.  ``max_edges="auto"``
    # sizes the buffer from the workload itself: the plan path counts the
    # edge map ON DEVICE and ``lax.switch``-es over the static tier set
    # (``hough_transform_tiered`` — zero host syncs, jit-safe); the eager
    # ``hough_transform`` counts the concrete edge map and the legacy
    # resolver estimates from a downsampled gradient pass
    # (``canny.estimate_edge_count``) — all land on a tier via
    # ``auto_max_edges`` that never exceeds the dense default.
    compact: bool = False
    max_edges: int | str | None = None
    # Prediction-gated voting (core/tracking.py): when set, the vote stage
    # sweeps only ``theta_band`` theta bins — a runtime int32 vector of bin
    # indices (the tracker's union of windows around predicted lanes,
    # padded to this static length) gathers the trig columns, and the band
    # scatters back into the full-width accumulator (zeros outside the
    # gate) so get_lines and every consumer keep full-sweep indexing.  The
    # *length* is static (a plan attribute — one compiled program per
    # band), the bin values are data (the gate slides every frame without
    # recompiling).  None = full sweep.
    theta_band: int | None = None


def rho_bins(height: int, width: int, cfg: HoughConfig) -> int:
    diag = math.hypot(height, width)
    return int(2.0 * diag / cfg.rho_res) + 1


def max_edge_tiers(height: int, width: int, *, base: int = 512
                   ) -> tuple[int, ...]:
    """The static set of compaction-buffer sizes for one resolution.

    Geometric tiers ``base, 2*base, 4*base, ...`` capped at (and always
    including) the dense-dispatch default (``kernels.ops.default_max_edges``)
    — a small finite set, so everything keyed on a resolved ``max_edges``
    (jit cache entries, the tiered ``lax.switch`` in the plan path) stays
    bounded no matter how edge density drifts across a stream.
    """
    cap = ops.default_max_edges(height * width)
    tiers = []
    t = base
    while t < cap:
        tiers.append(t)
        t *= 2
    tiers.append(cap)
    return tuple(tiers)


def auto_max_edges(n_edges: int, height: int, width: int, *,
                   base: int = 512) -> int:
    """Tiered compaction-buffer size for an (estimated) edge count.

    Snaps up to the smallest tier in ``max_edge_tiers`` that holds
    ``n_edges``, so nearby workloads share one jit cache entry, and caps at
    the dense-dispatch default — an autotuned buffer is never larger than
    the hand-tuned one, and past the cap both drop exactly the same
    trailing edges.
    """
    for t in max_edge_tiers(height, width, base=base):
        if int(n_edges) <= t:
            return t
    return max_edge_tiers(height, width, base=base)[-1]


def resolved_auto_config(cfg: HoughConfig, n_edges: int, height: int,
                         width: int) -> HoughConfig:
    """Shared tail of ``max_edges="auto"`` resolution: the dense path
    neutralizes the knob (it is inert there, and a stable value keeps jit
    cache keys shared), the compacted path gets the bucketed buffer."""
    if not cfg.compact:
        return dataclasses.replace(cfg, max_edges=None)
    return dataclasses.replace(
        cfg, max_edges=auto_max_edges(n_edges, height, width)
    )


def resolve_max_edges(edges, cfg: HoughConfig) -> HoughConfig:
    """Resolve ``max_edges="auto"`` against a *concrete* edge map.

    The compacted vote buffer is a static shape, so "auto" must become an
    int before tracing; here the edge map is already computed, so the exact
    per-frame count (max over a batch) feeds ``auto_max_edges``.  The
    pipeline resolves earlier — from the raw image, via the downsampled
    gradient estimate in ``canny.estimate_edge_count`` — because under its
    jit the edge map is a tracer.
    """
    if cfg.max_edges != "auto":
        return cfg
    H, W = edges.shape[-2:]
    if not cfg.compact:  # knob inert on the dense path; no count needed
        return resolved_auto_config(cfg, 0, H, W)
    if isinstance(edges, jax.core.Tracer):
        raise ValueError(
            "HoughConfig(max_edges='auto') needs a concrete edge map to "
            "size the compaction buffer; resolve via "
            "LineDetector/resolve_max_edges before jit."
        )
    counts = np.asarray(edges >= cfg.edge_threshold).sum(axis=(-2, -1))
    n = int(counts.max()) if getattr(counts, "ndim", 0) else int(counts)
    return resolved_auto_config(cfg, n, H, W)


def hough_transform(edges: jax.Array, cfg: HoughConfig = HoughConfig(),
                    theta_bins: jax.Array | None = None, *,
                    scatter: bool = True) -> jax.Array:
    """Vote accumulator (..., n_rho, n_theta) from an edge map (..., H, W).

    Thin wrapper resolving ``max_edges="auto"`` (a data-dependent static
    shape) before entering the jitted body below.  ``theta_bins`` carries
    the prediction gate when ``cfg.theta_band`` is set (see
    :class:`HoughConfig`); ``scatter=False`` then keeps the accumulator in
    band space, (..., n_rho, theta_band) — the plan path feeds that
    straight into ``get_lines(theta_bins=...)`` so the whole peak stage
    scales with the band.
    """
    if cfg.max_edges == "auto":
        cfg = resolve_max_edges(edges, cfg)
    return _hough_transform(edges, cfg, theta_bins, scatter=scatter)


def hough_transform_tiered(edges: jax.Array, cfg: HoughConfig,
                           tiers: tuple[int, ...] | None = None,
                           theta_bins: jax.Array | None = None, *,
                           scatter: bool = True) -> jax.Array:
    """Device-side ``max_edges`` autotune: trace-safe tiered dispatch.

    The compaction buffer is a static shape, so a *traced* edge map cannot
    pick an arbitrary size — but it can pick from a small static set.  The
    exact per-frame edge count (a cheap device reduction; max over a batch)
    selects the smallest tier in ``max_edge_tiers`` that holds every edge,
    and ``lax.switch`` runs the one branch compiled for that tier.  No
    host round-trip anywhere: this is how the plan layer (``core/plan.py``)
    keeps ``max_edges="auto"`` streams free of per-chunk syncs.

    Bit-exact with the dense path whenever the chosen tier drops no edges
    (the count is exact, so only the cap tier can drop any — the same
    trailing edges the hand-tuned dense default drops).  The jit cache
    stays finite: one compiled program per (shape, cfg), holding
    ``len(tiers)`` vote variants.
    """
    if not cfg.compact:
        return _hough_transform(
            edges, dataclasses.replace(cfg, max_edges=None), theta_bins,
            scatter=scatter,
        )
    H, W = edges.shape[-2:]
    if tiers is None:
        tiers = max_edge_tiers(H, W)
    counts = (edges >= cfg.edge_threshold).sum(axis=(-2, -1))
    worst = counts.max().astype(jnp.int32)
    idx = jnp.minimum(
        sum((worst > t).astype(jnp.int32) for t in tiers),
        len(tiers) - 1,
    )
    cfgs = [dataclasses.replace(cfg, max_edges=int(t)) for t in tiers]
    if theta_bins is None:
        branches = [
            functools.partial(_hough_transform, cfg=c) for c in cfgs
        ]
        return jax.lax.switch(idx, branches, edges)
    branches = [
        functools.partial(
            lambda e, tb, cfg: _hough_transform(e, cfg, tb,
                                                scatter=scatter),
            cfg=c,
        )
        for c in cfgs
    ]
    return jax.lax.switch(idx, branches, edges, theta_bins)


@functools.partial(
    jax.jit, static_argnames=("cfg", "scatter")
)
def _hough_transform(edges: jax.Array, cfg: HoughConfig = HoughConfig(),
                     theta_bins: jax.Array | None = None, *,
                     scatter: bool = True) -> jax.Array:
    """Vote accumulator (..., n_rho, n_theta) from an edge map (..., H, W).

    rho = j*cos(theta) + i*sin(theta)  (paper's convention: x=col, y=row),
    shifted by +rho_max and binned at cfg.rho_res.  The shift and the
    resolution are folded into a homogeneous third coordinate so the whole
    stage is literally one GEMM + histogram.  A batch of edge maps
    (N, H, W) shares one raster coordinate table and lowers as one batched
    vote; ``cfg.compact`` routes through the edge-compaction pre-pass;
    ``cfg.theta_band``/``theta_bins`` restrict the sweep to the prediction
    gate (the accumulator stays full width, zero outside the gate).
    """
    if (theta_bins is None) != (cfg.theta_band is None):
        raise ValueError(
            "HoughConfig.theta_band and the theta_bins argument come as a "
            f"pair (got theta_band={cfg.theta_band!r}, "
            f"theta_bins={'set' if theta_bins is not None else None!r})."
        )
    if theta_bins is not None and theta_bins.shape != (cfg.theta_band,):
        raise ValueError(
            f"theta_bins must have the plan's static band shape "
            f"({cfg.theta_band},); got {theta_bins.shape}."
        )
    H, W = edges.shape[-2:]
    n_rho = rho_bins(H, W, cfg)
    diag = math.hypot(H, W)

    theta = np.arange(cfg.n_theta, dtype=np.float32) * (
        math.pi / cfg.n_theta
    )
    trig = np.stack(
        [
            np.cos(theta) / cfg.rho_res,
            np.sin(theta) / cfg.rho_res,
            np.full_like(theta, diag / cfg.rho_res),
        ]
    ).astype(np.float32)

    jj, ii = jnp.meshgrid(jnp.arange(W), jnp.arange(H))
    xy = jnp.stack(
        [jj.ravel(), ii.ravel(), jnp.ones(H * W, jnp.int32)], axis=1
    ).astype(jnp.float32)
    flat = edges.reshape(edges.shape[:-2] + (H * W,))
    weights = (flat >= cfg.edge_threshold).astype(jnp.float32)

    return ops.hough_vote(
        xy, weights, jnp.asarray(trig), n_rho=n_rho, impl=cfg.impl,
        compact=cfg.compact, max_edges=cfg.max_edges,
        theta_bins=theta_bins, scatter_back=scatter,
    )


def hough_paper_loop(edges: jax.Array, cfg: HoughConfig = HoughConfig()
                     ) -> jax.Array:
    """Paper Algorithm 2, faithfully serial: for each edge point, for each
    theta, ``accumulators[(rho + c_rho)*n_theta + theta]++``.

    Implemented as a ``lax.fori_loop`` over pixels with a vectorized inner
    theta sweep — the closest a data-parallel host gets to the scalar-core
    loop while staying jittable.  Used as the measured baseline for the
    Table 7 speedup analogue.
    """
    H, W = edges.shape
    n_rho = rho_bins(H, W, cfg)
    diag = math.hypot(H, W)
    theta = jnp.arange(cfg.n_theta, dtype=jnp.float32) * (
        math.pi / cfg.n_theta
    )
    cos_t, sin_t = jnp.cos(theta), jnp.sin(theta)
    flat = edges.ravel().astype(jnp.float32)

    def body(p, acc):
        i = p // W
        j = p % W
        rho = j * cos_t + i * sin_t + diag
        idx = jnp.floor(rho / cfg.rho_res).astype(jnp.int32)
        w = jnp.where(flat[p] >= cfg.edge_threshold, 1.0, 0.0)
        return acc.at[idx, jnp.arange(cfg.n_theta)].add(w)

    acc0 = jnp.zeros((n_rho, cfg.n_theta), jnp.float32)
    return jax.lax.fori_loop(0, H * W, body, acc0)
