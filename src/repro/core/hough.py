"""Hough transform (paper Section 4.2 / Algorithm 2) in GEMM + histogram form.

The paper keeps this stage on the scalar core: its voting loop is a chain of
data-dependent read-modify-writes (CPI > 3 on both Rocket and BOOM, Table 6)
and Gemmini buys it nothing (Table 7).  The TPU adaptation dissolves the
dependency — see ``kernels/hough_vote.py``.  This module provides:

  * ``hough_transform``   — the accelerated path: homogeneous-coordinate rho
    GEMM + blockwise one-hot vote accumulation.
  * ``hough_paper_loop``  — a faithful scalar-form reference implementing
    Algorithm 2's per-pixel/per-theta loop nest (``lax`` loops, one pixel at
    a time).  This is the measured "no-accelerator baseline" in the
    benchmarks, the analogue of the paper's Rocket/BOOM-only runs.

``hough_transform`` accepts batches (N, H, W) — one batched vote kernel —
and ``HoughConfig(compact=True, max_edges=...)`` enables the edge-compaction
pre-pass (vote over <=max_edges compacted edge pixels instead of H*W; exact
same accumulator as long as the buffer isn't exceeded).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


@dataclasses.dataclass(frozen=True)
class HoughConfig:
    n_theta: int = 180          # 1-degree bins, theta in [0, 180)
    rho_res: float = 1.0        # rho bin width (pixels)
    edge_threshold: float = 250.0  # paper: image[i*width+j] >= 250
    impl: str | None = None
    # Edge-compaction fast path: prefix-sum-scatter the (typically <5%)
    # edge pixels into a static buffer so the vote stage iterates
    # ``max_edges`` pixels instead of H*W.  ``max_edges=None`` defers to
    # the dispatch default in ``kernels.ops.hough_vote`` (~H*W/16); edges
    # beyond the buffer are dropped, so leave compaction off when exact
    # parity on pathologically dense edge maps matters.  ``max_edges="auto"``
    # sizes the buffer from the workload itself: the plan path counts the
    # edge map ON DEVICE and ``lax.switch``-es over the static tier set
    # (``hough_transform_tiered`` — zero host syncs, jit-safe); the eager
    # ``hough_transform`` counts the concrete edge map and the legacy
    # resolver estimates from a downsampled gradient pass
    # (``canny.estimate_edge_count``) — all land on a tier via
    # ``auto_max_edges`` that never exceeds the dense default.
    compact: bool = False
    max_edges: int | str | None = None
    # Prediction-gated voting (core/tracking.py): when set, the vote stage
    # sweeps only ``theta_band`` theta bins — a runtime int32 vector of bin
    # indices (the tracker's union of windows around predicted lanes,
    # padded to this static length) gathers the trig columns, and the band
    # scatters back into the full-width accumulator (zeros outside the
    # gate) so get_lines and every consumer keep full-sweep indexing.  The
    # *length* is static (a plan attribute — one compiled program per
    # band), the bin values are data (the gate slides every frame without
    # recompiling).  None = full sweep.
    theta_band: int | None = None
    # Rho-corridor edge pre-filter (the fused hot path only): when set, the
    # fused detect kernel drops edge pixels outside every one of
    # ``corridors`` per-track rho windows before compaction/voting —
    # cutting the vote's *pixel* axis the way ``theta_band`` cuts its theta
    # axis.  Like the band, the corridor *count* is static (plan attribute)
    # while the window values (``[cos, sin, rho_lo, rho_hi]`` rows from
    # ``tracking.LaneTracker.corridors``) are runtime data.  None = no
    # filtering.  ``full_corridors`` builds pass-everything windows, under
    # which the fused path is bit-exact with the staged full sweep.
    corridors: int | None = None


# Corridor windows wider than any image diagonal: a (lo, hi) of
# (-CORRIDOR_INF, CORRIDOR_INF) passes every pixel.
CORRIDOR_INF = 1e9


def full_corridors(n: int = 1) -> np.ndarray:
    """(n, 4) corridor rows that pass every pixel (full-coverage fallback).

    Every row is the same all-pass window, so padding a real corridor set
    with these (or using them outright on cold start) is idempotent under
    the kernel's any-corridor OR.
    """
    row = np.array([1.0, 0.0, -CORRIDOR_INF, CORRIDOR_INF], np.float32)
    return np.tile(row, (n, 1))


def rho_bins(height: int, width: int, cfg: HoughConfig) -> int:
    diag = math.hypot(height, width)
    return int(2.0 * diag / cfg.rho_res) + 1


def hough_trig(height: int, width: int, cfg: HoughConfig) -> np.ndarray:
    """(3, n_theta) homogeneous trig table for the rho GEMM.

    Rows ``cos/rho_res``, ``sin/rho_res``, and the folded ``+diag`` shift —
    so ``floor(xy_homogeneous @ trig)`` is directly the rho bin index.
    Shared by the staged vote (``_hough_transform``) and the fused hot
    path's kernel B so both bin identically.
    """
    diag = math.hypot(height, width)
    theta = np.arange(cfg.n_theta, dtype=np.float32) * (
        math.pi / cfg.n_theta
    )
    return np.stack(
        [
            np.cos(theta) / cfg.rho_res,
            np.sin(theta) / cfg.rho_res,
            np.full_like(theta, diag / cfg.rho_res),
        ]
    ).astype(np.float32)


def max_edge_tiers(height: int, width: int, *, base: int = 512
                   ) -> tuple[int, ...]:
    """The static set of compaction-buffer sizes for one resolution.

    Geometric tiers ``base, 2*base, 4*base, ...`` capped at (and always
    including) the dense-dispatch default (``kernels.ops.default_max_edges``)
    — a small finite set, so everything keyed on a resolved ``max_edges``
    (jit cache entries, the tiered ``lax.switch`` in the plan path) stays
    bounded no matter how edge density drifts across a stream.
    """
    cap = ops.default_max_edges(height * width)
    tiers = []
    t = base
    while t < cap:
        tiers.append(t)
        t *= 2
    tiers.append(cap)
    return tuple(tiers)


def auto_max_edges(n_edges: int, height: int, width: int, *,
                   base: int = 512) -> int:
    """Tiered compaction-buffer size for an (estimated) edge count.

    Snaps up to the smallest tier in ``max_edge_tiers`` that holds
    ``n_edges``, so nearby workloads share one jit cache entry, and caps at
    the dense-dispatch default — an autotuned buffer is never larger than
    the hand-tuned one, and past the cap both drop exactly the same
    trailing edges.
    """
    for t in max_edge_tiers(height, width, base=base):
        if int(n_edges) <= t:
            return t
    return max_edge_tiers(height, width, base=base)[-1]


def resolved_auto_config(cfg: HoughConfig, n_edges: int, height: int,
                         width: int) -> HoughConfig:
    """Shared tail of ``max_edges="auto"`` resolution: the dense path
    neutralizes the knob (it is inert there, and a stable value keeps jit
    cache keys shared), the compacted path gets the bucketed buffer."""
    if not cfg.compact:
        return dataclasses.replace(cfg, max_edges=None)
    return dataclasses.replace(
        cfg, max_edges=auto_max_edges(n_edges, height, width)
    )


def resolve_max_edges(edges, cfg: HoughConfig) -> HoughConfig:
    """Resolve ``max_edges="auto"`` against a *concrete* edge map.

    The compacted vote buffer is a static shape, so "auto" must become an
    int before tracing; here the edge map is already computed, so the exact
    per-frame count (max over a batch) feeds ``auto_max_edges``.  The
    pipeline resolves earlier — from the raw image, via the downsampled
    gradient estimate in ``canny.estimate_edge_count`` — because under its
    jit the edge map is a tracer.
    """
    if cfg.max_edges != "auto":
        return cfg
    H, W = edges.shape[-2:]
    if not cfg.compact:  # knob inert on the dense path; no count needed
        return resolved_auto_config(cfg, 0, H, W)
    if isinstance(edges, jax.core.Tracer):
        raise ValueError(
            "HoughConfig(max_edges='auto') needs a concrete edge map to "
            "size the compaction buffer; resolve via "
            "LineDetector/resolve_max_edges before jit."
        )
    counts = np.asarray(edges >= cfg.edge_threshold).sum(axis=(-2, -1))
    n = int(counts.max()) if getattr(counts, "ndim", 0) else int(counts)
    return resolved_auto_config(cfg, n, H, W)


def hough_transform(edges: jax.Array, cfg: HoughConfig = HoughConfig(),
                    theta_bins: jax.Array | None = None, *,
                    scatter: bool = True) -> jax.Array:
    """Vote accumulator (..., n_rho, n_theta) from an edge map (..., H, W).

    Thin wrapper resolving ``max_edges="auto"`` (a data-dependent static
    shape) before entering the jitted body below.  ``theta_bins`` carries
    the prediction gate when ``cfg.theta_band`` is set (see
    :class:`HoughConfig`); ``scatter=False`` then keeps the accumulator in
    band space, (..., n_rho, theta_band) — the plan path feeds that
    straight into ``get_lines(theta_bins=...)`` so the whole peak stage
    scales with the band.
    """
    if cfg.max_edges == "auto":
        cfg = resolve_max_edges(edges, cfg)
    return _hough_transform(edges, cfg, theta_bins, scatter=scatter)


def hough_transform_tiered(edges: jax.Array, cfg: HoughConfig,
                           tiers: tuple[int, ...] | None = None,
                           theta_bins: jax.Array | None = None, *,
                           scatter: bool = True) -> jax.Array:
    """Device-side ``max_edges`` autotune: trace-safe tiered dispatch.

    The compaction buffer is a static shape, so a *traced* edge map cannot
    pick an arbitrary size — but it can pick from a small static set.  The
    exact per-frame edge count (a cheap device reduction; max over a batch)
    selects the smallest tier in ``max_edge_tiers`` that holds every edge,
    and ``lax.switch`` runs the one branch compiled for that tier.  No
    host round-trip anywhere: this is how the plan layer (``core/plan.py``)
    keeps ``max_edges="auto"`` streams free of per-chunk syncs.

    Bit-exact with the dense path whenever the chosen tier drops no edges
    (the count is exact, so only the cap tier can drop any — the same
    trailing edges the hand-tuned dense default drops).  The jit cache
    stays finite: one compiled program per (shape, cfg), holding
    ``len(tiers)`` vote variants.
    """
    if not cfg.compact:
        return _hough_transform(
            edges, dataclasses.replace(cfg, max_edges=None), theta_bins,
            scatter=scatter,
        )
    H, W = edges.shape[-2:]
    if tiers is None:
        tiers = max_edge_tiers(H, W)
    counts = (edges >= cfg.edge_threshold).sum(axis=(-2, -1))
    worst = counts.max().astype(jnp.int32)
    idx = jnp.minimum(
        sum((worst > t).astype(jnp.int32) for t in tiers),
        len(tiers) - 1,
    )
    cfgs = [dataclasses.replace(cfg, max_edges=int(t)) for t in tiers]
    if theta_bins is None:
        branches = [
            functools.partial(_hough_transform, cfg=c) for c in cfgs
        ]
        return jax.lax.switch(idx, branches, edges)
    branches = [
        functools.partial(
            lambda e, tb, cfg: _hough_transform(e, cfg, tb,
                                                scatter=scatter),
            cfg=c,
        )
        for c in cfgs
    ]
    return jax.lax.switch(idx, branches, edges, theta_bins)


@functools.partial(
    jax.jit, static_argnames=("cfg", "scatter")
)
def _hough_transform(edges: jax.Array, cfg: HoughConfig = HoughConfig(),
                     theta_bins: jax.Array | None = None, *,
                     scatter: bool = True) -> jax.Array:
    """Vote accumulator (..., n_rho, n_theta) from an edge map (..., H, W).

    rho = j*cos(theta) + i*sin(theta)  (paper's convention: x=col, y=row),
    shifted by +rho_max and binned at cfg.rho_res.  The shift and the
    resolution are folded into a homogeneous third coordinate so the whole
    stage is literally one GEMM + histogram.  A batch of edge maps
    (N, H, W) shares one raster coordinate table and lowers as one batched
    vote; ``cfg.compact`` routes through the edge-compaction pre-pass;
    ``cfg.theta_band``/``theta_bins`` restrict the sweep to the prediction
    gate (the accumulator stays full width, zero outside the gate).
    """
    if (theta_bins is None) != (cfg.theta_band is None):
        raise ValueError(
            "HoughConfig.theta_band and the theta_bins argument come as a "
            f"pair (got theta_band={cfg.theta_band!r}, "
            f"theta_bins={'set' if theta_bins is not None else None!r})."
        )
    if theta_bins is not None and theta_bins.shape != (cfg.theta_band,):
        raise ValueError(
            f"theta_bins must have the plan's static band shape "
            f"({cfg.theta_band},); got {theta_bins.shape}."
        )
    H, W = edges.shape[-2:]
    n_rho = rho_bins(H, W, cfg)
    trig = hough_trig(H, W, cfg)

    jj, ii = jnp.meshgrid(jnp.arange(W), jnp.arange(H))
    xy = jnp.stack(
        [jj.ravel(), ii.ravel(), jnp.ones(H * W, jnp.int32)], axis=1
    ).astype(jnp.float32)
    flat = edges.reshape(edges.shape[:-2] + (H * W,))
    weights = (flat >= cfg.edge_threshold).astype(jnp.float32)

    return ops.hough_vote(
        xy, weights, jnp.asarray(trig), n_rho=n_rho, impl=cfg.impl,
        compact=cfg.compact, max_edges=cfg.max_edges,
        theta_bins=theta_bins, scatter_back=scatter,
    )


def _check_corridors(corridors, cfg: HoughConfig) -> None:
    if (corridors is None) != (cfg.corridors is None):
        raise ValueError(
            "HoughConfig.corridors and the corridors argument come as a "
            f"pair (got corridors={cfg.corridors!r}, argument="
            f"{'set' if corridors is not None else None!r})."
        )
    if corridors is not None and corridors.shape != (cfg.corridors, 4):
        raise ValueError(
            f"corridors must have the plan's static shape "
            f"({cfg.corridors}, 4); got {corridors.shape}."
        )


def fused_hough(image: jax.Array, canny_cfg, cfg: HoughConfig,
                theta_bins: jax.Array | None = None,
                corridors: jax.Array | None = None, *,
                scatter: bool = True) -> jax.Array:
    """The fused hot path: image -> votes with no HBM round trips between.

    Kernel A (``ops.fused_detect``) runs the whole Canny front end,
    corridor-filters, and compacts in VMEM; kernel B is the standard vote
    over the compacted list.  Bit-exact with ``canny`` + ``hough_transform``
    at full corridor/band coverage whenever the edge count fits the
    compaction buffer (votes are small-integer sums in f32 and both paths
    produce the identical edge set).

    ``cfg.max_edges`` must be a resolved int (or None for the dense
    default): the fused path never materializes an edge map to count, so
    ``"auto"`` only exists in tiered form (``fused_hough_tiered``).
    """
    if cfg.max_edges == "auto":
        raise ValueError(
            "fused_hough cannot resolve max_edges='auto' (there is no "
            "edge map to count); use fused_hough_tiered."
        )
    return _fused_hough(image, canny_cfg, cfg, theta_bins, corridors,
                        scatter=scatter)


@functools.partial(
    jax.jit, static_argnames=("canny_cfg", "cfg", "scatter")
)
def _fused_hough(image: jax.Array, canny_cfg, cfg: HoughConfig,
                 theta_bins: jax.Array | None = None,
                 corridors: jax.Array | None = None, *,
                 scatter: bool = True) -> jax.Array:
    if (theta_bins is None) != (cfg.theta_band is None):
        raise ValueError(
            "HoughConfig.theta_band and the theta_bins argument come as a "
            f"pair (got theta_band={cfg.theta_band!r}, "
            f"theta_bins={'set' if theta_bins is not None else None!r})."
        )
    if theta_bins is not None and theta_bins.shape != (cfg.theta_band,):
        raise ValueError(
            f"theta_bins must have the plan's static band shape "
            f"({cfg.theta_band},); got {theta_bins.shape}."
        )
    _check_corridors(corridors, cfg)
    H, W = image.shape[-2:]
    n_rho = rho_bins(H, W, cfg)
    max_edges = cfg.max_edges
    if max_edges is None:
        max_edges = ops.default_max_edges(H * W)
    cxy, cw = ops.fused_detect(
        image, corridors, cfg=canny_cfg,
        edge_threshold=cfg.edge_threshold, max_edges=max_edges,
        impl=cfg.impl,
    )
    return ops.hough_vote(
        cxy, cw, jnp.asarray(hough_trig(H, W, cfg)), n_rho=n_rho,
        impl=cfg.impl, compact=False, theta_bins=theta_bins,
        scatter_back=scatter,
    )


def fused_hough_tiered(image: jax.Array, canny_cfg, cfg: HoughConfig,
                       tiers: tuple[int, ...] | None = None,
                       theta_bins: jax.Array | None = None,
                       corridors: jax.Array | None = None, *,
                       scatter: bool = True) -> jax.Array:
    """Tiered ``max_edges`` dispatch for the fused path (trace-safe).

    Two tier selectors, split by where the buffer size must be known:

    * **Host backends (xla/stencil):** the whole fused module — Canny,
      corridor filter, exact count, compaction, vote — is one jitted
      program.  The weights exist as an in-module intermediate, so the
      selector counts them *exactly* (post-corridor, max over a batch)
      and ``lax.switch``es over compact+vote branches, just like the
      staged ``hough_transform_tiered``.  Same count ⇒ same tier as
      staged at full coverage, and corridors genuinely shrink the tier
      on cluttered frames.
    * **Pallas (pallas/interpret):** kernel A's compaction buffer is an
      output shape fixed before launch, so the tier comes from the
      *pre-Canny* downsampled-gradient bound
      (``canny.estimate_edge_count_device``), made corridor-aware.  The
      estimate is an upper bound (validated per scenario family), so it
      over-provisions — a larger-than-needed tier votes zero rows and
      stays bit-exact.

    Either way only a genuine overflow of the cap tier drops edges,
    exactly like the staged cap.
    """
    if not cfg.compact:
        return _fused_hough(
            image, canny_cfg, dataclasses.replace(cfg, max_edges=None),
            theta_bins, corridors, scatter=scatter,
        )
    H, W = image.shape[-2:]
    if tiers is None:
        tiers = max_edge_tiers(H, W)
    if ops.resolve_impl(cfg.impl) in ("xla", "stencil"):
        return _fused_hough_tiered_exact(
            image, canny_cfg, cfg, tuple(tiers), theta_bins, corridors,
            scatter=scatter,
        )
    # function-level: plan imports both (and the package re-exports the
    # ``canny`` *function*, so import the module by its full path)
    from .canny import estimate_edge_count_device

    est = estimate_edge_count_device(image, canny_cfg, corridors=corridors)
    idx = jnp.minimum(
        sum((est > t).astype(jnp.int32) for t in tiers),
        len(tiers) - 1,
    )
    cfgs = [dataclasses.replace(cfg, max_edges=int(t)) for t in tiers]

    def make(c):
        # theta_bins/corridors captured by closure (lax.switch branches may
        # close over tracers) so every branch keeps one operand signature.
        def branch(img):
            return _fused_hough(img, canny_cfg, c, theta_bins, corridors,
                                scatter=scatter)

        return branch

    return jax.lax.switch(idx, [make(c) for c in cfgs], image)


@functools.partial(
    jax.jit, static_argnames=("canny_cfg", "cfg", "tiers", "scatter")
)
def _fused_hough_tiered_exact(image: jax.Array, canny_cfg, cfg: HoughConfig,
                              tiers: tuple[int, ...],
                              theta_bins: jax.Array | None = None,
                              corridors: jax.Array | None = None, *,
                              scatter: bool = True) -> jax.Array:
    """Exact-count fused tiering for host backends: one module end to end.

    Canny runs once; the exact post-corridor edge count (the same
    reduction as ``hough_transform_tiered``, on weights instead of the
    edge map) picks the branch; each branch compacts via the raster
    index scatter and votes.  Bit-exact with the staged path at full
    corridor/band coverage because the count — hence the tier — matches
    the staged dispatch and compaction preserves raster order.
    """
    if (theta_bins is None) != (cfg.theta_band is None):
        raise ValueError(
            "HoughConfig.theta_band and the theta_bins argument come as a "
            f"pair (got theta_band={cfg.theta_band!r}, "
            f"theta_bins={'set' if theta_bins is not None else None!r})."
        )
    if theta_bins is not None and theta_bins.shape != (cfg.theta_band,):
        raise ValueError(
            f"theta_bins must have the plan's static band shape "
            f"({cfg.theta_band},); got {theta_bins.shape}."
        )
    _check_corridors(corridors, cfg)
    H, W = image.shape[-2:]
    n_rho = rho_bins(H, W, cfg)
    trig = jnp.asarray(hough_trig(H, W, cfg))
    w = ops.fused_weights(
        image, corridors, cfg=canny_cfg, edge_threshold=cfg.edge_threshold,
        impl=cfg.impl,
    )
    worst = (w > 0).sum(axis=-1).max().astype(jnp.int32)
    idx = jnp.minimum(
        sum((worst > t).astype(jnp.int32) for t in tiers),
        len(tiers) - 1,
    )

    def make(t):
        # theta_bins captured by closure (lax.switch branches may close
        # over tracers) so every branch keeps one operand signature.
        def branch(w):
            cxy, cw = ops.compact_raster(
                w, width=W, max_edges=int(t), impl=cfg.impl
            )
            return ops.hough_vote(
                cxy, cw, trig, n_rho=n_rho, impl=cfg.impl, compact=False,
                theta_bins=theta_bins, scatter_back=scatter,
            )

        return branch

    return jax.lax.switch(idx, [make(t) for t in tiers], w)


def hough_paper_loop(edges: jax.Array, cfg: HoughConfig = HoughConfig()
                     ) -> jax.Array:
    """Paper Algorithm 2, faithfully serial: for each edge point, for each
    theta, ``accumulators[(rho + c_rho)*n_theta + theta]++``.

    Implemented as a ``lax.fori_loop`` over pixels with a vectorized inner
    theta sweep — the closest a data-parallel host gets to the scalar-core
    loop while staying jittable.  Used as the measured baseline for the
    Table 7 speedup analogue.
    """
    H, W = edges.shape
    n_rho = rho_bins(H, W, cfg)
    diag = math.hypot(H, W)
    theta = jnp.arange(cfg.n_theta, dtype=jnp.float32) * (
        math.pi / cfg.n_theta
    )
    cos_t, sin_t = jnp.cos(theta), jnp.sin(theta)
    flat = edges.ravel().astype(jnp.float32)

    def body(p, acc):
        i = p // W
        j = p % W
        rho = j * cos_t + i * sin_t + diag
        idx = jnp.floor(rho / cfg.rho_res).astype(jnp.int32)
        w = jnp.where(flat[p] >= cfg.edge_threshold, 1.0, 0.0)
        return acc.at[idx, jnp.arange(cfg.n_theta)].add(w)

    acc0 = jnp.zeros((n_rho, cfg.n_theta), jnp.float32)
    return jax.lax.fori_loop(0, H * W, body, acc0)
