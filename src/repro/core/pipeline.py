"""End-to-end line detection pipeline (paper Section 4.3-4.4).

Three phases, exactly the paper's Table 1 decomposition:

  1. image load        — decode/normalize the input frame (host -> device),
  2. line detection    — Canny -> Hough -> get-coordinates (device),
  3. image generation  — render detected lines into an output frame.

Phase 3 is implemented *and elidable* (``render_output=False``), reproducing
the paper's 4.2x elision win.  ``detect_profiled`` produces the paper-style
phase tables; ``benchmarks/`` consumes them.

Batched/streamed fast path: ``detect_batch`` runs a stack of frames
(N, H, W) through the same three phases as one jitted program (the conv and
vote kernels lower the batch as a leading grid axis), and ``detect_stream``
double-buffers a frame iterator — the host decodes/stages batch k+1 while
the device computes batch k (jax's async dispatch provides the overlap).
``benchmarks/lines_throughput.py`` measures both.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Iterable, Iterator, NamedTuple

import jax
import jax.numpy as jnp

from .canny import CannyConfig, canny, estimate_edge_count
from .hough import HoughConfig, hough_transform, resolved_auto_config
from .lines import LinesConfig, get_lines, render_lines
from .profiling import PhaseProfiler


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    canny: CannyConfig = CannyConfig()
    hough: HoughConfig = HoughConfig()
    lines: LinesConfig = LinesConfig()
    render_output: bool = False   # paper's elision: off by default


class DetectionResult(NamedTuple):
    # Per-frame shapes; every field gains a leading N axis from
    # detect_batch (detect_stream splits that axis back off).
    lines: jax.Array      # (K, 4) endpoints
    valid: jax.Array      # (K,) mask
    peaks: jax.Array      # (K, 2) (rho, theta)
    edges: jax.Array      # (H, W) uint8 Canny output
    rendered: jax.Array | None


@functools.partial(jax.jit, static_argnames=("cfg",))
def _detect(cfg: PipelineConfig, image: jax.Array) -> DetectionResult:
    """Jitted detection body; ``cfg`` is fully resolved (no "auto" knobs)
    and static, so the cache is shared across detector instances."""
    H, W = image.shape[-2:]
    edges = canny(image, cfg.canny)
    votes = hough_transform(edges, cfg.hough)
    lines, valid, peaks = get_lines(
        votes, height=H, width=W, cfg=cfg.lines
    )
    rendered = None
    if cfg.render_output:
        rendered = render_lines(image.astype(jnp.uint8), lines, valid)
    return DetectionResult(lines, valid, peaks, edges, rendered)


class LineDetector:
    """The paper's application as a composable, jittable module."""

    def __init__(self, cfg: PipelineConfig = PipelineConfig()):
        self.cfg = cfg

    # --- phase 1: image load ------------------------------------------
    @staticmethod
    def load(raw: jax.Array) -> jax.Array:
        """uint8 frame (possibly RGB) -> grayscale f32-ready device array."""
        img = jnp.asarray(raw)
        if img.ndim == 3:  # luma conversion
            img = (
                0.299 * img[..., 0] + 0.587 * img[..., 1]
                + 0.114 * img[..., 2]
            )
        return img

    # --- data-dependent config resolution ------------------------------
    def resolve_config(self, image: jax.Array | None = None
                       ) -> PipelineConfig:
        """Resolve data-dependent knobs against a concrete frame/batch.

        ``HoughConfig(max_edges="auto")`` sizes the edge-compaction buffer
        from a downsampled gradient pass over the input (max over a batch:
        heterogeneous scenario mixes share one buffer sized for the densest
        frame).  Buffer sizes are bucketed (``auto_max_edges``) so drifting
        streams reuse jit cache entries, and capped at the hand-tuned dense
        default — autotuning never allocates a larger buffer.
        """
        h = self.cfg.hough
        if h.max_edges != "auto":
            return self.cfg
        if h.compact:
            if image is None or isinstance(image, jax.core.Tracer):
                raise ValueError(
                    "max_edges='auto' needs a concrete input frame to size "
                    "the compaction buffer (it is a static shape)."
                )
            H, W = image.shape[-2:]
            n_est = estimate_edge_count(image, self.cfg.canny)
        else:  # dense path: the knob is inert, keep jit keys stable
            H = W = n_est = 0
        return dataclasses.replace(
            self.cfg, hough=resolved_auto_config(h, n_est, H, W)
        )

    # --- phase 2: line detection --------------------------------------
    def detect(self, image: jax.Array) -> DetectionResult:
        return _detect(self.resolve_config(image), image)

    # --- batched fast path --------------------------------------------
    def detect_batch(self, images: jax.Array) -> DetectionResult:
        """Detect lines in a stack of frames (N, H, W) as ONE jitted
        program: the conv/vote kernels lower the batch as a leading grid
        axis, so every field of the result gains a leading N axis.  The
        frames may be a heterogeneous scenario mix (``data/scenarios.py``)
        — with ``max_edges="auto"`` the shared compaction buffer is sized
        for the densest frame.  Bit-exact with a per-frame ``detect`` loop
        (the kernels are row/frame-independent, and integer-valued vote
        sums are exact in f32 at any buffer size that drops no edges)."""
        assert images.ndim == 3, images.shape
        return self.detect(images)

    def detect_stream(
        self, frames: Iterable, *, batch_size: int = 1,
    ) -> Iterator[DetectionResult]:
        """Double-buffered streaming detection over a frame iterator.

        Frames are staged into batches of ``batch_size`` and dispatched
        asynchronously: while the device computes batch k, the host decodes
        and stages batch k+1 (one batch in flight).  Yields one per-frame
        DetectionResult per input frame, in order.  A short final batch is
        dispatched at its own (recompiled) shape.
        """
        def dispatch(chunk):
            imgs = jnp.stack(
                [self.load(f).astype(jnp.float32) for f in chunk]
            )
            return self.detect_batch(imgs)

        def split(res):
            n = res.lines.shape[0]
            for i in range(n):
                yield DetectionResult(
                    res.lines[i], res.valid[i], res.peaks[i],
                    res.edges[i],
                    None if res.rendered is None else res.rendered[i],
                )

        in_flight = None
        buf = []
        for frame in frames:
            buf.append(frame)
            if len(buf) == batch_size:
                res = dispatch(buf)   # async: device starts batch k+1
                buf = []
                if in_flight is not None:
                    yield from split(in_flight)
                in_flight = res
        if buf:
            res = dispatch(buf)
            if in_flight is not None:
                yield from split(in_flight)
            in_flight = res
        if in_flight is not None:
            yield from split(in_flight)

    # --- full pipeline with paper-style phase profiling ----------------
    def detect_profiled(
        self, raw: jax.Array, profiler: PhaseProfiler | None = None,
        repeats: int = 1,
    ) -> tuple[DetectionResult, PhaseProfiler]:
        prof = profiler or PhaseProfiler()
        result = None
        for _ in range(repeats):
            image = prof.timeit("image_load", self.load, raw)
            result = prof.timeit("line_detection", self.detect, image)
            if self.cfg.render_output:
                prof.timeit(
                    "image_generation",
                    lambda: render_lines(
                        image.astype(jnp.uint8), result.lines, result.valid
                    ),
                )
        return result, prof

    def detect_stage_profiled(
        self, image: jax.Array, repeats: int = 1
    ) -> PhaseProfiler:
        """Paper Table 3: Canny vs Hough vs get-coordinates split.

        Accepts a single frame (H, W) or a batch (N, H, W) — the batched
        split feeds the throughput benchmark's per-stage table.
        """
        prof = PhaseProfiler()
        H, W = image.shape[-2:]
        cfg = self.resolve_config(image)
        canny_j = jax.jit(lambda im: canny(im, cfg.canny))
        hough_j = jax.jit(lambda e: hough_transform(e, cfg.hough))
        lines_j = jax.jit(
            lambda v: get_lines(v, height=H, width=W, cfg=cfg.lines)
        )
        edges = canny_j(image)  # warmup chains
        votes = hough_j(edges)
        lines_j(votes)
        for _ in range(repeats):
            edges = prof.timeit("canny", canny_j, image)
            votes = prof.timeit("hough", hough_j, edges)
            prof.timeit("get_coordinates", lines_j, votes)
        return prof
