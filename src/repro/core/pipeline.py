"""End-to-end line detection pipeline (paper Section 4.3-4.4).

Three phases, exactly the paper's Table 1 decomposition:

  1. image load        — decode/normalize the input frame (host -> device),
  2. line detection    — Canny -> Hough -> get-coordinates (device),
  3. image generation  — render detected lines into an output frame.

Phase 3 is implemented *and elidable* (``render_output=False``), reproducing
the paper's 4.2x elision win.  ``detect_profiled`` produces the paper-style
phase tables; ``benchmarks/`` consumes them.

Plan architecture (``core/plan.py``): a ``LineDetector`` no longer decides
anything per call.  Each ``(height, width, batch-bucket)`` workload resolves
ONCE into a frozen ``DetectionPlan`` — all ``"auto"`` knobs fixed, batch
padding bucket chosen, autotune tiers pinned — and every subsequent call
reuses the plan's compiled body.  ``max_edges="auto"`` is resolved *on the
device* (an edge-count reduction selects among a static set of compaction
tiers via ``lax.switch``), so ``detect_stream`` performs zero per-chunk
device<->host syncs: frames are staged on the host, shipped with one
explicit ``jax.device_put`` per batch, and the hot loop runs under
``jax.transfer_guard("disallow")``.  Short final batches pad to the plan's
bucket instead of recompiling.  ``benchmarks/lines_throughput.py`` measures
the batch path; ``serve/detection.py`` builds a request-level service on
the same plans.

Temporal layer (``core/tracking.py``): a camera stream carries frame-to-
frame continuity this per-frame facade ignores — ``TrackingPipeline``
wraps the same plans with a ``LaneTracker`` whose confirmed tracks gate
the next frame's Hough sweep to predicted theta windows
(``DetectionPlan.with_theta_band`` / ``run(theta_bins=...)``), falling
back to the full sweep on track loss; ``data/scenarios.py`` drive cycles
are the matching workload.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable, Iterator

import jax
import numpy as np

import jax.numpy as jnp

from .canny import canny, estimate_edge_count
from .hough import hough_transform, resolved_auto_config
from .lines import get_lines, render_lines
from .plan import (  # noqa: F401  (re-exported API)
    DetectionPlan, DetectionResult, LUMA_WEIGHTS, PipelineConfig, PlanCache,
    batch_bucket, load_frame,
)
from .profiling import PhaseProfiler


class LineDetector:
    """The paper's application as a composable, jittable module.

    A thin facade over ``core/plan.py``: calls look up (or build) the
    ``DetectionPlan`` for their workload shape and run it.  Detector
    instances with equal configs share compiled bodies via the jit cache.
    """

    def __init__(self, cfg: PipelineConfig = PipelineConfig()):
        self.cfg = cfg
        self._plans = PlanCache(cfg)

    # --- phase 1: image load ------------------------------------------
    @staticmethod
    def load(raw: jax.Array) -> jax.Array:
        """uint8 frame (possibly RGB) -> grayscale f32-ready device array.

        Trace-safe device twin of the host staging path ``plan.load_frame``
        (shared ``LUMA_WEIGHTS``, f32 math in the same order; XLA fusion
        may differ in the last ulp); grayscale inputs pass through at
        their own dtype (the integer pipeline keeps exact uint8 values)."""
        img = jnp.asarray(raw)
        if img.ndim == 3:  # luma conversion
            wr, wg, wb = LUMA_WEIGHTS
            img = img.astype(jnp.float32)
            img = wr * img[..., 0] + wg * img[..., 1] + wb * img[..., 2]
        return img

    # --- plan access ---------------------------------------------------
    def plan_for(self, height: int, width: int, *,
                 batch: int | None = None) -> DetectionPlan:
        """The resolve-once execution plan for a workload shape."""
        return self._plans.plan_for(height, width, batch=batch)

    # --- data-dependent config resolution ------------------------------
    def resolve_config(self, image: jax.Array | None = None
                       ) -> PipelineConfig:
        """Resolve data-dependent knobs against a concrete frame/batch.

        Legacy/introspection path: sizes the ``max_edges="auto"`` buffer
        from the downsampled gradient estimate (one host readback) and
        returns a fully pinned config.  The detect paths no longer need
        this — their plans resolve "auto" on the device (``core/plan.py``)
        — but benchmarks and the service use it to *report* the buffer a
        workload would get, and pinning a detector to the result is still
        valid (it just skips the tiered dispatch).
        """
        h = self.cfg.hough
        if h.max_edges != "auto":
            return self.cfg
        if h.compact:
            if image is None or isinstance(image, jax.core.Tracer):
                raise ValueError(
                    "max_edges='auto' needs a concrete input frame to size "
                    "the compaction buffer (it is a static shape)."
                )
            H, W = image.shape[-2:]
            n_est = estimate_edge_count(image, self.cfg.canny)
        else:  # dense path: the knob is inert, keep jit keys stable
            H = W = n_est = 0
        return dataclasses.replace(
            self.cfg, hough=resolved_auto_config(h, n_est, H, W)
        )

    # --- phase 2: line detection --------------------------------------
    def detect(self, image: jax.Array) -> DetectionResult:
        """Detect lines in one frame (H, W) — or a batch (N, H, W), which
        delegates to ``detect_batch``."""
        if image.ndim == 3:
            return self.detect_batch(image)
        H, W = image.shape[-2:]
        return self.plan_for(H, W).run(image)

    # --- batched fast path --------------------------------------------
    def detect_batch(self, images: jax.Array) -> DetectionResult:
        """Detect lines in a stack of frames (N, H, W) as ONE jitted
        program: the conv/vote kernels lower the batch as a leading grid
        axis, so every field of the result gains a leading N axis.  The
        batch pads to its plan's power-of-two bucket (frame-independent
        stages make pad rows inert) and the result is sliced back.  The
        frames may be a heterogeneous scenario mix (``data/scenarios.py``)
        — with ``max_edges="auto"`` the device-side autotune picks the
        tier that holds the densest frame.  Bit-exact with a per-frame
        ``detect`` loop (the kernels are row/frame-independent, and
        integer-valued vote sums are exact in f32 at any buffer size that
        drops no edges)."""
        assert images.ndim == 3, images.shape
        N, H, W = images.shape
        return self.plan_for(H, W, batch=batch_bucket(N)).run(images)

    def detect_stream(
        self, frames: Iterable, *, batch_size: int = 1,
    ) -> Iterator[DetectionResult]:
        """Pinned, double-buffered streaming detection over a frame iterator.

        ONE plan is built from the first frame's resolution and the
        ``batch_size`` bucket, then every chunk — including a short final
        one, which pads to the bucket instead of recompiling — reuses it.
        Chunks are staged on the host (numpy decode + stack) and shipped
        with a single explicit ``jax.device_put`` each; after the first
        (compiling) chunk the loop runs under
        ``jax.transfer_guard("disallow")``, so any per-chunk host
        round-trip — implicit transfer, estimator readback, re-resolution
        — is a hard error rather than a silent stall.  Dispatch is
        asynchronous: while the device computes batch k, the host decodes
        and stages batch k+1 (one batch in flight).  Yields one per-frame
        DetectionResult per input frame, in order.
        """
        plan: DetectionPlan | None = None
        warmed = False

        def dispatch(chunk):
            nonlocal plan, warmed
            arr = np.stack([load_frame(f) for f in chunk])
            n, H, W = arr.shape
            if plan is None:
                # same pow2 bucket as detect_batch, so a warmup batch and
                # the stream share one compiled program
                plan = self.plan_for(H, W, batch=batch_bucket(batch_size))
            if n < plan.batch:  # pad on the host: one transfer either way
                arr = np.concatenate(
                    [arr, np.zeros((plan.batch - n, H, W), arr.dtype)]
                )
            if not warmed:  # first chunk compiles: transfers constants
                warmed = True
                return plan.run(jax.device_put(arr)), n
            with jax.transfer_guard("disallow"):
                return plan.run(jax.device_put(arr)), n

        def split(res, n):
            for i in range(n):
                yield DetectionResult(
                    res.lines[i], res.valid[i], res.peaks[i],
                    res.edges[i],
                    None if res.rendered is None else res.rendered[i],
                )

        in_flight = None
        buf = []
        for frame in frames:
            buf.append(frame)
            if len(buf) == batch_size:
                res = dispatch(buf)   # async: device starts batch k+1
                buf = []
                if in_flight is not None:
                    yield from split(*in_flight)
                in_flight = res
        if buf:
            res = dispatch(buf)
            if in_flight is not None:
                yield from split(*in_flight)
            in_flight = res
        if in_flight is not None:
            yield from split(*in_flight)

    # --- full pipeline with paper-style phase profiling ----------------
    def detect_profiled(
        self, raw: jax.Array, profiler: PhaseProfiler | None = None,
        repeats: int = 1,
    ) -> tuple[DetectionResult, PhaseProfiler]:
        prof = profiler or PhaseProfiler()
        result = None
        for _ in range(repeats):
            image = prof.timeit("image_load", self.load, raw)
            result = prof.timeit("line_detection", self.detect, image)
            if self.cfg.render_output:
                prof.timeit(
                    "image_generation",
                    lambda: render_lines(
                        image.astype(jnp.uint8), result.lines, result.valid
                    ),
                )
        return result, prof

    def detect_stage_profiled(
        self, image: jax.Array, repeats: int = 1
    ) -> PhaseProfiler:
        """Paper Table 3: Canny vs Hough vs get-coordinates split.

        Accepts a single frame (H, W) or a batch (N, H, W) — the batched
        split feeds the throughput benchmark's per-stage table.
        """
        prof = PhaseProfiler()
        H, W = image.shape[-2:]
        cfg = self.resolve_config(image)
        canny_j = jax.jit(lambda im: canny(im, cfg.canny))
        hough_j = jax.jit(lambda e: hough_transform(e, cfg.hough))
        lines_j = jax.jit(
            lambda v: get_lines(v, height=H, width=W, cfg=cfg.lines)
        )
        edges = canny_j(image)  # warmup chains
        votes = hough_j(edges)
        lines_j(votes)
        for _ in range(repeats):
            edges = prof.timeit("canny", canny_j, image)
            votes = prof.timeit("hough", hough_j, edges)
            prof.timeit("get_coordinates", lines_j, votes)
        return prof
