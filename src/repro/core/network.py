"""Deterministic seeded network model for the cross-host fleet tier.

Schafhalter et al. ("Leveraging Cloud Computing to Make Autonomous
Vehicles Safer", PAPERS.md) measure real cellular links between a
vehicle and a remote datacenter: round-trip latency is heavy-tailed
(they report lognormal-shaped LTE/5G distributions with medians in the
tens of milliseconds and a long tail past the deadline), messages are
*lost*, and the uplink leg — shipping the full-resolution frame up — is
as real as the downlink that returns the answer.  PR 7's speculative
local/remote race modeled none of this: one fixed ``rtt_s`` charged
once on the response, which is a network that can delay an upgrade but
can never hurt you.  This module is the honest replacement:

  * **Two independent legs** — every race sends a request *uplink*
    (the remote replica cannot start before it lands) and a response
    *downlink* (the upgrade is not in hand before it lands).  The RTT
    budget splits ``uplink_fraction`` / ``1 - uplink_fraction``.
  * **Lognormal jitter** — each leg's delay is
    ``median * exp(jitter_sigma * z)`` with ``z ~ N(0, 1)``: the
    multiplicative lognormal form Schafhalter et al. fit to measured
    cellular RTTs (median-parameterized, so ``jitter_sigma=0`` recovers
    the fixed-delay model *bit-exactly* — the PR-7 compatibility gate
    in ``benchmarks/mesh_suite.py`` depends on this).
  * **Per-message loss** — each leg is independently lost with
    probability ``loss``; a lost uplink means the remote pass never
    runs, a lost downlink means the computed answer never arrives.
    Both resolve through the race's deadline timeout — never a hang.
  * **Determinism** — no wall clock, no global RNG.  Every message
    draws from ``np.random.default_rng((seed, message_index))``: the
    sample stream is a pure function of the config seed and the send
    sequence, so every race replays bit-exact (the seed flows in via
    :class:`NetworkConfig`, timestamps flow in from the caller's shared
    ``VirtualClock``).

The model is *passive*: it samples delays and loss, the serving layer
(:meth:`repro.serve.fleet.ShardedDetectionService.submit_speculative`)
charges them on the shared clock.  That keeps this module pure policy —
testable without a service — and keeps the service's race a
deterministic function of (trace, seed), like every other policy in the
repo.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class NetworkConfig:
    """Parameters of the modeled vehicle<->remote link.

    ``rtt_median_s`` is the *median* round trip (both legs, no loss);
    ``uplink_fraction`` splits it into the request leg (uplink median =
    ``rtt_median_s * uplink_fraction``) and the response leg (the
    rest).  ``uplink_fraction=0.0`` with ``jitter_sigma=0.0`` and
    ``loss=0.0`` is the **uplink-compat mode**: a free uplink and the
    whole RTT charged on the response — bit-exact with PR 7's
    ``SpeculativeConfig.rtt_s``-only arithmetic, kept as a regression
    gate, not as an honest model.  ``jitter_sigma`` is the lognormal
    sigma of each leg's multiplicative jitter; ``loss`` is the
    independent per-message loss probability of each leg.  ``seed``
    makes every sample stream replayable bit-exact.
    """
    seed: int = 0
    rtt_median_s: float = 0.03
    uplink_fraction: float = 0.5
    jitter_sigma: float = 0.0
    loss: float = 0.0

    def __post_init__(self):
        assert self.rtt_median_s >= 0.0, self.rtt_median_s
        assert 0.0 <= self.uplink_fraction <= 1.0, self.uplink_fraction
        assert self.jitter_sigma >= 0.0, self.jitter_sigma
        assert 0.0 <= self.loss <= 1.0, self.loss

    @property
    def uplink_median_s(self) -> float:
        return self.rtt_median_s * self.uplink_fraction

    @property
    def downlink_median_s(self) -> float:
        return self.rtt_median_s * (1.0 - self.uplink_fraction)


@dataclasses.dataclass(frozen=True)
class Delivery:
    """One message's fate: sampled one-way delay, or lost (pure data).

    ``arrives_at(sent_at)`` is the only arithmetic: a lost message
    arrives at ``inf`` — it never arrives, and whatever waits on it
    must resolve through a timeout, never by blocking.
    """
    kind: str          # "uplink" | "downlink"
    msg_id: int        # position in the model's send sequence
    delay_s: float     # sampled one-way delay (valid even when lost)
    lost: bool

    def arrives_at(self, sent_at: float) -> float:
        return math.inf if self.lost else sent_at + self.delay_s


class NetworkModel:
    """Seeded sampler of per-message deliveries (see module docstring).

    Each ``uplink()`` / ``downlink()`` call consumes one message id;
    message ``k`` draws from ``default_rng((seed, k))`` in a fixed
    order (loss uniform first, then the jitter normal), so the stream
    is bit-reproducible for a given send sequence and two models with
    the same config replay identically.
    """

    def __init__(self, cfg: NetworkConfig):
        self.cfg = cfg
        self._msg = 0
        self.sent = 0
        self.lost = 0

    def _sample(self, kind: str, median_s: float) -> Delivery:
        msg = self._msg
        self._msg += 1
        rng = np.random.default_rng((self.cfg.seed, msg))
        lost = bool(rng.random() < self.cfg.loss)
        z = float(rng.standard_normal())
        # sigma=0 -> exp(0*z) == 1.0 exactly: the fixed-delay model is
        # recovered bit-exact, not approximately (the compat gate)
        delay = median_s * math.exp(self.cfg.jitter_sigma * z)
        self.sent += 1
        self.lost += lost
        return Delivery(kind, msg, delay, lost)

    def uplink(self) -> Delivery:
        """Sample the request leg (vehicle -> remote)."""
        return self._sample("uplink", self.cfg.uplink_median_s)

    def downlink(self) -> Delivery:
        """Sample the response leg (remote -> vehicle)."""
        return self._sample("downlink", self.cfg.downlink_median_s)


def force_lost(d: Delivery) -> Delivery:
    """The fault harness's hook: the same sampled message, forcibly
    lost (``runtime.faults`` schedules per-race forced losses so the
    lost-uplink / lost-downlink arms are exact, not probabilistic)."""
    return dataclasses.replace(d, lost=True)


def expected_rtt_s(cfg: NetworkConfig) -> float:
    """Mean round trip implied by the config (no loss): each lognormal
    leg's mean is ``median * exp(sigma^2 / 2)``.  Diagnostics only —
    the race charges sampled legs, never this expectation."""
    scale = math.exp(cfg.jitter_sigma ** 2 / 2.0)
    return (cfg.uplink_median_s + cfg.downlink_median_s) * scale
