"""Phase profiling (paper Section 4.4, Tables 1-3 methodology).

The paper's optimization process starts from phase-level wall-time tables;
this module reproduces that instrument: named phases, block-until-ready
boundaries, microsecond means over repeats, and percentage-over-total
reports shaped like the paper's tables.  The analytic FLOP/byte counters
feed the roofline terms (``launch/roofline.py``) the same way the paper's
cycle counters feed its speedup tables.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import OrderedDict
from typing import Callable

import jax


@dataclasses.dataclass
class PhaseStat:
    total_us: float = 0.0
    calls: int = 0

    @property
    def mean_us(self) -> float:
        return self.total_us / max(self.calls, 1)


class PhaseProfiler:
    """Accumulates wall time per named phase across repeats."""

    def __init__(self) -> None:
        self.phases: "OrderedDict[str, PhaseStat]" = OrderedDict()

    @contextlib.contextmanager
    def phase(self, name: str):
        start = time.perf_counter()
        result_holder = []
        try:
            yield result_holder
        finally:
            if result_holder:
                jax.block_until_ready(result_holder[-1])
            elapsed = (time.perf_counter() - start) * 1e6
            stat = self.phases.setdefault(name, PhaseStat())
            stat.total_us += elapsed
            stat.calls += 1

    def timeit(self, name: str, fn: Callable, *args, repeats: int = 1, **kw):
        out = None
        for _ in range(repeats):
            with self.phase(name) as holder:
                out = fn(*args, **kw)
                holder.append(out)
        return out

    def table(self) -> list[tuple[str, float, float]]:
        """[(phase, mean_us, pct_over_total)] — the paper's table shape."""
        total = sum(s.mean_us for s in self.phases.values())
        return [
            (name, s.mean_us, 100.0 * s.mean_us / total if total else 0.0)
            for name, s in self.phases.items()
        ]

    def report(self) -> str:
        rows = self.table()
        width = max((len(n) for n, _, _ in rows), default=10)
        lines = [f"{'phase':<{width}}  {'time(us)':>12}  {'% over total':>12}"]
        for name, us, pct in rows:
            lines.append(f"{name:<{width}}  {us:>12.1f}  {pct:>11.2f}%")
        total = sum(us for _, us, _ in rows)
        lines.append(f"{'total':<{width}}  {total:>12.1f}")
        return "\n".join(lines)


# ----- analytic per-stage cost model (feeds offload planning + rooflines) --

@dataclasses.dataclass(frozen=True)
class StageCost:
    name: str
    flops: float           # useful arithmetic
    bytes_moved: float     # HBM traffic assuming perfect reuse in VMEM
    matmul_fraction: float  # share of flops expressible as GEMMs

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops / max(self.bytes_moved, 1.0)


def line_detection_costs(H: int, W: int, *, n_theta: int = 180,
                         kh: int = 5, fused: bool = False) -> list[StageCost]:
    """Analytic costs of the paper's stages for an HxW frame."""
    px = H * W
    k2 = (7 * 7) if fused else (kh * kh)
    conv_passes = 1 if fused else 2
    conv_flops = 2.0 * px * k2 * 3  # 3 masks
    conv_bytes = conv_passes * px * 4 * 2
    n_rho = int(2 * (H * H + W * W) ** 0.5) + 1
    return [
        StageCost("canny_conv_gemm", conv_flops, conv_bytes, 1.0),
        StageCost("canny_elementwise", 12.0 * px, px * 4 * 4, 0.0),
        StageCost("hough_rho_gemm", 2.0 * px * n_theta * 3, px * 4 * 2, 1.0),
        StageCost("hough_votes", 2.0 * px * n_theta, n_rho * n_theta * 4, 0.0),
        StageCost("get_coordinates", 10.0 * n_rho * n_theta,
                  n_rho * n_theta * 4, 0.0),
    ]
