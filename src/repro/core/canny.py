"""Canny edge detection in conv-as-GEMM form (paper Section 4.1 / Algorithm 1).

The paper's hot loop — 87.6% of line-detection time (Table 3) — is the Canny
stage, whose stencils it rewrites as mask x neighbourhood matrix products for
Gemmini.  Here the same stages lower to the ``conv2d_gemm`` Pallas kernel
(MXU) while the control-heavy stages (thresholding, non-max suppression,
hysteresis) stay element-wise (VPU) — the TPU version of the paper's
core/accelerator partition, decided by ``core.offload``.

Two execution variants:
  * ``paper``   — faithful to the paper's Algorithm 1: gradient-magnitude
    threshold, direction quantization, double threshold, one-step hysteresis.
  * ``full``    — textbook Canny with direction-aware non-max suppression and
    iterative hysteresis (better lines; used by default in the pipeline).

Two arithmetic modes (paper Section 4.4):
  * float (f32) and integer (uint8 image -> int32 accumulation, L1 gradient
    magnitude, tan-ratio direction tests) — the paper's float->int rewrite,
    validated for detection parity in tests.

One beyond-paper fusion (see ROADMAP.md): ``fused=True`` composes the
Gaussian into the Sobel masks offline (convolution associativity), so one
im2col GEMM pass with 7x7 masks replaces the two chained 5x5 passes — one
pass over HBM instead of two, and wider GEMMs that fill the MXU.

Batched fast path: every stage operates on ``(..., H, W)``, so a stack of
frames ``(N, H, W)`` flows through unchanged — the conv-GEMM kernel lowers
the batch as a leading grid axis and the elementwise stages broadcast.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

# The classic integer-friendly 5x5 Gaussian (sums to 159) and Sobel masks.
GAUSS_5x5 = np.array(
    [
        [2, 4, 5, 4, 2],
        [4, 9, 12, 9, 4],
        [5, 12, 15, 12, 5],
        [4, 9, 12, 9, 4],
        [2, 4, 5, 4, 2],
    ],
    np.float32,
)
GAUSS_NORM = 159.0
SOBEL_X = np.array([[-1, 0, 1], [-2, 0, 2], [-1, 0, 1]], np.float32)
SOBEL_Y = SOBEL_X.T.copy()

# tan(22.5 deg) and tan(67.5 deg) as integer ratios (paper's int rewrite:
# direction tests become cross-multiplications, no arctan anywhere).
TAN_22_NUM, TAN_22_DEN = 53, 128     # 53/128  = 0.4141 ~ tan 22.5
TAN_67_NUM, TAN_67_DEN = 309, 128    # 309/128 = 2.4141 ~ tan 67.5


def _pad_to(mask: np.ndarray, k: int) -> np.ndarray:
    p = (k - mask.shape[0]) // 2
    return np.pad(mask, ((p, p), (p, p)))


def _compose(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Full 2-D convolution of two masks (associativity: (a*b)*img == a*(b*img))."""
    ka, kb = a.shape[0], b.shape[0]
    k = ka + kb - 1
    out = np.zeros((k, k), np.float32)
    for i in range(ka):
        for j in range(ka):
            out[i : i + kb, j : j + kb] += a[i, j] * b
    return out


@functools.cache
def fused_masks() -> np.ndarray:
    """(3, 7, 7): [gauss(padded), gauss(*)sobel_x, gauss(*)sobel_y]."""
    g = GAUSS_5x5 / GAUSS_NORM
    return np.stack(
        [
            _pad_to(g, 7),
            _compose(g, SOBEL_X),
            _compose(g, SOBEL_Y),
        ]
    ).astype(np.float32)


@dataclasses.dataclass(frozen=True)
class CannyConfig:
    low: float = 40.0          # weak-edge threshold (on 0..255 magnitudes)
    high: float = 90.0         # strong-edge threshold
    variant: str = "full"      # "full" | "paper"
    integer: bool = False      # paper Section 4.4 float->int rewrite
    fused: bool = False        # beyond-paper single-pass 7x7 masks
    hysteresis_iters: int = 8
    border: int = 4            # suppress zero-padding artifacts at the rim
    impl: str | None = None    # kernel dispatch (None => backend default)
    # Gradient-accumulation tier: "f32" (exact, the bit-exactness contract),
    # "f16" (half-precision conv accumulation), or "int8" (per-frame
    # symmetric quantization via core.quantize + integer convs).  The
    # threshold compare downstream always happens on f32 magnitudes; the
    # low-precision tiers trade gradient accuracy for bandwidth and are
    # quality-gated by the quantized F1 floors in scripts/check_f1.py.
    grad_dtype: str = "f32"    # "f32" | "f16" | "int8"


@functools.cache
def gradient_masks(cfg: CannyConfig) -> tuple[np.ndarray, ...]:
    """The conv-mask constants ``_gradients`` needs for ``cfg``, in order.

    Exposed so the fused detection kernel can feed the masks in as Pallas
    operands (kernel bodies may not capture array constants) via the
    ``masks=`` override on :func:`canny` — the override is positional and
    must come from this function for the same ``cfg``.
    """
    if cfg.integer or cfg.grad_dtype == "int8":
        if cfg.fused:
            return (np.round(fused_masks() * GAUSS_NORM).astype(np.int32),)
        return (
            GAUSS_5x5.astype(np.int32)[None],
            np.stack([SOBEL_X, SOBEL_Y]).astype(np.int32),
        )
    dt = np.float16 if cfg.grad_dtype == "f16" else np.float32
    if cfg.fused:
        return (fused_masks().astype(dt),)
    return (
        (GAUSS_5x5 / GAUSS_NORM)[None].astype(dt),
        np.stack([SOBEL_X, SOBEL_Y]).astype(dt),
    )


def _gradients(image: jax.Array, cfg: CannyConfig, masks=None):
    """Stages 1-2: noise reduction + intensity gradient, all GEMM-form.

    ``image`` is (..., H, W); conv outputs stack masks on axis -3.
    ``masks`` optionally overrides the conv-mask constants (must match
    ``gradient_masks(cfg)`` positionally — the fused-kernel seam).
    Whatever the accumulation tier, ``gx``/``gy`` come back as f32 (int32
    for the paper's integer rewrite) so the threshold compare downstream
    is always full-precision.
    """
    if cfg.grad_dtype not in ("f32", "f16", "int8"):
        raise ValueError(f"unknown grad_dtype {cfg.grad_dtype!r}")
    if cfg.integer and cfg.grad_dtype != "f32":
        raise ValueError(
            "grad_dtype tiers apply to the float pipeline; the integer "
            "rewrite (integer=True) is its own arithmetic mode"
        )
    if masks is None:
        masks = tuple(jnp.asarray(m) for m in gradient_masks(cfg))

    if cfg.integer:
        img = image.astype(jnp.int32)
        if cfg.fused:
            # Integer fusion: scale fused float masks to int (x GAUSS_NORM).
            out = ops.conv2d_gemm(img, masks[0], impl=cfg.impl)
            nr = out[..., 0, :, :] // int(GAUSS_NORM)
            gx = out[..., 1, :, :] // int(GAUSS_NORM)
            gy = out[..., 2, :, :] // int(GAUSS_NORM)
        else:
            nr = ops.conv2d_gemm(img, masks[0], impl=cfg.impl)[
                ..., 0, :, :
            ] // int(GAUSS_NORM)
            gxy = ops.conv2d_gemm(nr, masks[1], impl=cfg.impl)
            gx, gy = gxy[..., 0, :, :], gxy[..., 1, :, :]
        return nr, gx, gy

    if cfg.grad_dtype == "int8":
        # Per-frame symmetric int8 (core.quantize): integer convs with int32
        # accumulation, dequantized back to f32 between stages so the
        # Gaussian's output re-quantizes at its own dynamic range.
        from .quantize import quantize_frames  # function-level: no cycle

        q = quantize_frames(image)
        if cfg.fused:
            out = ops.conv2d_gemm(q.values, masks[0], impl=cfg.impl)
            s = q.scale / GAUSS_NORM
            nr = out[..., 0, :, :].astype(jnp.float32) * s
            gx = out[..., 1, :, :].astype(jnp.float32) * s
            gy = out[..., 2, :, :].astype(jnp.float32) * s
            return nr, gx, gy
        nr_q = ops.conv2d_gemm(q.values, masks[0], impl=cfg.impl)[
            ..., 0, :, :
        ]
        nr = nr_q.astype(jnp.float32) * (q.scale / GAUSS_NORM)
        q2 = quantize_frames(nr)
        gxy = ops.conv2d_gemm(q2.values, masks[1], impl=cfg.impl)
        gx = gxy[..., 0, :, :].astype(jnp.float32) * q2.scale
        gy = gxy[..., 1, :, :].astype(jnp.float32) * q2.scale
        return nr, gx, gy

    if cfg.grad_dtype == "f16":
        img = image.astype(jnp.float16)
        if cfg.fused:
            out = ops.conv2d_gemm(img, masks[0], impl=cfg.impl)
            return tuple(
                out[..., k, :, :].astype(jnp.float32) for k in range(3)
            )
        nr16 = ops.conv2d_gemm(img, masks[0], impl=cfg.impl)[..., 0, :, :]
        gxy = ops.conv2d_gemm(nr16, masks[1], impl=cfg.impl)
        return (
            nr16.astype(jnp.float32),
            gxy[..., 0, :, :].astype(jnp.float32),
            gxy[..., 1, :, :].astype(jnp.float32),
        )

    img = image.astype(jnp.float32)
    if cfg.fused:
        out = ops.conv2d_gemm(img, masks[0], impl=cfg.impl)
        return out[..., 0, :, :], out[..., 1, :, :], out[..., 2, :, :]
    nr = ops.conv2d_gemm(img, masks[0], impl=cfg.impl)[..., 0, :, :]
    gxy = ops.conv2d_gemm(nr, masks[1], impl=cfg.impl)
    return nr, gxy[..., 0, :, :], gxy[..., 1, :, :]


def _magnitude_direction(gx, gy, integer: bool):
    """Stage 2b: |G| and direction bin in {0, 45, 90, 135} (VPU work)."""
    ax, ay = jnp.abs(gx), jnp.abs(gy)
    if integer:
        mag = ax + ay  # L1 magnitude: no sqrt in the int pipeline
        # direction via cross-multiplied tan thresholds (no arctan):
        d0 = TAN_22_DEN * ay < TAN_22_NUM * ax            # ~horizontal grad
        d90 = TAN_67_DEN * ay >= TAN_67_NUM * ax          # ~vertical grad
    else:
        mag = jnp.sqrt(gx * gx + gy * gy)
        t = ay / jnp.maximum(ax, 1e-9)
        d0 = t < (TAN_22_NUM / TAN_22_DEN)
        d90 = t >= (TAN_67_NUM / TAN_67_DEN)
    diag = jnp.logical_not(d0 | d90)
    same_sign = (gx >= 0) == (gy >= 0)
    # bins: 0 => E-W neighbour pair, 1 => NE-SW, 2 => N-S, 3 => NW-SE
    dirs = jnp.where(
        d0, 0, jnp.where(d90, 2, jnp.where(same_sign & diag, 1, 3))
    ).astype(jnp.int32)
    return mag, dirs


def _shift(x, dy, dx):
    """Zero-padded spatial shift over the trailing (H, W) axes."""
    H, W = x.shape[-2:]
    pad = jnp.pad(x, [(0, 0)] * (x.ndim - 2) + [(1, 1), (1, 1)])
    return pad[..., 1 + dy : 1 + dy + H, 1 + dx : 1 + dx + W]


def _nms(mag, dirs):
    """Direction-aware non-max suppression (full variant, stage 3)."""
    pairs = [((0, 1), (0, -1)), ((-1, 1), (1, -1)),
             ((1, 0), (-1, 0)), ((1, 1), (-1, -1))]
    keep = jnp.zeros_like(mag, dtype=bool)
    for b, (p, q) in enumerate(pairs):
        n1 = _shift(mag, *p)
        n2 = _shift(mag, *q)
        keep = keep | ((dirs == b) & (mag >= n1) & (mag >= n2))
    return jnp.where(keep, mag, 0)


def _dilate3(x):
    out = x
    for dy in (-1, 0, 1):
        for dx in (-1, 0, 1):
            if dy or dx:
                out = out | _shift(x, dy, dx)
    return out


def _clear_border(x: jax.Array, b: int) -> jax.Array:
    if b <= 0:
        return x
    H, W = x.shape[-2:]
    yy = jnp.arange(H)[:, None]
    xx = jnp.arange(W)[None, :]
    inside = (yy >= b) & (yy < H - b) & (xx >= b) & (xx < W - b)
    return jnp.where(inside, x, jnp.zeros_like(x))


def canny(image: jax.Array, cfg: CannyConfig = CannyConfig(),
          masks=None) -> jax.Array:
    """Edge map (..., H, W) uint8 in {0, 255} (paper's ``image_out``).

    Accepts a single frame (H, W) or a batch (N, H, W) — the batch lowers
    through the conv kernel as one launch and the VPU stages broadcast.
    ``masks`` optionally overrides the gradient conv masks (positional per
    ``gradient_masks(cfg)``) so a Pallas caller can pass them as operands.
    """
    nr, gx, gy = _gradients(image, cfg, masks)
    mag, dirs = _magnitude_direction(gx, gy, cfg.integer)
    mag = _clear_border(mag, cfg.border)

    if cfg.variant == "paper":
        # Algorithm 1 stages 3-5: pure thresholds, one hysteresis pass.
        edge = (mag >= cfg.low)
        strong = edge & (mag >= cfg.high)
        out = strong | (edge & _dilate3(strong))
        return jnp.where(out, 255, 0).astype(jnp.uint8)

    sup = _nms(mag, dirs)
    strong = sup >= cfg.high
    weak = (sup >= cfg.low) & ~strong

    def body(_, s):
        return s | (weak & _dilate3(s))

    strong = jax.lax.fori_loop(0, cfg.hysteresis_iters, body, strong)
    return jnp.where(strong, 255, 0).astype(jnp.uint8)


canny_jit = jax.jit(canny, static_argnames=("cfg",))


@functools.partial(jax.jit, static_argnames=("cfg", "stride", "margin"))
def estimate_edge_count_device(image: jax.Array,
                               cfg: CannyConfig = CannyConfig(), *,
                               stride: int = 2, margin: float = 2.5,
                               corridors: jax.Array | None = None
                               ) -> jax.Array:
    """Device-side downsampled-gradient edge-count bound (int32 scalar).

    The traced body of :func:`estimate_edge_count`: the image is subsampled
    by ``stride``, finite differences stand in for Sobel-of-Gaussian
    (``kernels.ops.grad_hits``), and coarse hits are scaled by
    ``stride * margin`` into an upper bound on the post-NMS Canny edge
    count.  Runs entirely on the device; batches reduce to the max
    per-frame estimate.  This pre-Canny estimate backs the legacy host
    resolver (``LineDetector.resolve_config`` — one readback, outside any
    hot loop); the plan path doesn't need it, because its jitted body has
    the actual edge map and tier-dispatches on the exact device-side count
    (``core.hough.hough_transform_tiered``).  ``tests/test_scenarios.py``
    validates the bound (estimate >= actual edge count) on every family.
    """
    # low/2, floored at 20: contrast below that never survives the double
    # threshold, and 20 sits >3 sigma above asphalt-texture differences so
    # the count tracks strokes/speckle, not ground-plane noise.
    #
    # ``corridors`` makes the bound corridor-aware for the fused path's
    # tier selection: coarse hits outside every (widened) rho window don't
    # count, since the fused kernel drops those pixels before compaction.
    # The windows are widened by 2*stride — the worst-case rho drift
    # between a coarse cell corner and any fine pixel it represents is
    # stride*sqrt(2) — so the estimate stays an upper bound.
    thresh = max(cfg.low / 2.0, 20.0)
    hits = ops.grad_hits(image, stride=stride, thresh=thresh,
                         corridors=corridors, widen=2.0 * stride,
                         impl=cfg.impl)
    worst = hits.max().astype(jnp.float32)
    return jnp.floor(worst * stride * margin).astype(jnp.int32) + 64


def estimate_edge_count(image, cfg: CannyConfig = CannyConfig(), *,
                        stride: int = 2, margin: float = 2.5) -> int:
    """Cheap downsampled gradient pass: upper-bound the Canny edge count.

    Sizes the Hough edge-compaction buffer (``HoughConfig(max_edges="auto")``)
    *before* the jitted pipeline runs, so the buffer is a static shape.  Each
    coarse hit represents at most ~``stride`` post-NMS edge pixels per stroke
    side, and ``margin`` absorbs the both-sides-of-a-stroke factor plus
    speckle that subsampling undercounts.

    Accepts a single frame (H, W) or a batch (N, H, W): batches return the
    max per-frame estimate, since the compaction buffer is shared.  This is
    the *host* entry point — it runs :func:`estimate_edge_count_device` and
    reads the scalar back, so it must see concrete values (never call under
    jit; the plan layer keeps the device value traced instead).
    """
    if isinstance(image, jax.core.Tracer):
        raise ValueError(
            "estimate_edge_count reads the estimate back to the host; under "
            "jit use estimate_edge_count_device (core/plan.py does)."
        )
    return int(estimate_edge_count_device(image, cfg, stride=stride,
                                          margin=margin))
