"""Bird's-eye (inverse-perspective) geometry: image-plane lines to
metric ground-plane lane boundaries under a fixed camera model.

The detector emits lines as Hough ``(rho, theta)`` pairs in *image*
coordinates (``x*cos(theta) + y*sin(theta) = rho``, x right, y DOWN,
theta in [0, pi)).  Steering needs those lines on the *ground plane* in
meters, in the vehicle frame (X right, Y forward).  For a pinhole camera
at height ``h`` above a flat ground plane, pitched down by ``phi``, the
image-to-ground map is a homography — and a homography maps lines to
lines, so a detected boundary converts to a metric ground line in closed
form, no per-pixel warp and no sampling.

Camera model (the repro's fixed rig):

  * optical center at height ``h`` over the ground origin,
  * pitched DOWN by ``phi`` from horizontal (so the road fills the lower
    image), no roll, no yaw,
  * focal length ``f`` in pixels, principal point ``(cx, cy)``.

A ground point ``(X, Y)`` (meters; X right, Y forward) sits at camera
coordinates ``(X, h*?, ...)`` — carrying the pitch through gives the
projection

    u - cx = f * X / (Y cos(phi) - ... )

compactly expressed by the 3x3 homography ``G`` below mapping ground
homogeneous coords to image homogeneous coords, with ``M = G^{-1}``
mapping image pixels to ground meters.  Rows above the horizon
``v_h = cy - f tan(phi)`` have no ground intersection (the denominator
changes sign); callers filter on :meth:`CameraGeometry.horizon_v`.

Lines transform contravariantly: an image line with homogeneous coeffs
``l = (cos t, sin t, -r)`` maps to the ground line ``l_g = M^T l``
(so that ``l_g . (X, Y, 1) = l . (u, v, 1) = 0``), renormalized back to
``(rho, theta)`` canonical form.  The round trip (image -> ground ->
image) is exact to float precision — tested in ``tests/test_drive.py``.

Everything here is plain numpy/math on scalars: geometry runs on the
host control path, never inside a jitted kernel.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

__all__ = [
    "CameraConfig", "CameraGeometry", "canonical_rho_theta",
    "DEFAULT_CAMERA",
]


def canonical_rho_theta(rho: float, theta: float) -> tuple[float, float]:
    """Canonicalize a line's normal form to theta in [0, pi), flipping
    rho's sign once per pi-wrap (the ``(rho, theta) ~ (-rho, theta+pi)``
    quotient every (rho, theta) consumer in this repo assumes)."""
    k = math.floor(theta / math.pi)
    theta = theta - k * math.pi
    if theta >= math.pi:        # guard the floor's float edge
        theta -= math.pi
        k += 1
    if k % 2:
        rho = -rho
    return rho, theta


@dataclasses.dataclass(frozen=True)
class CameraConfig:
    """The fixed rig: pinhole at ``height_m`` over flat ground, pitched
    down ``pitch_deg``, focal ``focal_px``, principal point defaulting
    to the image center.  Defaults are a roof-mounted wide-ish camera
    framing 2-10 m of road ahead at the harness's 240x320."""
    height_m: float = 1.6
    pitch_deg: float = 18.0
    focal_px: float = 280.0
    image_h: int = 240
    image_w: int = 320
    cx: Optional[float] = None      # principal point (None -> center)
    cy: Optional[float] = None

    @property
    def principal(self) -> tuple[float, float]:
        cx = (self.image_w - 1) / 2.0 if self.cx is None else self.cx
        cy = (self.image_h - 1) / 2.0 if self.cy is None else self.cy
        return cx, cy

    def for_image(self, height: int, width: int) -> "CameraConfig":
        """The same physical rig behind a rescaled sensor: focal length
        and principal point scale with resolution, the mounting (height,
        pitch) does not.  This is how the service reuses one camera model
        across resolution buckets."""
        if (height, width) == (self.image_h, self.image_w):
            return self
        sy = height / self.image_h
        sx = width / self.image_w
        cx, cy = self.principal
        return dataclasses.replace(
            self, image_h=height, image_w=width,
            focal_px=self.focal_px * (sx + sy) / 2.0,
            cx=cx * sx, cy=cy * sy,
        )


DEFAULT_CAMERA = CameraConfig()


class CameraGeometry:
    """Closed-form image <-> ground maps for one :class:`CameraConfig`.

    Builds the 3x3 ground->image homography ``G`` once; points and lines
    convert by 3-vector products.  Ground frame: X right (+m), Y forward
    (+m), origin on the ground directly under the camera.
    """

    def __init__(self, cfg: CameraConfig = DEFAULT_CAMERA):
        self.cfg = cfg
        phi = math.radians(cfg.pitch_deg)
        f, h = cfg.focal_px, cfg.height_m
        cx, cy = cfg.principal
        sp, cp = math.sin(phi), math.cos(phi)
        # Camera frame: x right, y down, z optical axis.  Pitch-down by
        # phi maps ground (X, Y) at height -h (camera at +h) to camera
        # coords (X, h*cp - Y*sp, Y*cp + h*sp); projecting with focal f
        # and principal point (cx, cy) gives image homogeneous coords
        # G @ (X, Y, 1):
        self.G = np.array([
            [f,   cx * cp,            cx * sp * h],
            [0.0, cy * cp - f * sp,   (f * cp + cy * sp) * h],
            [0.0, cp,                 sp * h],
        ], float)
        self.M = np.linalg.inv(self.G)          # image -> ground
        self._sp, self._cp, self._f, self._h = sp, cp, f, h
        self._cx, self._cy = cx, cy

    # --- horizon ---------------------------------------------------------
    @property
    def horizon_v(self) -> float:
        """Image row of the ground plane's vanishing line: pixels at or
        above it (v <= horizon) never intersect the ground ahead."""
        return self._cy - self._f * self._sp / self._cp

    # --- points ----------------------------------------------------------
    def pixel_to_ground(self, u: float, v: float) -> tuple[float, float]:
        """Ground (X, Y) in meters under pixel (u, v).  Pixels at/above
        the horizon raise ValueError — they see sky, not road."""
        p = self.M @ (float(u), float(v), 1.0)
        if p[2] <= 1e-12:
            raise ValueError(
                f"pixel (u={u}, v={v}) is at/above the horizon "
                f"v_h={self.horizon_v:.2f}: no ground intersection"
            )
        return float(p[0] / p[2]), float(p[1] / p[2])

    def ground_to_pixel(self, X: float, Y: float) -> tuple[float, float]:
        """Image (u, v) of ground point (X, Y) meters (Y > 0 required:
        the camera faces forward)."""
        q = self.G @ (float(X), float(Y), 1.0)
        if q[2] <= 1e-12:
            raise ValueError(f"ground point (X={X}, Y={Y}) is behind "
                             "or at the camera plane")
        return float(q[0] / q[2]), float(q[1] / q[2])

    # --- lines -----------------------------------------------------------
    def line_to_ground(self, rho: float, theta: float
                       ) -> tuple[float, float]:
        """Map an image-plane Hough line (rho, theta) to its ground-plane
        normal form (rho_g [m], theta_g in [0, pi)).

        An image line ``l = (cos t, sin t, -r)`` (``l . (u, v, 1) = 0``)
        pulls back through the ground->image homography to
        ``l_g = G^T l`` — points satisfy ``l_g . (X, Y, 1) = l . G(X,Y,1)
        = 0``.  Degenerate only if the image line is the horizon itself
        (its ground image is the line at infinity): ValueError.
        """
        l = (math.cos(theta), math.sin(theta), -float(rho))
        a = self.G[0, 0] * l[0] + self.G[1, 0] * l[1] + self.G[2, 0] * l[2]
        b = self.G[0, 1] * l[0] + self.G[1, 1] * l[1] + self.G[2, 1] * l[2]
        c = self.G[0, 2] * l[0] + self.G[1, 2] * l[1] + self.G[2, 2] * l[2]
        n = math.hypot(a, b)
        if n < 1e-9:
            raise ValueError(
                f"image line (rho={rho}, theta={theta}) is the horizon: "
                "no finite ground line"
            )
        return canonical_rho_theta(-c / n, math.atan2(b, a))

    def line_to_image(self, rho_g: float, theta_g: float
                      ) -> tuple[float, float]:
        """Inverse of :meth:`line_to_ground`: ground normal form back to
        the image-plane (rho, theta)."""
        l_g = (math.cos(theta_g), math.sin(theta_g), -float(rho_g))
        a = self.M[0, 0] * l_g[0] + self.M[1, 0] * l_g[1] \
            + self.M[2, 0] * l_g[2]
        b = self.M[0, 1] * l_g[0] + self.M[1, 1] * l_g[1] \
            + self.M[2, 1] * l_g[2]
        c = self.M[0, 2] * l_g[0] + self.M[1, 2] * l_g[1] \
            + self.M[2, 2] * l_g[2]
        n = math.hypot(a, b)
        if n < 1e-9:
            raise ValueError(
                f"ground line (rho={rho_g}, theta={theta_g}) maps to the "
                "image's line at infinity"
            )
        return canonical_rho_theta(-c / n, math.atan2(b, a))

    def lines_to_ground(self, peaks: np.ndarray,
                        valid: Optional[Sequence[bool]] = None
                        ) -> np.ndarray:
        """Vector form over a (K, 2) peak array (+ optional mask): the
        (M, 2) ground lines of the valid, non-horizon peaks."""
        peaks = np.asarray(peaks, float).reshape(-1, 2)
        if valid is None:
            valid = np.ones(peaks.shape[0], bool)
        out = []
        for (r, t), ok in zip(peaks, np.asarray(valid, bool)):
            if not ok:
                continue
            try:
                out.append(self.line_to_ground(float(r), float(t)))
            except ValueError:
                continue
        return np.array(out, float).reshape(-1, 2)
