"""Heterogeneous placement planning (paper Sections 4.4-5.3, generalized).

The paper decides *which stages go to the accelerator* by profiling and by
an implicit cost model: offload pays only if

    t_core(stage) > t_accel(stage) + t_transfer(operands)

On the paper's platform t_transfer is real (RoCC + scratchpad mvin/mvout) and
the Hough stage's serial dependencies make t_accel ~ t_core, so only Canny's
GEMMs move.  On TPU the "accelerator" (MXU) and the "core" (VPU) share VMEM
inside one fused program, so t_transfer ~ 0 and the placement rule reduces
to: *GEMM-expressible -> MXU; element-wise/control -> VPU; host only for
I/O*.  This module encodes that rule as an explicit, testable planner and
documents the assumption change.
"""

from __future__ import annotations

import dataclasses
from typing import Iterable

from .profiling import StageCost

# TPU v5e model constants (also used by launch/roofline.py).
PEAK_FLOPS_BF16 = 197e12      # per chip
PEAK_FLOPS_VPU = 4e12         # rough VPU f32 throughput
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s/link
MXU_MIN_DIM = 128             # systolic array edge (Gemmini: 16)


@dataclasses.dataclass(frozen=True)
class Placement:
    stage: str
    unit: str        # "mxu" | "vpu" | "host"
    reason: str
    est_time_s: float


def place(stage: StageCost, *, transfer_bytes: float = 0.0,
          link_bw: float = HBM_BW) -> Placement:
    """Place one stage. The paper's rule with TPU constants."""
    t_transfer = transfer_bytes / link_bw
    t_mxu = stage.flops * stage.matmul_fraction / PEAK_FLOPS_BF16 + (
        stage.flops * (1 - stage.matmul_fraction) / PEAK_FLOPS_VPU
    )
    t_mem = stage.bytes_moved / HBM_BW
    t_vpu = max(stage.flops / PEAK_FLOPS_VPU, t_mem)

    if stage.matmul_fraction >= 0.5:
        t_accel = max(t_mxu, t_mem) + t_transfer
        if t_accel < t_vpu:
            return Placement(
                stage.name, "mxu",
                f"GEMM-dominant (AI={stage.arithmetic_intensity:.1f}); "
                f"t_mxu={t_accel:.2e}s < t_vpu={t_vpu:.2e}s", t_accel,
            )
    return Placement(
        stage.name, "vpu",
        "element-wise/control-bound; offload gains nothing "
        "(the paper's Hough-on-core decision)", t_vpu,
    )


def plan(stages: Iterable[StageCost]) -> list[Placement]:
    return [place(s) for s in stages]


def plan_line_detection(H: int, W: int, *, fused: bool = False
                        ) -> list[Placement]:
    from .profiling import line_detection_costs

    return plan(line_detection_costs(H, W, fused=fused))
