"""Heterogeneous placement planning (paper Sections 4.4-5.3, generalized).

The paper decides *which stages go to the accelerator* by profiling and by
an implicit cost model: offload pays only if

    t_core(stage) > t_accel(stage) + t_transfer(operands)

On the paper's platform t_transfer is real (RoCC + scratchpad mvin/mvout) and
the Hough stage's serial dependencies make t_accel ~ t_core, so only Canny's
GEMMs move.  On TPU the "accelerator" (MXU) and the "core" (VPU) share VMEM
inside one fused program, so t_transfer ~ 0 and the placement rule reduces
to: *GEMM-expressible -> MXU; element-wise/control -> VPU; host only for
I/O*.  This module encodes that rule as an explicit, testable planner and
documents the assumption change.

**Speculative local/remote offload** (Schafhalter et al., "Leveraging
Cloud Computing to Make Autonomous Vehicles Safer", PAPERS.md): the same
offload calculus one tier up, between the vehicle and a remote replica
across a network.  A fast low-res *local* pass guarantees the deadline; a
high-res *remote* pass races it across the network and upgrades the
answer when it wins.  :class:`SpeculativeConfig` + :func:`decide_race`
are the pure deterministic policy — completion times in, winner out, no
clock or RNG — so the serving layer
(:meth:`repro.serve.fleet.ShardedDetectionService.submit_speculative`)
and its tests model the race exactly on a ``VirtualClock``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional

from .network import NetworkConfig
from .profiling import StageCost

# TPU v5e model constants (also used by launch/roofline.py).
PEAK_FLOPS_BF16 = 197e12      # per chip
PEAK_FLOPS_VPU = 4e12         # rough VPU f32 throughput
HBM_BW = 819e9                # bytes/s
ICI_BW = 50e9                 # bytes/s/link
MXU_MIN_DIM = 128             # systolic array edge (Gemmini: 16)


@dataclasses.dataclass(frozen=True)
class Placement:
    stage: str
    unit: str        # "mxu" | "vpu" | "host"
    reason: str
    est_time_s: float


def place(stage: StageCost, *, transfer_bytes: float = 0.0,
          link_bw: float = HBM_BW) -> Placement:
    """Place one stage. The paper's rule with TPU constants."""
    t_transfer = transfer_bytes / link_bw
    t_mxu = stage.flops * stage.matmul_fraction / PEAK_FLOPS_BF16 + (
        stage.flops * (1 - stage.matmul_fraction) / PEAK_FLOPS_VPU
    )
    t_mem = stage.bytes_moved / HBM_BW
    t_vpu = max(stage.flops / PEAK_FLOPS_VPU, t_mem)

    if stage.matmul_fraction >= 0.5:
        t_accel = max(t_mxu, t_mem) + t_transfer
        if t_accel < t_vpu:
            return Placement(
                stage.name, "mxu",
                f"GEMM-dominant (AI={stage.arithmetic_intensity:.1f}); "
                f"t_mxu={t_accel:.2e}s < t_vpu={t_vpu:.2e}s", t_accel,
            )
    return Placement(
        stage.name, "vpu",
        "element-wise/control-bound; offload gains nothing "
        "(the paper's Hough-on-core decision)", t_vpu,
    )


def plan(stages: Iterable[StageCost]) -> list[Placement]:
    return [place(s) for s in stages]


def plan_line_detection(H: int, W: int, *, fused: bool = False
                        ) -> list[Placement]:
    from .profiling import line_detection_costs

    return plan(line_detection_costs(H, W, fused=fused))


# --- speculative local/remote offload (Schafhalter et al.) ------------------

@dataclasses.dataclass(frozen=True)
class SpeculativeConfig:
    """Modeled network for the local/remote race.

    Two modes:

    * ``network`` set (:class:`repro.core.network.NetworkConfig`): the
      honest model.  The uplink leg is charged *before* the remote
      replica's submit (the remote pass cannot start until the request
      lands), the downlink leg on the response, each independently
      jittered and droppable; ``rtt_s`` is ignored.
    * ``network=None`` (the PR-7 compatibility path): ``rtt_s`` is the
      full round trip charged **once, on the response** — the uplink is
      *not* modeled and the remote clone is submitted with zero delay,
      so remote starts are optimistic by one uplink.  Kept so the PR-7
      race gates stay meaningful; new call sites should pass a
      ``network``.

    Either way "remote wins" means the *upgraded answer is in the
    vehicle's hands* before the deadline — not merely computed
    somewhere.  ``local_shape`` is the low-res bucket the guaranteed
    local pass runs at (None = the service's smallest bucket).

    ``race_timeout_s`` bounds deadline-less races: a race whose remote
    is still pending ``race_timeout_s`` after submit resolves to the
    local answer with ``timed_out=True``.  Deadlined races need no
    extra knob — their own ``deadline_at`` is the timeout (past it the
    remote can no longer upgrade, so waiting longer is pointless)."""
    rtt_s: float = 0.03
    local_shape: Optional[tuple[int, int]] = None
    network: Optional["NetworkConfig"] = None
    race_timeout_s: Optional[float] = None


@dataclasses.dataclass(frozen=True)
class RaceDecision:
    """Deterministic outcome of one speculative race (pure data)."""
    local_done_at: float        # when the local low-res answer landed
    remote_ready_at: float      # remote completion + downlink delay
    deadline_at: Optional[float]
    upgraded: bool              # remote answer replaces the local one
    local_met_deadline: bool    # the guarantee the local tier exists for
    timed_out: bool = False     # resolved by timeout, remote still pending

    @property
    def winner(self) -> str:
        return "remote" if self.upgraded else "local"


def decide_race(local_done_at: float, remote_done_at: Optional[float],
                deadline_at: Optional[float], *, rtt_s: float,
                downlink_s: Optional[float] = None,
                timed_out: bool = False) -> RaceDecision:
    """Pick the answer of one local/remote speculative race.

    The local pass is authoritative by default — it is the deadline
    guarantee.  The remote high-res answer upgrades it iff the remote
    replica actually completed (``remote_done_at`` not None: a shed,
    refused, or dead-replica remote pass never upgrades anything) and
    its answer, after the response leg, is in hand by the deadline.
    The response leg is ``downlink_s`` when given (the honest
    ``NetworkModel`` path: one sampled downlink, ``math.inf`` for a
    lost one — a lost response never upgrades), else the compat
    ``rtt_s`` (PR 7's whole round trip charged here, uplink unmodeled).
    With no deadline a *delivered* remote answer always upgrades once
    complete — there is nothing to race.  ``timed_out`` is a
    passthrough stamp: the caller resolved this race by timeout with
    the remote still pending (a timeout can never flip a correct
    upgrade — past the deadline the remote cannot win anyway).
    """
    leg = rtt_s if downlink_s is None else downlink_s
    remote_ready = (math.inf if remote_done_at is None
                    else remote_done_at + leg)
    upgraded = remote_ready <= (
        deadline_at if deadline_at is not None else math.inf
    ) if remote_done_at is not None else False
    if remote_done_at is not None and deadline_at is None:
        upgraded = math.isfinite(remote_ready)
    return RaceDecision(
        local_done_at=local_done_at,
        remote_ready_at=remote_ready,
        deadline_at=deadline_at,
        upgraded=upgraded,
        local_met_deadline=(deadline_at is None
                            or local_done_at <= deadline_at),
        timed_out=timed_out,
    )
