"""Resolve-once execution plans for the line-detection stack.

"Deciding how to run" and "running" used to be interleaved: every
``LineDetector`` call re-resolved data-dependent knobs (``max_edges="auto"``
copied each batch back to the host to count gradients), and every distinct
batch shape recompiled.  This module splits them:

  * A frozen :class:`DetectionPlan` is built exactly once per
    ``(height, width, batch-bucket)`` and pins everything static — the fully
    resolved :class:`PipelineConfig`, the batch padding bucket, and (for
    ``max_edges="auto"``) the static tier set the device-side autotune
    dispatches over.  Plans are pure facts; the compiled callables they bind
    to are the module-level jitted bodies below, so two detectors with equal
    configs share one compilation.
  * Device-side autotune: the plan's ``"auto"`` body counts edge pixels on
    the device (a reduction over the Canny output) and ``lax.switch``-es
    between vote kernels compiled for a small static set of ``max_edges``
    tiers (``core.hough.max_edge_tiers``).  No per-batch host round-trip —
    ``LineDetector.detect_stream`` runs its hot loop under
    ``jax.transfer_guard("disallow")``.

``core/pipeline.py`` re-exports the config/result types and layers the
user-facing ``LineDetector`` on top; ``serve/detection.py`` builds one plan
per resolution bucket for the continuous-batching detection service.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from .canny import CannyConfig, canny
from .hough import (
    HoughConfig, fused_hough, fused_hough_tiered, hough_transform,
    hough_transform_tiered, max_edge_tiers,
)
from .lines import LinesConfig, get_lines, render_lines


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    canny: CannyConfig = CannyConfig()
    hough: HoughConfig = HoughConfig()
    lines: LinesConfig = LinesConfig()
    render_output: bool = False   # paper's elision: off by default
    # Fused hot path (kernels/fused_detect.py): canny -> corridor filter ->
    # compact -> vote with no intermediate HBM arrays.  Requires
    # ``hough.compact=True`` (the fused kernel's output IS the compacted
    # edge list).  The ``edges`` field of the result is a zeros placeholder
    # on this path — eliding the edge map is the point of the fusion.
    # Bit-exact with the staged path at full corridor/band coverage.
    fused: bool = False


class DetectionResult(NamedTuple):
    # Per-frame shapes; every field gains a leading N axis from
    # detect_batch (detect_stream splits that axis back off).
    lines: jax.Array      # (K, 4) endpoints
    valid: jax.Array      # (K,) mask
    peaks: jax.Array      # (K, 2) (rho, theta)
    edges: jax.Array      # (H, W) uint8 Canny output
    rendered: jax.Array | None


# BT.601 luma weights — the single source for BOTH grayscale conversions:
# the host staging path (load_frame) and the device path
# (LineDetector.load).  Same weights, same f32 order; XLA may still fuse
# the multiply-adds, so the two can differ in the last ulp (gray inputs —
# every test/benchmark path — are untouched by either).
LUMA_WEIGHTS = (0.299, 0.587, 0.114)


def load_frame(raw) -> np.ndarray:
    """Host-side phase 1: uint8 frame (possibly RGB) -> grayscale f32.

    Pure numpy so streaming can stage whole batches on the host and ship
    them with ONE explicit ``jax.device_put`` — the pinned-transfer
    discipline ``transfer_guard("disallow")`` enforces on the hot loop.
    """
    img = np.asarray(raw)
    if img.ndim == 3:  # luma conversion
        wr, wg, wb = LUMA_WEIGHTS
        img = img.astype(np.float32)
        img = wr * img[..., 0] + wg * img[..., 1] + wb * img[..., 2]
    return np.asarray(img, np.float32)


def downsample2x(img: np.ndarray) -> np.ndarray:
    """Host-side 2x2 mean-pool of a grayscale f32 frame (edge-replicated
    to even dimensions first, so the last row/column is never dropped).

    Pure numpy on purpose: the degradation ladder downshifts frames on
    the scheduler/staging path, where everything stays host-side until
    the single ``jax.device_put`` per dispatch.  Mean pooling (not
    striding) keeps a 1-px lane stroke visible after the shift — a
    stride-2 subsample could step over the stroke entirely, which would
    turn "degraded answer" into "no answer".
    """
    img = np.asarray(img, np.float32)
    H, W = img.shape
    if H % 2:
        img = np.concatenate([img, img[-1:, :]], axis=0)
    if W % 2:
        img = np.concatenate([img, img[:, -1:]], axis=1)
    return (0.25 * (img[0::2, 0::2] + img[1::2, 0::2]
                    + img[0::2, 1::2] + img[1::2, 1::2])
            ).astype(np.float32)


def downshift_frame(raw, shape: tuple[int, int]
                    ) -> tuple[np.ndarray, int]:
    """Grayscale-load ``raw`` and halve its resolution until it fits the
    ``shape`` bucket; returns ``(image, factor)`` with ``factor`` the
    power-of-two divisor applied (1 = it already fit).

    Power-of-two factors keep the coordinate mapping exact: a native
    pixel center x maps to downshifted center ``(x - c) / factor`` with
    ``c = (factor - 1) / 2`` (the mean-pool's phase offset), so results
    computed at the low resolution scale back to native (rho, theta)
    coordinates in closed form (``serve.detection.upscale_result``).
    """
    img = load_frame(raw)
    factor = 1
    while img.shape[0] > shape[0] or img.shape[1] > shape[1]:
        img = downsample2x(img)
        factor *= 2
    return img, factor


@functools.partial(jax.jit, static_argnames=("cfg", "tiers"))
def _detect(cfg: PipelineConfig, image: jax.Array,
            theta_bins: jax.Array | None = None,
            corridors: jax.Array | None = None, *,
            tiers: tuple[int, ...] | None = None) -> DetectionResult:
    """The one jitted detection body, shared across detector instances.

    With ``tiers=None``, ``cfg`` must be fully resolved (no "auto" knobs).
    With a tier tuple — the ``max_edges="auto"`` plan path — the device
    counts the Canny edge pixels (max over a batch: the compaction buffer
    is shared) and ``lax.switch``-es the vote stage to the tier that holds
    them all; one compiled program per (shape, cfg), zero host
    round-trips.  ``theta_bins`` (required iff ``cfg.hough.theta_band`` is
    set) carries the prediction gate: the vote sweeps only those theta
    bins (``core/tracking.py`` slides the gate frame to frame; the band
    length is the static part, so the program never recompiles).
    ``corridors`` (required iff ``cfg.hough.corridors`` is set — fused
    path only) is the (C, 4) rho-window set that pre-filters edge pixels.
    """
    H, W = image.shape[-2:]
    if cfg.fused:
        # Fused hot path: no edge map ever materializes — kernel A emits
        # the compacted (corridor-filtered) edge list straight from the
        # frame, and the result's ``edges`` field is a zeros placeholder.
        edges = jnp.zeros(image.shape, jnp.uint8)
        if tiers is None:
            votes = fused_hough(image, cfg.canny, cfg.hough, theta_bins,
                                corridors, scatter=False)
        else:
            votes = fused_hough_tiered(image, cfg.canny, cfg.hough, tiers,
                                       theta_bins, corridors,
                                       scatter=False)
    else:
        if corridors is not None:
            raise ValueError(
                "corridors is a fused-path argument; this plan is staged "
                "(PipelineConfig.fused=False)"
            )
        edges = canny(image, cfg.canny)
        # gated frames stay in band space end to end: the vote emits the
        # (n_rho, theta_band) accumulator and get_lines searches exactly
        # those columns, so the whole post-Canny stack scales with the band
        if tiers is None:
            votes = hough_transform(edges, cfg.hough, theta_bins,
                                    scatter=False)
        else:
            votes = hough_transform_tiered(edges, cfg.hough, tiers,
                                           theta_bins, scatter=False)
    lines, valid, peaks = get_lines(
        votes, height=H, width=W, cfg=cfg.lines, theta_bins=theta_bins
    )
    rendered = None
    if cfg.render_output:
        rendered = render_lines(image.astype(jnp.uint8), lines, valid)
    return DetectionResult(lines, valid, peaks, edges, rendered)


def batch_bucket(n: int) -> int:
    """Round a batch size up to the next power of two.

    Drifting batch sizes (uneven stream tails, partially full service
    slots) pad to a bucket instead of recompiling at their own shape."""
    if n <= 1:
        return 1
    b = 1
    while b < n:
        b *= 2
    return b


def resolve_static(cfg: PipelineConfig, height: int, width: int
                   ) -> tuple[PipelineConfig, tuple[int, ...] | None]:
    """Resolve every shape-static knob of ``cfg`` for one resolution.

    Returns ``(resolved_cfg, tiers)``: ``tiers`` is the static
    ``max_edges`` tier set when the config asks for the device-side
    autotune (``compact=True, max_edges="auto"``), else ``None`` with any
    inert ``"auto"`` neutralized so jit cache keys stay shared.  Pure and
    idempotent — ``resolve_static(*resolve_static(cfg, h, w)[:1], h, w)``
    is a fixed point (property-tested in ``tests/test_detection_service``).
    """
    h = cfg.hough
    if h.max_edges != "auto":
        return cfg, None
    if not h.compact:  # knob inert on the dense path
        return dataclasses.replace(
            cfg, hough=dataclasses.replace(h, max_edges=None)
        ), None
    return cfg, max_edge_tiers(height, width)


@dataclasses.dataclass(frozen=True)
class DetectionPlan:
    """A frozen "how to run" record for one ``(H, W, batch)`` workload.

    Everything data-independent is decided at build time: the resolved
    config, the batch padding bucket, and the autotune tier set.  ``run``
    only pads, dispatches the shared jitted body, and slices — safe under
    ``jax.transfer_guard("disallow")`` once warm.
    """
    cfg: PipelineConfig           # resolved: "auto" only with tiers set
    height: int
    width: int
    batch: int | None             # padded batch bucket; None = single frame
    tiers: tuple[int, ...] | None  # static autotune tiers (iff "auto")

    @classmethod
    def build(cls, cfg: PipelineConfig, height: int, width: int, *,
              batch: int | None = None) -> "DetectionPlan":
        if cfg.fused and not cfg.hough.compact:
            raise ValueError(
                "PipelineConfig.fused requires hough.compact=True: the "
                "fused kernel's output IS the compacted edge list."
            )
        resolved, tiers = resolve_static(cfg, height, width)
        return cls(resolved, height, width, batch, tiers)

    # --- derived plans -------------------------------------------------
    def with_render(self, render: bool) -> "DetectionPlan":
        """The same plan with the render phase bound on or off.

        Rendering is a config-static knob of the jitted body, so each
        value is its own compiled program; binding it at the plan level
        lets callers with per-request render demands (the detection
        service) flip between two frozen plans instead of re-resolving.
        Detection outputs (lines/valid/peaks/edges) are computed by the
        same ops either way — only the extra ``rendered`` field differs.
        """
        if self.cfg.render_output == render:
            return self
        return dataclasses.replace(
            self, cfg=dataclasses.replace(self.cfg, render_output=render)
        )

    def with_theta_band(self, band: int | None) -> "DetectionPlan":
        """The same plan with the prediction-gated vote bound to a static
        band width (``None`` = full sweep).

        Like ``with_render``, the band width is a config-static knob of the
        jitted body — the tracking loop (``core/tracking.py``) holds the
        full plan and its gated twin and flips between them on track
        loss/recovery instead of re-resolving; the gate's *bin values* are
        runtime data passed to ``run``.
        """
        if self.cfg.hough.theta_band == band:
            return self
        return dataclasses.replace(
            self, cfg=dataclasses.replace(
                self.cfg,
                hough=dataclasses.replace(self.cfg.hough, theta_band=band),
            )
        )

    def with_fused(self, corridors: int | None = None) -> "DetectionPlan":
        """The fused-hot-path twin of this plan, optionally with the
        rho-corridor pre-filter bound to a static corridor count.

        Same pattern as ``with_theta_band``: the fused binding and the
        corridor *count* are config-static knobs of the jitted body (one
        compiled program per value), while the corridor *windows* are
        runtime data passed to ``run``.  Callers (the tracking loop, the
        detection service) hold the staged plan and this twin, dispatching
        fused only when the tracker's corridors are healthy — the staged
        plan is the full-sweep fallback on cold start and overflow.
        Requires ``hough.compact=True`` (checked at build).
        """
        cfg = dataclasses.replace(
            self.cfg, fused=True,
            hough=dataclasses.replace(self.cfg.hough, corridors=corridors),
        )
        if cfg == self.cfg:
            return self
        if not cfg.hough.compact:
            raise ValueError(
                "with_fused requires hough.compact=True: the fused "
                "kernel's output IS the compacted edge list."
            )
        return dataclasses.replace(self, cfg=cfg)

    # --- execution ----------------------------------------------------
    def _dispatch(self, images: jax.Array,
                  theta_bins: jax.Array | None = None,
                  corridors: jax.Array | None = None) -> DetectionResult:
        return _detect(self.cfg, images, theta_bins, corridors,
                       tiers=self.tiers)

    def run(self, images, theta_bins=None, corridors=None
            ) -> DetectionResult:
        """Detect on a frame (H, W) or batch (N <= bucket, H, W).

        Batches shorter than the bucket are padded with zero frames (every
        stage is frame-independent, so pad rows never leak into real
        results) and the result is sliced back to the true length.
        ``theta_bins`` — required exactly when the plan's config sets
        ``theta_band`` — is the (theta_band,) int32 prediction gate, shared
        across the batch.  ``corridors`` — required exactly when the
        config sets ``hough.corridors`` (fused plans) — is the
        (corridors, 4) f32 rho-window set, likewise shared.
        """
        if theta_bins is not None:
            theta_bins = jnp.asarray(theta_bins, jnp.int32)
        if corridors is not None:
            corridors = jnp.asarray(corridors, jnp.float32)
        if self.batch is None:
            assert images.shape[-2:] == (self.height, self.width), (
                images.shape, self)
            return self._dispatch(images, theta_bins, corridors)
        n = images.shape[0]
        assert (images.ndim == 3 and n <= self.batch
                and images.shape[-2:] == (self.height, self.width)), (
            images.shape, self)
        if n < self.batch:
            images = jnp.concatenate([
                images,
                jnp.zeros((self.batch - n, self.height, self.width),
                          images.dtype),
            ])
        res = self._dispatch(images, theta_bins, corridors)
        if n == self.batch:
            return res
        return DetectionResult(
            res.lines[:n], res.valid[:n], res.peaks[:n], res.edges[:n],
            None if res.rendered is None else res.rendered[:n],
        )

    __call__ = run


class PlanCache:
    """Per-detector memo of plans keyed by ``(H, W, batch-bucket)``.

    ``device`` pins the cache (and everything staged through ``put``) to
    one jax device: a sharded service keeps one PlanCache per replica, so
    each replica's dispatches compile and run on its own device instead
    of whatever the backend default is.  ``None`` keeps the pre-mesh
    behavior (default device, plain ``jax.device_put``).
    """

    def __init__(self, cfg: PipelineConfig, *, device=None):
        self.cfg = cfg
        self.device = device
        self._plans: dict[tuple[int, int, int | None], DetectionPlan] = {}

    def put(self, x):
        """Ship a host batch to this cache's device (the one explicit
        transfer per dispatch — callers keep their hot loops under
        ``jax.transfer_guard("disallow")``)."""
        if self.device is None:
            return jax.device_put(x)
        return jax.device_put(x, self.device)

    def plan_for(self, height: int, width: int, *,
                 batch: int | None = None) -> DetectionPlan:
        key = (height, width, batch)
        plan = self._plans.get(key)
        if plan is None:
            plan = DetectionPlan.build(self.cfg, height, width, batch=batch)
            self._plans[key] = plan
        return plan

    def __len__(self) -> int:
        return len(self._plans)
