"""Detection-quality metrics: (rho, theta) matching, precision/recall/F1.

The paper judges detection by visual comparison (Fig. 4).  This module makes
quality a number: detected Hough peaks are matched one-to-one against the
scenario engine's analytic ground truth under a (rho, theta) tolerance, and
the match is scored as precision / recall / F1 plus mean localization error.
``tests/test_scenarios.py`` and ``benchmarks/scenario_suite.py`` hold every
scenario family — and every future perf PR — to these numbers.

Matching is Hungarian-style: admissible (detection, truth) pairs — those
within ``max(|drho|/tol_rho, |dtheta|/tol_theta) <= 1`` — form a bipartite
graph, and a maximum-cardinality one-to-one matching is found with Kuhn's
augmenting-path algorithm (edges tried lowest-cost-first, so ties resolve
to the nearest pair).  Maximum cardinality matters: two parallel truths
within ~2x tolerance of each other must not cost a true positive to a
greedy first-come assignment.  Line identity is wrap-aware: ``(rho,
theta)`` and ``(-rho, theta +- pi)`` name the same line, so near-vertical
lanes match across the theta seam.

Everything here is host-side numpy — metrics score concrete detector output,
they are never traced.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

#: Default tolerances: one detector bin of slack on each axis (rho_res=1px
#: accumulators quantize rho; 1-degree theta bins), scaled by the stroke
#: width the scenario engine plants.
TOL_RHO_PX = 4.0
TOL_THETA_DEG = 3.0


def rho_theta_residual(det: tuple[float, float], truth: tuple[float, float]
                       ) -> tuple[float, float]:
    """Wrap-aware (|drho| px, |dtheta| rad) between two normal-form lines."""
    rd, td = float(det[0]), float(det[1])
    rt, tt = float(truth[0]), float(truth[1])
    best = (float("inf"), float("inf"))
    for r, t in ((rd, td), (-rd, td + math.pi), (-rd, td - math.pi)):
        cand = (abs(r - rt), abs(t - tt))
        if cand[1] < best[1] or (cand[1] == best[1] and cand[0] < best[0]):
            best = cand
    return best


@dataclasses.dataclass(frozen=True)
class DetectionScore:
    tp: int
    fp: int
    fn: int
    precision: float
    recall: float
    f1: float
    mean_rho_err: float        # px, over matched pairs (nan if none)
    mean_theta_err_deg: float  # degrees, over matched pairs (nan if none)
    # Unmatched detections that still fall within tolerance of a *matched*
    # truth line.  A painted stroke has two raster sides, so a Hough
    # detector legitimately yields doublet peaks a few rho bins apart;
    # these score as duplicates, not false positives (an empty scene still
    # counts every spurious peak as a true FP — nothing to duplicate).
    dup: int = 0

    @property
    def perfect(self) -> bool:
        return self.fp == 0 and self.fn == 0


def match_peaks(detected: np.ndarray, truth: np.ndarray, *,
                tol_rho: float = TOL_RHO_PX,
                tol_theta_deg: float = TOL_THETA_DEG
                ) -> list[tuple[int, int, float, float]]:
    """One-to-one matching of detected peaks to ground-truth lines.

    Args:
      detected: (K, 2) array of (rho, theta_rad) detections.
      truth:    (M, 2) array of planted (rho, theta_rad).

    Returns a list of (det_idx, truth_idx, |drho|, |dtheta_deg|) pairs of
    a maximum-cardinality one-to-one matching over the admissible pairs
    (Kuhn's augmenting paths; candidate edges tried lowest-cost-first).
    """
    detected = np.asarray(detected, np.float64).reshape(-1, 2)
    truth = np.asarray(truth, np.float64).reshape(-1, 2)
    tol_theta = math.radians(tol_theta_deg)
    # admissible edges per detection, nearest truth first
    edges: list[list[tuple[float, int, float, float]]] = []
    for d in detected:
        adm = []
        for j, t in enumerate(truth):
            drho, dth = rho_theta_residual(tuple(d), tuple(t))
            if drho <= tol_rho and dth <= tol_theta:
                cost = max(drho / max(tol_rho, 1e-9),
                           dth / max(tol_theta, 1e-9))
                adm.append((cost, j, drho, dth))
        adm.sort()
        edges.append(adm)

    owner: dict[int, int] = {}  # truth_idx -> det_idx

    def try_assign(i: int, seen: set[int]) -> bool:
        for _, j, _, _ in edges[i]:
            if j in seen:
                continue
            seen.add(j)
            if j not in owner or try_assign(owner[j], seen):
                owner[j] = i
                return True
        return False

    # seed detections in ascending best-cost order so equal-cardinality
    # matchings prefer the nearer pairs
    order = sorted(range(len(edges)),
                   key=lambda i: edges[i][0][0] if edges[i] else math.inf)
    for i in order:
        if edges[i]:
            try_assign(i, set())

    matches = []
    for j, i in sorted(owner.items(), key=lambda kv: kv[1]):
        drho, dth = next(
            (r, t) for _, jj, r, t in edges[i] if jj == j
        )
        matches.append((i, j, drho, math.degrees(dth)))
    return matches


def score_frame(peaks: np.ndarray, valid: np.ndarray, truth: np.ndarray, *,
                tol_rho: float = TOL_RHO_PX,
                tol_theta_deg: float = TOL_THETA_DEG) -> DetectionScore:
    """Score one frame's detector output against its planted lines.

    ``peaks``/``valid`` are the (K, 2)/(K,) fields of a DetectionResult
    (only rows with ``valid`` count as detections); ``truth`` is the
    scenario's (M, 2) ``lines_rho_theta``.
    """
    peaks = np.asarray(peaks, np.float64).reshape(-1, 2)
    valid = np.asarray(valid, bool).reshape(-1)
    det = peaks[valid]
    truth = np.asarray(truth, np.float64).reshape(-1, 2)
    matches = match_peaks(det, truth, tol_rho=tol_rho,
                          tol_theta_deg=tol_theta_deg)
    tp = len(matches)
    matched_d = {m[0] for m in matches}
    matched_t = truth[[m[1] for m in matches]] if matches else truth[:0]
    tol_theta = math.radians(tol_theta_deg)
    dup = sum(
        1
        for i in range(det.shape[0])
        if i not in matched_d and any(
            (lambda r: r[0] <= tol_rho and r[1] <= tol_theta)(
                rho_theta_residual(tuple(det[i]), tuple(t))
            )
            for t in matched_t
        )
    )
    fp = det.shape[0] - tp - dup
    fn = truth.shape[0] - tp
    precision = tp / (tp + fp) if (tp + fp) else 1.0
    recall = tp / truth.shape[0] if truth.shape[0] else 1.0
    f1 = (2 * precision * recall / (precision + recall)
          if (precision + recall) else 0.0)
    rho_errs = [m[2] for m in matches]
    th_errs = [m[3] for m in matches]
    return DetectionScore(
        tp=tp, fp=fp, fn=fn, precision=precision, recall=recall, f1=f1,
        mean_rho_err=float(np.mean(rho_errs)) if rho_errs else float("nan"),
        mean_theta_err_deg=(
            float(np.mean(th_errs)) if th_errs else float("nan")
        ),
        dup=dup,
    )


def score_batch(peaks: np.ndarray, valid: np.ndarray,
                truths: Sequence[np.ndarray], *,
                tol_rho: float = TOL_RHO_PX,
                tol_theta_deg: float = TOL_THETA_DEG
                ) -> list[DetectionScore]:
    """Score a batched DetectionResult: peaks (N, K, 2), valid (N, K),
    truths a per-frame sequence of (M_i, 2) arrays."""
    peaks = np.asarray(peaks)
    valid = np.asarray(valid)
    assert peaks.ndim == 3 and len(truths) == peaks.shape[0], (
        peaks.shape, len(truths),
    )
    return [
        score_frame(peaks[i], valid[i], truths[i], tol_rho=tol_rho,
                    tol_theta_deg=tol_theta_deg)
        for i in range(peaks.shape[0])
    ]


def aggregate_scores(scores: Sequence[DetectionScore]) -> dict:
    """Micro-averaged precision/recall/F1 + mean localization error."""
    tp = sum(s.tp for s in scores)
    fp = sum(s.fp for s in scores)
    fn = sum(s.fn for s in scores)
    precision = tp / (tp + fp) if (tp + fp) else 1.0
    recall = tp / (tp + fn) if (tp + fn) else 1.0
    f1 = (2 * precision * recall / (precision + recall)
          if (precision + recall) else 0.0)
    rho = [s.mean_rho_err for s in scores if not math.isnan(s.mean_rho_err)]
    th = [s.mean_theta_err_deg for s in scores
          if not math.isnan(s.mean_theta_err_deg)]
    return {
        "tp": tp, "fp": fp, "fn": fn,
        "dup": sum(s.dup for s in scores),
        "precision": precision, "recall": recall, "f1": f1,
        "mean_rho_err": float(np.mean(rho)) if rho else float("nan"),
        "mean_theta_err_deg": float(np.mean(th)) if th else float("nan"),
    }
