"""Waypoint extraction and a pure-pursuit lateral controller.

This is the consumer the detector has been optimized *for*: detected
image lines (raw Hough peaks or smoothed ``LaneTracker`` tracks) become
metric ground-plane lane boundaries (``core.geometry``), the paired
boundaries become a centerline with waypoints, and a pure-pursuit law
turns the lookahead waypoint into a steering command.  The f1tenth
pipeline the ROADMAP names (detection -> centroid/waypoints -> lane
following), grown onto this repo's tracked, deadline-scheduled stack.

Frame conventions (see ``core.geometry``): vehicle/ground frame X right
(+m), Y forward (+m); a positive curvature command turns RIGHT (toward
+X).  The controller reports its *perceived* state alongside the
command — ``cross_track_m`` (vehicle offset right of the lane center)
and ``heading_rad`` (vehicle yaw right of the lane direction) — which
the closed-loop harness checks against the plant's true state.

Fallback ladder (mirrors the service's degradation ladder):

  * both boundaries visible -> centerline = their midpoint   ("pair")
  * one boundary           -> offset by half a lane width    ("left"/"right")
  * nothing usable         -> hold the last command, decayed ("hold"),
                              a zero command once the hold budget is
                              spent or there is no history    ("none")

Everything is host-side numpy/math — control runs per frame on scalars,
never inside a kernel.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, NamedTuple, Optional, Sequence

import numpy as np

from .geometry import CameraConfig, CameraGeometry

__all__ = [
    "ControlConfig", "SteeringCommand", "Waypoints", "LateralController",
    "extract_waypoints", "ground_boundaries",
]


@dataclasses.dataclass(frozen=True)
class ControlConfig:
    """Pure-pursuit + lane-model knobs.

    Defaults are tuned to the synthetic road families under
    ``geometry.DEFAULT_CAMERA``: the "straight" family's boundaries sit
    ~1 m apart on the ground (a narrow test track), visible from ~1.8 m
    (image bottom) to the horizon.
    """
    lookahead_m: float = 2.5        # pure-pursuit target distance
    wheelbase_m: float = 0.33       # steer angle = atan(wheelbase * kappa)
    lane_width_m: float = 1.0       # assumed width for single-boundary mode
    near_m: float = 2.0             # waypoint band start (>= image bottom)
    far_m: float = 6.0              # waypoint band end
    n_waypoints: int = 5
    max_heading_deg: float = 50.0   # lane-like filter: ground heading off Y
    max_curvature: float = 2.0      # command clamp, 1/m
    hold_decay: float = 0.7         # per-frame decay of a held command
    hold_frames: int = 12           # frames a stale command may be held


class Waypoints(NamedTuple):
    """Sampled centerline in the vehicle ground frame."""
    points: np.ndarray      # (n, 2) columns (X right, Y forward), meters
    source: str             # "pair" | "left" | "right" | "none"
    offset_m: float         # centerline lateral offset at Y=0 (= a)
    slope: float            # centerline dX/dY (= b)

    @property
    def found(self) -> bool:
        return self.source != "none"


class SteeringCommand(NamedTuple):
    """One frame's lateral command plus the perceived state behind it."""
    curvature: float        # 1/m, positive turns right (+X)
    steer_rad: float        # atan(wheelbase * curvature)
    cross_track_m: float    # perceived vehicle offset right of lane center
    heading_rad: float      # perceived vehicle yaw right of lane direction
    source: str             # "pair"|"left"|"right"|"hold"|"none"
    age: int                # 0 = fresh observation; k = held for k frames
    t: float                # controller clock at emission

    @property
    def fresh(self) -> bool:
        return self.age == 0 and self.source != "none"


def ground_boundaries(peaks: np.ndarray,
                      valid: Optional[Sequence[bool]],
                      geometry: CameraGeometry,
                      cfg: ControlConfig) -> list[tuple[float, float]]:
    """Detected image peaks -> lane-like ground lines.

    Maps every valid peak through the bird's-eye homography and keeps
    the ones running roughly along the vehicle's forward axis: a ground
    line ``X cos(t) + Y sin(t) = r`` heads within ``max_heading_deg`` of
    the Y axis iff ``|cos(t)| >= cos(max_heading_deg)`` (its normal is
    mostly lateral).  Cross-traffic, stop lines, and horizon artifacts
    fail the filter."""
    lines = geometry.lines_to_ground(np.asarray(peaks), valid)
    min_c = math.cos(math.radians(cfg.max_heading_deg))
    return [(float(r), float(t)) for r, t in lines
            if abs(math.cos(t)) >= min_c]


def _offset_slope(rho_g: float, theta_g: float) -> tuple[float, float]:
    """A lane-like ground line as ``X(Y) = a + b Y`` (valid because the
    lane filter guarantees cos(theta_g) is bounded away from zero)."""
    c, s = math.cos(theta_g), math.sin(theta_g)
    return rho_g / c, -s / c


def _centerline(ab: list[tuple[float, float]], cfg: ControlConfig, *,
                ref: tuple[float, float] = (0.0, 0.0),
                deltas: Optional[dict] = None
                ) -> Optional[tuple[float, float, str]]:
    """Fit the centerline ``X(Y) = a + b Y`` from boundary models ``ab``.

    Boundaries split left/right of the *reference* centerline (``ref``,
    the previous frame's fit — under a big yaw both boundaries can sit
    on the same side of X=0, so splitting around the predicted center is
    what stays stable); the innermost of each side forms the pair.  A
    single visible boundary is offset by the remembered boundary->center
    delta from the last full pair (``deltas``; the road's boundaries
    need not be parallel, so a fixed half-width + the boundary's own
    slope would bias both offset and heading), falling back to the
    ``lane_width_m`` prior when there is no pair history."""
    if not ab:
        return None
    near = cfg.near_m
    ref_near = ref[0] + ref[1] * near
    x_near = [a + b * near for a, b in ab]
    left = [i for i, x in enumerate(x_near) if x < ref_near]
    right = [i for i, x in enumerate(x_near) if x >= ref_near]
    if left and right:
        li = max(left, key=lambda i: x_near[i])     # innermost left
        ri = min(right, key=lambda i: x_near[i])    # innermost right
        a = (ab[li][0] + ab[ri][0]) / 2.0
        b = (ab[li][1] + ab[ri][1]) / 2.0
        if deltas is not None:
            deltas["left"] = (a - ab[li][0], b - ab[li][1])
            deltas["right"] = (a - ab[ri][0], b - ab[ri][1])
        return a, b, "pair"
    if left:
        li = max(left, key=lambda i: x_near[i])
        d = (deltas or {}).get("left")
        if d is None:
            d = (cfg.lane_width_m / 2.0, 0.0)
        return ab[li][0] + d[0], ab[li][1] + d[1], "left"
    ri = min(right, key=lambda i: x_near[i])
    d = (deltas or {}).get("right")
    if d is None:
        d = (-cfg.lane_width_m / 2.0, 0.0)
    return ab[ri][0] + d[0], ab[ri][1] + d[1], "right"


def _sample(a: float, b: float, cfg: ControlConfig) -> np.ndarray:
    ys = np.linspace(cfg.near_m, cfg.far_m, cfg.n_waypoints)
    return np.stack([a + b * ys, ys], axis=1)


def extract_waypoints(peaks: np.ndarray,
                      valid: Optional[Sequence[bool]],
                      geometry: CameraGeometry,
                      cfg: ControlConfig = ControlConfig()) -> Waypoints:
    """Centerline waypoints from one frame's detections, stateless: the
    pair/single-boundary ladder with the vehicle axis as the split
    reference and the half-lane-width prior for singles.  The
    :class:`LateralController` runs the same fit with cross-frame memory
    (previous centerline as the split reference, remembered
    boundary->center deltas); this function is the one-shot form for
    tests and ad-hoc callers."""
    bounds = ground_boundaries(peaks, valid, geometry, cfg)
    fit = _centerline([_offset_slope(r, t) for r, t in bounds], cfg)
    if fit is None:
        return Waypoints(np.zeros((0, 2)), "none", 0.0, 0.0)
    a, b, source = fit
    return Waypoints(_sample(a, b, cfg), source, float(a), float(b))


class LateralController:
    """Pure-pursuit lane following on an injectable clock.

    ``command(peaks, valid)`` ingests one frame's detections (raw peaks,
    or tracks via ``tracks_as_peaks`` — anything in image (rho, theta)
    form), extracts the centerline, and steers at the lookahead point
    ``(X_L, L)``: ``kappa = 2 X_L / (X_L^2 + L^2)``, the circle through
    the vehicle tangent to its heading.  With the centerline model
    ``X(Y) = a + b Y`` this is a PD law in disguise — ``a`` is the
    (negated) cross-track error and ``b L`` contributes the heading
    damping — which is why the closed loop converges without a separate
    rate term.

    ``hold()`` is the no-answer path (dropout, shed request, refused
    frame): re-emit the last command decayed by ``hold_decay``, up to
    ``hold_frames`` consecutive frames, then command straight.  The
    decay chain composes: k held frames scale the last fresh curvature
    by ``hold_decay^k``, so a blackout eases the vehicle straight
    instead of freezing it into a circle.

    Cross-frame lane memory: the controller keeps the last fitted
    centerline (the left/right split reference — stable under yaw, when
    both boundaries can sit on one side of the vehicle axis) and the
    last full pair's boundary->center deltas (so a single visible
    boundary reconstructs the centerline the pair would have given,
    instead of leaning on the half-width prior).  ``reset()`` drops the
    memory at a stream boundary.
    """

    def __init__(self, geometry: Optional[CameraGeometry] = None,
                 cfg: ControlConfig = ControlConfig(), *,
                 clock: Callable[[], float] = time.perf_counter):
        self.geometry = geometry if geometry is not None \
            else CameraGeometry(CameraConfig())
        self.cfg = cfg
        self.clock = clock
        self.last: Optional[SteeringCommand] = None
        self.waypoints: Optional[Waypoints] = None
        self._ref = (0.0, 0.0)          # last centerline (a, b)
        self._deltas: dict = {}         # boundary->center deltas
        self.fresh_commands = 0
        self.held_commands = 0

    def reset(self) -> None:
        self.last = None
        self.waypoints = None
        self._ref = (0.0, 0.0)
        self._deltas = {}

    # --- command paths ---------------------------------------------------
    def command(self, peaks, valid: Optional[Sequence[bool]] = None
                ) -> SteeringCommand:
        """Steer from one frame's detections (falls back to ``hold()``
        when nothing lane-like is visible)."""
        peaks = _as_peaks(peaks)
        bounds = ground_boundaries(peaks, valid, self.geometry, self.cfg)
        fit = _centerline([_offset_slope(r, t) for r, t in bounds],
                          self.cfg, ref=self._ref, deltas=self._deltas)
        if fit is None:
            return self.hold()
        a, b, source = fit
        self._ref = (a, b)
        cfg = self.cfg
        L = cfg.lookahead_m
        x_l = a + b * L
        kappa = 2.0 * x_l / (x_l * x_l + L * L)
        kappa = max(-cfg.max_curvature, min(cfg.max_curvature, kappa))
        cmd = SteeringCommand(
            curvature=kappa,
            steer_rad=math.atan(cfg.wheelbase_m * kappa),
            cross_track_m=-a,
            heading_rad=-math.atan(b),
            source=source, age=0, t=self.clock(),
        )
        self.waypoints = Waypoints(_sample(a, b, cfg), source,
                                   float(a), float(b))
        self.last = cmd
        self.fresh_commands += 1
        return cmd

    def hold(self) -> SteeringCommand:
        """The no-observation fallback: decay and re-emit the last
        command, or command straight once the budget is spent."""
        cfg = self.cfg
        prev = self.last
        if prev is not None and prev.age < cfg.hold_frames \
                and prev.source != "none":
            kappa = prev.curvature * cfg.hold_decay
            cmd = SteeringCommand(
                curvature=kappa,
                steer_rad=math.atan(cfg.wheelbase_m * kappa),
                cross_track_m=prev.cross_track_m,
                heading_rad=prev.heading_rad,
                source="hold", age=prev.age + 1, t=self.clock(),
            )
        else:
            cmd = SteeringCommand(0.0, 0.0, 0.0, 0.0, "none",
                                  (prev.age + 1) if prev is not None else 0,
                                  self.clock())
        self.last = cmd
        self.held_commands += 1
        return cmd


def _as_peaks(obs) -> np.ndarray:
    """Accept detector peaks ((K, 2) array) or tracker ``Track`` objects
    (anything with .rho/.theta) without importing the tracking module."""
    if isinstance(obs, np.ndarray):
        return obs.reshape(-1, 2)
    seq = list(obs)
    if seq and hasattr(seq[0], "rho"):
        return np.array([[t.rho, t.theta] for t in seq],
                        float).reshape(-1, 2)
    return np.asarray(seq, float).reshape(-1, 2)
