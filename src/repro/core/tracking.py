"""Temporal lane tracking: a wrap-aware (rho, theta) track filter and the
prediction-gated detection loop built on it.

The paper's workload is a camera stream on a moving vehicle, but the
per-frame detector throws frame-to-frame continuity away: every frame
re-runs the full theta sweep from scratch.  This module adds the temporal
layer:

  * :class:`LaneTracker` — one constant-velocity alpha-beta filter per lane
    in (rho, theta) normal form.  Line identity is wrap-aware ((rho, theta)
    and (-rho, theta +- pi) name the same line — the same equivalence
    ``core.metrics.rho_theta_residual`` scores with), association is gated
    one-to-one maximum-cardinality matching (``core.metrics.match_peaks``
    with the gate as the tolerance), and tracks live a birth -> confirm ->
    coast -> kill lifecycle: a confirmed track predicts through dropped
    frames (dropout/blackout, rain bursts) and dies only after
    ``max_misses`` consecutive misses.
  * **Prediction-gated Hough** — confirmed tracks restrict the next
    frame's vote to theta windows around their predicted lanes:
    :meth:`LaneTracker.gate_bins` emits the (static-length, runtime-valued)
    bin vector ``HoughConfig.theta_band`` plans consume, so steady-state
    frames sweep a fraction of the theta bins and fall back to the full
    sweep on track loss.  ``benchmarks/tracking_suite.py`` measures the
    steady-state win.
  * :class:`TrackingPipeline` — the per-session frame loop gluing the two
    together (detect gated-or-full -> update tracker -> report smoothed
    tracks); ``serve/detection.py`` keeps one tracker per streaming
    session on the same API.

Everything here is host-side and deterministic: the filter is a handful of
scalar updates per track, association is the same Kuhn matching the
quality harness uses, and no step consults a clock or an RNG —
``tests/test_tracking.py`` replays drive cycles bit-identically.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple, Optional, Sequence

import numpy as np

from .metrics import match_peaks
from .plan import DetectionPlan, DetectionResult, PipelineConfig, load_frame


@dataclasses.dataclass(frozen=True)
class TrackerConfig:
    """Knobs of the per-lane alpha-beta filter and its lifecycle.

    The gates are deliberately wider than the quality harness's matching
    tolerance (4 px / 3 deg): association must hold a track through the
    frame-to-frame motion *plus* detector quantization, while scoring only
    judges the final smoothed state.
    """
    gate_rho: float = 14.0        # association gate (px)
    gate_theta_deg: float = 9.0   # association gate (degrees)
    alpha: float = 0.5            # position gain (per-frame dt = 1)
    beta: float = 0.2             # velocity gain
    confirm_hits: int = 2         # detections before a track is confirmed
    max_misses: int = 3           # coasted frames before a kill
    coast_hits: int = 6           # hits before a coasting track is REPORTED
    # Velocity decay per coasted frame: an unobserved lane's velocity is
    # stale (ego sway turns around in a few frames), so an undamped
    # constant-velocity coast overshoots exactly when the vehicle is
    # reversing its drift.  Decaying toward "hold position" keeps a
    # blackout-length coast close to the lane (a lane change continues
    # under a blackout, so full damping would undershoot as badly as no
    # damping overshoots a sway turnaround).
    coast_damping: float = 0.85
    # Full-sweep frames after a confirmed track dies.  The gate only
    # sweeps confirmed tracks' windows, so without a rescan a lane whose
    # track was lost (e.g. killed during a blackout) would be permanently
    # invisible while a surviving track keeps the gate engaged — the
    # classic gated-tracking lock-out.  Long enough to rebirth + confirm
    # a replacement (confirm_hits) with margin.
    rescan_frames: int = 5
    # Warm-start coast eligibility: a session that has been *grounded* —
    # step() matched at least one detection — this many frames EVER may
    # coast on any confirmed track, even one whose own ``hits`` count is
    # still short of ``coast_hits``.  Under overload shed pressure (or on
    # noisy families where detections flicker between a stroke's raster
    # sides) tracks churn faster than any single one can accumulate
    # ``coast_hits`` matched detections, so the strictly per-track bar
    # starves the ladder's coast rung exactly when it is needed; the
    # session-level bar says "this camera has proven it sees lanes",
    # which is the evidence the per-track bar was a proxy for.  The miss
    # budget (``misses + steps <= max_misses``) still applies per track,
    # so a warm-started coast can never outlive a real blackout.
    warm_frames: int = 10
    band_half_deg: float = 8.0    # per-track half-width of the Hough gate
    # Per-track half-width (px) of the fused path's rho corridor: the
    # window around a predicted lane inside which edge pixels may vote
    # (``corridors()``).  Sized to cover the association gate
    # (``gate_rho``) plus the worst-case rho drift of a real edge pixel
    # under the prediction's theta error (~s*sin(dtheta): a pixel ~200 px
    # along the lane under a ~1.7 deg error moves ~6 px in rho) with
    # slack — a lane's edge pixels must stay in-corridor whenever the
    # association gate would still claim the lane.
    corridor_half_px: float = 25.0
    # Pre-association doublet merge: a painted stroke has two raster
    # sides, so the detector legitimately yields peak pairs a few rho bins
    # apart (what metrics.DetectionScore counts as ``dup``).  Tracking
    # each side separately breeds twin tracks whose coasts drift apart;
    # merging the sides to their wrap-aware mean — the stroke centerline,
    # which is exactly where truth is planted — gives one track per lane.
    # The tolerance also folds noise-burst satellite peaks riding next to
    # a lane into its cluster, so a burst cannot capture the track while
    # the true detection births a twin (an ID switch + a lingering false
    # coast).  Real lanes sit far apart in every family, and clusters are
    # linked against their first member, so the tolerance bounds total
    # cluster spread.  0 disables the merge.
    merge_rho: float = 8.0
    merge_theta_deg: float = 2.5


@dataclasses.dataclass
class Track:
    """One lane's filter state (canonical form: theta in [0, pi))."""
    track_id: int
    rho: float
    theta: float
    drho: float = 0.0
    dtheta: float = 0.0
    hits: int = 1                 # total matched detections
    misses: int = 0               # consecutive missed frames (coasting)
    age: int = 1                  # frames since birth
    confirmed: bool = False

    @property
    def coasting(self) -> bool:
        return self.misses > 0

    @property
    def peak(self) -> tuple[float, float]:
        return (self.rho, self.theta)


def wrap_canonical(rho: float, theta: float) -> tuple[float, float]:
    """Fold (rho, theta) into the canonical theta in [0, pi) sheet
    (rho flips sign with each half-turn)."""
    while theta >= math.pi:
        theta -= math.pi
        rho = -rho
    while theta < 0.0:
        theta += math.pi
        rho = -rho
    return rho, theta


def signed_residual(det: tuple[float, float], ref: tuple[float, float]
                    ) -> tuple[float, float]:
    """Signed, wrap-aware (drho, dtheta) of a detection about a reference.

    The signed twin of ``core.metrics.rho_theta_residual`` (same candidate
    set, same theta-first tie-break, so the filter's innovation and the
    harness's score agree on which wrap sheet a detection lives on): picks
    the representation of ``det`` among (rho, theta) / (-rho, theta +- pi)
    nearest the reference in theta and returns the *signed* differences
    the alpha-beta update integrates.
    """
    rd, td = float(det[0]), float(det[1])
    rr, rt = float(ref[0]), float(ref[1])
    best: Optional[tuple[float, float]] = None
    for r, t in ((rd, td), (-rd, td + math.pi), (-rd, td - math.pi)):
        cand = (r - rr, t - rt)
        if (best is None or abs(cand[1]) < abs(best[1])
                or (abs(cand[1]) == abs(best[1])
                    and abs(cand[0]) < abs(best[0]))):
            best = cand
    return best


def merge_peaks(peaks: np.ndarray, *, tol_rho: float, tol_theta_deg: float
                ) -> np.ndarray:
    """Cluster near-identical detections into their wrap-aware means.

    Single-linkage against each cluster's first member, in input order
    (deterministic); members are folded onto the representative's wrap
    sheet via ``signed_residual`` before averaging, so a doublet
    straddling the theta seam still collapses to one line.  Returns the
    (K', 2) cluster means, canonicalized.
    """
    peaks = np.asarray(peaks, np.float64).reshape(-1, 2)
    tol_theta = math.radians(tol_theta_deg)
    reps: list[tuple[float, float]] = []      # cluster representatives
    residuals: list[list[tuple[float, float]]] = []
    for det in peaks:
        for rep, res in zip(reps, residuals):
            drho, dtheta = signed_residual(tuple(det), rep)
            if abs(drho) <= tol_rho and abs(dtheta) <= tol_theta:
                res.append((drho, dtheta))
                break
        else:
            reps.append((float(det[0]), float(det[1])))
            residuals.append([(0.0, 0.0)])
    out = [
        wrap_canonical(rep[0] + float(np.mean([r[0] for r in res])),
                       rep[1] + float(np.mean([r[1] for r in res])))
        for rep, res in zip(reps, residuals)
    ]
    return np.asarray(out, np.float64).reshape(-1, 2)


class LaneTracker:
    """Constant-velocity alpha-beta tracking of lane lines in (rho, theta).

    ``step(peaks, valid)`` advances one frame: predict every track by its
    velocity, associate detections one-to-one inside the gate
    (``core.metrics.match_peaks`` — maximum-cardinality, nearest-first, so
    two close lanes never steal each other's detection), update matched
    tracks, coast the unmatched ones, birth tentative tracks from leftover
    detections, and kill anything past ``max_misses``.  It returns the
    frame's *reported* tracks: every track matched this frame plus every
    mature (``hits >= coast_hits``) confirmed track coasting through a
    miss — i.e. the temporal layer's
    answer to "which lanes are in front of the vehicle right now", which
    is what the drive-cycle harness scores as "tracked F1".
    """

    def __init__(self, cfg: TrackerConfig = TrackerConfig()):
        self.cfg = cfg
        self._tracks: list[Track] = []
        self._next_id = 0
        self.frame = 0
        self._rescan = 0          # full-sweep frames still owed (see cfg)
        # frames where step() matched >= 1 detection to a track — the
        # session-level "has this camera ever seen lanes" evidence the
        # warm-start coast rule reads (cfg.warm_frames)
        self.grounded_frames = 0

    # --- introspection --------------------------------------------------
    @property
    def tracks(self) -> list[Track]:
        """Live tracks (snapshot copies — internal state stays private)."""
        return [dataclasses.replace(t) for t in self._tracks]

    @property
    def confirmed_tracks(self) -> list[Track]:
        return [dataclasses.replace(t)
                for t in self._tracks if t.confirmed]

    # --- the filter -----------------------------------------------------
    def _predict(self) -> None:
        for t in self._tracks:
            t.rho += t.drho
            t.theta += t.dtheta
            self._canonicalize(t)
            t.age += 1

    @staticmethod
    def _canonicalize(t: Track) -> None:
        # folding theta by +-pi negates rho — and therefore the rho
        # velocity: the motion is continuous on the covering space, so the
        # canonical-sheet representative flips drho with rho (dtheta is a
        # rotation rate, unchanged).
        while t.theta >= math.pi:
            t.theta -= math.pi
            t.rho, t.drho = -t.rho, -t.drho
        while t.theta < 0.0:
            t.theta += math.pi
            t.rho, t.drho = -t.rho, -t.drho

    def step(self, peaks, valid=None, *, scale: float = 1.0) -> list[Track]:
        """Advance one frame on the detector's (K, 2)/(K,) peak output.

        ``valid=None`` treats every row of ``peaks`` as a detection.
        ``scale`` is the resolution divisor the detections were computed
        at (1 = native): a frame served downshifted by ``factor`` carries
        rho quantization error ~``factor`` times the native bin, so the
        rho association gate (and the doublet-merge tolerance) widen by
        it — otherwise an upscaled coarse detection lands just outside
        the native gate, the true track coasts, and a twin is born at
        the quantized position (the track-churn path that starves the
        coast rung across resolution downshifts).  Theta is
        scale-invariant, so the theta gate does not widen.
        Returns the reported tracks for this frame (see class docstring).
        """
        peaks = np.asarray(peaks, np.float64).reshape(-1, 2)
        if valid is not None:
            peaks = peaks[np.asarray(valid, bool).reshape(-1)]
        cfg = self.cfg
        scale = max(1.0, float(scale))
        # consume one owed rescan frame BEFORE any kill below can open a
        # new window: a kill at this frame must leave the full
        # rescan_frames budget for the frames after it
        if self._rescan > 0:
            self._rescan -= 1
        if cfg.merge_rho > 0.0 and peaks.shape[0] > 1:
            peaks = merge_peaks(peaks, tol_rho=cfg.merge_rho * scale,
                                tol_theta_deg=cfg.merge_theta_deg)

        self._predict()
        predicted = np.array([[t.rho, t.theta] for t in self._tracks],
                             np.float64).reshape(-1, 2)
        matches = match_peaks(
            peaks, predicted,
            tol_rho=cfg.gate_rho * scale,
            tol_theta_deg=cfg.gate_theta_deg,
        )
        matched_det = {m[0] for m in matches}
        matched_trk = {m[1] for m in matches}
        if matches:
            self.grounded_frames += 1

        for det_i, trk_i, _, _ in matches:
            t = self._tracks[trk_i]
            drho, dtheta = signed_residual(
                tuple(peaks[det_i]), (t.rho, t.theta)
            )
            t.rho += cfg.alpha * drho
            t.theta += cfg.alpha * dtheta
            t.drho += cfg.beta * drho
            t.dtheta += cfg.beta * dtheta
            self._canonicalize(t)
            t.hits += 1
            t.misses = 0
            if t.hits >= cfg.confirm_hits:
                t.confirmed = True

        for i, t in enumerate(self._tracks):
            if i not in matched_trk:
                t.misses += 1   # state already holds the prediction: coast
                t.drho *= cfg.coast_damping
                t.dtheta *= cfg.coast_damping

        # kill: confirmed tracks coast through max_misses frames; a
        # tentative track was never corroborated, so one miss kills it.
        # Losing a *confirmed* track opens the rescan window — the next
        # rescan_frames sweeps run ungated so the lane (which may well
        # still be there) can be re-acquired.
        survivors = []
        for t in self._tracks:
            if t.misses <= (cfg.max_misses if t.confirmed else 0):
                survivors.append(t)
            elif t.confirmed:
                self._rescan = cfg.rescan_frames
        self._tracks = survivors

        for i in range(peaks.shape[0]):
            if i in matched_det:
                continue
            rho, theta = wrap_canonical(float(peaks[i, 0]),
                                        float(peaks[i, 1]))
            self._tracks.append(Track(self._next_id, rho, theta))
            self._next_id += 1

        self.frame += 1
        # report: everything matched this frame, plus coasting tracks that
        # EARNED the right to be predicted forward (>= coast_hits matched
        # detections).  A barely-confirmed spur — e.g. a transient doublet
        # side-peak that flickered twice — may keep coasting internally
        # for re-association, but reporting its drifting prediction would
        # trade the harness's false positives for the dropout coverage the
        # coast exists for.
        return [
            dataclasses.replace(t) for t in self._tracks
            if t.misses == 0
            or (t.confirmed and t.hits >= cfg.coast_hits)
        ]

    # --- coast-only prediction (degraded serving) -----------------------
    def coastable_tracks(self, steps: int = 1) -> list[Track]:
        """Tracks *eligible* to answer a frame from prediction alone.

        The degradation ladder's coast rung (``serve/detection.py``)
        answers an overloaded frame from the session tracker without
        running detection at all — but only a track that has EARNED the
        coast may back such an answer, by the same rules ``step`` applies
        to real missed frames: confirmed, mature, and still inside its
        miss budget after ``steps`` more unobserved frames
        (``misses + steps <= max_misses``).  A service can therefore
        never coast a session further than the tracker itself would have
        survived a real dropout — the coast budget and the blackout
        budget are one number.

        Maturity is per-track (``hits >= coast_hits``), with a
        session-level warm-start *fallback*: when no track meets the
        strict bar but the tracker has been grounded ``warm_frames``
        frames *ever* (not consecutively), the confirmed tracks qualify
        anyway — under shed pressure or detection churn no single track
        may survive long enough to accumulate ``coast_hits``, while the
        session as a whole has long since proven it sees lanes (see
        ``TrackerConfig.warm_frames``).  Fallback, not widening: a
        session with mature tracks answers from exactly those (immature
        twins never dilute a good coast), so the warm start only engages
        where the strict bar would have starved the rung entirely.
        """
        cfg = self.cfg
        strict = [
            t for t in self._tracks
            if t.confirmed and t.hits >= cfg.coast_hits
            and t.misses + steps <= cfg.max_misses
        ]
        if strict or self.grounded_frames < cfg.warm_frames:
            return strict
        return [
            t for t in self._tracks
            if t.confirmed and t.misses + steps <= cfg.max_misses
        ]

    def can_coast(self, steps: int = 1) -> bool:
        """True iff at least one track may answer ``steps`` frames ahead."""
        return bool(self.coastable_tracks(steps))

    def predict_tracks(self, steps: int = 1) -> list[Track]:
        """``steps``-ahead predicted state of the coast-eligible tracks,
        WITHOUT mutating the tracker.

        Applies exactly the per-frame coast update ``step`` would: state
        advances by the (decaying) velocity and the velocity damps by
        ``coast_damping`` each unobserved frame — so a coast-only answer
        for frame t+k is bit-identical to what the tracker would have
        reported had it actually coasted through k missed frames.  The
        tracker itself does NOT advance: the real frame may still arrive
        (late, after the deadline) or the next frame may be served for
        real, and session stream-order must survive either outcome.
        Returns [] when nothing is eligible (see ``coastable_tracks``).
        """
        cfg = self.cfg
        out = []
        for t in self.coastable_tracks(steps):
            p = dataclasses.replace(t)
            for _ in range(max(1, int(steps))):
                p.rho += p.drho
                p.theta += p.dtheta
                p.drho *= cfg.coast_damping
                p.dtheta *= cfg.coast_damping
                p.misses += 1
                p.age += 1
            self._canonicalize(p)
            out.append(p)
        return out

    # --- the prediction gate --------------------------------------------
    def gate_bins(self, n_theta: int = 180, *,
                  band: Optional[int] = None) -> Optional[np.ndarray]:
        """Theta bins the *next* frame's Hough sweep should vote over.

        The union of ``+- band_half_deg`` windows (mod n_theta — the gate
        follows a lane across the theta seam) around EVERY live track's
        one-frame-ahead predicted theta — tentative tracks included: a
        newly-born lane must be swept so it can confirm (or, if it was a
        ghost, miss and die) under the gate, otherwise a lane acquired one
        frame after its neighbor would be locked out forever.  Returns
        None — "run the full sweep" — whenever the tracker is not
        *healthy*: no confirmed track (cold start, total loss), any
        confirmed track coasting (its detection is missing — a gate would
        search only where we already failed to look), an open rescan
        window after a track death (a lost lane must be re-acquirable:
        the gate only covers surviving tracks, so without the rescan a
        dead track's lane would stay invisible forever), or a window
        union overflowing the static ``band`` length.  Otherwise a sorted
        (band,) int32 vector, padded by repeating the first bin
        (duplicate gate bins are idempotent in the vote scatter).
        """
        conf = [t for t in self._tracks if t.confirmed]
        if not conf or self._rescan > 0:
            return None
        if any(t.misses > 0 for t in conf):
            return None
        bin_deg = 180.0 / n_theta
        half = max(1, int(math.ceil(self.cfg.band_half_deg / bin_deg)))
        bins: set[int] = set()
        for t in self._tracks:
            pred_theta = t.theta + t.dtheta
            center = int(round(pred_theta / (math.pi / n_theta)))
            for d in range(-half, half + 1):
                bins.add((center + d) % n_theta)
        out = sorted(bins)
        if band is not None:
            if len(out) > band:
                return None
            out = out + [out[0]] * (band - len(out))
        return np.asarray(out, np.int32)

    # --- the rho corridors (fused hot path) -----------------------------
    def corridors(self, max_corridors: Optional[int] = None, *,
                  half_px: Optional[float] = None) -> Optional[np.ndarray]:
        """Rho windows the *next* frame's fused kernel may keep edges in.

        The spatial twin of :meth:`gate_bins`: one ``[cos, sin, rho_lo,
        rho_hi]`` row per live track (tentative included — a newborn lane's
        edge pixels must survive the filter so it can confirm or die) at
        the one-frame-ahead prediction, with half-width
        ``TrackerConfig.corridor_half_px`` (overridable via ``half_px``).
        Health rules are *identical* to the theta gate — None ("keep every
        pixel: run the staged full sweep") on cold start, any confirmed
        track coasting, an open rescan window, or (with ``max_corridors``
        set) window overflow — so a pipeline that consults both gates
        degrades them together.  With ``max_corridors`` the result is
        padded to the plan's static (max_corridors, 4) shape by repeating
        the first row (the kernel's any-corridor OR is idempotent);
        ``max_corridors=None`` returns the raw unpadded rows for callers
        that union across sessions first (``serve/detection.py``).
        """
        conf = [t for t in self._tracks if t.confirmed]
        if not conf or self._rescan > 0:
            return None
        if any(t.misses > 0 for t in conf):
            return None
        half = float(self.cfg.corridor_half_px
                     if half_px is None else half_px)
        rows = []
        for t in self._tracks:
            rho_p = t.rho + t.drho
            th_p = t.theta + t.dtheta
            rows.append([math.cos(th_p), math.sin(th_p),
                         rho_p - half, rho_p + half])
        if max_corridors is not None:
            if len(rows) > max_corridors:
                return None
            rows = rows + [rows[0]] * (max_corridors - len(rows))
        return np.asarray(rows, np.float32).reshape(-1, 4)


def tracks_as_peaks(tracks: Sequence[Track]) -> tuple[np.ndarray, np.ndarray]:
    """(M, 2) peaks + all-true valid mask from reported tracks — the
    adapter between a tracker's per-frame report and the (peaks, valid)
    interface of ``core.metrics.score_frame``."""
    peaks = np.array([[t.rho, t.theta] for t in tracks],
                     np.float32).reshape(-1, 2)
    return peaks, np.ones(peaks.shape[0], bool)


class TrackedFrame(NamedTuple):
    result: DetectionResult     # raw detector output for the frame
    tracks: list[Track]         # reported (smoothed) tracks
    gated: bool                 # True iff the frame ran the gated sweep
    steering: Optional[object] = None   # SteeringCommand when a
                                        # controller is attached

    @property
    def control_peaks(self) -> tuple[np.ndarray, np.ndarray]:
        """The (peaks, valid) a controller should steer from: smoothed
        tracks when the tracker reports any, the frame's raw detections
        otherwise (cold start / track loss — steering falls back exactly
        like detection falls back to the full sweep)."""
        if self.tracks:
            return tracks_as_peaks(self.tracks)
        return (np.asarray(self.result.peaks).reshape(-1, 2),
                np.asarray(self.result.valid).reshape(-1))


class TrackingPipeline:
    """The per-session frame loop: prediction-gated detect -> track.

    Holds one full-sweep plan and (when ``theta_band`` is set) its gated
    twin for a fixed resolution.  Each ``process(frame)``:

      1. asks the tracker for the prediction gate; confirmed tracks yield
         a theta-bin vector and the *gated* plan runs (a fraction of the
         theta sweep), otherwise the full plan runs (cold start / track
         loss fall back to the exhaustive sweep — gating is a perf hook,
         never a correctness dependence),
      2. advances the tracker on the frame's detections,
      3. returns the raw result, the smoothed reported tracks, and which
         path ran.

    ``gated_frames`` / ``full_frames`` count the split —
    ``benchmarks/tracking_suite.py`` requires the steady state to be
    (almost) all gated.

    ``fused_corridors`` (requires ``cfg.hough.compact=True`` and a theta
    band) additionally builds the fused-hot-path twin of the gated plan
    (``DetectionPlan.with_fused``): a steady-state frame whose tracker
    yields BOTH a healthy theta gate and healthy rho corridors runs the
    fused kernel (corridor-filtered, no edge map in HBM); any health
    failure falls back exactly as before (gated, then full sweep).
    ``fused_frames`` counts those dispatches.
    """

    def __init__(self, cfg: PipelineConfig = PipelineConfig(),
                 tracker: TrackerConfig = TrackerConfig(), *,
                 height: int = 240, width: int = 320,
                 theta_band: Optional[int] = 40,
                 fused_corridors: Optional[int] = None):
        if cfg.hough.theta_band is not None:
            raise ValueError(
                "pass the gate width via theta_band=, not through the "
                "config: the pipeline derives the gated plan itself"
            )
        if cfg.hough.corridors is not None or cfg.fused:
            raise ValueError(
                "pass the corridor count via fused_corridors=, not "
                "through the config: the pipeline derives the fused plan "
                "itself"
            )
        if fused_corridors is not None and theta_band is None:
            raise ValueError(
                "fused_corridors requires a theta_band: the fused plan "
                "is the gated plan's twin"
            )
        self.full_plan = DetectionPlan.build(cfg, height, width)
        self.gated_plan = (
            self.full_plan.with_theta_band(theta_band)
            if theta_band is not None else None
        )
        # with_fused raises unless cfg.hough.compact=True
        self.fused_plan = (
            self.gated_plan.with_fused(fused_corridors)
            if fused_corridors is not None else None
        )
        self.n_theta = cfg.hough.n_theta
        self.theta_band = theta_band
        self.fused_corridors = fused_corridors
        self.tracker = LaneTracker(tracker)
        self.gated_frames = 0
        self.full_frames = 0
        self.fused_frames = 0

    def process(self, frame, controller=None) -> TrackedFrame:
        """Detect + track one frame; with a ``controller``
        (``core.control.LateralController``) attached, also emit the
        frame's steering command (from the smoothed tracks when any are
        reported, the raw detections otherwise — see
        ``TrackedFrame.control_peaks``) so callers get the full
        perception -> control spine in one call."""
        img = load_frame(frame)
        bins = None
        if self.gated_plan is not None:
            bins = self.tracker.gate_bins(self.n_theta,
                                          band=self.theta_band)
        if bins is None:
            res = self.full_plan.run(img)
            self.full_frames += 1
        else:
            cors = (self.tracker.corridors(self.fused_corridors)
                    if self.fused_plan is not None else None)
            if cors is not None:
                res = self.fused_plan.run(img, bins, cors)
                self.fused_frames += 1
            else:
                res = self.gated_plan.run(img, bins)
            self.gated_frames += 1
        tracks = self.tracker.step(np.asarray(res.peaks),
                                   np.asarray(res.valid))
        out = TrackedFrame(res, tracks, bins is not None)
        if controller is not None:
            out = out._replace(
                steering=controller.command(*out.control_peaks)
            )
        return out
