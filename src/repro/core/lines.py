"""Get-lines-coordinates (paper Section 4.3 / Algorithm 3).

Local-maximum search over the Hough accumulator followed by conversion of
each (rho, theta) peak into the two endpoints of a segment clipped to the
image.  0.45% of line-detection time in the paper (Table 3) — it stays on
the "scalar" side of the partition (plain XLA elementwise/top-k; no kernel).

Static shapes: returns exactly ``max_lines`` rows plus a validity mask, so
the whole pipeline jits and shards (the paper's dynamically-growing
``lines`` list cannot cross a jit boundary).
"""

from __future__ import annotations

import dataclasses
import functools
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LinesConfig:
    threshold: float = 80.0   # min votes for a peak (paper's threshold)
    # if set, the effective threshold is max(floor, rel * max(votes)):
    # relative to the strongest peak so dashed/short strokes survive, but
    # never below an absolute floor — a markings-free frame (scenario
    # family "empty") must yield zero detections, not scaled-down noise.
    # The floor defaults to min_votes_frac * image diagonal (a line must
    # cover ~9% of the longest possible stroke), overridable via min_votes.
    threshold_rel: float | None = 0.5
    min_votes: float | None = None
    min_votes_frac: float = 0.09
    neighborhood: int = 7     # local-max window (paper checks a vecinity)
    max_lines: int = 16       # static K
    rho_res: float = 1.0
    n_theta: int = 180


def _maxpool(x: jax.Array, k: int) -> jax.Array:
    # max is associative: the k x k window separates into k x 1 then 1 x k
    # passes — bit-identical output, ~half the wall time of the fused 2-D
    # reduce_window on CPU XLA (2k vs k^2 comparisons per element)
    ones = (1,) * (x.ndim - 2)
    x = jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, ones + (k, 1), (1,) * x.ndim, "SAME"
    )
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, ones + (1, k), (1,) * x.ndim, "SAME"
    )


@functools.partial(jax.jit, static_argnames=("cfg", "height", "width"))
def get_lines(votes: jax.Array, *, height: int, width: int,
              cfg: LinesConfig = LinesConfig(),
              theta_bins: jax.Array | None = None):
    """Returns (lines (..., K, 4) f32 [x1, y1, x2, y2], valid (..., K) bool,
    peaks (..., K, 2) f32 [rho, theta_rad]).

    ``votes`` is (n_rho, n_theta) or batched (N, n_rho, n_theta); the peak
    search, top-k, and endpoint math all broadcast over leading axes.

    Prediction-gated band space: with ``theta_bins`` (B,) set, ``votes``
    is a band accumulator (..., n_rho, B) whose column k is GLOBAL theta
    bin ``theta_bins[k]`` — the whole peak stage (threshold, local max,
    top-k) then runs over B columns instead of ``cfg.n_theta`` and only
    the angle decode maps through the bin vector.  With ``theta_bins ==
    arange(n_theta)`` this is bit-exact with the ungated call.  Caveats of
    a gated band, by construction of the gate: the local-max neighborhood
    wraps across adjacent gate windows at their seams (3 columns at each
    window edge — the tracker centers true peaks away from edges), and
    duplicate padding bins yield duplicate peak rows (downstream merging
    collapses them; see ``core.tracking.merge_peaks``).
    """
    n_rho, n_theta = votes.shape[-2:]
    diag = math.hypot(height, width)

    if cfg.threshold_rel is not None:
        floor = (cfg.min_votes if cfg.min_votes is not None
                 else cfg.min_votes_frac * diag)
        thresh = jnp.maximum(
            floor,
            cfg.threshold_rel * jnp.max(votes, axis=(-2, -1), keepdims=True),
        )
    else:
        thresh = cfg.threshold
    is_peak = (votes >= thresh) & (
        votes >= _maxpool(votes, cfg.neighborhood)
    )
    score = jnp.where(is_peak, votes, -1.0).reshape(
        votes.shape[:-2] + (n_rho * n_theta,)
    )
    top, idx = jax.lax.top_k(score, cfg.max_lines)
    valid = top > 0

    rho_idx = idx // n_theta
    theta_idx = idx % n_theta
    if theta_bins is not None:
        theta_idx = theta_bins[theta_idx]   # band column -> global bin
        theta_scale = math.pi / cfg.n_theta  # bins index the FULL sweep
    else:
        theta_scale = math.pi / n_theta
    rho = rho_idx.astype(jnp.float32) * cfg.rho_res - diag
    theta = theta_idx.astype(jnp.float32) * theta_scale

    lines = peak_segments(rho, theta, half=float(max(height, width)))
    peaks = jnp.stack([rho, theta], axis=-1)
    return lines, valid, peaks


def peak_segments(rho: jax.Array, theta: jax.Array, *, half: float
                  ) -> jax.Array:
    """(..., 4) segment endpoints [x1, y1, x2, y2] of normal-form lines.

    Walk +-``half`` along the line direction from the foot of the
    perpendicular (the paper renders essentially the same way).  The one
    segment convention of the stack: ``get_lines`` emits detections
    through it and overlay consumers (``examples/video_pipeline.py``'s
    smoothed-track rendering) reuse it, so rendered geometry can never
    diverge from detected geometry.
    """
    rho = jnp.asarray(rho, jnp.float32)
    theta = jnp.asarray(theta, jnp.float32)
    c, s = jnp.cos(theta), jnp.sin(theta)
    x0, y0 = c * rho, s * rho
    half = jnp.float32(half)
    return jnp.stack(
        [x0 - half * s, y0 + half * c, x0 + half * s, y0 - half * c],
        axis=-1,
    )


def render_lines(image: jax.Array, lines: jax.Array, valid: jax.Array,
                 *, thickness: float = 1.5) -> jax.Array:
    """Paper phase 3 ("generation of an output image with detected lines").

    Deliberately implemented — the paper *measures* this phase at 76% of
    wall time and then elides it; we reproduce both the cost and the
    elision (pipeline option ``render_output``).  Distance-to-line test per
    pixel, vectorized over the static K lines.  Batched when ``image`` is
    (N, H, W) with lines (N, K, 4) / valid (N, K).
    """
    H, W = image.shape[-2:]
    yy, xx = jnp.meshgrid(
        jnp.arange(H, dtype=jnp.float32),
        jnp.arange(W, dtype=jnp.float32),
        indexing="ij",
    )
    x1, y1 = lines[..., 0], lines[..., 1]          # (..., K)
    x2, y2 = lines[..., 2], lines[..., 3]
    dx, dy = x2 - x1, y2 - y1
    norm = jnp.sqrt(dx * dx + dy * dy) + 1e-9
    # |cross product| / norm = distance from pixel to the infinite line
    dist = jnp.abs(
        dy[..., None, None] * (xx - x1[..., None, None])
        - dx[..., None, None] * (yy - y1[..., None, None])
    ) / norm[..., None, None]                      # (..., K, H, W)
    hit = jnp.any(
        (dist <= thickness) & valid[..., None, None], axis=-3
    )
    out = jnp.stack([image, image, image], axis=-1).astype(jnp.uint8)
    red = jnp.stack(
        [jnp.full((H, W), 255, jnp.uint8), jnp.zeros((H, W), jnp.uint8),
         jnp.zeros((H, W), jnp.uint8)],
        axis=-1,
    )
    return jnp.where(hit[..., None], red, out)
