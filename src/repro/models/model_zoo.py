"""Public model API: ``build(cfg) -> Model`` with init/loss/prefill/decode.

``input_specs`` produces weak-type-correct ``ShapeDtypeStruct`` stand-ins for
every model input of every assigned workload shape — the dry-run lowers
against these (no allocation), and real drivers materialize matching arrays.
Modality frontends are stubs per the assignment: whisper takes precomputed
frame embeddings, the vision arch takes precomputed patch embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from . import layers, transformer


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any
    param_specs: Any                    # P-pytree

    # ---- parameters -------------------------------------------------
    def init(self, rng: jax.Array) -> Any:
        return layers.materialize(rng, self.param_specs)

    def abstract_params(self) -> Any:
        return layers.abstract(self.param_specs)

    def param_axes(self) -> Any:
        return layers.axes_tree(self.param_specs)

    def param_count(self) -> int:
        return layers.param_count(self.param_specs)

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top_k of n_experts)."""
        total = self.param_count()
        cfg = self.cfg
        if cfg.moe is None:
            return total
        m = cfg.moe
        expert_p = 3 * cfg.d_model * m.d_ff * m.n_experts * cfg.n_layers
        active = expert_p * m.top_k // m.n_experts
        return total - expert_p + active

    # ---- compute ----------------------------------------------------
    def forward(self, params, batch) -> jax.Array:
        logits, _ = transformer.forward(params, batch, self.cfg)
        return logits

    def loss(self, params, batch):
        return transformer.loss_fn(params, batch, self.cfg)

    def prefill(self, params, batch, cache, *, positions=None):
        return transformer.prefill(params, batch, self.cfg, cache,
                                   positions=positions)

    def decode_step(self, params, token, cache, pos, *, ring: bool = False):
        return transformer.decode_step(params, token, self.cfg, cache, pos,
                                       ring=ring)

    # ---- caches -----------------------------------------------------
    def cache_spec(self, batch: int, max_len: int, *, ring: bool = False):
        return transformer.cache_spec(self.cfg, batch, max_len, ring=ring)

    def init_cache(self, batch: int, max_len: int, *, ring: bool = False):
        return transformer.init_cache(self.cfg, batch, max_len, ring=ring)


def build(cfg) -> Model:
    return Model(cfg=cfg, param_specs=transformer.param_specs(cfg))


# --- input stand-ins ------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_axes(cfg, kind: str) -> Any:
    """Logical axes for each batch input (feeds the sharding rules)."""
    if kind == "train":
        axes = {
            "tokens": ("batch", "seq"),
            "targets": ("batch", "seq"),
        }
        if cfg.family == "vlm":
            axes["image_embeds"] = ("batch", "img_seq", None)
        if cfg.family == "encdec":
            axes["frames"] = ("batch", "frames", None)
        return axes
    if kind == "prefill":
        axes = {"tokens": ("batch", "seq")}
        if cfg.family == "vlm":
            axes["image_embeds"] = ("batch", "img_seq", None)
        if cfg.family == "encdec":
            axes["frames"] = ("batch", "frames", None)
        return axes
    # decode
    return {"token": ("batch",), "pos": ("batch",)}


def input_specs(cfg, shape) -> Any:
    """ShapeDtypeStructs for one workload cell.

    * train:   {tokens, targets [, image_embeds | frames]}
    * prefill: {tokens [, image_embeds | frames]}
    * decode:  {token, pos}  (cache specs come from Model.cache_spec)
    """
    B, S = shape.global_batch, shape.seq_len
    kind = shape.kind
    if kind == "train":
        out = {
            "tokens": _sds((B, S), jnp.int32),
            "targets": _sds((B, S), jnp.int32),
        }
    elif kind == "prefill":
        out = {"tokens": _sds((B, S), jnp.int32)}
    else:
        return {
            "token": _sds((B,), jnp.int32),
            "pos": _sds((B,), jnp.int32),
        }
    if cfg.family == "vlm":
        out["image_embeds"] = _sds(
            (B, cfg.n_img_tokens, cfg.d_vision), cfg.compute_dtype
        )
    if cfg.family == "encdec":
        out["frames"] = _sds(
            (B, cfg.n_frames, cfg.d_model), cfg.compute_dtype
        )
    return out


def materialize_inputs(rng: jax.Array, cfg, shape) -> Any:
    """Random concrete inputs matching ``input_specs`` (smoke tests, drivers)."""
    specs = input_specs(cfg, shape)
    out = {}
    for i, (k, s) in enumerate(sorted(specs.items())):
        r = jax.random.fold_in(rng, i)
        if jnp.issubdtype(s.dtype, jnp.integer):
            hi = cfg.vocab if k in ("tokens", "targets", "token") else shape.seq_len
            out[k] = jax.random.randint(r, s.shape, 0, hi, dtype=s.dtype)
        else:
            out[k] = (0.02 * jax.random.normal(r, s.shape)).astype(s.dtype)
    return out
