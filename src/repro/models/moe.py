"""Mixture-of-Experts: top-k routing with capacity-bounded dispatch.

Two dispatch strategies, both "make it a GEMM" in the paper's spirit:

  * ``sort``   (default, production) — tokens are ranked per expert by a
    cumulative-count over the flattened (token, k) assignment list; each
    token occupies a (expert, position) slot if position < capacity, else it
    is dropped (weight 0, residual passes through).  Dispatch/combine are
    gathers — O(T*k*D + E*C*D) memory, no (T, E, C) one-hot ever exists.
  * ``onehot`` (reference, GShard-style) — explicit dispatch/combine one-hot
    einsums.  Quadratic in group size; used by tests as the semantics of
    record and by tiny smoke configs.

Experts are sharded on the ``model`` mesh axis (EP): 16e -> 1/chip,
64e -> 4/chip on a 16-way axis.  Under pjit the gathers between the
data-sharded token stream and the expert-sharded buffers lower to the
all-to-all-ish collectives the roofline section attributes to MoE cells.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding import constrain, shard_map

from .layers import P


def moe_spec(cfg) -> Any:
    m = cfg.moe
    return {
        "router": P((cfg.d_model, m.n_experts), ("embed", "experts"),
                    scale=cfg.d_model ** -0.5),
        "wi_gate": P((m.n_experts, cfg.d_model, m.d_ff),
                     ("experts", "embed", "mlp"), fan_in_dims=(1,)),
        "wi_up": P((m.n_experts, cfg.d_model, m.d_ff),
                   ("experts", "embed", "mlp"), fan_in_dims=(1,)),
        "wo": P((m.n_experts, m.d_ff, cfg.d_model),
                ("experts", "mlp", "embed"), fan_in_dims=(1,)),
    }


def _route(params, x2d, m):
    """Router probs and top-k choice.  x2d: (T, D)."""
    logits = jnp.einsum(
        "td,de->te", x2d.astype(jnp.float32),
        params["router"].astype(jnp.float32),
    ) * m.router_scale
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)      # (T, k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    return probs, top_w, top_e


def _capacity(T: int, m) -> int:
    c = int(m.capacity_factor * m.top_k * T / m.n_experts)
    return max(c, m.top_k)


def _expert_ffn(params, xs, dtype, *, annotate: bool = True):
    """xs: (E, C, D) -> (E, C, D); three stacked GEMMs on the EP axis.

    ``annotate=False`` inside manual (shard_map) regions where the expert
    axis is already physically local.
    """
    g = jnp.einsum("ecd,edf->ecf", xs, params["wi_gate"].astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", xs, params["wi_up"].astype(dtype))
    h = jax.nn.silu(g) * u
    if annotate:
        h = constrain(h, ("experts", "expert_cap", "mlp"))
    return jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(dtype))


def moe_sort(params, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """Sort-based capacity dispatch.  x: (B, S, D) -> (out, aux_loss)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    x2d = x.reshape(T, D)
    probs, top_w, top_e = _route(params, x2d, m)
    C = _capacity(T, m)

    flat_e = top_e.reshape(-1)                         # (T*k,)
    flat_w = top_w.reshape(-1)
    # position of each assignment within its expert: rank by stable order
    onehot_count = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot_count, axis=0) - 1    # (T*k, E)
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
    keep = pos < C
    slot = flat_e * C + jnp.where(keep, pos, 0)        # (T*k,) in [0, E*C)

    # dispatch: scatter token ids into slots, then gather token features
    token_of_assign = jnp.arange(T * m.top_k) // m.top_k
    slot_token = jnp.zeros((m.n_experts * C,), jnp.int32).at[
        jnp.where(keep, slot, m.n_experts * C)  # dropped -> OOB (ignored)
    ].set(token_of_assign, mode="drop")
    xs = jnp.take(x2d, slot_token, axis=0)             # (E*C, D) gather
    xs = constrain(
        xs.reshape(m.n_experts, C, D), ("experts", "expert_cap", None)
    )

    ys = _expert_ffn(params, xs, x.dtype).reshape(m.n_experts * C, D)

    # combine: each token gathers its k slots back, weighted
    gathered = jnp.take(ys, slot.reshape(T, m.top_k), axis=0)  # (T, k, D)
    w = (flat_w * keep).reshape(T, m.top_k, 1).astype(x.dtype)
    out = jnp.sum(gathered * w, axis=1).reshape(B, S, D)

    aux = _load_balance_loss(probs, top_e, m)
    return out, aux


def moe_onehot(params, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """GShard-style one-hot dispatch/combine einsums (semantics of record)."""
    m = cfg.moe
    B, S, D = x.shape
    T = B * S
    x2d = x.reshape(T, D)
    probs, top_w, top_e = _route(params, x2d, m)
    C = _capacity(T, m)

    dispatch = jnp.zeros((T, m.n_experts, C), jnp.float32)
    combine = jnp.zeros((T, m.n_experts, C), jnp.float32)
    onehot_count = jax.nn.one_hot(
        top_e.reshape(-1), m.n_experts, dtype=jnp.int32
    )
    pos_flat = (jnp.cumsum(onehot_count, axis=0) - 1)
    pos = jnp.take_along_axis(
        pos_flat, top_e.reshape(-1)[:, None], axis=1
    )[:, 0].reshape(T, m.top_k)
    for j in range(m.top_k):
        keep = pos[:, j] < C
        oh = (
            jax.nn.one_hot(top_e[:, j], m.n_experts)[:, :, None]
            * jax.nn.one_hot(jnp.where(keep, pos[:, j], 0), C)[:, None, :]
            * keep[:, None, None]
        )
        dispatch = dispatch + oh
        combine = combine + oh * top_w[:, j][:, None, None]

    xs = jnp.einsum("tec,td->ecd", dispatch, x2d.astype(jnp.float32))
    ys = _expert_ffn(params, xs.astype(x.dtype), x.dtype)
    out = jnp.einsum(
        "tec,ecd->td", combine, ys.astype(jnp.float32)
    ).astype(x.dtype).reshape(B, S, D)
    aux = _load_balance_loss(probs, top_e, m)
    return out, aux


def _load_balance_loss(probs, top_e, m) -> jax.Array:
    """Switch-style aux loss: E * sum_e f_e * p_e."""
    T = probs.shape[0]
    f = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], m.n_experts, dtype=jnp.float32), axis=0
    )
    p = jnp.mean(probs, axis=0)
    return m.n_experts * jnp.sum(f * p)


def moe_ep(params, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """Fully-manual 2D expert parallelism: data-local dispatch, zero token
    movement (§Perf iteration 2).

    Device (d, m) holds data-shard d's tokens (activations are replicated
    over ``model``) and expert slice m.  Routing, capacity assignment,
    dispatch gather, expert GEMMs, and weighted combine are all *local*;
    the only collectives per layer are

      * the FSDP all-gather of the expert weight shards over ``data``
        (what a dense FSDP MLP already pays), and
      * one f32 psum of the output over ``model`` (what a dense TP MLP
        already pays).

    Under pjit-auto (``moe_sort``), the same dispatch lowers to all-gathers
    of the full token stream per layer — 310 s/step of DCN+ICI time on the
    llama4 train cell; this path removes all of it.  Capacity is enforced
    per (data-shard, expert) — the locally-bounded drop rule production
    MoE systems use.

    Falls back to ``moe_sort`` when no mesh is active (single-device tests)
    or the expert count does not divide the ``model`` axis.
    """
    from jax.sharding import PartitionSpec as PS

    from repro.sharding.partition import _ACTIVE

    active = _ACTIVE.get()
    m = cfg.moe
    if active is None:
        return moe_sort(params, x, cfg)
    mesh, active_rules = active
    if "model" not in mesh.axis_names or \
            m.n_experts % mesh.shape["model"] != 0:
        return moe_sort(params, x, cfg)
    # FSDP-shard weights over `data` only when the active rule table says
    # so (training); decode rules replicate weights — no per-layer gathers.
    fsdp = any(
        c == "data" or (isinstance(c, tuple) and "data" in c)
        for c in active_rules.get("embed", ())
    )
    ep = mesh.shape["model"]
    e_local = m.n_experts // ep
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]

    B, S, D = x.shape
    if B % dp != 0:
        return moe_sort(params, x, cfg)
    B_loc = B // dp
    T_loc = B_loc * S
    # floor the per-shard capacity for small token counts (decode steps):
    # a handful of tokens must never contend for C=1 slots
    C = max(int(m.capacity_factor * m.top_k * T_loc / m.n_experts),
            m.top_k, min(T_loc * m.top_k, 32))
    dtype = x.dtype

    def local(router, wi_gate, wi_up, wo, x_f32):
        # x_f32 (B_loc, S, D): this data shard's tokens, f32 at the
        # boundary — the model-replicated input's cotangent is psummed over
        # ``model`` by the transpose, and XLA:CPU crashes promoting that
        # all-reduce in bf16 (TPU-fine, dry-run-fatal).
        x_in = x_f32.astype(dtype)
        x2d = x_in.reshape(T_loc, D)
        if fsdp:
            # FSDP-unshard the expert weights (gather over `data` only —
            # they are replicated over `pod` by the rule table)
            wi_g = jax.lax.all_gather(wi_gate, "data", axis=1, tiled=True)
            wi_u = jax.lax.all_gather(wi_up, "data", axis=1, tiled=True)
            wo_f = jax.lax.all_gather(wo, "data", axis=2, tiled=True)
        else:
            wi_g, wi_u, wo_f = wi_gate, wi_up, wo

        probs, top_w, top_e = _route({"router": router}, x2d, m)
        shard = jax.lax.axis_index("model")
        lo = shard * e_local

        flat_e = top_e.reshape(-1)
        flat_w = top_w.reshape(-1)
        onehot_count = jax.nn.one_hot(flat_e, m.n_experts, dtype=jnp.int32)
        pos_in_e = jnp.cumsum(onehot_count, axis=0) - 1
        pos = jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]
        local_e = flat_e - lo
        mine = (local_e >= 0) & (local_e < e_local) & (pos < C)
        slot = jnp.where(mine, local_e * C + pos, e_local * C)

        token_of_assign = jnp.arange(T_loc * m.top_k) // m.top_k
        slot_token = jnp.zeros((e_local * C,), jnp.int32).at[slot].set(
            token_of_assign, mode="drop")
        xs = jnp.take(x2d, slot_token, axis=0).reshape(e_local, C, D)

        g = jnp.einsum("ecd,edf->ecf", xs, wi_g.astype(dtype))
        u = jnp.einsum("ecd,edf->ecf", xs, wi_u.astype(dtype))
        h = jax.nn.silu(g) * u
        ys = jnp.einsum("ecf,efd->ecd", h, wo_f.astype(dtype))
        ys = ys.reshape(e_local * C, D)
        ys = jnp.concatenate(
            [ys, jnp.zeros((1, D), ys.dtype)], axis=0
        )   # OOB slot -> zero contribution
        gathered = jnp.take(ys, slot.reshape(T_loc, m.top_k), axis=0)
        w = (flat_w * mine).reshape(T_loc, m.top_k, 1).astype(dtype)
        partial = jnp.sum(gathered * w, axis=1).reshape(B_loc, S, D)
        # f32 psums: XLA:CPU's AllReducePromotion crashes on bf16
        out = jax.lax.psum(
            partial.astype(jnp.float32), "model"
        ).astype(dtype)
        aux = _load_balance_loss(probs, top_e, m)
        aux = jax.lax.pmean(aux, dp_axes) if dp_axes else aux
        return out, aux

    dp_spec = dp_axes if len(dp_axes) > 1 else (dp_axes[0] if dp_axes
                                                else None)
    wi_spec = PS("model", "data") if fsdp else PS("model")
    wo_spec = PS("model", None, "data") if fsdp else PS("model")
    out, aux = shard_map(
        local,
        mesh=mesh,
        in_specs=(PS(), wi_spec, wi_spec, wo_spec, PS(dp_spec)),
        out_specs=(PS(dp_spec), PS()),
        axis_names=set(("model",) + dp_axes),
        check_vma=False,
    )(params["router"], params["wi_gate"], params["wi_up"], params["wo"],
      x.astype(jnp.float32))
    return out, aux


def apply_moe(params, x, cfg, *, strategy: str = "sort"):
    if strategy == "onehot":
        return moe_onehot(params, x, cfg)
    if strategy == "ep":
        return moe_ep(params, x, cfg)
    return moe_sort(params, x, cfg)
