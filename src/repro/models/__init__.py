"""Model zoo: the 10 assigned architectures on one JAX substrate.

Every model is a pure-function bundle (init/apply/train/prefill/decode) over
an explicit parameter pytree with logical sharding axes.  Layer stacks are
``lax.scan`` over stacked parameters so the lowered HLO stays one-block-sized
regardless of depth (critical for 88-layer granite on a single-host compile).

The paper's organizing idea — restructure the hot loop into tiled GEMMs
sized to the systolic array, and keep control-heavy stages on the scalar
unit — shows up here as: attention/MLP/MoE dispatch as blocked GEMMs
(MXU), norms/gating/rope elementwise (VPU), and Mamba-1's genuinely serial
scan left in recurrent form (the Hough-on-core decision, honestly ported).
"""

from .model_zoo import Model, build  # noqa: F401
