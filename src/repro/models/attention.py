"""Attention blocks: GQA/MQA/MHA self-attention, sliding-window, cross-attn.

Training/prefill paths route through ``kernels.ops.flash_attention`` (Pallas
blocked-GEMM attention on TPU, blockwise-scan jnp on host lowering).  Decode
is a single-query dense product against the cache — one skinny GEMM, mask on
the VPU — with two cache layouts:

  * linear cache  (max_len slots, write at ``pos``)       — full attention
  * ring cache    (window slots, write at ``pos % W``)    — SWA long-context,
    O(window) memory at 500k positions (the sub-quadratic decode the
    assignment requires for ``long_500k``)

Per-request position vectors are supported everywhere (the serving engine
batches requests at different depths).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.sharding import constrain

from . import layers
from .layers import P, apply_rope


# --- parameter specs ----------------------------------------------------------

def self_attn_spec(cfg) -> Any:
    hd = cfg.hd
    spec = {
        "wq": P((cfg.d_model, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": P((cfg.d_model, cfg.n_kv_heads, hd),
                ("embed", "kv_heads", "head_dim")),
        "wv": P((cfg.d_model, cfg.n_kv_heads, hd),
                ("embed", "kv_heads", "head_dim")),
        "wo": P((cfg.n_heads, hd, cfg.d_model), ("heads", "head_dim", "embed"),
                fan_in_dims=(0, 1)),
    }
    if cfg.qkv_bias:
        spec["bq"] = P((cfg.n_heads, hd), ("heads", "head_dim"), init="zeros")
        spec["bk"] = P((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"),
                       init="zeros")
        spec["bv"] = P((cfg.n_kv_heads, hd), ("kv_heads", "head_dim"),
                       init="zeros")
    return spec


def cross_attn_spec(cfg, d_ctx: Optional[int] = None) -> Any:
    """Cross-attention: queries from x, keys/values from a context stream."""
    hd = cfg.hd
    d_ctx = d_ctx or cfg.d_model
    return {
        "wq": P((cfg.d_model, cfg.n_heads, hd), ("embed", "heads", "head_dim")),
        "wk": P((d_ctx, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wv": P((d_ctx, cfg.n_kv_heads, hd), ("embed", "kv_heads", "head_dim")),
        "wo": P((cfg.n_heads, hd, cfg.d_model), ("heads", "head_dim", "embed"),
                fan_in_dims=(0, 1)),
    }


# --- projections ----------------------------------------------------------------

def _proj_q(params, x, cfg):
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
    return q


def _proj_kv(params, x, cfg):
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if "bk" in params:
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    return k, v


def _proj_out(params, attn, x_dtype):
    return jnp.einsum(
        "bshk,hkd->bsd", attn, params["wo"].astype(x_dtype)
    )


# --- full-sequence attention (train / prefill) -----------------------------------

def self_attention(params, x, cfg, *, positions=None, causal=True,
                   rope: bool = True, impl=None) -> jax.Array:
    """x: (B, S, D) -> (B, S, D).  Window comes from cfg.window."""
    B, S, D = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
    q = _proj_q(params, x, cfg)            # (B, S, H, hd)
    k, v = _proj_kv(params, x, cfg)        # (B, S, Hkv, hd)
    if rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    # heads carry TP when divisible, else the sequence does (see the
    # "attn_seq" rule): never let head_dim shard here — contracting a
    # sharded head_dim psums (B, H, L, L) score tensors.
    attn_axes = ("batch", "heads", "attn_seq", "head_dim")
    qt = constrain(q.transpose(0, 2, 1, 3), attn_axes)
    kt = constrain(k.transpose(0, 2, 1, 3),
                   ("batch", "kv_heads", None, "head_dim"))
    vt = constrain(v.transpose(0, 2, 1, 3),
                   ("batch", "kv_heads", None, "head_dim"))
    out = ops.flash_attention(
        qt, kt, vt, causal=causal, window=cfg.window, impl=impl,
    )
    out = constrain(out, attn_axes).transpose(0, 2, 1, 3)
    return _proj_out(params, out, x.dtype)


def cross_attention(params, x, ctx_k, ctx_v, cfg) -> jax.Array:
    """x: (B, S, D); precomputed context K/V: (B, T, Hkv, hd)."""
    q = _proj_q(params, x, cfg)
    out = ops.flash_attention(
        q.transpose(0, 2, 1, 3), ctx_k.transpose(0, 2, 1, 3),
        ctx_v.transpose(0, 2, 1, 3), causal=False, window=None,
    ).transpose(0, 2, 1, 3)
    return _proj_out(params, out, x.dtype)


def project_context(params, ctx, cfg):
    """Precompute cross-attention K/V from a context stream (B, T, d_ctx)."""
    return _proj_kv(params, ctx, cfg)


# --- KV caches --------------------------------------------------------------------

def cache_spec(cfg, batch: int, max_len: int, *, ring: bool = False) -> Any:
    """Cache entry shapes for one attention layer (stacked by the model).

    ``ring=True`` allocates ``window`` slots (SWA long-context decode).
    """
    slots = cfg.window if (ring and cfg.window) else max_len
    kv = (batch, cfg.n_kv_heads, slots, cfg.hd)
    axes = ("batch", "kv_heads", "cache_seq", "head_dim")
    return {
        "k": jax.ShapeDtypeStruct(kv, cfg.cdtype),
        "v": jax.ShapeDtypeStruct(kv, cfg.cdtype),
    }, {"k": axes, "v": axes}


def init_cache(cfg, batch: int, max_len: int, *, ring: bool = False) -> Any:
    spec, _ = cache_spec(cfg, batch, max_len, ring=ring)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


def _write_at(cache_kv: jax.Array, new: jax.Array, slot: jax.Array):
    """Write (B, Hkv, S_new, hd) into cache at per-batch slot offsets."""
    def one(c, n, s):
        return jax.lax.dynamic_update_slice(c, n, (0, s, 0))
    return jax.vmap(one)(cache_kv, new, slot)


def prefill_attention(params, x, cfg, cache, *, positions) -> tuple:
    """Full-sequence causal attention that also fills the cache from slot 0.

    Returns (out, cache).  Cache slots == positions (linear layout; a 32k
    prefill into a ring cache is done chunkwise by the engine instead).
    """
    B, S, D = x.shape
    q = _proj_q(params, x, cfg)
    k, v = _proj_kv(params, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    kt = k.transpose(0, 2, 1, 3)   # (B, Hkv, S, hd)
    vt = v.transpose(0, 2, 1, 3)
    slots = positions[:, 0]        # requests start at their first position
    cache = {
        "k": _write_at(cache["k"], kt.astype(cache["k"].dtype), slots),
        "v": _write_at(cache["v"], vt.astype(cache["v"].dtype), slots),
    }
    attn_axes = ("batch", "heads", "attn_seq", "head_dim")
    qt = constrain(q.transpose(0, 2, 1, 3), attn_axes)
    out = ops.flash_attention(
        qt, constrain(kt, ("batch", "kv_heads", None, "head_dim")),
        constrain(vt, ("batch", "kv_heads", None, "head_dim")),
        causal=True, window=cfg.window,
    )
    out = constrain(out, attn_axes).transpose(0, 2, 1, 3)
    return _proj_out(params, out, x.dtype), cache


def decode_attention(params, x, cfg, cache, *, pos, ring: bool = False
                     ) -> tuple:
    """One-token decode: x (B, 1, D), per-request positions pos (B,).

    Dense masked product against the cache — a (1, hd) x (hd, L) GEMM per
    head; the mask covers linear ([0, pos]) or ring (last ``window``) layouts.
    """
    B, _, D = x.shape
    L = cache["k"].shape[2]
    q = _proj_q(params, x, cfg)                       # (B, 1, H, hd)
    k_new, v_new = _proj_kv(params, x, cfg)           # (B, 1, Hkv, hd)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    slot = (pos % L) if ring else pos
    cache = {
        "k": _write_at(cache["k"], k_new.transpose(0, 2, 1, 3)
                       .astype(cache["k"].dtype), slot),
        "v": _write_at(cache["v"], v_new.transpose(0, 2, 1, 3)
                       .astype(cache["v"].dtype), slot),
    }

    # Cache stays in its storage dtype (bf16): one skinny GEMM per head with
    # f32 accumulation — no f32 copy of the (L-deep) cache is ever
    # materialized, so decode reads exactly cache-bytes from HBM.
    kc = cache["k"]                                   # (B, Hkv, L, hd)
    vc = cache["v"]
    rep = cfg.n_heads // cfg.n_kv_heads
    qg = q[:, 0].astype(kc.dtype).reshape(B, cfg.n_kv_heads, rep, cfg.hd)
    s = jnp.einsum(
        "bgrk,bglk->bgrl", qg, kc, preferred_element_type=jnp.float32
    ) / (cfg.hd ** 0.5)

    idx = jnp.arange(L)
    if ring:
        # slot s holds absolute position pos - ((pos - s) mod L), if >= 0
        kv_pos = pos[:, None] - ((pos[:, None] - idx[None, :]) % L)
    else:
        kv_pos = jnp.broadcast_to(idx[None, :], (B, L))
    mask = (kv_pos >= 0) & (kv_pos <= pos[:, None])
    if cfg.window is not None:
        mask &= (pos[:, None] - kv_pos) < cfg.window
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1).astype(vc.dtype)
    out = jnp.einsum(
        "bgrl,bglk->bgrk", p, vc, preferred_element_type=jnp.float32
    )                                                 # (B, Hkv, rep, hd)
    out = out.reshape(B, 1, cfg.n_heads, cfg.hd).astype(x.dtype)
    return _proj_out(params, out, x.dtype), cache


def decode_cross_attention(params, x, cfg, ctx_k, ctx_v) -> jax.Array:
    """Decode-time cross-attention against static context K/V (bf16 reads,
    f32 accumulation — same traffic discipline as ``decode_attention``).

    ctx_k/ctx_v: cache layout (B, Hkv, T, hd).
    """
    B = x.shape[0]
    q = _proj_q(params, x, cfg)                       # (B, 1, H, hd)
    rep = cfg.n_heads // cfg.n_kv_heads
    kc, vc = ctx_k, ctx_v
    qg = q[:, 0].astype(kc.dtype).reshape(B, cfg.n_kv_heads, rep, cfg.hd)
    s = jnp.einsum(
        "bgrk,bglk->bgrl", qg, kc, preferred_element_type=jnp.float32
    ) / (cfg.hd ** 0.5)
    p = jax.nn.softmax(s, axis=-1).astype(vc.dtype)
    out = jnp.einsum(
        "bgrl,bglk->bgrk", p, vc, preferred_element_type=jnp.float32
    )
    out = out.reshape(B, 1, cfg.n_heads, cfg.hd).astype(x.dtype)
    return _proj_out(params, out, x.dtype)
