"""Parameter specs and primitive layers shared by every architecture.

Parameters are described declaratively by ``P(shape, axes, ...)`` pytrees so
that the same tree yields (a) materialized weights for execution, (b)
``ShapeDtypeStruct`` stand-ins for the no-allocation dry-run, and (c)
``NamedSharding``s via the logical-axes rule table in ``repro.sharding``.
No framework magic: a model is a dict of arrays plus pure functions.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


# --- parameter descriptors ---------------------------------------------------

@dataclasses.dataclass(frozen=True)
class P:
    """Declarative parameter: shape + logical axes + init law."""

    shape: tuple
    axes: tuple                  # logical axis names, len == len(shape)
    init: str = "normal"         # normal | zeros | ones | embed | custom
    scale: Optional[float] = None  # stddev; default 1/sqrt(fan_in) for normal
    dtype: Any = jnp.float32
    fan_in_dims: tuple = (0,)    # which dims count as fan-in for default scale

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, P)


def _leaf_init(rng: jax.Array, p: P) -> jax.Array:
    if p.init == "zeros":
        return jnp.zeros(p.shape, p.dtype)
    if p.init == "ones":
        return jnp.ones(p.shape, p.dtype)
    if p.init == "embed":
        scale = p.scale if p.scale is not None else 0.02
        return (scale * jax.random.normal(rng, p.shape)).astype(p.dtype)
    # default: normal with 1/sqrt(fan_in)
    fan_in = int(np.prod([p.shape[d] for d in p.fan_in_dims])) or 1
    scale = p.scale if p.scale is not None else fan_in ** -0.5
    return (scale * jax.random.normal(rng, p.shape)).astype(p.dtype)


def materialize(rng: jax.Array, specs: Any) -> Any:
    """Instantiate a P-pytree into arrays (per-leaf folded rngs)."""
    leaves, treedef = jax.tree.flatten(specs, is_leaf=is_spec)
    out = []
    for i, leaf in enumerate(leaves):
        out.append(_leaf_init(jax.random.fold_in(rng, i), leaf))
    return jax.tree.unflatten(treedef, out)


def abstract(specs: Any) -> Any:
    """ShapeDtypeStruct pytree (dry-run stand-in, no allocation)."""
    return jax.tree.map(
        lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype), specs,
        is_leaf=is_spec,
    )


def axes_tree(specs: Any) -> Any:
    """Logical-axes pytree (leaves are tuples; feed to sharding rules)."""
    return jax.tree.map(lambda p: p.axes, specs, is_leaf=is_spec)


def stack(specs: Any, n: int, axis_name: str = "layers") -> Any:
    """Prepend a stacked-layer dim to every P in the tree (for lax.scan)."""
    def bump(p: P) -> P:
        return dataclasses.replace(
            p,
            shape=(n,) + p.shape,
            axes=(axis_name,) + p.axes,
            fan_in_dims=tuple(d + 1 for d in p.fan_in_dims),
        )
    return jax.tree.map(bump, specs, is_leaf=is_spec)


def param_count(specs: Any) -> int:
    leaves = jax.tree.leaves(specs, is_leaf=is_spec)
    return int(sum(np.prod(p.shape) for p in leaves))


# --- primitive layers --------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(dt) * w.astype(dt)


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return y.astype(dt) * w.astype(dt) + b.astype(dt)


def norm_spec(d: int, kind: str = "rms") -> Any:
    if kind == "rms":
        return {"w": P((d,), ("norm",), init="ones")}
    return {"w": P((d,), ("norm",), init="ones"),
            "b": P((d,), ("norm",), init="zeros")}


def apply_norm(params: Any, x: jax.Array, kind: str = "rms",
               eps: float = 1e-5) -> jax.Array:
    if kind == "rms":
        return rms_norm(x, params["w"], eps)
    return layer_norm(x, params["w"], params["b"], eps)


# --- rotary embeddings -------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies."""
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate (..., L, heads, head_dim) by per-position angles.

    positions: (..., L) int32 absolute positions (supports decode offsets and
    per-request positions in the serving engine).
    """
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], theta)          # (half,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., L, half)
    cos = jnp.cos(ang)[..., None, :]                # (..., L, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    ).astype(x.dtype)


def sinusoidal_positions(n: int, d: int) -> np.ndarray:
    """Whisper-style fixed sinusoidal table (n, d)."""
    half = d // 2
    freq = np.exp(-np.log(10000.0) * np.arange(half) / max(half - 1, 1))
    t = np.arange(n)[:, None] * freq[None, :]
    return np.concatenate([np.sin(t), np.cos(t)], axis=1).astype(np.float32)


# --- MLPs ---------------------------------------------------------------------

def mlp_spec(d_model: int, d_ff: int, act: str = "silu") -> Any:
    if act == "silu":  # SwiGLU: gate + up + down
        return {
            "wi_gate": P((d_model, d_ff), ("embed", "mlp")),
            "wi_up": P((d_model, d_ff), ("embed", "mlp")),
            "wo": P((d_ff, d_model), ("mlp", "embed")),
        }
    return {   # plain 2-layer (whisper: GELU)
        "wi": P((d_model, d_ff), ("embed", "mlp")),
        "bi": P((d_ff,), ("mlp",), init="zeros"),
        "wo": P((d_ff, d_model), ("mlp", "embed")),
        "bo": P((d_model,), ("embed",), init="zeros"),
    }


def apply_mlp(params: Any, x: jax.Array, act: str = "silu") -> jax.Array:
    dt = x.dtype
    if act == "silu":
        g = jnp.einsum("...d,df->...f", x, params["wi_gate"].astype(dt))
        u = jnp.einsum("...d,df->...f", x, params["wi_up"].astype(dt))
        h = jax.nn.silu(g) * u
        return jnp.einsum("...f,fd->...d", h, params["wo"].astype(dt))
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(dt))
    h = jax.nn.gelu(h + params["bi"].astype(dt), approximate=True)
    return jnp.einsum(
        "...f,fd->...d", h, params["wo"].astype(dt)
    ) + params["bo"].astype(dt)


# --- embeddings / logits -------------------------------------------------------

def embed_spec(vocab: int, d_model: int, tie: bool = True) -> Any:
    spec = {"table": P((vocab, d_model), ("vocab", "embed"), init="embed")}
    if not tie:
        spec["unembed"] = P(
            (d_model, vocab), ("embed", "vocab"), init="embed"
        )
    return spec


def embed_tokens(params: Any, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0).astype(dtype)


def logits_out(params: Any, x: jax.Array) -> jax.Array:
    """Final projection: bf16 GEMM, f32 accumulation (loss stability at half
    the bytes of an f32 GEMM)."""
    if "unembed" in params:
        w = params["unembed"].astype(x.dtype)
        return jnp.einsum("...d,dv->...v", x, w,
                          preferred_element_type=jnp.float32)
    w = params["table"].astype(x.dtype)              # tied
    return jnp.einsum("...d,vd->...v", x, w,
                      preferred_element_type=jnp.float32)
