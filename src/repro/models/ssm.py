"""State-space blocks: Mamba-1 (selective scan) and Mamba-2 (SSD).

Applicability of the paper's technique (DESIGN.md §Arch-applicability):

  * Mamba-2's recurrence admits the SSD rewrite — *chunked matmuls*, the
    paper's stencil->GEMM move applied to a recurrence.  Train/prefill route
    through ``kernels.ops.ssd_scan`` (Pallas on TPU).
  * Mamba-1's decay varies per (channel, state) pair, so no shared GEMM
    exists — the honest analogue of the paper keeping Hough on the scalar
    core.  We still break the serial chain where math allows: a *chunked
    associative scan* (log-depth within chunks, sequential carry across
    chunks) instead of a 4096-step recurrence, with the chunk size bounding
    the materialized (B, Q, d_inner, N) workspace.

Decode for both is an O(1) state update per token.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.kernels import ops

from .layers import P, rms_norm


# --- Mamba-1 -------------------------------------------------------------------

def mamba1_spec(cfg) -> Any:
    s = cfg.ssm
    D, Din, N, R = cfg.d_model, s.d_inner, s.d_state, s.dt_rank
    return {
        "in_proj": P((D, 2 * Din), ("embed", "inner")),
        "conv_w": P((s.d_conv, Din), ("conv_k", "inner"), scale=0.5),
        "conv_b": P((Din,), ("inner",), init="zeros"),
        "x_proj": P((Din, R + 2 * N), ("inner", "dt_rank")),
        "dt_w": P((R, Din), ("dt_rank", "inner")),
        "dt_b": P((Din,), ("inner",), init="zeros"),
        "A_log": P((Din, N), ("inner", "state"), init="zeros"),
        "D": P((Din,), ("inner",), init="ones"),
        "out_proj": P((Din, D), ("inner", "embed")),
    }


def _causal_conv(x, w, b, *, state=None):
    """Depthwise causal conv along L.  x: (B, L, C), w: (K, C).

    ``state``: (B, K-1, C) trailing context from the previous segment (decode
    / chunked prefill); returns (y, new_state).
    """
    B, L, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    ctx = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = jnp.zeros((B, L, C), x.dtype)
    for i in range(K):
        y = y + ctx[:, i : i + L] * w[i].astype(x.dtype)
    new_state = ctx[:, -(K - 1):] if K > 1 else state
    return jax.nn.silu(y + b.astype(x.dtype)), new_state


def _mamba1_scan(u, dt, A, Bt, Ct, h0, chunk: int):
    """Chunked associative selective scan.

    u, dt: (B, L, Din); A: (Din, N); Bt, Ct: (B, L, N); h0: (B, Din, N) f32.
    Returns y (B, L, Din) f32, hL (B, Din, N) f32.
    """
    B, L, Din = u.shape
    N = A.shape[1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bt = jnp.pad(Bt, ((0, 0), (0, pad), (0, 0)))
        Ct = jnp.pad(Ct, ((0, 0), (0, pad), (0, 0)))
    nc = (L + pad) // Q

    def chunk_step(h, inp):
        uc, dtc, Bc, Cc = inp                      # (B, Q, ...)
        la = dtc[..., None] * A                    # (B, Q, Din, N) log-decay
        a = jnp.exp(la)
        x_in = (dtc * uc)[..., None] * Bc[:, :, None, :]   # dt*B*x

        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, b2 + a2 * b1

        a_cum, s = jax.lax.associative_scan(combine, (a, x_in), axis=1)
        h_all = s + a_cum * h[:, None]             # (B, Q, Din, N)
        y = jnp.einsum("bqn,bqdn->bqd", Cc, h_all)
        return h_all[:, -1], y

    xs = tuple(
        t.reshape(B, nc, Q, -1).swapaxes(0, 1)
        for t in (u, dt, Bt, Ct)
    )
    hL, ys = jax.lax.scan(chunk_step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(B, nc * Q, Din)[:, :L]
    return y, hL


def mamba1_forward(params, x, cfg, *, state=None):
    """x: (B, L, D) -> (y, new_state).  state = {"conv", "ssm"}."""
    s = cfg.ssm
    B, L, D = x.shape
    xz = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(x.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)              # (B, L, Din)

    conv_state = None if state is None else state["conv"]
    xi, new_conv = _causal_conv(
        xi, params["conv_w"], params["conv_b"], state=conv_state
    )

    proj = jnp.einsum(
        "bld,dr->blr", xi.astype(jnp.float32),
        params["x_proj"].astype(jnp.float32),
    )
    dt_lr, Bt, Ct = jnp.split(
        proj, [s.dt_rank, s.dt_rank + s.d_state], axis=-1
    )
    dt = jax.nn.softplus(
        jnp.einsum("blr,rd->bld", dt_lr, params["dt_w"].astype(jnp.float32))
        + params["dt_b"].astype(jnp.float32)
    )
    A = -jnp.exp(params["A_log"].astype(jnp.float32))

    h0 = (
        jnp.zeros((B, s.d_inner, s.d_state), jnp.float32)
        if state is None else state["ssm"]
    )
    y, hL = _mamba1_scan(
        xi.astype(jnp.float32), dt, A, Bt, Ct, h0, s.chunk
    )
    y = y + params["D"].astype(jnp.float32) * xi.astype(jnp.float32)
    y = (y.astype(x.dtype)) * jax.nn.silu(z)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"].astype(x.dtype))
    return out, {"conv": new_conv, "ssm": hL}


def mamba1_decode(params, x, cfg, state):
    """Single-token step.  x: (B, 1, D)."""
    return mamba1_forward(params, x, cfg, state=state)


def mamba1_state_spec(cfg, batch: int):
    s = cfg.ssm
    return (
        {
            "conv": jax.ShapeDtypeStruct(
                (batch, s.d_conv - 1, s.d_inner), cfg.cdtype),
            "ssm": jax.ShapeDtypeStruct(
                (batch, s.d_inner, s.d_state), jnp.float32),
        },
        {
            "conv": ("batch", "conv_k", "inner"),
            "ssm": ("batch", "inner", "state"),
        },
    )


# --- Mamba-2 -------------------------------------------------------------------

def mamba2_spec(cfg) -> Any:
    s = cfg.ssm
    D, Din = cfg.d_model, s.d_inner
    G, N, H = s.n_groups, s.d_state, s.n_heads
    conv_dim = Din + 2 * G * N
    return {
        "in_proj": P((D, 2 * Din + 2 * G * N + H), ("embed", "inner")),
        "conv_w": P((s.d_conv, conv_dim), ("conv_k", "inner"), scale=0.5),
        "conv_b": P((conv_dim,), ("inner",), init="zeros"),
        "A_log": P((H,), ("inner_heads",), init="zeros"),
        "dt_b": P((H,), ("inner_heads",), init="zeros"),
        "D": P((H,), ("inner_heads",), init="ones"),
        "norm_w": P((Din,), ("inner",), init="ones"),
        "out_proj": P((Din, D), ("inner", "embed")),
    }


def mamba2_forward(params, x, cfg, *, state=None, impl=None):
    """x: (B, L, D) -> (y, new_state); SSD chunked-matmul scan."""
    s = cfg.ssm
    B, L, D = x.shape
    G, N, H, Ph = s.n_groups, s.d_state, s.n_heads, s.head_dim
    Din = s.d_inner

    zxbcdt = jnp.einsum("bld,de->ble", x, params["in_proj"].astype(x.dtype))
    z, xbc, dt_raw = jnp.split(zxbcdt, [Din, 2 * Din + 2 * G * N], axis=-1)

    conv_state = None if state is None else state["conv"]
    xbc, new_conv = _causal_conv(
        xbc, params["conv_w"], params["conv_b"], state=conv_state
    )
    xi, Bt, Ct = jnp.split(xbc, [Din, Din + G * N], axis=-1)

    dt = jax.nn.softplus(
        dt_raw.astype(jnp.float32) + params["dt_b"].astype(jnp.float32)
    )                                               # (B, L, H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))   # (H,)

    xh = xi.reshape(B, L, H, Ph)
    Bg = Bt.reshape(B, L, G, N)
    Cg = Ct.reshape(B, L, G, N)

    if state is None:
        y, hL = ops.ssd_scan(
            xh.astype(jnp.float32), dt, A,
            Bg.astype(jnp.float32), Cg.astype(jnp.float32), impl=impl,
        )
    else:
        y, hL = _mamba2_step(xh, dt, A, Bg, Cg, state["ssm"])
    y = y.astype(x.dtype) + (
        params["D"].astype(x.dtype)[:, None] * xh.astype(x.dtype)
    ).astype(x.dtype)
    y = y.reshape(B, L, Din) * jax.nn.silu(z)
    y = rms_norm(y, params["norm_w"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, params["out_proj"].astype(x.dtype))
    return out, {"conv": new_conv, "ssm": hL}


def _mamba2_step(xh, dt, A, Bg, Cg, h):
    """Single-step (L==1) recurrence: h <- exp(dt A) h + dt B x."""
    B, L, H, Ph = xh.shape
    G, N = Bg.shape[2], Bg.shape[3]
    rep = H // G
    dt0 = dt[:, 0].astype(jnp.float32)              # (B, H)
    a = jnp.exp(dt0 * A[None, :])                   # (B, H)
    Bh = jnp.repeat(Bg[:, 0], rep, axis=1).astype(jnp.float32)  # (B, H, N)
    Ch = jnp.repeat(Cg[:, 0], rep, axis=1).astype(jnp.float32)
    u = jnp.einsum(
        "bh,bhn,bhp->bhnp", dt0, Bh, xh[:, 0].astype(jnp.float32)
    )
    h = a[..., None, None] * h + u                  # (B, H, N, P)
    y = jnp.einsum("bhn,bhnp->bhp", Ch, h)[:, None]  # (B, 1, H, P)
    return y, h


def mamba2_decode(params, x, cfg, state):
    return mamba2_forward(params, x, cfg, state=state)


def mamba2_state_spec(cfg, batch: int):
    s = cfg.ssm
    conv_dim = s.d_inner + 2 * s.n_groups * s.d_state
    return (
        {
            "conv": jax.ShapeDtypeStruct(
                (batch, s.d_conv - 1, conv_dim), cfg.cdtype),
            "ssm": jax.ShapeDtypeStruct(
                (batch, s.n_heads, s.d_state, s.head_dim), jnp.float32),
        },
        {
            "conv": ("batch", "conv_k", "inner"),
            "ssm": ("batch", "inner_heads", "state", None),
        },
    )
