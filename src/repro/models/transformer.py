"""Generic stacked decoder: one engine, ten architectures.

Every assigned arch is a *pattern* of sub-blocks repeated ``n_super`` times
and executed as a single ``lax.scan`` over stacked parameters, so the lowered
HLO is one superblock regardless of depth (88-layer granite compiles as fast
as 24-layer danube).  Sub-block kinds:

  attn         pre-norm self-attention (+RoPE, causal, optional SWA/bias)
  mlp          pre-norm dense MLP (SwiGLU or GELU)
  moe          pre-norm mixture-of-experts FFN
  cross        pre-norm cross-attention against a context stream (vlm/encdec)
  mamba1/2     pre-norm SSM block
  (shared attention for zamba2 is applied inside the scan from *unstacked*
  closure parameters — tied weights, per-application caches)

Patterns per family:
  dense   ("attn", "mlp")                        x n_layers
  moe     ("attn", "moe")                        x n_layers
  vlm     (("attn","mlp") x (cross_every-1)) + ("cross","mlp")   x n_super
  encdec  decoder ("attn", "cross", "mlp")       x n_layers  (+ encoder stack)
  ssm     ("mamba1",)                            x n_layers
  hybrid  ("mamba2",) x share_every [+ shared attn]  x n_super  (+ tail)

Three modes share the same sub-block code:
  train    full sequence, no cache
  prefill  full sequence, fills caches from the request offsets
  decode   one token per request at per-request positions
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.sharding import constrain

from . import attention as attn
from . import layers, moe as moe_lib, ssm as ssm_lib
from .layers import P


# --- patterns -------------------------------------------------------------------

def pattern_for(cfg) -> tuple[tuple[str, ...], int, tuple[str, ...], int]:
    """(pattern, n_super, tail_pattern, n_tail)."""
    fam = cfg.family
    if fam == "dense":
        return ("attn", "mlp"), cfg.n_layers, (), 0
    if fam == "moe":
        return ("attn", "moe"), cfg.n_layers, (), 0
    if fam == "vlm":
        k = cfg.cross_every
        assert cfg.n_layers % k == 0, (cfg.n_layers, k)
        pat = ("attn", "mlp") * (k - 1) + ("cross", "mlp")
        return pat, cfg.n_layers // k, (), 0
    if fam == "encdec":
        return ("attn", "cross", "mlp"), cfg.n_layers, (), 0
    if fam == "ssm":
        kind = cfg.ssm.kind
        return (kind,), cfg.n_layers, (), 0
    if fam == "hybrid":
        k = cfg.share_every
        n_super, tail = divmod(cfg.n_layers, k)
        return ("mamba2",) * k, n_super, ("mamba2",) * tail, tail
    raise ValueError(f"unknown family {cfg.family!r}")


def _block_spec(cfg, kind: str) -> Any:
    d = cfg.d_model
    if kind == "attn":
        return {"norm": layers.norm_spec(d, cfg.norm),
                "attn": attn.self_attn_spec(cfg)}
    if kind == "mlp":
        return {"norm": layers.norm_spec(d, cfg.norm),
                "mlp": layers.mlp_spec(d, cfg.d_ff, cfg.act)}
    if kind == "moe":
        return {"norm": layers.norm_spec(d, cfg.norm),
                "moe": moe_lib.moe_spec(cfg)}
    if kind == "cross":
        return {"norm": layers.norm_spec(d, cfg.norm),
                "attn": attn.cross_attn_spec(cfg)}
    if kind == "mamba1":
        return {"norm": layers.norm_spec(d, cfg.norm),
                "ssm": ssm_lib.mamba1_spec(cfg)}
    if kind == "mamba2":
        return {"norm": layers.norm_spec(d, cfg.norm),
                "ssm": ssm_lib.mamba2_spec(cfg)}
    raise ValueError(kind)


def _shared_attn_cfg(cfg):
    """Zamba2 shared block: its own head geometry on the same d_model."""
    return cfg.replace(
        n_heads=cfg.shared_attn_heads, n_kv_heads=cfg.shared_attn_heads,
        head_dim=cfg.d_model // cfg.shared_attn_heads, window=None,
        qkv_bias=False,
    )


def param_specs(cfg) -> Any:
    pattern, n_super, tail, n_tail = pattern_for(cfg)
    spec: dict = {
        "embed": layers.embed_spec(cfg.vocab, cfg.d_model,
                                   tie=cfg.tie_embeddings),
        "final_norm": layers.norm_spec(cfg.d_model, cfg.norm),
        "blocks": layers.stack(
            {f"{i}_{k}": _block_spec(cfg, k) for i, k in enumerate(pattern)},
            n_super,
        ),
    }
    if n_tail:
        spec["tail"] = layers.stack(
            {f"{i}_{k}": _block_spec(cfg, k) for i, k in enumerate(tail)},
            n_tail,
        )
    if cfg.family == "hybrid":
        sc = _shared_attn_cfg(cfg)
        spec["shared"] = {
            "norm": layers.norm_spec(cfg.d_model, cfg.norm),
            "attn": attn.self_attn_spec(sc),
            "mlp_norm": layers.norm_spec(cfg.d_model, cfg.norm),
            "mlp": layers.mlp_spec(cfg.d_model, cfg.d_ff, cfg.act),
        }
    if cfg.family == "vlm":
        spec["adapter"] = {
            "w": P((cfg.d_vision, cfg.d_model), ("embed", "embed")),
            "b": P((cfg.d_model,), ("embed",), init="zeros"),
        }
    if cfg.family == "encdec":
        spec["encoder"] = {
            "blocks": layers.stack(
                {"0_attn": _block_spec(cfg, "attn"),
                 "1_mlp": _block_spec(cfg, "mlp")},
                cfg.encoder_layers,
            ),
            "final_norm": layers.norm_spec(cfg.d_model, cfg.norm),
        }
    return spec


# --- sub-block application --------------------------------------------------------

def _apply_block(kind: str, bp, x, cfg, ctx, cache):
    """Returns (x, new_cache_entry)."""
    mode = ctx["mode"]
    h = layers.apply_norm(bp["norm"], x, cfg.norm, cfg.norm_eps)

    if kind == "attn":
        if mode == "train":
            y = attn.self_attention(
                bp["attn"], h, cfg, positions=ctx["positions"], causal=True
            )
            return x + y, cache
        if mode == "prefill":
            y, cache = attn.prefill_attention(
                bp["attn"], h, cfg, cache, positions=ctx["positions"]
            )
            return x + y, cache
        y, cache = attn.decode_attention(
            bp["attn"], h, cfg, cache, pos=ctx["pos"], ring=ctx["ring"]
        )
        return x + y, cache

    if kind == "mlp":
        return x + layers.apply_mlp(bp["mlp"], h, cfg.act), cache

    if kind == "moe":
        y, aux = moe_lib.apply_moe(bp["moe"], h, cfg,
                                   strategy=ctx["moe_strategy"])
        ctx["moe_aux"].append(aux)
        return x + y, cache

    if kind == "cross":
        if mode == "train":
            ck, cv = attn.project_context(bp["attn"], ctx["ctx_stream"], cfg)
            y = attn.cross_attention(bp["attn"], h, ck, cv, cfg)
            return x + y, cache
        if mode == "prefill":
            ck, cv = attn.project_context(bp["attn"], ctx["ctx_stream"], cfg)
            # cache layout (B, Hkv, T, hd) — matches cache_spec
            cache = {
                "ck": ck.transpose(0, 2, 1, 3).astype(cfg.cdtype),
                "cv": cv.transpose(0, 2, 1, 3).astype(cfg.cdtype),
            }
            y = attn.cross_attention(bp["attn"], h, ck, cv, cfg)
            return x + y, cache
        y = attn.decode_cross_attention(
            bp["attn"], h, cfg, cache["ck"], cache["cv"]
        )
        return x + y, cache

    if kind in ("mamba1", "mamba2"):
        fwd = (ssm_lib.mamba1_forward if kind == "mamba1"
               else ssm_lib.mamba2_forward)
        state = cache if mode == "decode" else None
        y, new_state = fwd(bp["ssm"], h, cfg, state=state)
        if mode == "train":
            return x + y, cache
        return x + y, new_state

    raise ValueError(kind)


def _apply_shared_attn(sp, x, cfg, ctx, cache):
    """Zamba2 tied transformer block (attention + MLP), own cache slot."""
    sc = _shared_attn_cfg(cfg)
    h = layers.apply_norm(sp["norm"], x, cfg.norm, cfg.norm_eps)
    mode = ctx["mode"]
    if mode == "train":
        y = attn.self_attention(
            sp["attn"], h, sc, positions=ctx["positions"], causal=True
        )
    elif mode == "prefill":
        y, cache = attn.prefill_attention(
            sp["attn"], h, sc, cache, positions=ctx["positions"]
        )
    else:
        y, cache = attn.decode_attention(
            sp["attn"], h, sc, cache, pos=ctx["pos"], ring=False
        )
    x = x + y
    h = layers.apply_norm(sp["mlp_norm"], x, cfg.norm, cfg.norm_eps)
    return x + layers.apply_mlp(sp["mlp"], h, cfg.act), cache


# --- stacks -----------------------------------------------------------------------

def _superblock(cfg, pattern, shared_params):
    def run(x, bp, cache, ctx):
        new_cache = dict(cache) if cache is not None else None
        for i, kind in enumerate(pattern):
            key = f"{i}_{kind}"
            ce = None if cache is None else cache.get(key)
            x, ce = _apply_block(kind, bp[key], x, cfg, ctx, ce)
            if new_cache is not None and key in new_cache:
                new_cache[key] = ce
        if shared_params is not None:
            ce = None if cache is None else cache.get("shared")
            x, ce = _apply_shared_attn(shared_params, x, cfg, ctx, ce)
            if new_cache is not None and "shared" in new_cache:
                new_cache["shared"] = ce
        return x, new_cache
    return run


def _scan_stack(cfg, x, stacked_params, stacked_cache, ctx, pattern,
                shared_params=None):
    """lax.scan over stacked superblocks; cache scanned alongside params."""
    run = _superblock(cfg, pattern, shared_params)

    def body(carry, xs):
        bp, cache = xs
        # ctx is closed over; moe aux collected via list (traced values are
        # per-scan-step accumulated below instead)
        aux_in = ctx["moe_aux"]
        ctx["moe_aux"] = []
        y, new_cache = run(carry, bp, cache, ctx)
        step_aux = sum(ctx["moe_aux"]) if ctx["moe_aux"] else jnp.float32(0)
        ctx["moe_aux"] = aux_in
        return y, (new_cache, step_aux)

    if cfg.remat and ctx["mode"] == "train":
        # Full recompute inside a superblock: the only per-layer residual is
        # the layer input carried by the scan (B, S, D) in bf16.  Saving
        # MLP/QK dots (the dots_* policies) costs O(d_ff) per token per
        # layer — 20+ GiB per device at granite scale — and the *default*
        # policy additionally saves an f32 convert of the layer input
        # (observed as a 2x-sized duplicate residual stack in the h2o
        # dry-run HLO); ``nothing_saveable`` pins the residual set to the
        # bf16 carry only.
        body = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable
        )
    x, (new_cache, aux) = jax.lax.scan(
        body, x, (stacked_params, stacked_cache)
    )
    ctx["moe_aux"].append(jnp.sum(aux))
    return x, new_cache


def _empty_cache_like(stacked_params, n_super):
    """A scan-compatible empty cache pytree (no cacheable state)."""
    return {"_": jnp.zeros((n_super, 1), jnp.float32)}


# --- cache construction -------------------------------------------------------------

def cache_spec(cfg, batch: int, max_len: int, *, ring: bool = False):
    """(ShapeDtypeStruct pytree, axes pytree) for the full decode cache."""
    pattern, n_super, tail, n_tail = pattern_for(cfg)

    def entry(kind, n_ctx):
        if kind == "attn":
            return attn.cache_spec(cfg, batch, max_len, ring=ring)
        if kind == "cross":
            kv = (batch, cfg.n_kv_heads, n_ctx, cfg.hd)
            axes = ("batch", "kv_heads", "img_seq", "head_dim")
            return ({"ck": jax.ShapeDtypeStruct(kv, cfg.cdtype),
                     "cv": jax.ShapeDtypeStruct(kv, cfg.cdtype)},
                    {"ck": axes, "cv": axes})
        if kind == "mamba1":
            return ssm_lib.mamba1_state_spec(cfg, batch)
        if kind == "mamba2":
            return ssm_lib.mamba2_state_spec(cfg, batch)
        return None

    n_ctx = cfg.n_img_tokens if cfg.family == "vlm" else cfg.n_frames

    def stack_tree(tree, n):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n,) + s.shape, s.dtype), tree
        )

    def stack_axes(tree, prefix="layers"):
        return jax.tree.map(
            lambda a: (prefix,) + a, tree,
            is_leaf=lambda t: isinstance(t, tuple),
        )

    def build(pat, n):
        spec, axes = {}, {}
        for i, kind in enumerate(pat):
            e = entry(kind, n_ctx)
            if e is not None:
                spec[f"{i}_{kind}"], axes[f"{i}_{kind}"] = e
        if cfg.family == "hybrid":
            sc = _shared_attn_cfg(cfg)
            spec["shared"], axes["shared"] = attn.cache_spec(
                sc, batch, max_len, ring=False
            )
        if not spec:
            return None, None
        return stack_tree(spec, n), stack_axes(axes)

    pattern_spec, pattern_axes = build(pattern, n_super)
    out_spec = {"blocks": pattern_spec}
    out_axes = {"blocks": pattern_axes}
    if n_tail:
        t_spec, t_axes = build(tail, n_tail)
        # tail has no shared block
        if t_spec is not None and "shared" in t_spec:
            del t_spec["shared"], t_axes["shared"]
        out_spec["tail"], out_axes["tail"] = t_spec, t_axes
    return out_spec, out_axes


def init_cache(cfg, batch: int, max_len: int, *, ring: bool = False):
    spec, _ = cache_spec(cfg, batch, max_len, ring=ring)
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), spec)


# --- encoder (whisper) ----------------------------------------------------------------

def encode(params, frames, cfg):
    """Audio frames (B, T, d_model) -> encoder states.  Frontend is a stub:
    ``input_specs`` supplies precomputed frame embeddings per assignment."""
    B, T, D = frames.shape
    x = frames.astype(cfg.cdtype)
    x = x + jnp.asarray(
        layers.sinusoidal_positions(T, D), cfg.cdtype
    )[None]

    def body(carry, bp):
        h = layers.apply_norm(bp["0_attn"]["norm"], carry, cfg.norm,
                              cfg.norm_eps)
        y = attn.self_attention(
            bp["0_attn"]["attn"], h, cfg,
            positions=jnp.broadcast_to(jnp.arange(T), (B, T)),
            causal=False, rope=False,
        )
        x1 = carry + y
        h = layers.apply_norm(bp["1_mlp"]["norm"], x1, cfg.norm, cfg.norm_eps)
        return x1 + layers.apply_mlp(bp["1_mlp"]["mlp"], h, cfg.act), None

    if cfg.remat:
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
        )
    x, _ = jax.lax.scan(body, x, params["encoder"]["blocks"])
    return layers.apply_norm(
        params["encoder"]["final_norm"], x, cfg.norm, cfg.norm_eps
    )


# --- top-level passes --------------------------------------------------------------------

def _context_stream(params, cfg, batch_inputs):
    """The cross-attention context: adapted image embeds / encoder states."""
    if cfg.family == "vlm":
        img = batch_inputs["image_embeds"].astype(cfg.cdtype)
        a = params["adapter"]
        return jnp.einsum(
            "btd,de->bte", img, a["w"].astype(cfg.cdtype)
        ) + a["b"].astype(cfg.cdtype)
    if cfg.family == "encdec":
        return encode(params, batch_inputs["frames"], cfg)
    return None


def _make_ctx(cfg, mode, positions=None, pos=None, ctx_stream=None,
              ring=False, moe_strategy="ep"):
    return {"mode": mode, "positions": positions, "pos": pos,
            "ctx_stream": ctx_stream, "ring": ring,
            "moe_strategy": moe_strategy, "moe_aux": []}


def cast_params(params, cfg):
    """One compute-dtype copy of the parameters, taken *before* the layer
    scan: FSDP all-gathers then move bf16, not the f32 master — half the
    weight-gather bytes per microbatch (§Perf follow-up to iteration 2).
    Leaves used in f32 inside blocks re-upcast locally (norms, A_log, ...).
    """
    return jax.tree.map(
        lambda p: p.astype(cfg.cdtype)
        if jnp.issubdtype(p.dtype, jnp.floating) else p,
        params,
    )


def forward_hidden(params, batch_inputs, cfg, *, moe_strategy="ep"):
    """Final hidden states (B, S, D) before the unembedding + aux metrics.

    Positional encoding: RoPE everywhere a decoder self-attends (including
    the whisper decoder — divergence from the vendor's learned table, noted
    in the config); the whisper *encoder* uses its sinusoidal table inside
    ``encode``.
    """
    params = cast_params(params, cfg)
    tokens = batch_inputs["tokens"]
    B, S = tokens.shape
    pattern, n_super, tail, n_tail = pattern_for(cfg)
    positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    x = layers.embed_tokens(params["embed"], tokens, cfg.cdtype)
    x = constrain(x, ("batch", "seq", "embed_act"))

    ctx = _make_ctx(cfg, "train", positions=positions,
                    ctx_stream=_context_stream(params, cfg, batch_inputs),
                    moe_strategy=moe_strategy)
    shared = params.get("shared")
    x, _ = _scan_stack(
        cfg, x, params["blocks"], _empty_cache_like(params["blocks"], n_super),
        ctx, pattern, shared,
    )
    if n_tail:
        x, _ = _scan_stack(
            cfg, x, params["tail"], _empty_cache_like(params["tail"], n_tail),
            ctx, tail, None,
        )
    x = layers.apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    x = constrain(x, ("batch", "seq", "embed_act"))
    aux = sum(ctx["moe_aux"]) if ctx["moe_aux"] else jnp.float32(0)
    return x, {"moe_aux": aux}


def forward(params, batch_inputs, cfg, *, moe_strategy="ep"):
    """Teacher-forced logits (B, S, vocab f32) + aux metrics."""
    x, aux = forward_hidden(params, batch_inputs, cfg,
                            moe_strategy=moe_strategy)
    return layers.logits_out(params["embed"], x), aux


def prefill(params, batch_inputs, cfg, cache, *, positions=None,
            moe_strategy="ep"):
    """Fill caches for a batch of requests; returns (last logits, cache)."""
    params = cast_params(params, cfg)
    tokens = batch_inputs["tokens"]
    B, S = tokens.shape
    pattern, n_super, tail, n_tail = pattern_for(cfg)
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))

    x = layers.embed_tokens(params["embed"], tokens, cfg.cdtype)
    x = constrain(x, ("batch", "seq", "embed_act"))

    ctx = _make_ctx(cfg, "prefill", positions=positions,
                    ctx_stream=_context_stream(params, cfg, batch_inputs),
                    moe_strategy=moe_strategy)
    shared = params.get("shared")
    x, cache_blocks = _scan_stack(
        cfg, x, params["blocks"], cache["blocks"], ctx, pattern, shared
    )
    new_cache = {"blocks": cache_blocks}
    if n_tail:
        x, cache_tail = _scan_stack(
            cfg, x, params["tail"], cache["tail"], ctx, tail, None
        )
        new_cache["tail"] = cache_tail
    x = layers.apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = layers.logits_out(params["embed"], x[:, -1:])
    return logits[:, 0], new_cache


def decode_step(params, token, cfg, cache, pos, *, ring=False,
                moe_strategy="ep"):
    """One token per request.  token: (B,), pos: (B,).  Returns (logits, cache)."""
    params = cast_params(params, cfg)
    B = token.shape[0]
    pattern, n_super, tail, n_tail = pattern_for(cfg)

    x = layers.embed_tokens(params["embed"], token[:, None], cfg.cdtype)
    x = constrain(x, ("batch", None, None))

    ctx = _make_ctx(cfg, "decode", pos=pos, ring=ring,
                    moe_strategy=moe_strategy)
    shared = params.get("shared")
    x, cache_blocks = _scan_stack(
        cfg, x, params["blocks"], cache["blocks"], ctx, pattern, shared
    )
    new_cache = {"blocks": cache_blocks}
    if n_tail:
        x, cache_tail = _scan_stack(
            cfg, x, params["tail"], cache["tail"], ctx, tail, None
        )
        new_cache["tail"] = cache_tail
    x = layers.apply_norm(params["final_norm"], x, cfg.norm, cfg.norm_eps)
    logits = layers.logits_out(params["embed"], x)
    return logits[:, 0], new_cache


# --- loss ------------------------------------------------------------------------------------

def _ce_chunks(S: int, target: int = 8) -> int:
    """Largest divisor of S that is <= target (keeps seq chunks exact)."""
    c = min(target, S)
    while S % c:
        c -= 1
    return c


def loss_fn(params, batch, cfg, *, moe_strategy="ep", aux_coef=0.01,
            ce_chunks: int = 8):
    """Next-token CE, computed in sequence chunks.

    The unembedding is the single largest activation of a training step
    (256x4096x202k f32 logits for llama4-scout would be ~3.3 GB/device);
    scanning the loss over sequence chunks caps it at chunk/S of that —
    the memory-roofline trick measured by ``launch/roofline.py`` over
    ``benchmarks`` dry-run artifacts (see ROADMAP.md).
    """
    x, aux = forward_hidden(params, batch, cfg, moe_strategy=moe_strategy)
    targets = batch["targets"]
    B, S = targets.shape
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones((B, S), jnp.float32)

    nc = _ce_chunks(S, ce_chunks)
    Q = S // nc

    def chunk(carry, inp):
        xc, tc, mc = inp                        # (B, Q, D), (B, Q), (B, Q)
        logits = layers.logits_out(params["embed"], xc)
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, tc[..., None], axis=-1)[..., 0]
        return carry + jnp.sum(nll * mc), None

    xs = (
        jnp.moveaxis(x.reshape(B, nc, Q, -1), 1, 0),
        jnp.moveaxis(targets.reshape(B, nc, Q), 1, 0),
        jnp.moveaxis(mask.reshape(B, nc, Q), 1, 0),
    )
    total_nll, _ = jax.lax.scan(chunk, jnp.float32(0), xs)
    loss = total_nll / jnp.maximum(jnp.sum(mask), 1.0)
    total = loss + aux_coef * aux["moe_aux"]
    return total, {"ce": loss, "moe_aux": aux["moe_aux"]}
