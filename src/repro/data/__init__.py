"""Data substrate: synthetic road frames (the paper's camera feed), the
scenario engine (procedural road-scene families with analytic ground truth),
and a deterministic, resumable, shard-aware token pipeline for the LM archs."""

from .images import RoadScene, frame_stream, synthetic_road  # noqa: F401
from .scenarios import (  # noqa: F401
    NOISY_FAMILIES,
    ClosedLoopConfig,
    ClosedLoopCycle,
    DriveCycle,
    DriveCycleFrame,
    ScenarioFamily,
    get_family,
    make_drive_cycle,
    make_scenario,
    scenario_batch,
    scenario_names,
    scenario_stream,
    segment_rho_theta,
    standard_closed_loop,
    standard_drive_cycle,
    transform_rho_theta,
)
from .tokens import (  # noqa: F401
    TokenPipelineConfig,
    TokenStream,
    PrefetchLoader,
    SkipAheadLoader,
)
