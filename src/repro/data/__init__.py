"""Data substrate: synthetic road frames (the paper's camera feed) and a
deterministic, resumable, shard-aware token pipeline for the LM archs."""

from .images import RoadScene, frame_stream, synthetic_road  # noqa: F401
from .tokens import (  # noqa: F401
    TokenPipelineConfig,
    TokenStream,
    PrefetchLoader,
    SkipAheadLoader,
)
