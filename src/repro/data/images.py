"""Synthetic road-scene frames (the paper's camera input, offline).

No image assets ship offline, so the line-detection pipeline is exercised on
procedurally generated road scenes: a textured ground plane, two converging
lane lines with known analytic (rho, theta), optional dashes and noise.
Because ground truth is known exactly, tests can assert that the detector
recovers the planted lines — a stronger check than the paper's visual
comparison (Fig. 4).

This module keeps the seed workload (``synthetic_road``); the full family
registry — curved, night, glare, rain, occlusion, multi-lane, ... — lives in
``data/scenarios.py``, which builds on these primitives.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class RoadScene:
    image: np.ndarray            # (H, W) uint8 grayscale
    lines_rho_theta: np.ndarray  # (n_lines, 2) planted (rho, theta)


def _draw_line(img: np.ndarray, rho: float, theta: float,
               intensity: int, width: float) -> None:
    H, W = img.shape
    yy, xx = np.mgrid[0:H, 0:W]
    # distance from pixel to the line x cos(t) + y sin(t) = rho
    dist = np.abs(xx * math.cos(theta) + yy * math.sin(theta) - rho)
    img[dist <= width] = intensity


def synthetic_road(height: int = 240, width: int = 320, *, seed: int = 0,
                   noise: float = 4.0, n_lines: int = 2,
                   dashed: bool = False) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = np.full((height, width), 90, np.float32)  # asphalt
    img += rng.normal(0.0, noise, img.shape)  # texture

    planted = []
    # converging lane markings: theta measured per the paper's convention
    # rho = x cos(theta) + y sin(theta), theta in [0, pi)
    base = [(0.35, 55.0), (0.65, 125.0)][: max(n_lines, 0)]
    extra = [(0.5, 90.0), (0.15, 70.0)]
    for k in range(n_lines):
        fx, deg = (base + extra)[k]
        theta = math.radians(deg + rng.uniform(-4, 4))
        x_anchor = fx * width
        y_anchor = 0.75 * height
        rho = x_anchor * math.cos(theta) + y_anchor * math.sin(theta)
        _draw_line(img, rho, theta, 235, 1.6)
        planted.append((rho, theta))

    if dashed:  # punch gaps to emulate dashed center lines
        mask = (np.arange(height)[:, None] // 12) % 2 == 0
        img = np.where(mask & (img > 200), 90.0, img)

    img = np.clip(img, 0, 255).astype(np.uint8)
    return RoadScene(img, np.array(planted, np.float32))


def frame_stream(n_frames: int, height: int = 240, width: int = 320,
                 seed: int = 0):
    """Generator of frames with slowly drifting lanes (video analogue)."""
    for t in range(n_frames):
        yield synthetic_road(height, width, seed=seed + t)
