"""Scenario engine: a registry of procedural road-scene families.

The paper validates on a single clean frame (Fig. 4); the ROADMAP north-star
asks for "as many scenarios as you can imagine".  This module grows
``data/images.py`` into a registry of road-scene *families*, each a
procedural generator with analytic ground truth — every planted stroke's
(rho, theta) normal form is known exactly, so ``core/metrics.py`` can score
detections quantitatively (precision/recall/F1, localization error) instead
of eyeballing an output image.

Families cover the conditions AV accelerator surveys judge deployments on
(straight/converging/dashed lanes, curved polylines, night contrast, glare,
rain, occlusion, perspective multi-lane).  Each family is registered with an
empirically tuned ``f1_floor`` — the regression bar ``tests/test_scenarios.py``
and ``benchmarks/scenario_suite.py`` hold every future perf PR to.

Registry API:

  * ``scenario_names()``                  — all registered family names,
  * ``get_family(name)``                  — the ``ScenarioFamily`` record,
  * ``make_scenario(name, h, w, seed)``   — one ``RoadScene`` with truth,
  * ``scenario_batch(names, ...)``        — heterogeneous (N, H, W) stacks,
  * ``scenario_stream(name, n, ...)``     — drifting-seed frame generator
    (``name="mixed"`` rotates through every family — the heterogeneous
    stream ``LineDetector.detect_stream`` is exercised on).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterator, Sequence

import numpy as np

from .images import RoadScene, synthetic_road

# ---------------------------------------------------------------------------
# drawing primitives (all ground truth is derived, never fitted)
# ---------------------------------------------------------------------------


def segment_rho_theta(x0: float, y0: float, x1: float, y1: float
                      ) -> tuple[float, float]:
    """Normal form (rho, theta) of the infinite line through a segment.

    Matches the detector's convention ``x cos(theta) + y sin(theta) = rho``
    with theta canonicalized into [0, pi) (rho flips sign with theta+pi).
    """
    dx, dy = x1 - x0, y1 - y0
    theta = math.atan2(dx, -dy)  # normal direction of (dx, dy)
    rho = x0 * math.cos(theta) + y0 * math.sin(theta)
    if theta < 0.0:
        theta += math.pi
        rho = -rho
    if theta >= math.pi:
        theta -= math.pi
        rho = -rho
    return rho, theta


def _asphalt(height: int, width: int, rng: np.random.Generator, *,
             level: float = 90.0, noise: float = 4.0) -> np.ndarray:
    img = np.full((height, width), level, np.float32)
    img += rng.normal(0.0, noise, img.shape).astype(np.float32)
    return img


def _draw_segment(img: np.ndarray, p0: tuple[float, float],
                  p1: tuple[float, float], intensity: float,
                  width: float = 1.6) -> None:
    """Paint pixels within ``width`` of the segment p0-p1 (clamped ends)."""
    H, W = img.shape
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float32)
    dx, dy = p1[0] - p0[0], p1[1] - p0[1]
    norm2 = dx * dx + dy * dy + 1e-9
    t = np.clip(((xx - p0[0]) * dx + (yy - p0[1]) * dy) / norm2, 0.0, 1.0)
    dist = np.hypot(xx - (p0[0] + t * dx), yy - (p0[1] + t * dy))
    img[dist <= width] = intensity


def _plant_segment(img: np.ndarray, planted: list, p0, p1,
                   intensity: float, width: float = 1.6) -> None:
    _draw_segment(img, p0, p1, intensity, width)
    planted.append(segment_rho_theta(*p0, *p1))


def _finish(img: np.ndarray, planted: Sequence[tuple[float, float]]
            ) -> RoadScene:
    out = np.clip(img, 0, 255).astype(np.uint8)
    truth = np.array(planted, np.float32).reshape(-1, 2)
    return RoadScene(out, truth)


def _upward_direction(theta_deg: float) -> tuple[float, float]:
    """Unit direction along a line with normal angle ``theta_deg``,
    oriented to travel toward the top of the frame (dy <= 0)."""
    theta = math.radians(theta_deg)
    dx, dy = math.sin(theta), -math.cos(theta)
    if dy > 0:
        dx, dy = -dx, -dy
    return dx, dy


def _walk_up(p0: tuple[float, float], theta_deg: float, y_stop: float
             ) -> tuple[float, float]:
    """Endpoint of the stroke from ``p0`` along the ``theta_deg`` line's
    upward direction, stopping at height ``y_stop``."""
    dx, dy = _upward_direction(theta_deg)
    span = (p0[1] - y_stop) / max(-dy, 1e-6)
    return p0[0] + span * dx, p0[1] + span * dy


def _lane_endpoints(height: int, width: int, x_bottom_frac: float,
                    theta_deg: float, *, y_top_frac: float = 0.05,
                    y_bottom_frac: float = 0.98):
    """Endpoints of a lane stroke with a prescribed normal angle, anchored
    at ``x_bottom_frac * width`` on the bottom edge."""
    p0 = (x_bottom_frac * width, y_bottom_frac * height)
    return p0, _walk_up(p0, theta_deg, y_top_frac * height)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioFamily:
    name: str
    make: Callable[..., RoadScene]   # (height, width, seed) -> RoadScene
    f1_floor: float                  # regression bar for the quality harness
    description: str


_REGISTRY: dict[str, ScenarioFamily] = {}


def _register(name: str, f1_floor: float, description: str):
    def deco(fn):
        _REGISTRY[name] = ScenarioFamily(name, fn, f1_floor, description)
        return fn
    return deco


def scenario_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def get_family(name: str) -> ScenarioFamily:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name]


def make_scenario(name: str, height: int = 240, width: int = 320, *,
                  seed: int = 0) -> RoadScene:
    return get_family(name).make(height, width, seed=seed)


# --- families --------------------------------------------------------------


@_register("straight", 0.9,
           "two near-vertical lane strokes, highway straightaway")
def _straight(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.30, 8.0), (0.70, 172.0)):
        p0, p1 = _lane_endpoints(
            height, width, fx + rng.uniform(-0.02, 0.02),
            deg + rng.uniform(-2.0, 2.0),
        )
        _plant_segment(img, planted, p0, p1, 235.0)
    return _finish(img, planted)


@_register("converging", 0.85,
           "the seed workload: two converging lane lines (images.py)")
def _converging(height: int, width: int, *, seed: int = 0) -> RoadScene:
    return synthetic_road(height, width, seed=seed)


@_register("dashed", 0.85, "converging lanes with dashed center markings")
def _dashed(height: int, width: int, *, seed: int = 0) -> RoadScene:
    return synthetic_road(height, width, seed=seed, dashed=True)


@_register("curved", 0.7,
           "gentle curve as a 2-segment polyline per lane, truth per segment")
def _curved(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    # a bend whose curvature eases toward the horizon: each lane is two
    # segments, the upper one rotated ~8 degrees toward vertical, so both
    # polylines converge without crossing and every segment keeps a sharp
    # Hough peak (near-vertical strokes concentrate votes).
    for fx, deg, bend in ((0.30, 22.0, -12.0), (0.70, 158.0, 12.0)):
        deg += rng.uniform(-2.0, 2.0)
        p0 = (fx * width, 0.98 * height)
        pm = _walk_up(p0, deg, 0.50 * height)
        _plant_segment(img, planted, p0, pm, 235.0)
        _plant_segment(
            img, planted, pm, _walk_up(pm, deg + bend, 0.10 * height), 235.0
        )
    return _finish(img, planted)


@_register("night", 0.85,
           "low-contrast night scene: dim markings on dark asphalt")
def _night(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng, level=42.0, noise=5.0)
    planted: list = []
    for fx, deg in ((0.35, 35.0), (0.65, 145.0)):
        p0, p1 = _lane_endpoints(
            height, width, fx, deg + rng.uniform(-3.0, 3.0),
            y_bottom_frac=0.9, y_top_frac=0.1,
        )
        _plant_segment(img, planted, p0, p1, 130.0)
    return _finish(img, planted)


@_register("glare", 0.75,
           "oncoming-headlight glare: bright soft blobs over the lanes")
def _glare(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.35, 35.0), (0.65, 145.0)):
        p0, p1 = _lane_endpoints(height, width, fx,
                                 deg + rng.uniform(-3.0, 3.0))
        _plant_segment(img, planted, p0, p1, 235.0)
    yy, xx = np.mgrid[0:height, 0:width].astype(np.float32)
    for _ in range(3):
        cx = rng.uniform(0.15, 0.85) * width
        cy = rng.uniform(0.05, 0.4) * height
        r = rng.uniform(0.03, 0.07) * min(height, width)
        blob = 165.0 * np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2)
                              / (2.0 * r * r))
        img = np.minimum(img + blob, 255.0)
    return _finish(img, planted)


@_register("rain", 0.85,
           "rain/sensor speckle: salt-and-pepper noise over the lanes")
def _rain(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.35, 35.0), (0.65, 145.0)):
        p0, p1 = _lane_endpoints(height, width, fx,
                                 deg + rng.uniform(-3.0, 3.0))
        _plant_segment(img, planted, p0, p1, 235.0)
    speck = rng.uniform(size=img.shape)
    img[speck < 0.004] = 255.0
    img[speck > 0.996] = 0.0
    return _finish(img, planted)


@_register("occlusion", 0.85,
           "partial occlusion: a vehicle-sized patch blanks one lane's midsection")
def _occlusion(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.35, 35.0), (0.65, 145.0)):
        p0, p1 = _lane_endpoints(height, width, fx,
                                 deg + rng.uniform(-3.0, 3.0))
        _plant_segment(img, planted, p0, p1, 235.0)
    # occluder painted AFTER the lanes erases their midsections; its own
    # edges are short enough to stay under the relative peak threshold.
    x0 = int(rng.uniform(0.3, 0.45) * width)
    y0 = int(rng.uniform(0.35, 0.5) * height)
    w = int(0.18 * width)
    h = int(0.14 * height)
    img[y0:y0 + h, x0:x0 + w] = 108.0 + rng.normal(
        0.0, 3.0, (min(h, height - y0), min(w, width - x0))
    ).astype(np.float32)
    return _finish(img, planted)


@_register("multilane", 0.85,
           "perspective 4-lane: strokes converging on a vanishing point")
def _multilane(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    vx = (0.5 + rng.uniform(-0.03, 0.03)) * width
    vy = 0.04 * height
    for fx in (0.18, 0.40, 0.60, 0.82):
        x0 = fx * width
        y0 = 0.98 * height
        # draw from the bottom edge toward (not into) the vanishing point
        t = (0.32 * height - y0) / (vy - y0)
        p1 = (x0 + t * (vx - x0), y0 + t * (vy - y0))
        _plant_segment(img, planted, (x0, y0), p1, 235.0)
    return _finish(img, planted)


@_register("fog", 0.85,
           "atmospheric haze: contrast decays exponentially toward the horizon")
def _fog(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.35, 30.0), (0.65, 150.0)):
        p0, p1 = _lane_endpoints(
            height, width, fx, deg + rng.uniform(-3.0, 3.0),
            y_top_frac=0.12,
        )
        _plant_segment(img, planted, p0, p1, 235.0)
    # Koschmieder scattering: I = I0*t + A*(1-t) with transmission
    # t = exp(-beta * depth); rows near the top of the frame are far away,
    # so their contrast collapses toward the airlight A.  beta is drawn so
    # the worst seed still leaves the upper lane ends ~25 gray levels
    # above the hazed asphalt — visible, but a real low-contrast regime.
    airlight = 190.0
    beta = rng.uniform(1.1, 1.5)
    depth = np.linspace(1.0, 0.0, height, dtype=np.float32)[:, None]
    t = np.exp(-beta * depth)
    img = img * t + airlight * (1.0 - t)
    return _finish(img, planted)


@_register("lens_distortion", 0.85,
           "mild barrel distortion: straight markings bow toward the rim")
def _lens_distortion(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.32, 25.0), (0.68, 155.0)):
        p0, p1 = _lane_endpoints(height, width, fx,
                                 deg + rng.uniform(-2.0, 2.0))
        _plant_segment(img, planted, p0, p1, 235.0)
    # Barrel remap (inverse mapping, nearest-neighbour): the sampled source
    # radius grows as r*(1 + k1*(r/rmax)^2), bowing straight strokes by at
    # most ~k1*rmax pixels at the rim.  k1 is small enough that the
    # dominant Hough peak of each bowed stroke stays within the harness's
    # (4 px, 3 deg) matching tolerance of the undistorted ground truth —
    # the family measures robustness to mild uncorrected optics, not a
    # fisheye rectifier.
    k1 = rng.uniform(0.010, 0.018)
    cy, cx = (height - 1) / 2.0, (width - 1) / 2.0
    yy, xx = np.mgrid[0:height, 0:width].astype(np.float32)
    dx, dy = xx - cx, yy - cy
    r = np.hypot(dx, dy)
    rmax = math.hypot(cx, cy)
    scale = 1.0 + k1 * (r / rmax) ** 2
    sx = np.clip(np.rint(cx + dx * scale), 0, width - 1).astype(np.int32)
    sy = np.clip(np.rint(cy + dy * scale), 0, height - 1).astype(np.int32)
    img = img[sy, sx]
    return _finish(img, planted)


@_register("empty", 0.99, "no markings at all: false-positive control")
def _empty(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    return _finish(img, [])


# ---------------------------------------------------------------------------
# batch / stream assembly (heterogeneous inputs for the fast paths)
# ---------------------------------------------------------------------------


def scenario_batch(names: Sequence[str], height: int = 240, width: int = 320,
                   *, seed: int = 0) -> tuple[np.ndarray, list[np.ndarray]]:
    """Stack a heterogeneous batch: (N, H, W) f32 images + per-frame truth.

    ``names`` may repeat (e.g. 8 frames of one family) or mix families —
    the stack is what ``LineDetector.detect_batch`` consumes directly.
    """
    scenes = [
        make_scenario(n, height, width, seed=seed + i)
        for i, n in enumerate(names)
    ]
    imgs = np.stack([s.image for s in scenes]).astype(np.float32)
    return imgs, [s.lines_rho_theta for s in scenes]


def scenario_stream(name: str, n_frames: int, height: int = 240,
                    width: int = 320, *, seed: int = 0
                    ) -> Iterator[RoadScene]:
    """Drifting-seed frame generator; ``name="mixed"`` rotates families."""
    if name == "mixed":
        fams = scenario_names()
        for t in range(n_frames):
            yield make_scenario(fams[t % len(fams)], height, width,
                                seed=seed + t)
    else:
        for t in range(n_frames):
            yield make_scenario(name, height, width, seed=seed + t)
