"""Scenario engine: a registry of procedural road-scene families.

The paper validates on a single clean frame (Fig. 4); the ROADMAP north-star
asks for "as many scenarios as you can imagine".  This module grows
``data/images.py`` into a registry of road-scene *families*, each a
procedural generator with analytic ground truth — every planted stroke's
(rho, theta) normal form is known exactly, so ``core/metrics.py`` can score
detections quantitatively (precision/recall/F1, localization error) instead
of eyeballing an output image.

Families cover the conditions AV accelerator surveys judge deployments on
(straight/converging/dashed lanes, curved polylines, night contrast, glare,
rain, occlusion, perspective multi-lane).  Each family is registered with an
empirically tuned ``f1_floor`` — the regression bar ``tests/test_scenarios.py``
and ``benchmarks/scenario_suite.py`` hold every future perf PR to.

Registry API:

  * ``scenario_names()``                  — all registered family names,
  * ``get_family(name)``                  — the ``ScenarioFamily`` record,
  * ``make_scenario(name, h, w, seed)``   — one ``RoadScene`` with truth,
  * ``scenario_batch(names, ...)``        — heterogeneous (N, H, W) stacks,
  * ``scenario_stream(name, n, ...)``     — drifting-seed frame generator
    (``name="mixed"`` rotates through every family — the heterogeneous
    stream ``LineDetector.detect_stream`` is exercised on),
  * ``make_drive_cycle(family, n, ...)``  — temporal sequences: rigid
    ego-motion (sway, curvature ramp, lane change) over one base scene
    with exact per-frame (rho, theta) trajectories, plus dropout/blackout
    frames and noise bursts — the workload ``core/tracking.py`` follows
    over time (``standard_drive_cycle`` is the canonical harness cycle).
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterator, Sequence

import numpy as np

from .images import RoadScene, synthetic_road

# ---------------------------------------------------------------------------
# drawing primitives (all ground truth is derived, never fitted)
# ---------------------------------------------------------------------------


def segment_rho_theta(x0: float, y0: float, x1: float, y1: float
                      ) -> tuple[float, float]:
    """Normal form (rho, theta) of the infinite line through a segment.

    Matches the detector's convention ``x cos(theta) + y sin(theta) = rho``
    with theta canonicalized into [0, pi) (rho flips sign with theta+pi).
    """
    dx, dy = x1 - x0, y1 - y0
    theta = math.atan2(dx, -dy)  # normal direction of (dx, dy)
    rho = x0 * math.cos(theta) + y0 * math.sin(theta)
    if theta < 0.0:
        theta += math.pi
        rho = -rho
    if theta >= math.pi:
        theta -= math.pi
        rho = -rho
    return rho, theta


def _asphalt(height: int, width: int, rng: np.random.Generator, *,
             level: float = 90.0, noise: float = 4.0) -> np.ndarray:
    img = np.full((height, width), level, np.float32)
    img += rng.normal(0.0, noise, img.shape).astype(np.float32)
    return img


def _draw_segment(img: np.ndarray, p0: tuple[float, float],
                  p1: tuple[float, float], intensity: float,
                  width: float = 1.6) -> None:
    """Paint pixels within ``width`` of the segment p0-p1 (clamped ends)."""
    H, W = img.shape
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float32)
    dx, dy = p1[0] - p0[0], p1[1] - p0[1]
    norm2 = dx * dx + dy * dy + 1e-9
    t = np.clip(((xx - p0[0]) * dx + (yy - p0[1]) * dy) / norm2, 0.0, 1.0)
    dist = np.hypot(xx - (p0[0] + t * dx), yy - (p0[1] + t * dy))
    img[dist <= width] = intensity


def _plant_segment(img: np.ndarray, planted: list, p0, p1,
                   intensity: float, width: float = 1.6) -> None:
    _draw_segment(img, p0, p1, intensity, width)
    planted.append(segment_rho_theta(*p0, *p1))


def _finish(img: np.ndarray, planted: Sequence[tuple[float, float]]
            ) -> RoadScene:
    out = np.clip(img, 0, 255).astype(np.uint8)
    truth = np.array(planted, np.float32).reshape(-1, 2)
    return RoadScene(out, truth)


def _upward_direction(theta_deg: float) -> tuple[float, float]:
    """Unit direction along a line with normal angle ``theta_deg``,
    oriented to travel toward the top of the frame (dy <= 0)."""
    theta = math.radians(theta_deg)
    dx, dy = math.sin(theta), -math.cos(theta)
    if dy > 0:
        dx, dy = -dx, -dy
    return dx, dy


def _walk_up(p0: tuple[float, float], theta_deg: float, y_stop: float
             ) -> tuple[float, float]:
    """Endpoint of the stroke from ``p0`` along the ``theta_deg`` line's
    upward direction, stopping at height ``y_stop``."""
    dx, dy = _upward_direction(theta_deg)
    span = (p0[1] - y_stop) / max(-dy, 1e-6)
    return p0[0] + span * dx, p0[1] + span * dy


def _lane_endpoints(height: int, width: int, x_bottom_frac: float,
                    theta_deg: float, *, y_top_frac: float = 0.05,
                    y_bottom_frac: float = 0.98):
    """Endpoints of a lane stroke with a prescribed normal angle, anchored
    at ``x_bottom_frac * width`` on the bottom edge."""
    p0 = (x_bottom_frac * width, y_bottom_frac * height)
    return p0, _walk_up(p0, theta_deg, y_top_frac * height)


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ScenarioFamily:
    name: str
    make: Callable[..., RoadScene]   # (height, width, seed) -> RoadScene
    f1_floor: float                  # regression bar for the quality harness
    description: str


_REGISTRY: dict[str, ScenarioFamily] = {}


def _register(name: str, f1_floor: float, description: str):
    def deco(fn):
        _REGISTRY[name] = ScenarioFamily(name, fn, f1_floor, description)
        return fn
    return deco


def scenario_names() -> tuple[str, ...]:
    return tuple(_REGISTRY)


def get_family(name: str) -> ScenarioFamily:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown scenario {name!r}; registered: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[name]


def make_scenario(name: str, height: int = 240, width: int = 320, *,
                  seed: int = 0) -> RoadScene:
    return get_family(name).make(height, width, seed=seed)


# --- families --------------------------------------------------------------


@_register("straight", 0.9,
           "two near-vertical lane strokes, highway straightaway")
def _straight(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.30, 8.0), (0.70, 172.0)):
        p0, p1 = _lane_endpoints(
            height, width, fx + rng.uniform(-0.02, 0.02),
            deg + rng.uniform(-2.0, 2.0),
        )
        _plant_segment(img, planted, p0, p1, 235.0)
    return _finish(img, planted)


@_register("converging", 0.85,
           "the seed workload: two converging lane lines (images.py)")
def _converging(height: int, width: int, *, seed: int = 0) -> RoadScene:
    return synthetic_road(height, width, seed=seed)


@_register("dashed", 0.85, "converging lanes with dashed center markings")
def _dashed(height: int, width: int, *, seed: int = 0) -> RoadScene:
    return synthetic_road(height, width, seed=seed, dashed=True)


@_register("curved", 0.7,
           "gentle curve as a 2-segment polyline per lane, truth per segment")
def _curved(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    # a bend whose curvature eases toward the horizon: each lane is two
    # segments, the upper one rotated ~8 degrees toward vertical, so both
    # polylines converge without crossing and every segment keeps a sharp
    # Hough peak (near-vertical strokes concentrate votes).
    for fx, deg, bend in ((0.30, 22.0, -12.0), (0.70, 158.0, 12.0)):
        deg += rng.uniform(-2.0, 2.0)
        p0 = (fx * width, 0.98 * height)
        pm = _walk_up(p0, deg, 0.50 * height)
        _plant_segment(img, planted, p0, pm, 235.0)
        _plant_segment(
            img, planted, pm, _walk_up(pm, deg + bend, 0.10 * height), 235.0
        )
    return _finish(img, planted)


@_register("night", 0.85,
           "low-contrast night scene: dim markings on dark asphalt")
def _night(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng, level=42.0, noise=5.0)
    planted: list = []
    for fx, deg in ((0.35, 35.0), (0.65, 145.0)):
        p0, p1 = _lane_endpoints(
            height, width, fx, deg + rng.uniform(-3.0, 3.0),
            y_bottom_frac=0.9, y_top_frac=0.1,
        )
        _plant_segment(img, planted, p0, p1, 130.0)
    return _finish(img, planted)


@_register("glare", 0.75,
           "oncoming-headlight glare: bright soft blobs over the lanes")
def _glare(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.35, 35.0), (0.65, 145.0)):
        p0, p1 = _lane_endpoints(height, width, fx,
                                 deg + rng.uniform(-3.0, 3.0))
        _plant_segment(img, planted, p0, p1, 235.0)
    yy, xx = np.mgrid[0:height, 0:width].astype(np.float32)
    for _ in range(3):
        cx = rng.uniform(0.15, 0.85) * width
        cy = rng.uniform(0.05, 0.4) * height
        r = rng.uniform(0.03, 0.07) * min(height, width)
        blob = 165.0 * np.exp(-((xx - cx) ** 2 + (yy - cy) ** 2)
                              / (2.0 * r * r))
        img = np.minimum(img + blob, 255.0)
    return _finish(img, planted)


@_register("rain", 0.85,
           "rain/sensor speckle: salt-and-pepper noise over the lanes")
def _rain(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.35, 35.0), (0.65, 145.0)):
        p0, p1 = _lane_endpoints(height, width, fx,
                                 deg + rng.uniform(-3.0, 3.0))
        _plant_segment(img, planted, p0, p1, 235.0)
    speck = rng.uniform(size=img.shape)
    img[speck < 0.004] = 255.0
    img[speck > 0.996] = 0.0
    return _finish(img, planted)


@_register("occlusion", 0.85,
           "partial occlusion: a vehicle-sized patch blanks one lane's midsection")
def _occlusion(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.35, 35.0), (0.65, 145.0)):
        p0, p1 = _lane_endpoints(height, width, fx,
                                 deg + rng.uniform(-3.0, 3.0))
        _plant_segment(img, planted, p0, p1, 235.0)
    # occluder painted AFTER the lanes erases their midsections; its own
    # edges are short enough to stay under the relative peak threshold.
    x0 = int(rng.uniform(0.3, 0.45) * width)
    y0 = int(rng.uniform(0.35, 0.5) * height)
    w = int(0.18 * width)
    h = int(0.14 * height)
    img[y0:y0 + h, x0:x0 + w] = 108.0 + rng.normal(
        0.0, 3.0, (min(h, height - y0), min(w, width - x0))
    ).astype(np.float32)
    return _finish(img, planted)


@_register("multilane", 0.85,
           "perspective 4-lane: strokes converging on a vanishing point")
def _multilane(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    vx = (0.5 + rng.uniform(-0.03, 0.03)) * width
    vy = 0.04 * height
    for fx in (0.18, 0.40, 0.60, 0.82):
        x0 = fx * width
        y0 = 0.98 * height
        # draw from the bottom edge toward (not into) the vanishing point
        t = (0.32 * height - y0) / (vy - y0)
        p1 = (x0 + t * (vx - x0), y0 + t * (vy - y0))
        _plant_segment(img, planted, (x0, y0), p1, 235.0)
    return _finish(img, planted)


@_register("fog", 0.85,
           "atmospheric haze: contrast decays exponentially toward the horizon")
def _fog(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.35, 30.0), (0.65, 150.0)):
        p0, p1 = _lane_endpoints(
            height, width, fx, deg + rng.uniform(-3.0, 3.0),
            y_top_frac=0.12,
        )
        _plant_segment(img, planted, p0, p1, 235.0)
    # Koschmieder scattering: I = I0*t + A*(1-t) with transmission
    # t = exp(-beta * depth); rows near the top of the frame are far away,
    # so their contrast collapses toward the airlight A.  beta is drawn so
    # the worst seed still leaves the upper lane ends ~25 gray levels
    # above the hazed asphalt — visible, but a real low-contrast regime.
    airlight = 190.0
    beta = rng.uniform(1.1, 1.5)
    depth = np.linspace(1.0, 0.0, height, dtype=np.float32)[:, None]
    t = np.exp(-beta * depth)
    img = img * t + airlight * (1.0 - t)
    return _finish(img, planted)


@_register("lens_distortion", 0.85,
           "mild barrel distortion: straight markings bow toward the rim")
def _lens_distortion(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    planted: list = []
    for fx, deg in ((0.32, 25.0), (0.68, 155.0)):
        p0, p1 = _lane_endpoints(height, width, fx,
                                 deg + rng.uniform(-2.0, 2.0))
        _plant_segment(img, planted, p0, p1, 235.0)
    # Barrel remap (inverse mapping, nearest-neighbour): the sampled source
    # radius grows as r*(1 + k1*(r/rmax)^2), bowing straight strokes by at
    # most ~k1*rmax pixels at the rim.  k1 is small enough that the
    # dominant Hough peak of each bowed stroke stays within the harness's
    # (4 px, 3 deg) matching tolerance of the undistorted ground truth —
    # the family measures robustness to mild uncorrected optics, not a
    # fisheye rectifier.
    k1 = rng.uniform(0.010, 0.018)
    cy, cx = (height - 1) / 2.0, (width - 1) / 2.0
    yy, xx = np.mgrid[0:height, 0:width].astype(np.float32)
    dx, dy = xx - cx, yy - cy
    r = np.hypot(dx, dy)
    rmax = math.hypot(cx, cy)
    scale = 1.0 + k1 * (r / rmax) ** 2
    sx = np.clip(np.rint(cx + dx * scale), 0, width - 1).astype(np.int32)
    sy = np.clip(np.rint(cy + dy * scale), 0, height - 1).astype(np.int32)
    img = img[sy, sx]
    return _finish(img, planted)


@_register("empty", 0.99, "no markings at all: false-positive control")
def _empty(height: int, width: int, *, seed: int = 0) -> RoadScene:
    rng = np.random.default_rng(seed)
    img = _asphalt(height, width, rng)
    return _finish(img, [])


# ---------------------------------------------------------------------------
# batch / stream assembly (heterogeneous inputs for the fast paths)
# ---------------------------------------------------------------------------


def scenario_batch(names: Sequence[str], height: int = 240, width: int = 320,
                   *, seed: int = 0) -> tuple[np.ndarray, list[np.ndarray]]:
    """Stack a heterogeneous batch: (N, H, W) f32 images + per-frame truth.

    ``names`` may repeat (e.g. 8 frames of one family) or mix families —
    the stack is what ``LineDetector.detect_batch`` consumes directly.
    """
    scenes = [
        make_scenario(n, height, width, seed=seed + i)
        for i, n in enumerate(names)
    ]
    imgs = np.stack([s.image for s in scenes]).astype(np.float32)
    return imgs, [s.lines_rho_theta for s in scenes]


def scenario_stream(name: str, n_frames: int, height: int = 240,
                    width: int = 320, *, seed: int = 0
                    ) -> Iterator[RoadScene]:
    """Drifting-seed frame generator; ``name="mixed"`` rotates families."""
    if name == "mixed":
        fams = scenario_names()
        for t in range(n_frames):
            yield make_scenario(fams[t % len(fams)], height, width,
                                seed=seed + t)
    else:
        for t in range(n_frames):
            yield make_scenario(name, height, width, seed=seed + t)


# ---------------------------------------------------------------------------
# drive cycles: temporal sequences with analytic (rho, theta) trajectories
# ---------------------------------------------------------------------------

#: Families whose per-frame detection is noisy enough that the temporal
#: layer must beat it (the tracked-F1 >= per-frame-F1 gate in
#: ``tests/test_tracking.py`` / ``benchmarks/tracking_suite.py``).
NOISY_FAMILIES: tuple[str, ...] = ("rain", "night", "glare")


@dataclasses.dataclass(frozen=True)
class DriveCycleFrame:
    """One frame of a drive cycle: a valid RoadScene plus its provenance."""
    scene: RoadScene          # warped image + exactly transformed truth
    t: int                    # frame index within the cycle
    dropout: bool             # camera blackout: lanes exist, signal doesn't
    noise_burst: bool         # extra speckle burst on top of the family
    dx_px: float              # ego lateral translation applied this frame
    yaw_deg: float            # ego rotation applied this frame
    dy_px: float = 0.0        # ego longitudinal translation (surge/bob)


@dataclasses.dataclass(frozen=True)
class DriveCycle:
    """A drive-cycle sequence over one scenario family.

    Frame-to-frame continuity comes from rigid ego-motion over a single
    base scene: every frame is the SAME world (same asphalt texture, same
    planted strokes) seen through a camera that sways, yaws through a
    curvature ramp, and executes a lane change — so the per-frame
    ``lines_rho_theta`` is an exact analytic trajectory, not a re-rolled
    random scene.  Dropout frames keep their trajectory truth (the lanes
    are still there; the camera failed) and carry ``dropout=True`` so the
    harness knows the detector *should* see nothing while a tracker
    *should* coast.
    """
    family: str
    frames: tuple[DriveCycleFrame, ...]

    def __len__(self) -> int:
        return len(self.frames)

    def __iter__(self) -> Iterator[DriveCycleFrame]:
        return iter(self.frames)

    def images(self) -> list[np.ndarray]:
        return [f.scene.image for f in self.frames]

    def truths(self) -> list[np.ndarray]:
        return [f.scene.lines_rho_theta for f in self.frames]


def _smoothstep(u: np.ndarray | float) -> np.ndarray | float:
    u = np.clip(u, 0.0, 1.0)
    return u * u * (3.0 - 2.0 * u)


def transform_rho_theta(rho: float, theta: float, *, yaw_rad: float,
                        dx: float, dy: float, cx: float, cy: float
                        ) -> tuple[float, float]:
    """Exact (rho, theta) image of a line under the rigid ego-motion
    ``q = R_yaw (p - c) + c + (dx, dy)`` (rotation about the frame center,
    then translation), canonicalized to theta in [0, pi).

    Derivation: the mapped line's normal rotates with the frame
    (theta' = theta + yaw) and its offset picks up the center swing plus
    the translation's projection on the new normal:
    ``rho' = rho - c.n + c.n' + t.n'``.
    """
    tp = theta + yaw_rad
    n = (math.cos(theta), math.sin(theta))
    np_ = (math.cos(tp), math.sin(tp))
    rp = (rho - (cx * n[0] + cy * n[1])
          + (cx * np_[0] + cy * np_[1]) + dx * np_[0] + dy * np_[1])
    # Canonicalize with a true modulo, not a single +-pi correction: the
    # closed-loop harness accumulates yaw without bound, so tp can land
    # any number of wraps outside [0, pi).  Each pi-wrap flips the normal,
    # so rho's sign flips once per wrap parity.
    k = math.floor(tp / math.pi)
    tp -= k * math.pi
    if tp >= math.pi:       # guard the floor's float edge
        tp -= math.pi
        k += 1
    if k % 2:
        rp = -rp
    return rp, tp


def _warp_rigid(img: np.ndarray, *, yaw_rad: float, dx: float, dy: float,
                fill: float) -> np.ndarray:
    """Nearest-neighbour inverse warp of the forward map in
    ``transform_rho_theta``; samples leaving the base frame read ``fill``
    (the family's asphalt level, so the revealed border stays textureless
    and under the Canny thresholds)."""
    H, W = img.shape
    cy, cx = (H - 1) / 2.0, (W - 1) / 2.0
    yy, xx = np.mgrid[0:H, 0:W].astype(np.float32)
    qx, qy = xx - cx - dx, yy - cy - dy
    c, s = math.cos(yaw_rad), math.sin(yaw_rad)
    sx = np.rint(c * qx + s * qy + cx).astype(np.int64)
    sy = np.rint(-s * qx + c * qy + cy).astype(np.int64)
    inside = (sx >= 0) & (sx < W) & (sy >= 0) & (sy < H)
    out = np.full((H, W), np.uint8(np.clip(round(fill), 0, 255)))
    out[inside] = img[sy[inside], sx[inside]]
    return out


def make_drive_cycle(family: str, n_frames: int, height: int = 240,
                     width: int = 320, *, seed: int = 0,
                     sway_px: float = 5.0, sway_period: float = 32.0,
                     surge_px: float = 0.0, surge_period: float = 24.0,
                     yaw_amp_deg: float = 2.5,
                     lane_change_at: int | None = None,
                     lane_change_px: float | None = None,
                     lane_change_len: int = 12,
                     dropout_frames: Sequence[int] = (),
                     noise_burst_frames: Sequence[int] = (),
                     burst_frac: float = 0.012) -> DriveCycle:
    """Parameterized ego-motion over one scenario family.

    The base scene is generated ONCE (``make_scenario(family, seed)``) and
    every frame applies a rigid camera motion to it — sinusoidal lateral
    sway (``sway_px``/``sway_period``), sinusoidal longitudinal surge/bob
    (``surge_px``/``surge_period``, the ``dy`` leg of the rigid motion),
    a curvature ramp that yaws up to
    ``yaw_amp_deg`` mid-cycle and back (half-sine), and an optional
    s-curve lane change of ``lane_change_px`` (default 12% of the width)
    over ``lane_change_len`` frames centered at ``lane_change_at``.  The
    per-frame (rho, theta) ground truth is the exact analytic image of the
    planted lines under the same transform (``transform_rho_theta``), so
    trajectory-recovery assertions carry no fitting slack beyond the
    warp's nearest-neighbour rasterization.

    ``dropout_frames`` replace the listed frames with near-black sensor
    blackout (truth retained, ``dropout=True``); ``noise_burst_frames``
    overlay an extra salt-and-pepper burst.  Both draw from rngs seeded by
    ``(seed, t)`` — the whole cycle is bit-reproducible.
    """
    base = make_scenario(family, height, width, seed=seed)
    cy, cx = (height - 1) / 2.0, (width - 1) / 2.0
    fill = float(np.median(base.image))
    if lane_change_px is None:
        lane_change_px = 0.12 * width
    dropout_set = set(int(t) for t in dropout_frames)
    burst_set = set(int(t) for t in noise_burst_frames)
    span = max(n_frames - 1, 1)

    frames: list[DriveCycleFrame] = []
    for t in range(n_frames):
        dx = sway_px * math.sin(2.0 * math.pi * t / sway_period)
        dy = surge_px * math.sin(2.0 * math.pi * t / surge_period)
        if lane_change_at is not None:
            u = (t - (lane_change_at - lane_change_len / 2.0)) / max(
                lane_change_len, 1
            )
            dx += lane_change_px * float(_smoothstep(u))
        yaw = math.radians(yaw_amp_deg) * math.sin(math.pi * t / span)

        truth = np.array(
            [
                transform_rho_theta(float(r), float(th), yaw_rad=yaw,
                                    dx=dx, dy=dy, cx=cx, cy=cy)
                for r, th in base.lines_rho_theta
            ],
            np.float32,
        ).reshape(-1, 2)

        if t in dropout_set:
            rng = np.random.default_rng([seed, 7_000_000 + t])
            img = np.clip(
                rng.normal(10.0, 3.0, (height, width)), 0, 255
            ).astype(np.uint8)
        else:
            img = _warp_rigid(base.image, yaw_rad=yaw, dx=dx, dy=dy,
                              fill=fill)
            if t in burst_set:
                rng = np.random.default_rng([seed, 9_000_000 + t])
                speck = rng.uniform(size=img.shape)
                img = img.copy()
                img[speck < burst_frac] = 255
                img[speck > 1.0 - burst_frac] = 0

        frames.append(DriveCycleFrame(
            scene=RoadScene(img, truth), t=t,
            dropout=t in dropout_set, noise_burst=t in burst_set,
            dx_px=dx, yaw_deg=math.degrees(yaw), dy_px=dy,
        ))
    return DriveCycle(family, tuple(frames))


def standard_drive_cycle(family: str, n_frames: int = 48,
                         height: int = 240, width: int = 320, *,
                         seed: int = 0) -> DriveCycle:
    """The canonical cycle the test harness, the tracking benchmark, and
    the CI F1 gate all share: sway + curvature ramp + a mid-cycle lane
    change, with a 3-frame dropout and a 4-frame noise burst added on the
    noisy families (``NOISY_FAMILIES``) — the regime where the temporal
    layer must beat per-frame detection."""
    noisy = family in NOISY_FAMILIES
    third = n_frames // 3
    return make_drive_cycle(
        family, n_frames, height, width, seed=seed,
        lane_change_at=n_frames // 2,
        # a lane change is seconds of driving: stretch it with the cycle
        # so its peak pixel velocity stays trackable at any length
        lane_change_len=max(12, n_frames // 2),
        dropout_frames=tuple(range(third, third + 3)) if noisy else (),
        noise_burst_frames=(
            tuple(range(2 * third, 2 * third + 4)) if noisy else ()
        ),
    )


# ---------------------------------------------------------------------------
# closed loop: steering feeds the ego-motion that renders the next frame
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ClosedLoopConfig:
    """Plant + world-model knobs for :class:`ClosedLoopCycle`.

    The plant is the standard lateral kinematic model: state ``e``
    (cross-track offset, meters, + = right of lane center) and ``psi``
    (heading error, radians, + = yawed right), driven by the commanded
    curvature ``kappa`` (+ = turn right)::

        psi' = psi + v dt kappa
        e'   = e + v dt sin(psi') + w(t) dt

    ``w(t)`` is a deterministic lateral disturbance (constant drift +
    sinusoidal gust — crosswind / road crown) that the controller must
    keep fighting: an arm that stops steering drifts off center and the
    trajectory gates see it.

    The world model renders the plant state as the rigid image motion of
    the existing drive-cycle machinery: ``dx = -px_per_m * e`` (drive
    right of center -> the scene slides left), ``yaw_img = psi``, and a
    scripted longitudinal surge ``dy`` (suspension bob; exercises the
    ``dy`` leg end to end).  ``px_per_m`` is the near-row image scale of
    ``geometry.DEFAULT_CAMERA`` (~125 px/m at the bottom of 240x320) so
    the perceived and true states agree to first order.
    """
    px_per_m: float = 125.0
    speed_mps: float = 4.0
    frame_dt_s: float = 0.1
    drift_mps: float = 0.15         # constant lateral disturbance
    gust_mps: float = 0.2           # gust amplitude on top of the drift
    gust_period: float = 9.0        # frames per gust cycle (above the
                                    # loop's natural period: attenuated)
    surge_px: float = 3.0           # scripted dy bob amplitude
    surge_period: float = 23.0      # frames per bob cycle
    max_curvature: float = 2.0      # actuator clamp, 1/m
    max_heading_rad: float = 0.6    # plant clamp (keeps the warp sane)
    hold_decay: float = 0.7         # actuator decay when no command lands


class ClosedLoopCycle:
    """A drive cycle whose ego-motion is *closed over the controller*.

    Unlike :func:`make_drive_cycle` (scripted pose trajectory), each
    frame here is rendered from the plant's CURRENT state, and the pose
    advances only when the harness feeds back a steering command::

        cyc = ClosedLoopCycle("straight", 48, seed=0)
        for _ in range(48):
            frame = cyc.observe()          # render + exact truth
            cmd = pipeline_or_service(frame.scene.image)
            cyc.advance(cmd.curvature)     # or advance(None) on refusal

    so a dropout, a shed request, or a degraded answer costs *trajectory
    error*, not just F1.  ``advance(None)`` models the actuator with no
    fresh command: the last curvature decays by ``hold_decay`` each
    frame (the vehicle eases straight while blind).

    Truth is exact by construction: the absolute pose (accumulated yaw +
    translation) is applied to the base scene's analytic lines in ONE
    ``transform_rho_theta`` call per frame — no per-step composition
    drift, which is why that function's canonicalization must survive
    |yaw| >= pi (the PR-10 wrap bugfix).

    Determinism: the disturbance is a closed-form drift+gust (no rng);
    dropout/burst imagery reuses the drive-cycle's ``(seed, t)``-keyed
    rngs — a cycle replays bit-identically for the same seed and the
    same command sequence.
    """

    def __init__(self, family: str, n_frames: int, height: int = 240,
                 width: int = 320, *, seed: int = 0,
                 cfg: ClosedLoopConfig = ClosedLoopConfig(),
                 e0_m: float = 0.25, psi0_rad: float = 0.0,
                 dropout_frames: Sequence[int] = (),
                 noise_burst_frames: Sequence[int] = (),
                 burst_frac: float = 0.012):
        self.family = family
        self.n_frames = n_frames
        self.height, self.width = height, width
        self.seed = seed
        self.cfg = cfg
        self.base = make_scenario(family, height, width, seed=seed)
        self._fill = float(np.median(self.base.image))
        self._cy, self._cx = (height - 1) / 2.0, (width - 1) / 2.0
        self._dropout = set(int(t) for t in dropout_frames)
        self._burst = set(int(t) for t in noise_burst_frames)
        self._burst_frac = burst_frac
        # plant state
        self.t = 0
        self.e_m = float(e0_m)
        self.psi_rad = float(psi0_rad)
        self._held_kappa = 0.0
        # history: (t, e_m, psi_rad, kappa_cmd) per advance()
        self.trajectory: list[tuple[int, float, float, float]] = []

    # --- world model -----------------------------------------------------
    def pose(self) -> tuple[float, float, float]:
        """Current absolute render pose ``(yaw_rad, dx_px, dy_px)``."""
        c = self.cfg
        dy = c.surge_px * math.sin(2.0 * math.pi * self.t / c.surge_period)
        return self.psi_rad, -c.px_per_m * self.e_m, dy

    def _disturbance_mps(self, t: int) -> float:
        c = self.cfg
        return c.drift_mps + c.gust_mps * math.sin(
            2.0 * math.pi * t / c.gust_period
        )

    def observe(self) -> DriveCycleFrame:
        """Render the current plant state as one frame with exact truth
        (dropout frames keep their truth — the lanes are still there)."""
        yaw, dx, dy = self.pose()
        truth = np.array(
            [
                transform_rho_theta(float(r), float(th), yaw_rad=yaw,
                                    dx=dx, dy=dy, cx=self._cx, cy=self._cy)
                for r, th in self.base.lines_rho_theta
            ],
            np.float32,
        ).reshape(-1, 2)
        if self.t in self._dropout:
            rng = np.random.default_rng([self.seed, 7_000_000 + self.t])
            img = np.clip(
                rng.normal(10.0, 3.0, (self.height, self.width)), 0, 255
            ).astype(np.uint8)
        else:
            img = _warp_rigid(self.base.image, yaw_rad=yaw, dx=dx, dy=dy,
                              fill=self._fill)
            if self.t in self._burst:
                rng = np.random.default_rng([self.seed, 9_000_000 + self.t])
                speck = rng.uniform(size=img.shape)
                img = img.copy()
                img[speck < self._burst_frac] = 255
                img[speck > 1.0 - self._burst_frac] = 0
        return DriveCycleFrame(
            scene=RoadScene(img, truth), t=self.t,
            dropout=self.t in self._dropout,
            noise_burst=self.t in self._burst,
            dx_px=dx, yaw_deg=math.degrees(yaw), dy_px=dy,
        )

    # --- plant -----------------------------------------------------------
    def advance(self, curvature: float | None) -> None:
        """Step the plant on one steering command (``None`` = no command
        landed this frame: hold the last one, decayed)."""
        c = self.cfg
        if curvature is None:
            self._held_kappa *= c.hold_decay
        else:
            self._held_kappa = max(-c.max_curvature,
                                   min(c.max_curvature, float(curvature)))
        kappa = self._held_kappa
        v_dt = c.speed_mps * c.frame_dt_s
        self.psi_rad = max(-c.max_heading_rad,
                           min(c.max_heading_rad,
                               self.psi_rad + v_dt * kappa))
        self.e_m += v_dt * math.sin(self.psi_rad) \
            + self._disturbance_mps(self.t) * c.frame_dt_s
        self.trajectory.append((self.t, self.e_m, self.psi_rad, kappa))
        self.t += 1

    # --- end metrics -----------------------------------------------------
    @property
    def cross_track(self) -> np.ndarray:
        """|e| after each advance — THE end metric of the drive suite."""
        return np.array([abs(e) for _, e, _, _ in self.trajectory], float)

    @property
    def max_cross_track_m(self) -> float:
        ct = self.cross_track
        return float(ct.max()) if ct.size else abs(self.e_m)

    @property
    def mean_cross_track_m(self) -> float:
        ct = self.cross_track
        return float(ct.mean()) if ct.size else abs(self.e_m)


def standard_closed_loop(family: str, n_frames: int = 48,
                         height: int = 240, width: int = 320, *,
                         seed: int = 0,
                         cfg: ClosedLoopConfig = ClosedLoopConfig()
                         ) -> ClosedLoopCycle:
    """The canonical closed-loop cycle the drive suite and tests share:
    an off-center start plus drift+gust disturbance, with a 5-frame
    dropout and a 4-frame noise burst on the noisy families — the regime
    where coasting and holding must show up as trajectory error, not
    just missed detections.

    The dropout sits MID-TRANSIENT (frames 6-10, while the loop is still
    pulling the off-center start back in): a blackout there costs real
    trajectory error, so an arm that coasts on predicted tracks
    measurably beats one that can only decay its last command.  A
    dropout placed after the transient settles (``standard_drive_cycle``
    puts its at n/3) is nearly free — hold-decay rides it out — and the
    tracked-vs-per-frame trajectory gate would have nothing to bite on.
    The noise burst lands at 2n/3, in steady state."""
    noisy = family in NOISY_FAMILIES
    burst0 = 2 * n_frames // 3
    return ClosedLoopCycle(
        family, n_frames, height, width, seed=seed, cfg=cfg,
        dropout_frames=tuple(range(6, 11)) if noisy else (),
        noise_burst_frames=(
            tuple(range(burst0, burst0 + 4)) if noisy else ()
        ),
    )
