"""Deterministic, resumable, shard-aware synthetic token pipeline.

Design requirements at 1000+ nodes (DESIGN.md §5):

  * **step-indexed determinism** — ``batch_at(step)`` is a pure function of
    (seed, step, shard), so restart-from-checkpoint resumes the exact token
    stream with no persisted iterator state, and elastic resharding just
    changes the (shard, n_shards) arguments;
  * **shard awareness** — each data-parallel host pulls only its slice of
    the global batch;
  * **prefetch** — a background thread keeps ``depth`` batches ready so the
    host never blocks the device (``PrefetchLoader``);
  * **straggler mitigation** — ``SkipAheadLoader`` bounds how long a step
    may wait for a slow producer; on timeout it *skips ahead* to the next
    step index (bounded skips, logged), trading a sliver of data for step
    cadence — the bounded-staleness trick large jobs use when one host's
    storage hiccups.

The synthetic distribution is a mixture of integer-sequence "documents"
(arithmetic ramps, periodic motifs, noisy copies) with enough structure that
a small LM's loss visibly drops — tests assert learning, not just shapes.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class TokenPipelineConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards


class TokenStream:
    """Pure step-indexed batch source."""

    def __init__(self, cfg: TokenPipelineConfig):
        self.cfg = cfg

    def _doc(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """One synthetic document of n tokens."""
        v = self.cfg.vocab
        kind = rng.integers(0, 3)
        if kind == 0:     # arithmetic ramp with random stride
            start = rng.integers(0, v)
            stride = rng.integers(1, 7)
            return (start + stride * np.arange(n)) % v
        if kind == 1:     # periodic motif
            period = rng.integers(2, 9)
            motif = rng.integers(0, v, period)
            return np.tile(motif, n // period + 1)[:n]
        # noisy copy: token repeated with occasional jumps
        out = np.empty(n, np.int64)
        tok = rng.integers(0, v)
        for i in range(n):
            if rng.random() < 0.1:
                tok = rng.integers(0, v)
            out[i] = tok
        return out

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Shard-local batch for global ``step``: {tokens, targets}."""
        cfg = self.cfg
        B, S = cfg.shard_batch, cfg.seq_len
        tokens = np.empty((B, S + 1), np.int32)
        for b in range(B):
            # deterministic per (seed, step, global row)
            row = cfg.shard * B + b
            rng = np.random.default_rng(
                np.random.SeedSequence([cfg.seed, step, row])
            )
            buf = []
            while sum(len(d) for d in buf) < S + 1:
                buf.append(self._doc(rng, int(rng.integers(16, S + 2))))
            tokens[b] = np.concatenate(buf)[: S + 1].astype(np.int32)
        return {"tokens": tokens[:, :-1], "targets": tokens[:, 1:]}

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchLoader:
    """Background-thread prefetch of a step-indexed source."""

    def __init__(self, stream: TokenStream, *, depth: int = 2,
                 start_step: int = 0):
        self.stream = stream
        self.depth = depth
        self._q: "queue.Queue[tuple[int, Any]]" = queue.Queue(maxsize=depth)
        self._next = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._next
        while not self._stop.is_set():
            batch = self.stream.batch_at(step)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def get(self) -> tuple[int, Any]:
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)


class SkipAheadLoader:
    """Bounded-staleness wrapper: never wait more than ``timeout_s`` per step.

    If the underlying producer (possibly artificially slowed — see
    ``delay_fn`` used by the straggler tests) misses the deadline, the step
    index advances anyway and the late batch is discarded on arrival.
    ``skipped`` records the step ids sacrificed to keep cadence; the cap
    ``max_consecutive_skips`` turns a persistent stall into a hard error
    instead of silently training on nothing.
    """

    def __init__(self, stream: TokenStream, *, timeout_s: float = 1.0,
                 max_consecutive_skips: int = 3,
                 delay_fn=None, start_step: int = 0):
        self.stream = stream
        self.timeout_s = timeout_s
        self.max_skips = max_consecutive_skips
        self.delay_fn = delay_fn
        self.step = start_step
        self.skipped: list[int] = []
        self._consecutive = 0

    def _produce(self, step: int, out: dict):
        if self.delay_fn is not None:
            time.sleep(self.delay_fn(step))
        out["batch"] = self.stream.batch_at(step)

    def get(self) -> tuple[int, Any]:
        while True:
            out: dict = {}
            t = threading.Thread(
                target=self._produce, args=(self.step, out), daemon=True
            )
            t.start()
            t.join(self.timeout_s)
            if "batch" in out:
                step = self.step
                self.step += 1
                self._consecutive = 0
                return step, out["batch"]
            # straggler: skip this step, bounded
            self.skipped.append(self.step)
            self._consecutive += 1
            if self._consecutive > self.max_skips:
                raise RuntimeError(
                    f"data pipeline stalled: {self._consecutive} consecutive "
                    f"skips at step {self.step}"
                )
            self.step += 1
