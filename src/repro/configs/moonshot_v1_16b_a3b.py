"""moonshot-v1-16b-a3b [moe]: 48L, d_model=2048, 16H (kv=16, full MHA),
expert d_ff=1408, vocab=163840, MoE 64 experts top-6.
[hf:moonshotai/Moonlight-16B-A3B; hf]

Divergences (DESIGN.md §7): assignment spec wins — no shared experts
(vendor has 2), no dense first layer, and the assigned 48L (vendor has 27,
so totals land at ~28B rather than 16B; active ~4B).  64 experts on a
16-way model axis = 4 experts per chip; with top-6 routing this is the most
collective-hungry MoE cell in the matrix (a natural hillclimb candidate).
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163840,
    moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408),
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64, vocab=256,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=64), remat=False,
)
