"""h2o-danube-1.8b [dense]: 24L, d_model=2560, 32H (GQA kv=8), d_ff=6912,
vocab=32000.  [arXiv:2401.16818; hf]

Llama + Mistral mix with sliding-window attention (window=4096), which is
what qualifies it for the long_500k decode shape: the ring KV cache is
O(window) at 500k positions.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-1.8b",
    family="dense",
    n_layers=24,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6912,
    vocab=32000,
    window=4096,              # Mistral-style SWA
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    window=16, remat=False,
)
