"""yi-9b [dense]: 48L, d_model=4096, 32H (GQA kv=4), d_ff=11008,
vocab=64000.  [arXiv:2403.04652; hf]

Llama-architecture GQA decoder; the straight Megatron-style GEMM path.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    rope_theta=10000.0,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    remat=False,
)
