"""ModelConfig dataclass + the assigned input-shape matrix.

The 10 assigned architectures each get a module in this package defining
``CONFIG`` (exact assigned dims) and ``SMOKE`` (reduced same-family config
for CPU tests).  Inline ``# assignment:`` comments flag any divergence
between the assignment table and the vendor checkpoint, per DESIGN.md §7.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                     # per-expert hidden dim
    capacity_factor: float = 1.25
    router_scale: float = 1.0     # optional logit scaling


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    kind: str                     # "mamba1" | "mamba2"
    d_state: int
    d_inner: int
    d_conv: int = 4
    n_heads: int = 0              # mamba2: d_inner // head_dim
    head_dim: int = 64            # mamba2 P
    n_groups: int = 1             # mamba2 B/C groups
    chunk: int = 128              # SSD / chunked-scan length
    dt_rank: int = 0              # mamba1 dt low-rank


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None
    qkv_bias: bool = False
    window: Optional[int] = None          # sliding-window attention
    rope_theta: float = 10000.0
    norm: str = "rms"                     # rms | layer
    norm_eps: float = 1e-5
    act: str = "silu"                     # silu (SwiGLU) | gelu
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # vlm
    cross_every: int = 0                  # 1 cross-attn layer per this many
    n_img_tokens: int = 0
    d_vision: int = 0                     # vision-embed dim (adapter input)
    # encoder-decoder
    encoder_layers: int = 0
    n_frames: int = 0                     # audio frames (frontend stub)
    # hybrid (zamba2)
    share_every: int = 0                  # shared attn block cadence
    shared_attn_heads: int = 0
    # numerics / training
    compute_dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# --- assigned shape matrix ----------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCHS = (
    "whisper-large-v3",
    "llama-3.2-vision-11b",
    "h2o-danube-1.8b",
    "yi-9b",
    "granite-34b",
    "qwen1.5-32b",
    "llama4-scout-17b-a16e",
    "moonshot-v1-16b-a3b",
    "zamba2-1.2b",
    "falcon-mamba-7b",
)

_MODULES = {
    "whisper-large-v3": "whisper_large_v3",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "yi-9b": "yi_9b",
    "granite-34b": "granite_34b",
    "qwen1.5-32b": "qwen1_5_32b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "zamba2-1.2b": "zamba2_1_2b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}


def _module(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}")


def get(name: str) -> ModelConfig:
    return _module(name).CONFIG


def get_smoke(name: str) -> ModelConfig:
    return _module(name).SMOKE


def shapes_for(cfg: ModelConfig) -> list[str]:
    """Which assigned shapes run for this arch (per DESIGN.md skips).

    long_500k needs sub-quadratic attention: runs for ssm/hybrid archs and
    SWA dense archs; skipped for pure full-attention archs.  Every assigned
    arch here has a decoder, so decode shapes always apply.
    """
    out = ["train_4k", "prefill_32k", "decode_32k"]
    subquadratic = (
        cfg.family in ("ssm", "hybrid") or cfg.window is not None
    )
    if subquadratic:
        out.append("long_500k")
    return out
