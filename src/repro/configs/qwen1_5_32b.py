"""qwen1.5-32b [dense]: 64L, d_model=5120, 40H (kv=40, full MHA),
d_ff=27392, vocab=152064.  [hf:Qwen/Qwen1.5-0.5B; hf]

QKV bias per the Qwen lineage.  40 heads do not divide the 16-way model
axis; the fallback chain shards head_dim instead (the whisper/qwen case in
``sharding/partition.py``).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab=152064,
    qkv_bias=True,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    remat=False,
)
