"""whisper-large-v3 [audio]: 32L enc + 32L dec, d_model=1280, 20H, d_ff=5120,
vocab=51866.  [arXiv:2212.04356; unverified]

Encoder-decoder; the conv audio frontend is a STUB per assignment —
``input_specs`` supplies precomputed (B, 1500, 1280) frame embeddings.
Assignment divergences (DESIGN.md §7):
  * assignment says "32L": implemented as 32 encoder + 32 decoder layers
    (the actual whisper-large-v3 topology).
  * decoder positions use RoPE instead of the vendor's learned table (the
    assigned decode shapes reach 32k positions, far past the 448-entry
    table); encoder keeps its sinusoidal embedding.
  * GQA kv=20 == full MHA (kv == heads), as assigned.
  * vocab 51866 does not divide a 16-way model axis -> the sharding rules
    fall back to replicating the vocab dim and FSDP-sharding the embed dim.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,
    encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    n_frames=1500,
    norm="layer",
    act="gelu",
    tie_embeddings=True,      # whisper ties decoder embed/unembed
)

SMOKE = CONFIG.replace(
    n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, n_frames=12, remat=False,
)
