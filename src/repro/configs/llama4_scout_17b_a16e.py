"""llama4-scout-17b-a16e [moe]: 48L, d_model=5120, 40H (GQA kv=8),
expert d_ff=8192, vocab=202048, MoE 16 experts top-1.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Divergences (DESIGN.md §7): assignment spec wins over vendor quirks — every
layer is MoE (vendor interleaves dense layers), no shared expert, RoPE on
all layers (vendor uses NoPE on some).  16 experts on a 16-way model axis =
exactly 1 expert per chip (the cleanest EP case).
"""

from .base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,                # == expert d_ff (informational for dense path)
    vocab=202048,
    rope_theta=500000.0,
    moe=MoEConfig(n_experts=16, top_k=1, d_ff=8192),
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    moe=MoEConfig(n_experts=4, top_k=1, d_ff=128), remat=False,
)
