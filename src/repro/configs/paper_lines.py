"""The paper's own application as a config: line detection pipelines.

Not an LM arch — this parameterizes ``repro.core`` the way the paper's
platform matrix (Table 7) does, so drivers/benchmarks can select execution
variants by name the same way ``--arch`` selects a model.

    from repro.configs.paper_lines import PLATFORMS
    det = LineDetector(PLATFORMS["boom+gemmini"])
"""

from repro.core import CannyConfig, HoughConfig, LinesConfig, PipelineConfig

# The paper's platform matrix, as execution variants of the same algorithm.
PLATFORMS = {
    # Rocket 50MHz baseline: scalar stencils, loop-form Hough semantics
    "rocket": PipelineConfig(
        canny=CannyConfig(impl="stencil"),
    ),
    # BOOM: same program on a better core (vectorized paths)
    "boom": PipelineConfig(
        canny=CannyConfig(),
    ),
    # +Gemmini (the paper's Workload 3): conv-as-GEMM offload, int pipeline
    "rocket+gemmini": PipelineConfig(
        canny=CannyConfig(integer=True),
    ),
    "boom+gemmini": PipelineConfig(
        canny=CannyConfig(integer=True),
    ),
    # beyond-paper: fused 7x7 single-pass masks + GEMM-form Hough voting
    "tpu-fused": PipelineConfig(
        canny=CannyConfig(fused=True),
    ),
}

# The paper's frame geometry (Fig. 4-scale) and deployment target.
FRAME_HW = (240, 320)
DEPLOY_HW = (720, 1280)
REALTIME_BUDGET_S = 0.300     # paper: 300 ms/frame -> ~4 m at 50 km/h
