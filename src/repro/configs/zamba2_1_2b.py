"""zamba2-1.2b [hybrid]: 38L, d_model=2048, 32H (kv=32, shared attn block),
d_ff=8192, vocab=32000, ssm_state=64.  [arXiv:2411.15242; hf]

Mamba-2 backbone with one *weight-tied* transformer block (attention + MLP)
applied after every 6th Mamba block: 38 layers = 6 superblocks of 6 + a
2-layer tail without the shared block.  The shared block's parameters are
closure-captured (unstacked) in the scan; its KV caches are per-application
(stacked).  Divergence (DESIGN.md §7): vendor concatenates the residual
stream with the original embedding at the shared block and LoRA-adapts the
tied weights; we apply the tied block directly.
"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,                # shared attn block geometry
    n_kv_heads=32,
    d_ff=8192,                 # shared block MLP
    vocab=32000,
    share_every=6,
    shared_attn_heads=32,
    ssm=SSMConfig(
        kind="mamba2", d_state=64, d_inner=4096, d_conv=4,
        n_heads=64, head_dim=64, n_groups=1, chunk=128,
    ),
)

SMOKE = CONFIG.replace(
    n_layers=5, share_every=2, d_model=64, n_heads=4, n_kv_heads=4,
    shared_attn_heads=4, d_ff=128, vocab=256,
    ssm=SSMConfig(kind="mamba2", d_state=16, d_inner=128, d_conv=4,
                  n_heads=4, head_dim=32, n_groups=2, chunk=16),
    remat=False,
)
