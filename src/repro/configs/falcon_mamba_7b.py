"""falcon-mamba-7b [ssm]: 64L, d_model=4096 (attention-free), d_ff=0,
vocab=65024, ssm_state=16.  [arXiv:2410.05355; unverified]

Pure Mamba-1.  The selective scan's per-(channel, state) decay admits no
SSD/GEMM rewrite (DESIGN.md §Arch-applicability) — it runs as a chunked
associative scan (log-depth inside chunks, sequential carry across), the
honest analogue of the paper leaving Hough's serial loop on the scalar
core.  All projection GEMMs still ride the MXU path.
"""

from .base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=0,                 # attention-free
    n_kv_heads=0,
    d_ff=0,
    vocab=65024,
    ssm=SSMConfig(
        kind="mamba1", d_state=16, d_inner=8192, d_conv=4,
        dt_rank=256, chunk=64,
    ),
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, vocab=256,
    ssm=SSMConfig(kind="mamba1", d_state=8, d_inner=128, d_conv=4,
                  dt_rank=8, chunk=16),
    remat=False,
)
