"""llama-3.2-vision-11b [vlm]: 40L, d_model=4096, 32H (GQA kv=8),
d_ff=14336, vocab=128256.  [hf:meta-llama/Llama-3.2-11B-Vision; unverified]

40 layers = 8 superblocks of (4 self-attn layers + 1 cross-attn layer) —
the vendor's 8 interleaved cross-attention layers.  The vision tower is a
STUB per assignment: ``input_specs`` supplies precomputed (B, 1600, 1280)
patch embeddings; a learned adapter projects 1280 -> 4096.
Divergence: vendor emits 1601 patch tokens (CLS + 40x40); we use 1600 for
tile alignment (DESIGN.md §7).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    rope_theta=500000.0,
    cross_every=5,            # 1 cross layer per 5 -> 8 cross layers
    n_img_tokens=1600,
    d_vision=1280,
)

SMOKE = CONFIG.replace(
    n_layers=4, cross_every=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab=256, n_img_tokens=8, d_vision=32, remat=False,
)
