"""Architecture configs: one module per assigned arch + the paper's own.

``get(name)`` returns the full-size ModelConfig; ``get_smoke(name)`` a
family-preserving reduced config for CPU smoke tests.  ``ARCHS`` lists every
selectable ``--arch`` id.
"""

from .base import (  # noqa: F401
    ModelConfig,
    MoEConfig,
    SSMConfig,
    SHAPES,
    ShapeSpec,
    get,
    get_smoke,
    ARCHS,
    shapes_for,
)
