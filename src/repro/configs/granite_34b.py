"""granite-34b [dense]: 88L, d_model=6144, 48H (MQA kv=1), d_ff=24576,
vocab=49152.  [arXiv:2405.04324; hf]

Code model with multi-query attention: kv=1 cannot shard on a 16-way model
axis, so the rule table's fallback shards head_dim (128/16) for the kv
projections and the decode cache — the arch that motivates the fallback
chain in ``sharding/partition.py``.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    n_layers=88,
    d_model=6144,
    n_heads=48,
    n_kv_heads=1,             # MQA
    d_ff=24576,
    vocab=49152,
    act="gelu",               # GPT-BigCode 2-matrix MLP (34B total; SwiGLU
                              # would be 47B — vendor uses plain GELU)
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128, vocab=256,
    remat=False,
)
