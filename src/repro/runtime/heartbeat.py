"""Heartbeats: workers report liveness; a monitor flags the silent ones.

At cluster scale this runs over the coordination service; here it is an
in-process implementation with the same contract, used by the supervisor
tests to detect a simulated hung worker and trigger the restart policy.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Optional


class Heartbeat:
    """Worker side: beat() regularly (or let the auto-thread do it).

    ``clock`` is injectable (default ``time.monotonic``) so the serving
    path can beat on the same :class:`~repro.serve.detection.VirtualClock`
    the scheduler runs on — liveness decisions then become deterministic
    functions of the driven schedule, not of wall time.
    """

    def __init__(self, worker_id: str, registry: dict, *,
                 interval_s: float = 0.05, auto: bool = False,
                 clock: Callable[[], float] = time.monotonic):
        self.worker_id = worker_id
        self.registry = registry
        self.interval_s = interval_s
        self.clock = clock
        self._stop = threading.Event()
        self.beat()
        self._thread = None
        if auto:
            self._thread = threading.Thread(target=self._loop, daemon=True)
            self._thread.start()

    def beat(self):
        self.registry[self.worker_id] = self.clock()

    def _loop(self):
        while not self._stop.is_set():
            self.beat()
            time.sleep(self.interval_s)

    def stop(self):
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1.0)


class HeartbeatMonitor:
    """Controller side: which workers missed their deadline?"""

    def __init__(self, registry: dict, *, timeout_s: float = 0.25,
                 on_dead: Optional[Callable[[str], None]] = None,
                 clock: Callable[[], float] = time.monotonic):
        self.registry = registry
        self.timeout_s = timeout_s
        self.on_dead = on_dead
        self.clock = clock

    def dead_workers(self) -> list[str]:
        now = self.clock()
        dead = [
            w for w, t in self.registry.items()
            if now - t > self.timeout_s
        ]
        if self.on_dead:
            for w in dead:
                self.on_dead(w)
        return dead

    def all_alive(self) -> bool:
        return not self.dead_workers()
