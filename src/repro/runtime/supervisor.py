"""Restart supervision: checkpoint/restart with bounded retries + elasticity.

``run_with_restarts`` drives a step function under a fault model: any
``WorkerFailure`` (raised by the real stack on node loss, or by
``FaultInjector`` in tests) rolls the loop back to the last checkpoint and
continues, up to ``max_restarts``.  The step function receives the restored
state and the step index to resume from, so together with the step-indexed
data pipeline the post-restart trajectory is *bitwise identical* to an
uninterrupted run (asserted in tests/test_runtime.py).

Elasticity hook: ``on_restart`` may return a new ``(mesh, shardings)`` —
restore re-places the same host arrays on the surviving device set
(checkpoints are mesh-agnostic; see checkpoint/store.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

from repro.checkpoint import CheckpointManager


class WorkerFailure(RuntimeError):
    """A (simulated) node loss / hang escalated by the heartbeat monitor."""


@dataclasses.dataclass
class FaultInjector:
    """Deterministic fault schedule: fail when step hits each trigger once."""

    fail_at_steps: tuple[int, ...] = ()
    _fired: set = dataclasses.field(default_factory=set)

    def check(self, step: int):
        if step in self.fail_at_steps and step not in self._fired:
            self._fired.add(step)
            raise WorkerFailure(f"injected failure at step {step}")


def run_with_restarts(
    *,
    init_state: Any,
    step_fn: Callable[[Any, int], Any],     # (state, step) -> state
    n_steps: int,
    ckpt: CheckpointManager,
    ckpt_every: int = 10,
    max_restarts: int = 3,
    state_template: Optional[Any] = None,
    shardings: Any = None,
    on_restart: Optional[Callable[[int], Any]] = None,
) -> tuple[Any, dict]:
    """Returns (final_state, stats {restarts, completed_steps, resumed_from})."""
    state = init_state
    step = 0
    restarts = 0
    resumed_from: list[int] = []
    ckpt.save_sync(state, step)

    while step < n_steps:
        try:
            state = step_fn(state, step)
            step += 1
            if step % ckpt_every == 0:
                ckpt.save_async(state, step)
        except WorkerFailure:
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                new = on_restart(restarts)
                if new is not None:
                    shardings = new
            ckpt.wait()
            template = state_template if state_template is not None else state
            state = ckpt.restore_latest(template, shardings=shardings)
            from repro.checkpoint import latest_step
            step = latest_step(ckpt.directory)
            resumed_from.append(step)
    ckpt.wait()
    return state, {
        "restarts": restarts,
        "completed_steps": step,
        "resumed_from": resumed_from,
    }
