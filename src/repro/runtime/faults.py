"""Deterministic fault injection for the detection service path.

``supervisor.FaultInjector`` schedules step-indexed worker failures for
the training loop; this module is its serving-side twin: a one-shot,
fully deterministic schedule of the fault classes the fleet harness
(``benchmarks/fleet_suite.py``) and ``tests/test_fleet.py`` exercise
against :class:`repro.serve.detection.DetectionService`:

  * **stager death** — the ``check_stage`` hook runs inside the
    ``PrefetchStager`` worker thread, once per staged task; at a
    scheduled ordinal it raises :class:`WorkerFailure`, killing the
    worker mid-stream (the stager surfaces the death to callers as an
    explicit error — never a silent hang — and the service restarts it).
  * **dispatch failure** — ``fails_dispatch(k)`` fires at scheduled
    dispatch ordinals; the service resolves the whole would-be batch to
    ``RequestStatus.FAILED`` instead of running the plan.
  * **dispatch stall** — ``stall_for_dispatch(k)`` returns extra seconds
    of modeled service time for scheduled dispatches; the batch
    completes late (the EMA never sees the stalled sample).
  * **corrupt frames** — ``corrupts(uid)`` marks request uids whose
    frames the service NaN-poisons at submit; the finiteness check at
    admission turns them into coast answers or ``INVALID_FRAME``.
  * **clock jumps** — ``clock_jump_for_step(k)`` returns seconds to jump
    the service's :class:`VirtualClock` forward before scheduled
    scheduler steps (a large jump expires a whole EDF wave at once).
  * **replica death** — ``replicas_to_kill(k)`` returns the replica
    indices scheduled to die before router step ``k`` of a
    :class:`repro.serve.fleet.ShardedDetectionService`; the router
    fails the dead replica's in-flight work, re-routes its queue to
    survivors, and drops its session pins (trackers die with the
    replica — failover is explicit, never silent).
  * **host death** — ``hosts_to_kill(k)`` is the same schedule one
    failure domain up: a host id whose *entire replica group* dies
    before router step ``k`` (``ShardedDetectionService.kill_host``
    marks the whole group dead first, then fails/re-routes, so no
    victim's queue can land on a dying same-host sibling).
  * **message loss** — ``loses_uplink(i)`` / ``loses_downlink(i)``
    force-drop the named leg of speculative race ``i`` (the race
    ordinal, 0-based).  The ``NetworkModel`` already loses messages
    probabilistically; these make the lost-uplink / lost-downlink
    harness arms *exact* instead of fishing for a lossy seed.

Every trigger fires exactly once (the ``_fired`` set), so an injected
fault can never livelock a bounded driver loop, and every schedule is a
plain tuple — the harness's fault matrix is reproducible bit-for-bit.
"""

from __future__ import annotations

import dataclasses

from .supervisor import WorkerFailure


@dataclasses.dataclass
class ServiceFaultInjector:
    """One-shot deterministic fault schedule for ``DetectionService``."""

    kill_stager_at: tuple[int, ...] = ()     # staged-task ordinals
    fail_dispatch_at: tuple[int, ...] = ()   # dispatch ordinals
    stall_dispatch_at: tuple[int, ...] = ()  # dispatch ordinals
    stall_s: float = 1.0                     # extra seconds per stall
    corrupt_frame_uids: tuple[int, ...] = () # request uids to NaN-poison
    clock_jump_at_step: tuple[int, ...] = () # scheduler-step ordinals
    clock_jump_s: float = 10.0               # forward jump per trigger
    # (router step, replica index) pairs: replica dies before that step
    kill_replica_at: tuple[tuple[int, int], ...] = ()
    # (router step, host id) pairs: the host's whole group dies
    kill_host_at: tuple[tuple[int, int], ...] = ()
    # speculative-race ordinals whose named leg is force-dropped
    lose_uplink_races: tuple[int, ...] = ()
    lose_downlink_races: tuple[int, ...] = ()
    _stage_calls: int = 0
    _fired: set = dataclasses.field(default_factory=set)

    def _once(self, kind: str, k: int, schedule: tuple[int, ...]) -> bool:
        if k in schedule and (kind, k) not in self._fired:
            self._fired.add((kind, k))
            return True
        return False

    # -- stager (called from the worker thread, one thread at a time) ----
    def check_stage(self) -> None:
        """Per-staged-task hook; raises ``WorkerFailure`` on schedule.

        The ordinal counts staged tasks across the service's lifetime —
        stager restarts do not reset it, so a schedule like ``(0, 5)``
        kills the restarted worker too.
        """
        k = self._stage_calls
        self._stage_calls += 1
        if self._once("stage", k, self.kill_stager_at):
            raise WorkerFailure(f"injected stager death at staged task {k}")

    # -- dispatch --------------------------------------------------------
    def fails_dispatch(self, k: int) -> bool:
        return self._once("dispatch", k, self.fail_dispatch_at)

    def stall_for_dispatch(self, k: int) -> float:
        """Extra modeled seconds for dispatch ``k`` (0.0 = no stall)."""
        if self._once("stall", k, self.stall_dispatch_at):
            return float(self.stall_s)
        return 0.0

    # -- frames ----------------------------------------------------------
    def corrupts(self, uid: int) -> bool:
        return self._once("corrupt", uid, self.corrupt_frame_uids)

    # -- clock -----------------------------------------------------------
    def clock_jump_for_step(self, k: int) -> float:
        """Seconds to jump the clock before scheduler step ``k``."""
        if self._once("clock", k, self.clock_jump_at_step):
            return float(self.clock_jump_s)
        return 0.0

    # -- replicas (fleet router) -----------------------------------------
    def replicas_to_kill(self, k: int) -> tuple[int, ...]:
        """Replica indices scheduled to die before router step ``k``
        (one-shot per (step, replica) pair, like every other trigger)."""
        out = []
        for step, replica in self.kill_replica_at:
            if step == k and self._once("replica", (k, replica),
                                        ((k, replica),)):
                out.append(replica)
        return tuple(out)

    # -- hosts (fleet front tier) ----------------------------------------
    def hosts_to_kill(self, k: int) -> tuple[int, ...]:
        """Host ids scheduled to die before router step ``k`` — a whole
        failure domain at once (one-shot per (step, host) pair)."""
        out = []
        for step, host in self.kill_host_at:
            if step == k and self._once("host", (k, host), ((k, host),)):
                out.append(host)
        return tuple(out)

    # -- network (speculative race legs) ---------------------------------
    def loses_uplink(self, race: int) -> bool:
        """Force-drop race ``race``'s request leg (one-shot)."""
        return self._once("uplink", race, self.lose_uplink_races)

    def loses_downlink(self, race: int) -> bool:
        """Force-drop race ``race``'s response leg (one-shot)."""
        return self._once("downlink", race, self.lose_downlink_races)
