"""Fault-tolerant runtime: heartbeats, restart supervision, fault injection."""

from .faults import ServiceFaultInjector  # noqa: F401
from .heartbeat import Heartbeat, HeartbeatMonitor  # noqa: F401
from .supervisor import (  # noqa: F401
    WorkerFailure,
    FaultInjector,
    run_with_restarts,
)
