"""Logical-axis sharding rules (DP/FSDP/TP/EP/SP + the multi-pod axis).

The paper's placement question — *which unit should run this stage* — becomes,
at framework scale, *which mesh axis should carry this tensor dimension*.
This package answers it the MaxText way: every parameter and activation is
annotated with logical axis names, and a rule table maps those names onto
mesh axes with divisibility-checked fallbacks.
"""

from .partition import (  # noqa: F401
    AxisRules,
    DEFAULT_RULES,
    DECODE_RULES,
    SP_RULES,
    activate,
    logical_to_spec,
    named_sharding,
    shardings_for_tree,
    constrain,
    rules_for_shape,
    shard_map,
)
