"""Logical axes -> PartitionSpec with divisibility-checked fallbacks.

Every model parameter / activation / cache tensor carries a tuple of logical
axis names (e.g. ``("embed", "heads", "head_dim")``).  A rule table maps each
logical name to an ordered list of *candidate* mesh placements; the first
candidate whose mesh-axis product divides the dimension size — and whose mesh
axes are not already taken by an earlier dimension of the same tensor — wins.
``None`` (replicate) is always a legal last resort.

Why candidates instead of a fixed map: the assigned archs are adversarial to
any single rule.  granite-34b has 1 kv head (cannot TP-shard heads), whisper
has 20 heads and a 51866 vocab (neither divides a 16-way model axis), and
long_500k decodes at global batch 1 (cannot DP-shard batch).  The fallback
chain keeps one rule table valid for every (arch x shape x mesh) cell instead
of 40 bespoke tables — the same move the paper makes when `tiled_matmul_auto`
picks tile factors per matrix instead of hardcoding them.

Mesh conventions (launch/mesh.py):
  * single-pod: ``("data", "model")`` = (16, 16)
  * multi-pod:  ``("pod", "data", "model")`` = (2, 16, 16); the ``pod`` axis
    crosses the slow DCN/ICI-pod boundary, so rules only ever put *batch*
    (pure DP) on it — parameters are FSDP-sharded over the intra-pod ``data``
    axis so their all-gathers never cross pods, and only the once-per-step
    gradient reduction does (where ``train/compression.py`` applies the
    paper's int8 trick).
"""

from __future__ import annotations

import math
from typing import Any, Mapping, Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

Candidate = Union[None, str, tuple]
AxisRules = Mapping[str, Sequence[Candidate]]

# --- rule tables -----------------------------------------------------------

# Training / prefill defaults: FSDP over `data`, TP over `model`, DP over
# (`pod`, `data`).
DEFAULT_RULES: AxisRules = {
    # activations
    "batch": (("pod", "data"),),
    "seq": (None,),
    "embed_act": (None,),
    # params: table below is ordered so a param's dims are tried in tensor
    # order — fallbacks engage only when an earlier dim failed (see module
    # docstring for the arch cases that need it).
    "vocab": ("model", None),
    "embed": ("data", None),            # FSDP axis
    "mlp": ("model", None),             # Megatron column/row split
    "heads": ("model", None),
    "kv_heads": ("model", None),
    "head_dim": ("model", None),        # engaged when heads/kv_heads fail
    "qkv": (None,),                     # fused-qkv minor dims
    "experts": ("model", None),         # expert parallelism
    "expert_mlp": (None,),
    "expert_cap": (("pod", "data"), None),  # dispatched token slots
    "state": (None,),                   # SSM state dim (small: 16..128)
    "inner": ("model", None),           # SSM d_inner (channel TP)
    "inner_heads": ("model", None),     # Mamba-2 head axis
    "conv_k": (None,),
    "dt_rank": (None,),
    "layers": (None,),                  # stacked-scan leading dim
    "img_seq": (None,),
    "frames": (None,),
    "norm": (None,),
    # KV-cache timeline (prefill fills it, decode extends it): TP shards
    # kv_heads when they divide, else the sequence (split-KV)
    "cache_seq": ("model", None),
    # Full-sequence attention activations (B, H, L, hd): heads carry TP
    # when they divide; otherwise the *sequence* does (context-parallel
    # attention — GSPMD all-gathers K/V per shard instead of psumming
    # (B, H, L, L) score tensors, the whisper/qwen 20/40-head fix visible
    # in the benchmarks/t5_dp_scaling tables).  Dim order (batch, heads,
    # attn_seq, head_dim) encodes the fallback.
    "attn_seq": ("model", None),
}

# Sequence parallelism (32k prefill / long-context): activations carry their
# sequence dim on `model` between blocks; attention/scan internals gather it.
SP_RULES: AxisRules = {
    **DEFAULT_RULES,
    "seq": ("model", None),
}

# Decode: the KV cache is the resident tensor.  Batch over DP; cache heads
# over TP, falling back to *sequence* sharding of the cache (flash-decoding
# style split-KV: each model shard scans its stretch of the timeline and the
# softmax combines via psum) when kv heads don't divide — granite kv=1,
# h2o kv=8.  Dim order (batch, kv_heads, seq, head_dim) encodes the chain.
DECODE_RULES: AxisRules = {
    **DEFAULT_RULES,
    "batch": (("pod", "data"), None),
    "cache_seq": ("model", None),
    "kv_heads": ("model", None),
    # Decode reads every weight once per token: FSDP weight-gathers would
    # cost ~param-bytes of collective per step (measured on falcon decode,
    # §Perf iteration 3) — replicate across `data`, shard on `model` only.
    "embed": (None,),
}


def _axes_in_mesh(cand: Candidate, mesh: Mesh) -> tuple:
    """Normalize a candidate to a tuple of axes present in this mesh."""
    if cand is None:
        return ()
    if isinstance(cand, str):
        cand = (cand,)
    return tuple(a for a in cand if a in mesh.axis_names)


def logical_to_spec(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: AxisRules = DEFAULT_RULES,
) -> PartitionSpec:
    """Map one tensor's logical axes to a PartitionSpec on ``mesh``."""
    assert len(axes) == len(shape), (axes, shape)
    taken: set = set()
    out = []
    for name, size in zip(axes, shape):
        pick = None
        for cand in rules.get(name, (None,)) if name is not None else (None,):
            mesh_axes = _axes_in_mesh(cand, mesh)
            if not mesh_axes:       # None candidate or axis absent: replicate
                pick = None
                break
            if any(a in taken for a in mesh_axes):
                continue
            n = math.prod(mesh.shape[a] for a in mesh_axes)
            if n and size % n == 0:
                pick = mesh_axes if len(mesh_axes) > 1 else mesh_axes[0]
                taken.update(mesh_axes)
                break
        out.append(pick)
    # strip trailing None for tidy specs
    while out and out[-1] is None:
        out.pop()
    return PartitionSpec(*out)


def named_sharding(
    axes: Sequence[Optional[str]],
    shape: Sequence[int],
    mesh: Mesh,
    rules: AxisRules = DEFAULT_RULES,
) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(axes, shape, mesh, rules))


def _is_axes_leaf(x) -> bool:
    """A logical-axes tuple: plain tuple of names/None (not a NamedTuple)."""
    return (
        isinstance(x, tuple)
        and not hasattr(x, "_fields")
        and all(e is None or isinstance(e, str) for e in x)
    )


def shardings_for_tree(
    axes_tree: Any,
    shape_tree: Any,
    mesh: Mesh,
    rules: AxisRules = DEFAULT_RULES,
) -> Any:
    """NamedSharding pytree for (axes pytree, ShapeDtypeStruct pytree).

    ``axes_tree`` leaves are tuples of logical names; tuples are leaves here
    (matched positionally against the shape tree).
    """
    leaves_axes, treedef = jax.tree.flatten(axes_tree, is_leaf=_is_axes_leaf)
    leaves_shape = treedef.flatten_up_to(shape_tree)
    shardings = [
        named_sharding(a, s.shape, mesh, rules)
        for a, s in zip(leaves_axes, leaves_shape)
    ]
    return jax.tree.unflatten(treedef, shardings)


# --- activation-constraint context ------------------------------------------
#
# Model code annotates activations by logical axes unconditionally; the
# constraint engages only inside ``activate(mesh, rules)`` (used by the
# launchers/dry-run), and is a no-op in single-device unit tests.  The
# context is read at *trace* time, so it must wrap ``jit(...).lower()`` /
# the first call, not execution.

import contextlib
import contextvars
import functools

_ACTIVE: contextvars.ContextVar = contextvars.ContextVar(
    "repro_sharding_active", default=None
)


@contextlib.contextmanager
def activate(mesh: Mesh, rules: AxisRules = DEFAULT_RULES):
    token = _ACTIVE.set((mesh, rules))
    try:
        with mesh:
            yield
    finally:
        _ACTIVE.reset(token)


_MANUAL_AXES: contextvars.ContextVar = contextvars.ContextVar(
    "repro_manual_axes", default=frozenset()
)


def _manual_axes_here() -> set:
    """Mesh axes that are Manual in the current trace (inside shard_map).

    Two sources: the abstract-mesh axis types (newer jax), plus the set our
    ``shard_map`` wrapper records while tracing its body (works on jax
    versions whose traces don't expose manual-ness).
    """
    manual = set(_MANUAL_AXES.get())
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and am.axis_names:
            manual |= {
                n for n, t in zip(am.axis_names, am.axis_types)
                if "Manual" in str(t)
            }
    except Exception:
        pass
    return manual


def constrain(
    x: jax.Array,
    axes: Sequence[Optional[str]],
    rules: Optional[AxisRules] = None,
) -> jax.Array:
    """``with_sharding_constraint`` by logical axes, against the active mesh.

    No-op outside an ``activate(...)`` region so model code can annotate
    unconditionally.  Inside a shard_map manual region (e.g. the
    pod-compressed trainer), axes that are already Manual are dropped from
    the spec — they're physically fixed there.
    """
    active = _ACTIVE.get()
    if active is None:
        return x
    mesh, active_rules = active
    spec = logical_to_spec(axes, x.shape, mesh, rules or active_rules)
    manual = _manual_axes_here()
    if manual and not hasattr(jax, "shard_map"):
        # Old-jax partial-manual shard_map: XLA's SPMD partitioner cannot
        # honour auto-axis constraints inside a manual subgroup (it hard-
        # crashes on IsManualSubgroup).  Constraints are hints, not
        # semantics — drop them there and let GSPMD place the body freely.
        return x
    if manual:
        def strip(entry):
            if entry is None:
                return None
            names = (entry,) if isinstance(entry, str) else tuple(entry)
            kept = tuple(n for n in names if n not in manual)
            if not kept:
                return None
            return kept if len(kept) > 1 else kept[0]
        spec = PartitionSpec(*(strip(e) for e in spec))
    return jax.lax.with_sharding_constraint(x, spec)


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None,
              check_vma: bool = False):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map(..., axis_names=..., check_vma=...)``;
    older releases have ``jax.experimental.shard_map.shard_map`` where the
    manual axis set is expressed as its complement (``auto``) and the
    replication check is ``check_rep``.  ``axis_names`` is the set of
    *manual* axes; ``None`` (the jax default) means all mesh axes.
    """
    manual = (
        frozenset(mesh.axis_names) if axis_names is None
        else frozenset(axis_names)
    )

    @functools.wraps(f)
    def traced(*args, **kwargs):
        # Record the manual set for constrain()'s axis stripping: tracing
        # of the body happens inside this call, so the contextvar is live
        # exactly while sharding constraints inside ``f`` are staged.
        token = _MANUAL_AXES.set(frozenset(_MANUAL_AXES.get()) | manual)
        try:
            return f(*args, **kwargs)
        finally:
            _MANUAL_AXES.reset(token)

    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(
            traced, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=check_vma, **kw,
        )
    from jax.experimental.shard_map import shard_map as _shard_map
    auto = frozenset(mesh.axis_names) - manual
    return _shard_map(
        traced, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=check_vma, auto=auto,
    )


# --- detection fleet (replica mesh) -----------------------------------------

# The detection service's slot grids are (slots, H, W) batches: the only
# shardable axis is the slot axis, over the 1-D ("replica",) mesh of
# launch.mesh.make_replica_mesh — rows/columns stay whole (the Canny halo
# and the Hough vote read whole frames).
DETECTION_RULES: AxisRules = {
    "slots": ("replica", None),
    "row": (None,),
    "col": (None,),
}


def slot_sharding(mesh: Mesh, n_slots: int) -> NamedSharding:
    """NamedSharding splitting a (slots, H, W) grid's slot axis over the
    replica mesh (replicated fallback when slots don't divide it)."""
    return named_sharding(
        ("slots", "row", "col"), (n_slots, 1, 1), mesh, DETECTION_RULES,
    )


def shard_slots(batch, mesh: Mesh):
    """Place a host-side (slots, H, W) batch slot-sharded on ``mesh`` —
    the one explicit transfer of an SPMD detection dispatch (each device
    holds ``slots / n_replica`` frames; the frame-independent kernels
    then run without any cross-replica collective)."""
    import numpy as np
    arr = np.asarray(batch)
    return jax.device_put(arr, slot_sharding(mesh, arr.shape[0]))


def rules_for_shape(shape_kind: str) -> AxisRules:
    """Pick the rule table for a workload shape class.

    train_*   -> DEFAULT (FSDP+TP, batch DP)
    prefill_* -> SP (sequence-sharded activations between blocks)
    decode_* / long_* -> DECODE (cache-resident layout)
    """
    if shape_kind.startswith("prefill"):
        return SP_RULES
    if shape_kind.startswith(("decode", "long")):
        return DECODE_RULES
    return DEFAULT_RULES
