"""Detection service + plan layer: bucketing, slot reuse, resolve-once.

The continuous-batching ``DetectionService`` (``serve/detection.py``) and
the ``DetectionPlan`` substrate it runs on (``core/plan.py``): the
pad-to-bucket round trip must be bit-exact with the unbatched detector,
slots must recycle under mixed-resolution load, plan/config resolution must
be idempotent, and the pinned ``detect_stream`` hot loop must survive
``jax.transfer_guard("disallow")``.
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    HoughConfig, LineDetector, PipelineConfig, batch_bucket, max_edge_tiers,
    resolve_static,
)
from repro.core.plan import DetectionPlan, _detect
from repro.data import make_scenario, scenario_stream
from repro.serve.detection import (
    DetectionRequest, DetectionService, crop_result, pad_to_bucket,
)

pytestmark = pytest.mark.serve

VARIANTS = {
    "dense": HoughConfig(compact=False),
    "compact": HoughConfig(compact=True),
    "auto": HoughConfig(compact=True, max_edges="auto"),
}


def _cfg(variant: str) -> PipelineConfig:
    return PipelineConfig(hough=VARIANTS[variant])


# --- plan layer -------------------------------------------------------------


@pytest.mark.parametrize("variant", sorted(VARIANTS))
def test_plan_path_bit_exact_with_resolved_detector(variant):
    """Acceptance bar: the plan path equals the PR-2 construction — a
    detector pinned via ``resolve_config`` running the plain jitted body —
    bit-for-bit, on every execution variant."""
    det = LineDetector(_cfg(variant))
    for name in ("converging", "rain", "empty", "fog"):
        img = jnp.asarray(
            make_scenario(name, 120, 160, seed=0).image, jnp.float32
        )
        got = det.detect(img)
        ref = _detect(det.resolve_config(img), img)  # resolve-then-run
        np.testing.assert_array_equal(np.asarray(got.lines),
                                      np.asarray(ref.lines))
        np.testing.assert_array_equal(np.asarray(got.valid),
                                      np.asarray(ref.valid))
        np.testing.assert_array_equal(np.asarray(got.peaks),
                                      np.asarray(ref.peaks))
        np.testing.assert_array_equal(np.asarray(got.edges),
                                      np.asarray(ref.edges))


def test_plan_cache_reuses_by_shape_bucket():
    det = LineDetector(_cfg("auto"))
    p1 = det.plan_for(96, 128, batch=4)
    p2 = det.plan_for(96, 128, batch=4)
    assert p1 is p2
    assert det.plan_for(96, 128, batch=8) is not p1
    assert p1.tiers == max_edge_tiers(96, 128)
    # batch buckets: pow2 round-up keeps drifting sizes on few plans
    assert batch_bucket(3) == 4 and batch_bucket(5) == 8
    assert batch_bucket(1) == 1 and batch_bucket(8) == 8


def test_batch_pads_to_bucket_without_result_change():
    """detect_batch(N=3) pads to the 4-bucket; results match the per-frame
    loop exactly (pad frames are inert)."""
    det = LineDetector(_cfg("auto"))
    imgs = jnp.asarray(np.stack([
        make_scenario("straight", 96, 128, seed=s).image for s in range(3)
    ]), jnp.float32)
    rb = det.detect_batch(imgs)
    assert rb.lines.shape[0] == 3
    for i in range(3):
        r = det.detect(imgs[i])
        np.testing.assert_array_equal(np.asarray(rb.lines[i]),
                                      np.asarray(r.lines))
        np.testing.assert_array_equal(np.asarray(rb.valid[i]),
                                      np.asarray(r.valid))


def test_stream_hot_loop_under_transfer_guard():
    """The pinned stream performs zero per-chunk host round-trips: every
    post-warmup chunk dispatches inside transfer_guard("disallow") (the
    implementation guards itself; this exercises auto + uneven tail), and
    results still match the per-frame loop."""
    frames = [s.image for s in scenario_stream("mixed", 7, 96, 128, seed=4)]
    det = LineDetector(_cfg("auto"))
    ref_det = LineDetector(_cfg("auto"))
    got = list(det.detect_stream(iter(frames), batch_size=3))
    assert len(got) == 7
    for f, r in zip(frames, got):
        ref = ref_det.detect(jnp.asarray(f, jnp.float32))
        np.testing.assert_array_equal(np.asarray(r.lines),
                                      np.asarray(ref.lines))
        np.testing.assert_array_equal(np.asarray(r.peaks),
                                      np.asarray(ref.peaks))
    # one plan serves steady chunks AND the padded tail
    assert len(det._plans) == 1


# --- resolution idempotence -------------------------------------------------

# hypothesis-driven where available (the toolchain image may lack it — the
# same importorskip discipline as tests/test_properties.py, but scoped so
# the non-property service tests above always run); a deterministic sweep
# keeps the idempotence contract covered either way.

_RESOLVE_CASES = [
    (PipelineConfig(hough=HoughConfig(compact=c, max_edges=me,
                                      n_theta=nt)), h, w)
    for c in (False, True)
    for me in (None, "auto", 512, 2048)
    for nt, h, w in [(180, 96, 128), (90, 120, 160)]
]


def _assert_resolve_fixed_point(cfg, h, w):
    once, tiers_once = resolve_static(cfg, h, w)
    twice, tiers_twice = resolve_static(once, h, w)
    assert twice == once and tiers_twice == tiers_once
    if tiers_once is None:
        assert once.hough.max_edges != "auto"
    # plans built from raw vs resolved configs are identical
    p1 = DetectionPlan.build(cfg, h, w, batch=2)
    p2 = DetectionPlan.build(p1.cfg, h, w, batch=2)
    assert p1 == p2


@pytest.mark.parametrize("cfg,h,w", _RESOLVE_CASES)
def test_resolve_static_is_idempotent(cfg, h, w):
    """Plan resolution is a projection: resolving an already-resolved
    config changes nothing (same config, same tiers)."""
    _assert_resolve_fixed_point(cfg, h, w)


def test_resolve_static_is_idempotent_hypothesis():
    """Property form over a wider knob/shape space (skips w/o hypothesis)."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @st.composite
    def pipeline_configs(draw):
        return PipelineConfig(hough=HoughConfig(
            compact=draw(st.booleans()),
            max_edges=draw(st.sampled_from([None, "auto", 512, 2048])),
            n_theta=draw(st.sampled_from([90, 180])),
        ))

    @settings(max_examples=25, deadline=None)
    @given(pipeline_configs(), st.integers(48, 160), st.integers(48, 160))
    def prop(cfg, h, w):
        _assert_resolve_fixed_point(cfg, h, w)

    prop()


@pytest.mark.parametrize("name,seed",
                         [("converging", 0), ("rain", 2), ("empty", 4)])
def test_resolve_config_is_idempotent(name, seed):
    """The legacy host-side resolver is equally a projection."""
    det = LineDetector(_cfg("auto"))
    img = jnp.asarray(make_scenario(name, 96, 128, seed=seed).image,
                      jnp.float32)
    once = det.resolve_config(img)
    assert once.hough.max_edges != "auto"
    assert LineDetector(once).resolve_config(img) == once


# --- service: bucketing round trip ------------------------------------------


def test_pad_to_bucket_diffuses_top_left_anchored():
    img = np.arange(12, dtype=np.float32).reshape(3, 4) * 20.0
    out = pad_to_bucket(img, (40, 44))
    fill = np.float32(img.mean())
    assert out.shape == (40, 44)
    np.testing.assert_array_equal(out[:3, :4], img)       # anchored content
    # no step at the content border: the first pad line stays close to the
    # border line (continuation, not a jump to the fill level)
    assert np.abs(out[3, :4] - img[2]).max() < np.abs(img[2] - fill).max()
    # monotone fade: pad converges to the frame mean by the taper horizon
    np.testing.assert_allclose(out[3 + 32:, :], fill, atol=1e-4)
    np.testing.assert_allclose(out[:, 4 + 32:], fill, atol=1e-4)


def test_pad_region_casts_no_votes():
    """The whole point of the diffusing pad: a bright stroke running into
    the frame border must not extrude into an edge-forming bar — the pad
    region contributes (near) zero Canny edge pixels at any pad size."""
    from repro.core import CannyConfig, canny
    rng = np.random.default_rng(0)
    for (h, w), (bh, bw) in [((100, 150), (120, 160)),
                             ((180, 240), (240, 320))]:
        img = np.full((h, w), 90.0, np.float32)
        img += rng.normal(0, 4.0, img.shape).astype(np.float32)
        img[:, w // 2 - 1: w // 2 + 1] = 235.0   # stroke into the border
        img[h // 2 - 1: h // 2 + 1, :] = 235.0
        padded = pad_to_bucket(img, (bh, bw))
        edges = np.asarray(
            canny(jnp.asarray(padded), CannyConfig())) >= 250
        pad_edges = edges.sum() - edges[:h, :w].sum()
        assert pad_edges <= 16, (h, w, bh, bw, int(pad_edges))


@pytest.mark.parametrize("variant", ("compact", "auto"))
def test_service_round_trip_bit_exact_vs_unbatched(variant):
    """pad -> service detect -> unpad equals the unbatched detector run on
    the same padded frame, bit for bit, with raster fields cropped back to
    the request's native resolution."""
    svc = DetectionService(_cfg(variant),
                           buckets=((96, 128), (120, 160)), batch_size=3)
    shapes = [(96, 128), (120, 160), (80, 100), (100, 144), (96, 128)]
    frames = [make_scenario("converging", h, w, seed=i).image
              for i, (h, w) in enumerate(shapes)]
    reqs = svc.detect_many(frames)
    det = LineDetector(_cfg(variant))
    for r in reqs:
        padded = pad_to_bucket(r.frame, r.bucket)
        ref = crop_result(det.detect(jnp.asarray(padded)),
                          *r.frame.shape[:2])
        np.testing.assert_array_equal(np.asarray(r.result.lines),
                                      np.asarray(ref.lines))
        np.testing.assert_array_equal(np.asarray(r.result.valid),
                                      np.asarray(ref.valid))
        np.testing.assert_array_equal(np.asarray(r.result.peaks),
                                      np.asarray(ref.peaks))
        np.testing.assert_array_equal(np.asarray(r.result.edges),
                                      np.asarray(ref.edges))
        assert r.result.edges.shape == r.frame.shape[:2]


def test_service_detection_quality_survives_bucketing():
    """A padded off-bucket frame still recovers its planted lines with no
    spurious detections (the tapered pad neither steps at the content
    border nor extrudes border strokes into vote-worthy bars)."""
    from repro.core import score_frame
    svc = DetectionService(_cfg("auto"), batch_size=2)
    for seed in range(3):
        sc = make_scenario("converging", 100, 150, seed=seed)
        (req,) = svc.detect_many([sc.image])
        s = score_frame(req.result.peaks, req.result.valid,
                        sc.lines_rho_theta)
        assert s.fn == 0 and s.fp == 0, (seed, s)


def test_service_large_pad_adds_no_false_positives():
    """The worst padding regime — 60/80 px of pad below/right of lanes
    that run into the frame border — must not manufacture detections
    (plain edge replication produced 12 fp per frame here; the taper is
    the regression guard)."""
    from repro.core import score_frame
    svc = DetectionService(_cfg("auto"), batch_size=2)
    for seed in range(2):
        sc = make_scenario("converging", 180, 240, seed=seed)
        (req,) = svc.detect_many([sc.image])
        assert req.bucket == (240, 320)
        s = score_frame(req.result.peaks, req.result.valid,
                        sc.lines_rho_theta)
        assert s.fp == 0, (seed, s)


# --- service: slots, ordering, continuous batching ---------------------------


def test_service_slot_reuse_under_mixed_queue():
    """More requests than slots, two buckets interleaved: every request
    completes via slot recycling (dispatch count proves reuse), results
    return in submit order, and full grids dominate dispatches."""
    svc = DetectionService(_cfg("auto"),
                           buckets=((96, 128), (120, 160)), batch_size=2)
    shapes = [(96, 128), (120, 160)] * 5                  # 10 reqs, 2 slots
    frames = [make_scenario("straight", h, w, seed=i).image
              for i, (h, w) in enumerate(shapes)]
    reqs = svc.detect_many(frames)
    assert [r.uid for r in reqs] == list(range(10))
    assert all(r.done and r.result is not None for r in reqs)
    assert svc.completed == 10
    # 10 requests over 2-slot grids => at least 5 dispatches, each grid
    # reused across waves
    assert svc.dispatches >= 5
    for r, (h, w) in zip(reqs, shapes):
        assert r.result.edges.shape == (h, w)
        assert r.latency_s >= 0.0


def test_service_rejects_oversized_frame():
    svc = DetectionService(_cfg("compact"), buckets=((96, 128),),
                           batch_size=2)
    with pytest.raises(ValueError, match="exceeds every bucket"):
        svc.submit(DetectionRequest(uid=0,
                                    frame=np.zeros((200, 300), np.float32)))


def test_service_partial_grid_flushes_on_drain():
    """A lone request (grid never fills) still completes via run()'s
    flush — waiting-for-full never deadlocks a drain."""
    svc = DetectionService(_cfg("compact"), batch_size=4)
    (req,) = svc.detect_many([make_scenario("straight", 120, 160).image])
    assert req.done and int(np.asarray(req.result.valid).sum()) > 0
