"""Behaviour tests for the paper's system: Canny -> Hough -> lines.

These tests assert the paper's *claims*, not just shapes:
  * §4.4 float->int rewrite loses no detection accuracy (int == float peaks),
  * the GEMM-form Hough equals the paper's Algorithm 2 loop,
  * the full pipeline recovers planted lines with known (rho, theta),
  * eliding output-image generation (paper Table 1/2) changes no detection.
"""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    CannyConfig, HoughConfig, LineDetector, LinesConfig, PipelineConfig,
    canny, get_lines, hough_paper_loop, hough_transform, plan_line_detection,
    quantize, dequantize, quantized_matmul,
)
from repro.core.lines import render_lines
from repro.data.images import synthetic_road


def detected_set(res, tol_rho=4.0, tol_theta_deg=3.0):
    out = []
    for (r, t), ok in zip(np.asarray(res.peaks), np.asarray(res.valid)):
        if ok:
            out.append((float(r), math.degrees(float(t))))
    return out


def recovers(planted, got, tol_rho=5.0, tol_theta=3.0):
    for rho, theta in planted:
        deg = math.degrees(theta)
        if not any(
            abs(r - rho) <= tol_rho and abs(t - deg) <= tol_theta
            for r, t in got
        ):
            return False
    return True


@pytest.fixture(scope="module")
def scene():
    return synthetic_road(120, 160, seed=3)


def test_detects_planted_lines(scene):
    det = LineDetector(PipelineConfig())
    res = det.detect(jnp.asarray(scene.image, jnp.float32))
    got = detected_set(res)
    assert recovers(scene.lines_rho_theta, got), (scene.lines_rho_theta, got)


def test_int_rewrite_detection_parity(scene):
    """Paper §4.4: integer pipeline, no accuracy loss."""
    det_f = LineDetector(PipelineConfig())
    det_i = LineDetector(PipelineConfig(canny=CannyConfig(integer=True)))
    img = jnp.asarray(scene.image, jnp.float32)
    got_f = detected_set(det_f.detect(img))
    got_i = detected_set(det_i.detect(jnp.asarray(scene.image)))
    assert recovers(scene.lines_rho_theta, got_i)
    # same peaks within a bin
    assert len(got_f) == len(got_i)
    for (rf, tf), (ri, ti) in zip(sorted(got_f), sorted(got_i)):
        assert abs(rf - ri) <= 2.0 and abs(tf - ti) <= 2.0


def test_fused_masks_detection_parity(scene):
    """Beyond-paper single-pass 7x7 fusion detects the same lines."""
    det = LineDetector(PipelineConfig(canny=CannyConfig(fused=True)))
    got = detected_set(det.detect(jnp.asarray(scene.image, jnp.float32)))
    assert recovers(scene.lines_rho_theta, got)


def test_paper_variant_runs(scene):
    det = LineDetector(PipelineConfig(canny=CannyConfig(variant="paper")))
    res = det.detect(jnp.asarray(scene.image, jnp.float32))
    assert res.edges.shape == scene.image.shape


def test_hough_gemm_equals_paper_loop(scene):
    edges = canny(jnp.asarray(scene.image, jnp.float32), CannyConfig())
    cfg = HoughConfig(n_theta=90)
    fast = hough_transform(edges, cfg)
    slow = hough_paper_loop(edges, cfg)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow), atol=1e-3)


def test_vote_conservation(scene):
    """Every edge pixel casts exactly n_theta votes (minus out-of-range)."""
    edges = canny(jnp.asarray(scene.image, jnp.float32), CannyConfig())
    cfg = HoughConfig()
    votes = hough_transform(edges, cfg)
    n_edge = int(np.asarray(edges >= cfg.edge_threshold).sum())
    total = float(np.asarray(votes).sum())
    assert abs(total - n_edge * cfg.n_theta) <= n_edge  # floor() edge bins


def test_render_and_elide(scene):
    det_off = LineDetector(PipelineConfig(render_output=False))
    det_on = LineDetector(PipelineConfig(render_output=True))
    img = jnp.asarray(scene.image, jnp.float32)
    r_off = det_off.detect(img)
    r_on = det_on.detect(img)
    assert r_off.rendered is None
    assert r_on.rendered is not None and r_on.rendered.shape == (
        *scene.image.shape, 3)
    np.testing.assert_array_equal(np.asarray(r_on.lines),
                                  np.asarray(r_off.lines))
    # rendered image marks some pixels red
    red = np.asarray(r_on.rendered)
    assert ((red[..., 0] == 255) & (red[..., 1] == 0)).sum() > 0


def test_detect_batch_matches_loop_bit_exact():
    """The batched fast path is the same program per frame: every field of
    detect_batch((N, H, W)) equals the per-frame detect loop bit-for-bit."""
    frames = np.stack(
        [synthetic_road(96, 128, seed=s).image for s in (1, 2, 3)]
    )
    det = LineDetector(PipelineConfig(render_output=True))
    imgs = jnp.asarray(frames, jnp.float32)
    rb = det.detect_batch(imgs)
    for i in range(frames.shape[0]):
        r = det.detect(imgs[i])
        np.testing.assert_array_equal(np.asarray(rb.lines[i]),
                                      np.asarray(r.lines))
        np.testing.assert_array_equal(np.asarray(rb.valid[i]),
                                      np.asarray(r.valid))
        np.testing.assert_array_equal(np.asarray(rb.peaks[i]),
                                      np.asarray(r.peaks))
        np.testing.assert_array_equal(np.asarray(rb.edges[i]),
                                      np.asarray(r.edges))
        np.testing.assert_array_equal(np.asarray(rb.rendered[i]),
                                      np.asarray(r.rendered))


def test_detect_stream_matches_batch():
    """Double-buffered streaming yields the same per-frame results, in
    order, across batch boundaries and a short final batch."""
    frames = [synthetic_road(96, 128, seed=s).image for s in range(5)]
    det = LineDetector(PipelineConfig())
    rb = det.detect_batch(jnp.asarray(np.stack(frames), jnp.float32))
    got = list(det.detect_stream(iter(frames), batch_size=2))
    assert len(got) == 5
    for i, r in enumerate(got):
        np.testing.assert_array_equal(np.asarray(r.lines),
                                      np.asarray(rb.lines[i]))
        np.testing.assert_array_equal(np.asarray(r.valid),
                                      np.asarray(rb.valid[i]))


@pytest.mark.parametrize("max_edges", [None, "auto"])
def test_detect_stream_uneven_tail_matches_frame_loop(max_edges):
    """Batch-tail correctness: a batch size that does not divide the frame
    count (7 frames, batch 3 -> chunks 3/3/1) is bit-exact with the
    per-frame detect loop on every result field, including with the
    autotuned compaction buffer resolved per chunk."""
    frames = [synthetic_road(96, 128, seed=s).image for s in range(7)]
    det = LineDetector(PipelineConfig(
        hough=HoughConfig(compact=True, max_edges=max_edges)
    ))
    got = list(det.detect_stream(iter(frames), batch_size=3))
    assert len(got) == 7
    for f, r in zip(frames, got):
        ref = det.detect(jnp.asarray(f, jnp.float32))
        np.testing.assert_array_equal(np.asarray(r.lines),
                                      np.asarray(ref.lines))
        np.testing.assert_array_equal(np.asarray(r.valid),
                                      np.asarray(ref.valid))
        np.testing.assert_array_equal(np.asarray(r.peaks),
                                      np.asarray(ref.peaks))
        np.testing.assert_array_equal(np.asarray(r.edges),
                                      np.asarray(ref.edges))


def test_compact_hough_pipeline_bit_exact(scene):
    """Edge compaction changes the iteration space, not the votes: the
    compacted pipeline's accumulator and detections match the dense path
    exactly (vote counts are small integers in f32)."""
    img = jnp.asarray(scene.image, jnp.float32)
    edges = canny(img, CannyConfig())
    v_dense = hough_transform(edges, HoughConfig())
    v_comp = hough_transform(edges, HoughConfig(compact=True))
    np.testing.assert_array_equal(np.asarray(v_dense), np.asarray(v_comp))

    det_d = LineDetector(PipelineConfig())
    det_c = LineDetector(PipelineConfig(hough=HoughConfig(compact=True)))
    rd, rc = det_d.detect(img), det_c.detect(img)
    np.testing.assert_array_equal(np.asarray(rd.lines), np.asarray(rc.lines))
    np.testing.assert_array_equal(np.asarray(rd.valid), np.asarray(rc.valid))


def test_batched_canny_and_hough_shapes(scene):
    """(N, H, W) flows through canny/hough/get_lines with leading axes."""
    imgs = jnp.asarray(
        np.stack([scene.image, np.flipud(scene.image)]), jnp.float32
    )
    edges = canny(imgs, CannyConfig())
    assert edges.shape == imgs.shape and edges.dtype == jnp.uint8
    votes = hough_transform(edges, HoughConfig())
    assert votes.ndim == 3 and votes.shape[0] == 2
    lines, valid, peaks = get_lines(
        votes, height=imgs.shape[1], width=imgs.shape[2],
        cfg=LinesConfig(max_lines=8),
    )
    assert lines.shape == (2, 8, 4)
    assert valid.shape == (2, 8)
    assert peaks.shape == (2, 8, 2)


def test_get_lines_static_shapes():
    votes = jnp.zeros((100, 180))
    votes = votes.at[30, 45].set(99.0)
    lines, valid, peaks = get_lines(votes, height=64, width=64,
                                    cfg=LinesConfig(max_lines=8))
    assert lines.shape == (8, 4) and valid.shape == (8,)
    assert int(valid.sum()) == 1


def test_quantize_roundtrip(rng):
    x = rng.normal(size=(64, 64)).astype(np.float32)
    q = quantize(jnp.asarray(x))
    err = np.abs(np.asarray(dequantize(q)) - x).max()
    assert err <= float(np.abs(x).max()) / 127.0 + 1e-6


def test_quantized_matmul_error_bound(rng):
    x = rng.normal(size=(32, 48)).astype(np.float32)
    y = rng.normal(size=(48, 16)).astype(np.float32)
    got = np.asarray(quantized_matmul(jnp.asarray(x), jnp.asarray(y)))
    want = x @ y
    rel = np.abs(got - want).max() / np.abs(want).max()
    assert rel < 0.05, rel


def test_offload_plan_matches_paper_partition():
    """GEMM stages -> MXU; elementwise/control stages -> VPU (paper's split)."""
    plan = plan_line_detection(720, 1280)
    units = {p.stage: p.unit for p in plan}
    assert units["canny_conv_gemm"] == "mxu"
    assert units["hough_rho_gemm"] == "mxu"
    assert units["canny_elementwise"] == "vpu"
    assert units["hough_votes"] == "vpu"
    assert units["get_coordinates"] == "vpu"
