"""Per-architecture smoke + consistency tests (reduced configs, CPU)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ShapeSpec, get, get_smoke, shapes_for
from repro.models import build
from repro.models.model_zoo import materialize_inputs

SMOKE_SHAPE = ShapeSpec("smoke", 24, 2, "train")


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    """One forward + one train step on a reduced config: shapes + no NaNs."""
    cfg = get_smoke(arch)
    m = build(cfg)
    rng = jax.random.PRNGKey(0)
    params = m.init(rng)
    batch = materialize_inputs(rng, cfg, SMOKE_SHAPE)

    logits = m.forward(params, batch)
    assert logits.shape == (2, 24, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))

    from repro.train import AdamWConfig, make_train_step
    from repro.train.state import init_train_state
    step = make_train_step(m, AdamWConfig(warmup_steps=1, decay_steps=10))
    state = init_train_state(params)
    state, metrics = jax.jit(step)(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert int(state.step) == 1


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    """prefill(n-1) + decode steps reproduce teacher-forced logits."""
    cfg = get_smoke(arch)
    if cfg.moe is not None:  # avoid capacity-drop divergence between paths
        cfg = cfg.replace(
            moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    m = build(cfg)
    rng = jax.random.PRNGKey(1)
    params = m.init(rng)
    B, S = 2, 12
    batch = materialize_inputs(rng, cfg, ShapeSpec("t", S, B, "train"))
    tokens = batch["tokens"]
    full = m.forward(params, batch)

    cache = m.init_cache(B, 16)
    pre = {k: v for k, v in batch.items() if k != "targets"}
    pre["tokens"] = tokens[:, :8]
    lg, cache = m.prefill(params, pre, cache)
    errs = [float(jnp.max(jnp.abs(lg - full[:, 7])))]
    for t in range(8, S):
        lg, cache = m.decode_step(
            params, tokens[:, t], cache, jnp.full((B,), t, jnp.int32))
        errs.append(float(jnp.max(jnp.abs(lg - full[:, t]))))
    # decode runs in bf16 with f32 accumulation; forward accumulates in f32
    assert max(errs) < 5e-2, errs


def test_swa_ring_cache_matches_linear():
    """h2o SWA: ring cache (window slots) decode == linear cache decode."""
    cfg = get_smoke("h2o-danube-1.8b")   # window=16
    m = build(cfg)
    rng = jax.random.PRNGKey(2)
    params = m.init(rng)
    B, S = 2, 40                          # run past the window
    tokens = jax.random.randint(rng, (B, S), 0, cfg.vocab, dtype=jnp.int32)

    lin = m.init_cache(B, S + 1)
    ring = m.init_cache(B, S + 1, ring=True)
    assert ring["blocks"]["0_attn"]["k"].shape[-2] == cfg.window

    for t in range(S):
        pos = jnp.full((B,), t, jnp.int32)
        l1, lin = m.decode_step(params, tokens[:, t], lin, pos)
        l2, ring = m.decode_step(params, tokens[:, t], ring, pos, ring=True)
        err = float(jnp.max(jnp.abs(l1 - l2)))
        assert err < 5e-2, (t, err)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_abstract_params(arch):
    """Full-size configs build abstract params (no allocation) with sane
    counts; exercised for real via the dry-run."""
    cfg = get(arch)
    m = build(cfg)
    abs_p = m.abstract_params()
    n = m.param_count()
    assert n > 1e9, n
    leaves = jax.tree.leaves(abs_p)
    assert all(hasattr(l, "shape") for l in leaves)
    # axes tree matches param tree structure
    axes = m.param_axes()
    flat_a = jax.tree.leaves(
        axes, is_leaf=lambda x: isinstance(x, tuple) and not hasattr(
            x, "_fields"))
    assert len(flat_a) == len(leaves)


def test_shapes_for_policy():
    """long_500k only for sub-quadratic archs (DESIGN.md skip table)."""
    longs = {a for a in ARCHS if "long_500k" in shapes_for(get(a))}
    assert longs == {"h2o-danube-1.8b", "zamba2-1.2b", "falcon-mamba-7b"}


def test_moe_sort_matches_onehot():
    """The production sort-based dispatch == GShard one-hot semantics."""
    from repro.models import moe as moe_lib
    from repro.models.layers import materialize
    cfg = get_smoke("moonshot-v1-16b-a3b")
    spec = moe_lib.moe_spec(cfg)
    params = materialize(jax.random.PRNGKey(0), spec)
    x = (0.1 * jax.random.normal(
        jax.random.PRNGKey(1), (2, 16, cfg.d_model))).astype(jnp.float32)
    y1, a1 = moe_lib.moe_sort(params, x, cfg)
    y2, a2 = moe_lib.moe_onehot(params, x, cfg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)
