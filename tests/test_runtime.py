"""Fault tolerance: restarts resume bitwise-identically; heartbeats detect."""

import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import ShapeSpec, get_smoke
from repro.data import TokenPipelineConfig, TokenStream
from repro.models import build
from repro.runtime import (
    FaultInjector, Heartbeat, HeartbeatMonitor, WorkerFailure,
    run_with_restarts,
)
from repro.train import AdamWConfig, make_train_step
from repro.train.state import init_train_state


def _setup():
    cfg = get_smoke("yi-9b")
    m = build(cfg)
    state = init_train_state(m.init(jax.random.PRNGKey(0)))
    step_fn = jax.jit(make_train_step(
        m, AdamWConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=50)))
    stream = TokenStream(TokenPipelineConfig(
        vocab=cfg.vocab, seq_len=16, global_batch=4, seed=3))
    return m, state, step_fn, stream


def test_restart_resumes_bitwise_identically(tmp_path):
    m, state0, step_fn, stream = _setup()

    def drive(state, step):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_at(step).items()}
        state, _ = step_fn(state, batch)
        return state

    # uninterrupted run
    ref_state = state0
    for s in range(12):
        ref_state = drive(ref_state, s)

    # faulty run: dies at steps 4 and 9, restarts from checkpoints
    inj = FaultInjector(fail_at_steps=(4, 9))

    def faulty(state, step):
        inj.check(step)
        return drive(state, step)

    mgr = CheckpointManager(str(tmp_path), keep=3)
    final, stats = run_with_restarts(
        init_state=state0, step_fn=faulty, n_steps=12, ckpt=mgr,
        ckpt_every=3, state_template=state0,
    )
    assert stats["restarts"] == 2
    assert stats["completed_steps"] == 12
    for a, b in zip(jax.tree.leaves(final.params),
                    jax.tree.leaves(ref_state.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restart_budget_exceeded(tmp_path):
    m, state0, step_fn, stream = _setup()

    def always_fail(state, step):
        raise WorkerFailure("node gone")

    mgr = CheckpointManager(str(tmp_path / "b"), keep=2)
    with pytest.raises(WorkerFailure):
        run_with_restarts(
            init_state=state0, step_fn=always_fail, n_steps=5, ckpt=mgr,
            max_restarts=2, state_template=state0,
        )


def test_heartbeat_monitor_detects_hang():
    registry = {}
    hb_good = Heartbeat("w0", registry, interval_s=0.02, auto=True)
    hb_bad = Heartbeat("w1", registry, auto=False)   # beats once, then hangs
    mon = HeartbeatMonitor(registry, timeout_s=0.15)
    try:
        assert mon.all_alive()
        time.sleep(0.3)
        dead = mon.dead_workers()
        assert dead == ["w1"]
    finally:
        hb_good.stop()


def test_fault_injector_fires_once():
    inj = FaultInjector(fail_at_steps=(3,))
    inj.check(2)
    with pytest.raises(WorkerFailure):
        inj.check(3)
    inj.check(3)   # second pass (post-restart) does not re-fire
