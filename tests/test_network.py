"""Seeded network model: determinism, loss, legs, race arithmetic.

Pure-policy tests for :mod:`repro.core.network` and the
``downlink_s``/``timed_out`` extensions of
:func:`repro.core.offload.decide_race` — no service, no devices: the
model is a deterministic function of (config seed, send sequence), so
everything here asserts exact values, not distributions.
"""

import math

import pytest

from repro.core.network import (
    Delivery, NetworkConfig, NetworkModel, expected_rtt_s, force_lost,
)
from repro.core.offload import decide_race

pytestmark = pytest.mark.mesh

JITTERY = NetworkConfig(seed=7, rtt_median_s=0.03, uplink_fraction=0.5,
                        jitter_sigma=0.6, loss=0.05)


def _stream(cfg: NetworkConfig, n: int = 32) -> list[Delivery]:
    m = NetworkModel(cfg)
    out = []
    for _ in range(n):
        out.append(m.uplink())
        out.append(m.downlink())
    return out


# --- determinism ---------------------------------------------------------

def test_same_seed_same_delivery_stream():
    assert _stream(JITTERY) == _stream(JITTERY)


def test_different_seed_different_stream():
    other = NetworkConfig(seed=8, rtt_median_s=0.03, uplink_fraction=0.5,
                          jitter_sigma=0.6, loss=0.05)
    a, b = _stream(JITTERY), _stream(other)
    assert [d.delay_s for d in a] != [d.delay_s for d in b]


def test_message_ids_are_sequential():
    assert [d.msg_id for d in _stream(JITTERY, n=4)] == list(range(8))


# --- the compat limit: sigma=0 is the fixed-delay model, bit-exact -------

def test_zero_jitter_is_exactly_the_median():
    m = NetworkModel(NetworkConfig(rtt_median_s=0.03, uplink_fraction=0.5))
    for _ in range(8):
        assert m.uplink().delay_s == 0.015
        assert m.downlink().delay_s == 0.015


def test_uplink_compat_mode_charges_everything_on_the_response():
    # uplink_fraction=0: a free uplink, the whole RTT on the downlink —
    # PR 7's arithmetic, reproduced exactly (the mesh-suite compat gate)
    m = NetworkModel(NetworkConfig(rtt_median_s=0.03, uplink_fraction=0.0))
    assert m.uplink().delay_s == 0.0
    assert m.downlink().delay_s == 0.03


# --- loss ----------------------------------------------------------------

def test_loss_zero_never_loses():
    cfg = NetworkConfig(seed=3, jitter_sigma=0.6, loss=0.0)
    assert not any(d.lost for d in _stream(cfg, n=128))


def test_loss_rate_tracks_config():
    cfg = NetworkConfig(seed=3, jitter_sigma=0.6, loss=0.3)
    m = NetworkModel(cfg)
    for _ in range(500):
        m.uplink()
        m.downlink()
    assert m.sent == 1000
    assert m.lost / m.sent == pytest.approx(0.3, abs=0.05)


def test_lost_message_never_arrives():
    d = Delivery("uplink", 0, 0.01, lost=True)
    assert math.isinf(d.arrives_at(5.0))
    ok = Delivery("uplink", 0, 0.01, lost=False)
    assert ok.arrives_at(5.0) == 5.01


def test_force_lost_keeps_the_sampled_delay():
    d = NetworkModel(JITTERY).uplink()
    f = force_lost(d)
    assert f.lost and f.delay_s == d.delay_s and f.msg_id == d.msg_id


# --- leg split + diagnostics ---------------------------------------------

def test_uplink_fraction_splits_the_median():
    cfg = NetworkConfig(rtt_median_s=0.04, uplink_fraction=0.25)
    assert cfg.uplink_median_s == 0.01
    assert cfg.downlink_median_s == pytest.approx(0.03)


def test_expected_rtt_grows_with_jitter():
    flat = NetworkConfig(rtt_median_s=0.03, jitter_sigma=0.0)
    jittery = NetworkConfig(rtt_median_s=0.03, jitter_sigma=0.8)
    assert expected_rtt_s(flat) == 0.03
    # lognormal mean = median * exp(sigma^2/2) > median
    assert expected_rtt_s(jittery) > 0.03


# --- decide_race: downlink override + timeout stamp ----------------------

def test_decide_race_rtt_path_unchanged():
    d = decide_race(0.02, 0.07, 0.10, rtt_s=0.01)
    assert d.upgraded and d.remote_ready_at == 0.08 and not d.timed_out


def test_decide_race_downlink_overrides_rtt():
    # sampled downlink (0.04) blows the deadline even though rtt_s says fine
    d = decide_race(0.02, 0.07, 0.10, rtt_s=0.01, downlink_s=0.04)
    assert not d.upgraded and d.remote_ready_at == pytest.approx(0.11)


def test_decide_race_lost_downlink_never_upgrades():
    d = decide_race(0.02, 0.07, 0.10, rtt_s=0.01, downlink_s=math.inf)
    assert not d.upgraded and math.isinf(d.remote_ready_at)
    # even with no deadline: an undelivered answer is not an answer
    d2 = decide_race(0.02, 0.07, None, rtt_s=0.01, downlink_s=math.inf)
    assert not d2.upgraded


def test_decide_race_timed_out_is_a_passthrough_stamp():
    d = decide_race(0.02, None, 0.10, rtt_s=0.01, timed_out=True)
    assert d.timed_out and not d.upgraded and d.winner == "local"
    assert d.local_met_deadline
