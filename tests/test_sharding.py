"""Sharding rules: fallback chains for the adversarial arch geometries."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.sharding import (
    DECODE_RULES, DEFAULT_RULES, SP_RULES, logical_to_spec, rules_for_shape,
)


def _abstract_mesh(shape, names):
    # shape-only stand-in mesh: rules only read axis names and sizes.
    # Newer jax takes (shape, names); jax<=0.4.x takes ((name, size), ...).
    from jax.sharding import AbstractMesh
    try:
        return AbstractMesh(shape, names)
    except TypeError:
        return AbstractMesh(tuple(zip(names, shape)))


@pytest.fixture(scope="module")
def mesh():
    return _abstract_mesh((16, 16), ("data", "model"))


@pytest.fixture(scope="module")
def pod_mesh():
    return _abstract_mesh((2, 16, 16), ("pod", "data", "model"))


def spec(axes, shape, mesh, rules=DEFAULT_RULES):
    return logical_to_spec(axes, shape, mesh, rules)


def test_mlp_weight_2d_sharded(mesh):
    # yi-9b w_ff: FSDP on embed (data), TP on mlp (model)
    assert spec(("embed", "mlp"), (4096, 11008), mesh) == P("data", "model")


def test_vocab_fallback_whisper(mesh):
    # whisper vocab 51866 does not divide 16 -> replicate vocab, FSDP embed
    assert spec(("vocab", "embed"), (51866, 1280), mesh) == P(None, "data")
    # llama4 vocab divides -> vocab on model
    assert spec(("vocab", "embed"), (202048, 5120), mesh) == P("model", "data")


def test_mqa_kv_fallback_granite(mesh):
    # granite kv=1: kv_heads can't shard; head_dim 128 picks up model
    assert spec(
        ("embed", "kv_heads", "head_dim"), (6144, 1, 128), mesh
    ) == P("data", None, "model")


def test_qwen_heads_fallback(mesh):
    # 40 heads don't divide 16 -> head_dim carries TP
    assert spec(
        ("embed", "heads", "head_dim"), (5120, 40, 128), mesh
    ) == P("data", None, "model")
    # 32 heads divide -> heads carry TP, head_dim replicated
    assert spec(
        ("embed", "heads", "head_dim"), (4096, 32, 128), mesh
    ) == P("data", "model")


def test_batch_composite_pod_axis(pod_mesh):
    assert spec(("batch", "seq"), (256, 4096), pod_mesh) == P(("pod", "data"))
    # batch=1 (long_500k) can't shard -> replicated
    assert spec(("batch", "seq"), (1, 524288), pod_mesh,
                DECODE_RULES) == P()


def test_decode_cache_seq_fallback(mesh):
    # h2o kv=8 on 16-way model: cache timeline carries TP (split-KV)
    assert spec(
        ("batch", "kv_heads", "cache_seq", "head_dim"),
        (128, 8, 32768, 80), mesh, DECODE_RULES,
    ) == P("data", None, "model")
    # zamba shared kv=32: heads carry TP, timeline replicated
    assert spec(
        ("batch", "kv_heads", "cache_seq", "head_dim"),
        (1, 32, 524288, 64), mesh, DECODE_RULES,
    ) == P(None, "model")


def test_experts_on_model(mesh):
    assert spec(
        ("experts", "embed", "mlp"), (64, 2048, 1408), mesh
    ) == P("model", "data")


def test_no_axis_used_twice(mesh):
    # embed takes data; a second embed-like dim must not also take data
    s = spec(("embed", "embed"), (1280, 4096), mesh)
    used = [a for a in s if a is not None]
    assert len(set(used)) == len(used)


def test_sp_rules_shard_seq(mesh):
    assert spec(("batch", "seq", "embed_act"), (32, 32768, 4096), mesh,
                SP_RULES) == P("data", "model")
    # whisper frames 1500 don't divide -> replicate
    assert spec(("batch", "frames", None), (32, 1500, 1280), mesh,
                SP_RULES) == P("data")


def test_rules_for_shape():
    assert rules_for_shape("train_4k") is DEFAULT_RULES
    assert rules_for_shape("prefill_32k") is SP_RULES
    assert rules_for_shape("decode_32k") is DECODE_RULES
    assert rules_for_shape("long_500k") is DECODE_RULES
