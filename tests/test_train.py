"""Training substrate: optimizer math, microbatching, compression, learning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ShapeSpec, get_smoke
from repro.models import build
from repro.models.model_zoo import materialize_inputs
from repro.train import (
    AdamWConfig, adamw_init, adamw_update, lr_at, make_train_step,
    compress_decompress, compressed_allreduce,
)
from repro.train.state import init_train_state


def test_lr_schedule():
    cfg = AdamWConfig(peak_lr=1.0, warmup_steps=10, decay_steps=110,
                      floor_ratio=0.1)
    assert float(lr_at(jnp.int32(0), cfg)) == 0.0
    assert abs(float(lr_at(jnp.int32(10), cfg)) - 1.0) < 1e-6
    assert abs(float(lr_at(jnp.int32(110), cfg)) - 0.1) < 1e-6
    assert float(lr_at(jnp.int32(5), cfg)) == pytest.approx(0.5)


def test_adamw_matches_reference(rng):
    """One AdamW step against a hand-rolled numpy reference."""
    p = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
    g = {"w": jnp.asarray(rng.normal(size=(4, 4)), jnp.float32)}
    cfg = AdamWConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10,
                      clip_norm=1e9, weight_decay=0.1, floor_ratio=1.0)
    opt = adamw_init(p)
    new_p, new_opt, metrics = adamw_update(g, opt, p, jnp.int32(0), cfg)

    gw = np.asarray(g["w"])
    m = 0.1 * gw
    v = 0.05 * gw * gw
    mh = m / (1 - 0.9)
    vh = v / (1 - 0.95)
    want = np.asarray(p["w"]) - 1e-2 * (
        mh / (np.sqrt(vh) + 1e-8) + 0.1 * np.asarray(p["w"]))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)


def test_grad_clip():
    p = {"w": jnp.ones((2,), jnp.float32)}
    g = {"w": jnp.full((2,), 100.0)}
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, decay_steps=10)
    _, _, metrics = adamw_update(g, adamw_init(p), p, jnp.int32(0), cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(
        np.sqrt(2 * 100.0 ** 2), rel=1e-5)


def test_microbatching_equivalent():
    """n_micro=2 equals n_micro=1 up to float assoc (same data)."""
    cfg = get_smoke("yi-9b")
    m = build(cfg)
    rng = jax.random.PRNGKey(0)
    params = m.init(rng)
    batch = materialize_inputs(rng, cfg, ShapeSpec("t", 16, 4, "train"))
    opt = AdamWConfig(peak_lr=1e-3, warmup_steps=0, decay_steps=10)
    s1, m1 = jax.jit(make_train_step(m, opt, n_micro=1))(
        init_train_state(params), batch)
    s2, m2 = jax.jit(make_train_step(m, opt, n_micro=2))(
        init_train_state(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    a = np.asarray(s1.params["final_norm"]["w"])
    b = np.asarray(s2.params["final_norm"]["w"])
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-6)


def test_training_learns():
    cfg = get_smoke("h2o-danube-1.8b")
    m = build(cfg)
    rng = jax.random.PRNGKey(0)
    state = init_train_state(m.init(rng))
    step = jax.jit(make_train_step(
        m, AdamWConfig(peak_lr=1e-2, warmup_steps=5, decay_steps=100)))
    batch = materialize_inputs(rng, cfg, ShapeSpec("t", 32, 4, "train"))
    first = None
    for _ in range(25):
        state, metrics = step(state, batch)
        first = first or float(metrics["loss"])
    assert float(metrics["loss"]) < 0.3 * first


def test_compression_roundtrip_error_feedback(rng):
    """Error feedback: accumulated residual keeps the *sum* of transmitted
    values within one quantization step of the true sum."""
    x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    err = jnp.zeros_like(x)
    sent = jnp.zeros_like(x)
    for _ in range(20):
        deq, err = compress_decompress(x, err)
        sent = sent + deq
    # after T steps, sum(sent) ~= T * x with O(1) error
    drift = np.abs(np.asarray(sent - 20 * x)).max()
    step_size = float(jnp.abs(x).max()) / 127.0
    assert drift <= 2 * step_size, (drift, step_size)


def test_compressed_allreduce_in_shard_map():
    """int8 compressed mean over a 2-way axis == exact mean within quant tol."""
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices (see test_distributed.py)")
